"""Padded-agent sweep batching: ghost rows, masking, bitwise parity.

Contracts (docs/SWEEPS.md, "Padded-agent batching"):

* ``pad_mixing`` keeps the matrix doubly stochastic/symmetric and gives
  ghost agents identity self-loops, so active agents' combines are
  bitwise unchanged and ghosts never leak into active rows.
* ``per_agent_keys`` is m-independent: agent i draws the same stream
  whether the state carries m or m' > m agents.
* A padded m ∈ {4, 8} x topology group runs as ONE dispatch per
  algorithm, and every config's trace is **bitwise** equal to the
  unpadded per-size sweep on the dense backend.
* Ghost-agent invariance: the amount of padding never changes active-
  agent trajectories (property-tested over pad sizes).
* The mixed-network-size error names the offending configs' static keys
  and points at ``pad_agents=True``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # offline container: vendored fallback
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    HypergradConfig,
    MLPMetaProblem,
    convergence_metric,
    init_head,
    init_mlp_backbone,
    make_synthetic_agents,
    masked_convergence_metric,
    masked_convergence_metric_fn,
    pad_agent_data,
    pad_mixing,
    per_agent_keys,
    ring_mixing,
    validate_mixing,
)
from repro.solvers import SolverConfig, TopologyConfig, expand_grid, sweep

ALGOS = ("interact", "svr-interact", "gt-dsgd", "d-sgd")
SIZES = (4, 8)
N = 60


@pytest.fixture(scope="module")
def setup():
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=8)
    y0 = init_head(jax.random.PRNGKey(2), 8, 3)
    hg = HypergradConfig(method="cg", cg_iters=8)
    datas = {m: make_synthetic_agents(jax.random.PRNGKey(0), num_agents=m,
                                      n_per_agent=N, d_in=8, num_classes=3)
             for m in SIZES}
    metric = masked_convergence_metric_fn(prob, hg, inner_steps=20)
    return prob, x0, y0, hg, datas, metric


def _config(setup, algo, **kw):
    _, _, _, hg, _, _ = setup
    base = dict(algo=algo, alpha=0.1, beta=0.1, batch_size=6, q=5,
                topology=TopologyConfig(kind="ring"), hypergrad=hg, seed=7)
    base.update(kw)
    return SolverConfig(**base)


def _unpadded_rows(setup, configs, num_steps, record_every):
    """Per-size unpadded sweeps with the same masked metric closure —
    the reference the padded program must reproduce bitwise."""
    prob, x0, y0, _, datas, metric = setup
    rows = {}
    for m in sorted({c.num_agents for c in configs}):
        sub = [(i, c) for i, c in enumerate(configs) if c.num_agents == m]
        mfn = (lambda d, na: lambda st: metric(st, d, na))(
            datas[m], jnp.int32(m))
        res = sweep([c for _, c in sub], num_steps, record_every,
                    problem=prob, x0=x0, y0=y0, data=datas[m],
                    metric_fn=mfn)
        for r, (i, _) in enumerate(sub):
            rows[i] = res.traces[r]
    return np.stack([rows[i] for i in range(len(configs))])


# -- padding primitives ----------------------------------------------------

def test_pad_mixing_properties():
    spec = ring_mixing(5)
    padded = pad_mixing(spec, 8)
    assert padded.shape == (8, 8)
    validate_mixing(padded)                       # still Section-4.1 legal
    np.testing.assert_array_equal(padded[:5, :5], spec.matrix)
    np.testing.assert_array_equal(padded[5:, 5:], np.eye(3))
    assert not padded[:5, 5:].any() and not padded[5:, :5].any()
    with pytest.raises(ValueError, match="cannot pad"):
        pad_mixing(spec, 4)


def test_dense_engine_padded_mix_bitwise_on_active_rows():
    """The padded dense combine leaves active agents' rows bitwise
    unchanged and ghost rows fixed (identity self-loops)."""
    from repro.consensus.dense import DenseEngine
    spec = ring_mixing(5)
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 13))
    mixed = DenseEngine.padded(spec, 8).mix(x)
    np.testing.assert_array_equal(
        np.asarray(DenseEngine(spec).mix(x[:5])), np.asarray(mixed[:5]))
    np.testing.assert_array_equal(np.asarray(x[5:]), np.asarray(mixed[5:]))


def test_pad_agent_data_tiles_real_agents(setup):
    _, _, _, _, datas, _ = setup
    padded = pad_agent_data(datas[4], 7)
    assert padded.inner_x.shape[0] == 7
    np.testing.assert_array_equal(np.asarray(padded.inner_x[:4]),
                                  np.asarray(datas[4].inner_x))
    # ghost rows tile real agents' (finite) data, never zeros/NaNs
    np.testing.assert_array_equal(np.asarray(padded.inner_x[4:]),
                                  np.asarray(datas[4].inner_x[:3]))
    assert pad_agent_data(datas[4], 4) is datas[4]


def test_per_agent_keys_prefix_stable():
    key = jax.random.PRNGKey(3)
    k4 = np.asarray(per_agent_keys(key, 4))
    k9 = np.asarray(per_agent_keys(key, 9))
    np.testing.assert_array_equal(k4, k9[:4])
    # distinct agents draw distinct keys
    assert len({tuple(row) for row in k9}) == 9


# -- grouping and the static key -------------------------------------------

def test_static_key_pad_to_merges_network_fields(setup):
    a = _config(setup, "interact", num_agents=4)
    b = _config(setup, "interact", num_agents=8,
                topology=TopologyConfig(kind="erdos-renyi"))
    assert a.static_key() != b.static_key()
    assert a.static_key(pad_to=8) == b.static_key(pad_to=8)
    # algo / hypergrad / backend still split padded groups
    c = _config(setup, "gt-dsgd", num_agents=4)
    assert a.static_key(pad_to=8) != c.static_key(pad_to=8)


def test_num_agents_drives_declarative_topology(setup):
    cfg = _config(setup, "interact", num_agents=6)
    assert cfg.mixing_spec().num_agents == 6
    assert cfg.mixing_spec(4).num_agents == 6     # num_agents wins
    assert cfg.resolve_num_agents(99) == 6
    assert _config(setup, "interact").resolve_num_agents(5) == 5


def test_padded_sweep_collapses_dispatches(setup):
    prob, x0, y0, _, datas, _ = setup
    configs = expand_grid(
        _config(setup, "interact"), num_agents=SIZES,
        topology=(TopologyConfig(kind="ring"),
                  TopologyConfig(kind="erdos-renyi")), seed=(0, 1))
    solo = sweep(configs, 2, 0, problem=prob, x0=x0, y0=y0, data=datas)
    assert solo.num_dispatches == 4               # one per (m, topology)
    res = sweep(configs, 2, 0, problem=prob, x0=x0, y0=y0, data=datas,
                pad_agents=True)
    assert res.num_dispatches == 1
    assert res.pad_to == 8
    assert res.groups[0].num_active == tuple(c.num_agents for c in configs)


# -- parity: the acceptance contract ---------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_padded_traces_bitwise_match_unpadded(setup, algo):
    """m ∈ {4, 8} padded into one program: every active-agent trace is
    bitwise equal to the unpadded per-size sweep (dense backend)."""
    prob, x0, y0, _, datas, metric = setup
    configs = expand_grid(_config(setup, algo), num_agents=SIZES,
                          seed=(0, 1))
    res = sweep(configs, 4, 2, problem=prob, x0=x0, y0=y0, data=datas,
                metric_fn=metric, pad_agents=True)
    assert res.num_dispatches == 1
    reference = _unpadded_rows(setup, configs, 4, 2)
    np.testing.assert_array_equal(reference, res.traces)


def test_padded_final_states_match_unpadded_active_rows(setup):
    prob, x0, y0, _, datas, _ = setup
    configs = [_config(setup, "interact", num_agents=m) for m in SIZES]
    res = sweep(configs, 3, 0, problem=prob, x0=x0, y0=y0, data=datas,
                pad_agents=True, return_states=True)
    for i, m in enumerate(SIZES):
        solo = sweep([configs[i]], 3, 0, problem=prob, x0=x0, y0=y0,
                     data=datas[m], return_states=True)
        for a, b in zip(jax.tree_util.tree_leaves(solo.states[0].x),
                        jax.tree_util.tree_leaves(res.states[i].x)):
            np.testing.assert_array_equal(np.asarray(a),
                                          np.asarray(b)[:m])


@settings(max_examples=4, deadline=None)
@given(extra=st.integers(min_value=0, max_value=5))
def test_ghost_agents_never_change_active_trajectories(extra):
    """Property: however much padding is stacked on top of the grid's
    largest network, active-agent traces are bitwise unchanged."""
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=8)
    y0 = init_head(jax.random.PRNGKey(2), 8, 3)
    hg = HypergradConfig(method="cg", cg_iters=8)
    datas = {4: make_synthetic_agents(jax.random.PRNGKey(0), num_agents=4,
                                      n_per_agent=N, d_in=8, num_classes=3)}
    configs = [SolverConfig(algo="svr-interact", alpha=0.1, beta=0.1,
                            batch_size=6, q=5, num_agents=4,
                            topology=TopologyConfig(kind="ring"),
                            hypergrad=hg, seed=s) for s in (0, 1)]
    metric = masked_convergence_metric_fn(prob, hg, inner_steps=10)
    base = sweep(configs, 3, 1, problem=prob, x0=x0, y0=y0, data=datas,
                 metric_fn=metric, pad_agents=True, pad_to=4)
    padded = sweep(configs, 3, 1, problem=prob, x0=x0, y0=y0, data=datas,
                   metric_fn=metric, pad_agents=True, pad_to=4 + extra)
    np.testing.assert_array_equal(base.traces, padded.traces)


# -- masked metric ----------------------------------------------------------

def test_masked_metric_matches_unmasked_at_full_occupancy(setup):
    """num_active == m on unpadded iterates: same value as the eager
    eq.-11 metric (association differs, so allclose not bitwise)."""
    prob, x0, y0, hg, datas, _ = setup
    data = datas[4]
    bcast = lambda tree: jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (4,) + l.shape), tree)
    x, y = bcast(x0), bcast(y0)
    ref = convergence_metric(prob, hg, x, y, 20, 0.5, data)
    masked = masked_convergence_metric(prob, hg, x, y, 20, 0.5, data,
                                       jnp.int32(4))
    np.testing.assert_allclose(float(masked.total), float(ref.total),
                               rtol=1e-5)
    np.testing.assert_allclose(float(masked.stationarity),
                               float(ref.stationarity), rtol=1e-5)


def test_masked_metric_ignores_ghost_rows(setup):
    """Poisoning ghost rows (huge values) must not move the metric."""
    prob, x0, y0, hg, datas, _ = setup
    data = pad_agent_data(datas[4], 6)
    bcast = lambda tree: jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (6,) + l.shape), tree)
    x, y = bcast(x0), bcast(y0)
    clean = masked_convergence_metric(prob, hg, x, y, 10, 0.5, data,
                                      jnp.int32(4))
    poison = lambda tree: jax.tree_util.tree_map(
        lambda l: l.at[4:].set(1e6), tree)
    dirty = masked_convergence_metric(prob, hg, poison(x), poison(y),
                                      10, 0.5, data, jnp.int32(4))
    assert float(clean.total) == float(dirty.total)


# -- diagnostics ------------------------------------------------------------

def test_mixed_m_error_names_static_keys(setup):
    prob, x0, y0, _, datas, _ = setup
    configs = [_config(setup, "interact", num_agents=4),
               _config(setup, "interact", num_agents=8)]
    with pytest.raises(ValueError) as exc:
        sweep(configs, 2, 0, problem=prob, x0=x0, y0=y0, data=datas[4])
    msg = str(exc.value)
    assert "pad_agents=True" in msg
    assert "static_key" in msg
    assert "[4, 8]" in msg                        # the grid's sizes


def test_build_rejects_config_data_network_mismatch(setup):
    """Direct init() with num_agents disagreeing with the data fails with
    a named error, not an XLA dot-shape error inside the first mix."""
    from repro.solvers import make_solver
    prob, x0, y0, hg, datas, _ = setup
    solver = make_solver(_config(setup, "interact", num_agents=8))
    with pytest.raises(ValueError, match="8-agent network .* m=4"):
        solver.init(None, prob, hg, x0, y0, datas[4])


def test_data_mapping_missing_size_is_diagnosed(setup):
    prob, x0, y0, _, datas, _ = setup
    configs = [_config(setup, "interact", num_agents=4),
               _config(setup, "interact", num_agents=6)]
    with pytest.raises(ValueError, match="pad_agents=True"):
        sweep(configs, 2, 0, problem=prob, x0=x0, y0=y0,
              data={4: datas[4]})


def test_pad_agents_requires_dense_backend(setup):
    prob, x0, y0, _, datas, _ = setup
    configs = [_config(setup, "interact", num_agents=4,
                       backend="pallas")]
    with pytest.raises(ValueError, match="dense"):
        sweep(configs, 2, 0, problem=prob, x0=x0, y0=y0, data=datas,
              pad_agents=True)


def test_pad_to_below_largest_network_rejected(setup):
    prob, x0, y0, _, datas, _ = setup
    configs = [_config(setup, "interact", num_agents=8)]
    with pytest.raises(ValueError, match="largest"):
        sweep(configs, 2, 0, problem=prob, x0=x0, y0=y0, data=datas,
              pad_agents=True, pad_to=4)


def test_mixed_sample_counts_rejected_under_padding(setup):
    prob, x0, y0, _, datas, _ = setup
    short = make_synthetic_agents(jax.random.PRNGKey(0), num_agents=8,
                                  n_per_agent=N // 2, d_in=8,
                                  num_classes=3)
    configs = [_config(setup, "interact", num_agents=4),
               _config(setup, "interact", num_agents=8)]
    with pytest.raises(ValueError, match="sample counts"):
        sweep(configs, 2, 0, problem=prob, x0=x0, y0=y0,
              data={4: datas[4], 8: short}, pad_agents=True)


# -- compressed wire under padding ------------------------------------------

def test_static_key_splits_and_groups_wire_configs(setup):
    """Compression/interval are static: differing wire options split a
    group (unpadded AND pad_to branches); identical ones merge."""
    from repro.solvers import CompressionConfig
    a = _config(setup, "interact", num_agents=4)
    b = dataclasses.replace(a, compression=CompressionConfig("sign1bit"))
    c = dataclasses.replace(a, communication_interval=2)
    d = dataclasses.replace(a, compression=CompressionConfig("sign1bit"))
    for kw in ({}, {"pad_to": 8}):
        assert a.static_key(**kw) != b.static_key(**kw)
        assert a.static_key(**kw) != c.static_key(**kw)
        assert b.static_key(**kw) != c.static_key(**kw)
        assert b.static_key(**kw) == d.static_key(**kw)
    # same wire options across network sizes still merge under padding
    e = dataclasses.replace(b, num_agents=8)
    assert b.static_key(pad_to=8) == e.static_key(pad_to=8)


@pytest.mark.parametrize("kind", ("int8", "sign1bit"))
def test_padded_compressed_traces_bitwise_match_unpadded(setup, kind):
    """Per-agent row-wise compression is padding-invariant: the padded
    compressed program reproduces the unpadded compressed sweep bitwise,
    and ghost rows (identity self-loops) stay fixed."""
    from repro.solvers import CompressionConfig
    prob, x0, y0, _, datas, metric = setup
    comp = CompressionConfig(kind)
    configs = expand_grid(
        _config(setup, "interact", compression=comp),
        num_agents=SIZES, seed=(0, 1))
    res = sweep(configs, 4, 2, problem=prob, x0=x0, y0=y0, data=datas,
                metric_fn=metric, pad_agents=True)
    assert res.num_dispatches == 1
    reference = _unpadded_rows(setup, configs, 4, 2)
    np.testing.assert_array_equal(reference, res.traces)


def test_padded_ghost_rows_contribute_zero_compressed_payload(setup):
    """A ghost row's compressed contribution to active agents is exactly
    zero: poisoning ghost rows of the state does not move active rows of
    a compressed padded combine (block-diagonal mixing + row-wise
    compression never crosses the active/ghost boundary)."""
    from repro.consensus.dense import DenseEngine
    from repro.solvers import CompressionConfig
    spec = ring_mixing(5)
    eng = DenseEngine.padded(spec, 8,
                             compression=CompressionConfig("sign1bit"))
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 13))
    z = jnp.zeros((8, 13), jnp.float32)
    ef = {"e": z, "ref": z}
    t0 = jnp.zeros((), jnp.int32)
    mixed, _ = eng.mix_ef(x, ef, t0)
    poisoned = x.at[5:].set(1e6)
    mixed_p, ef_p = eng.mix_ef(poisoned, ef, t0)
    np.testing.assert_array_equal(np.asarray(mixed[:5]),
                                  np.asarray(mixed_p[:5]))
    # ghost wire state never leaks into active rows either
    ghost_state = jax.tree_util.tree_map(
        lambda l: l.at[:5].set(0.0), ef_p)
    mixed2, _ = eng.mix_ef(x, ghost_state, t0)
    np.testing.assert_array_equal(np.asarray(mixed[:5]),
                                  np.asarray(mixed2[:5]))


def test_padded_compressed_final_states_carry_ef(setup):
    from repro.solvers import CompressionConfig
    prob, x0, y0, _, datas, _ = setup
    comp = CompressionConfig("int8")
    configs = [_config(setup, "interact", num_agents=m, compression=comp)
               for m in SIZES]
    res = sweep(configs, 3, 0, problem=prob, x0=x0, y0=y0, data=datas,
                pad_agents=True, return_states=True)
    for i, m in enumerate(SIZES):
        assert set(res.states[i].ef) == {"x", "u"}
        solo = sweep([configs[i]], 3, 0, problem=prob, x0=x0, y0=y0,
                     data=datas[m], return_states=True)
        for a, b in zip(jax.tree_util.tree_leaves(solo.states[0].x),
                        jax.tree_util.tree_leaves(res.states[i].x)):
            np.testing.assert_array_equal(np.asarray(a),
                                          np.asarray(b)[:m])
