"""Byzantine-resilience subsystem: attacks, robust combines, guards.

Covers the contract docs/BYZANTINE.md states: attack-schedule
determinism and pad-safety, ghost-pad invariance of every combine rule,
robust-rule properties (permutation invariance, loud breakdown errors),
the weighted rule's bitwise no-op through all four registry solvers,
the EF-compression x attack interaction (CHOCO refs track the
post-attack payload), dense-vs-ppermute parity for ``weighted`` under
attack, the in-scan divergence guard, and the sweep-batching story
(static-key participation, one dispatch per attack grid).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.byzantine import (
    ByzantineConfig, GuardConfig, apply_attack, attack_names,
    byzantine_mask, combine_rule_names, make_attack, robust_combine,
)
from repro.consensus import DenseEngine, init_ef
from repro.consensus.compress import CompressionConfig
from repro.core import (HypergradConfig, erdos_renyi_adjacency,
                        laplacian_mixing)
from repro.core.consensus import pad_mixing
from repro.solvers import SolverConfig, expand_grid, solve, sweep

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

RULES = ("weighted", "coordinate-median", "trimmed-mean", "krum-like")
ALGOS = ("interact", "svr-interact", "gt-dsgd", "d-sgd")

M = 5


def _spec(m=M, p=0.8, seed=2):
    return laplacian_mixing(erdos_renyi_adjacency(m, p, seed=seed))


def _tree(key, m=M):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 7, 3)),
            "b": jax.random.normal(k2, (m, 11))}


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def _tiny_cfg(**kw):
    return SolverConfig(hypergrad=HypergradConfig(method="cg", cg_iters=4),
                        **kw)


def _cheap_metric(state):
    return sum(jnp.sum(jnp.abs(l)) for l in _leaves(state.x))


# -- attacks -----------------------------------------------------------


def test_attack_registry_names():
    assert set(attack_names()) >= {"sign-flip", "gaussian", "same-value",
                                   "inner-outer-split"}
    with pytest.raises(ValueError, match="unknown attack"):
        make_attack("carrier-pigeon")


def test_attack_determinism_and_step_variation():
    key = jax.random.PRNGKey(3)
    tree = _tree(jax.random.PRNGKey(0))
    mask = byzantine_mask(key, M, 2)
    atk = make_attack("gaussian")
    k0 = jax.random.fold_in(key, 0)
    a = apply_attack(atk, tree, mask, k0, 1.5)
    b = apply_attack(atk, tree, mask, k0, 1.5)
    for x, y in zip(_leaves(a), _leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))
    # a different per-round key draws different corruption
    c = apply_attack(atk, tree, mask, jax.random.fold_in(key, 1), 1.5)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(_leaves(a), _leaves(c)))


def test_attack_honest_rows_bitwise():
    key = jax.random.PRNGKey(3)
    tree = _tree(jax.random.PRNGKey(0))
    mask = byzantine_mask(key, M, 1)
    got = apply_attack(make_attack("sign-flip"), tree, mask, key, 2.0)
    m_np = np.asarray(mask)
    assert m_np.sum() == 1
    for orig, out in zip(_leaves(tree), _leaves(got)):
        assert np.array_equal(np.asarray(orig)[~m_np],
                              np.asarray(out)[~m_np])
        assert np.array_equal(np.asarray(out)[m_np],
                              -2.0 * np.asarray(orig)[m_np])
    # zero attackers: every row bitwise
    clean = apply_attack(make_attack("sign-flip"), tree,
                         byzantine_mask(key, M, 0), key, 2.0)
    for orig, out in zip(_leaves(tree), _leaves(clean)):
        assert np.array_equal(np.asarray(orig), np.asarray(out))


def test_same_value_attack_colludes():
    key = jax.random.PRNGKey(4)
    tree = {"w": jax.random.normal(key, (M, 6))}
    mask = jnp.ones((M,), bool)
    out = np.asarray(apply_attack(make_attack("same-value"), tree, mask,
                                  key, 1.0)["w"])
    assert np.array_equal(out, np.broadcast_to(out[0], out.shape))


def test_inner_outer_split_targets_u_stream_only():
    assert make_attack("inner-outer-split").streams == ("u",)
    assert make_attack("sign-flip").streams == ("x", "u")


def test_byzantine_mask_fixed_subset_and_pad_safe():
    key = jax.random.PRNGKey(9)
    small = np.asarray(byzantine_mask(key, 5, 2))
    padded = np.asarray(byzantine_mask(key, 8, 2, num_active=5))
    assert small.sum() == 2 and padded.sum() == 2
    assert np.array_equal(small, padded[:5])   # same active subset
    assert not padded[5:].any()                # ghosts never attack
    # num_byzantine may be traced
    traced = jax.jit(lambda nb: byzantine_mask(key, 5, nb))(jnp.int32(2))
    assert np.array_equal(small, np.asarray(traced))


# -- combine rules -----------------------------------------------------


@pytest.mark.parametrize("rule", RULES)
def test_ghost_pad_invariance(rule):
    """Poisoned ghost rows leave active agents' aggregates bitwise."""
    spec = _spec()
    tree = _tree(jax.random.PRNGKey(1))
    want = robust_combine(jnp.asarray(spec.matrix), tree, rule, 1)
    padded_mat = jnp.asarray(pad_mixing(spec, 8))
    poison = jax.tree_util.tree_map(
        lambda l: jnp.concatenate(
            [l, jnp.full((8 - M,) + l.shape[1:], 1e30, l.dtype)]), tree)
    got = robust_combine(padded_mat, poison, rule, 1)
    for a, b in zip(_leaves(want), _leaves(got)):
        assert np.array_equal(np.asarray(a), np.asarray(b)[:M])


@pytest.mark.parametrize("rule", ["coordinate-median", "trimmed-mean"])
def test_permutation_invariance(rule):
    """combine(P M P^T, P X) == P combine(M, X) for the order-free rules."""
    mat = jnp.asarray(_spec().matrix, jnp.float32)
    vals = {"w": jax.random.normal(jax.random.PRNGKey(5), (M, 9))}
    perm = np.array([3, 0, 4, 1, 2])
    p_mat = mat[perm][:, perm]
    p_vals = {"w": vals["w"][perm]}
    base = np.asarray(robust_combine(mat, vals, rule, 1)["w"])
    permuted = np.asarray(robust_combine(p_mat, p_vals, rule, 1)["w"])
    np.testing.assert_allclose(permuted, base[perm], atol=1e-6)


def test_trimmed_mean_screens_one_outlier():
    """On a complete graph, trim=1 removes a single huge row exactly."""
    mat = jnp.full((M, M), 1.0 / M, jnp.float32)
    honest = jnp.broadcast_to(jnp.arange(4.0), (M, 4)).copy()
    vals = {"w": honest.at[2].set(1e6)}
    out = np.asarray(robust_combine(mat, vals, "trimmed-mean", 1)["w"])
    np.testing.assert_allclose(out, np.broadcast_to(np.arange(4.0),
                                                    (M, 4)), atol=1e-5)


def test_breakdown_and_config_validation_raise():
    with pytest.raises(ValueError, match="unknown attack"):
        ByzantineConfig(kind="nope")
    with pytest.raises(ValueError, match="unknown combine rule"):
        ByzantineConfig(combine="nope")
    with pytest.raises(ValueError, match="trimmed-mean breakdown"):
        DenseEngine(_spec(), byzantine=ByzantineConfig(
            combine="trimmed-mean", trim=3))
    with pytest.raises(ValueError, match="honest agent"):
        DenseEngine(_spec(), byzantine=ByzantineConfig(
            kind="sign-flip", num_byzantine=5))


def test_ppermute_refuses_robust_rules():
    from repro.consensus import PermuteEngine
    from repro.core import ring_mixing
    with pytest.raises(NotImplementedError, match="dense backend"):
        PermuteEngine(ring_mixing(8, self_weight=1 / 3),
                      byzantine=ByzantineConfig(combine="trimmed-mean",
                                                trim=1))


def test_combine_rule_registry_names():
    assert set(combine_rule_names()) == set(RULES)


# -- the engine wire path ----------------------------------------------


def test_engine_weighted_rule_is_plain_mix():
    spec = _spec()
    tree = _tree(jax.random.PRNGKey(2))
    plain = DenseEngine(spec)
    byz = DenseEngine(spec, byzantine=ByzantineConfig(
        kind="sign-flip", num_byzantine=0))
    a, _ = byz.mix_ef(tree, None, 0)
    b = plain.mix(tree)
    for x, y in zip(_leaves(a), _leaves(b)):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def test_ef_refs_track_post_attack_payload():
    """CHOCO refs must advance by what was actually transmitted: the
    byzantine engine on a clean tree equals a plain engine fed the
    pre-attacked tree — payload and wire state bitwise."""

    class CaptureDense(DenseEngine):
        def _combine(self, tree, **kw):
            self.captured = tree
            return super()._combine(tree, **kw)

    spec = _spec()
    comp = CompressionConfig(kind="sign1bit")
    bcfg = ByzantineConfig(kind="sign-flip", num_byzantine=1, scale=2.0,
                           seed=5)
    byz = CaptureDense(spec, compression=comp, byzantine=bcfg)
    plain = CaptureDense(spec, compression=comp)
    tree = _tree(jax.random.PRNGKey(6))
    attacked = byz._attack_payload(tree, 0, "x")
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(_leaves(tree), _leaves(attacked)))

    _, ef_b = byz.mix_ef(tree, init_ef(comp, x=tree)["x"], 0, stream="x")
    _, ef_p = plain.mix_ef(attacked, init_ef(comp, x=attacked)["x"], 0)
    for a, b in zip(_leaves(byz.captured), _leaves(plain.captured)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(_leaves(ef_b["ref"]), _leaves(ef_p["ref"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # and the ref IS the decoded payload the neighbours combined
    for a, b in zip(_leaves(ef_b["ref"]), _leaves(byz.captured)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_dense_vs_ppermute_weighted_under_attack():
    """The sharded backend corrupts the same slots with the same draws
    as the dense reference (global slot ids thread through shard_map)."""
    out = _run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.byzantine import ByzantineConfig
        from repro.consensus import DenseEngine, PermuteEngine
        from repro.core import ring_mixing
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = ring_mixing(m, self_weight=1/3)
        bcfg = ByzantineConfig(kind="gaussian", num_byzantine=2,
                               scale=3.0, seed=7)
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, 37, 5)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (m, 131))}
        dense = DenseEngine(spec, byzantine=bcfg)
        eng = PermuteEngine(spec, agent_axes=("data",), byzantine=bcfg)
        fn = shard_map(lambda t: eng.mix_ef(t, None, 0, stream="x")[0],
                       mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                       axis_names={"data"}, check_vma=False)
        with set_mesh(mesh):
            got = jax.jit(fn)(tree)
        want, _ = dense.mix_ef(tree, None, 0, stream="x")
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


def _run_in_subprocess(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


# -- solvers end to end ------------------------------------------------


@pytest.mark.parametrize("algo", ALGOS)
def test_weighted_zero_attackers_bitwise_all_solvers(algo):
    clean = solve(_tiny_cfg(algo=algo), 3, 1, num_agents=4,
                  n_per_agent=32, metric_fn=_cheap_metric,
                  measure_hypergrad=False)
    byz = solve(_tiny_cfg(algo=algo, byzantine=ByzantineConfig(
        kind="sign-flip", num_byzantine=0)), 3, 1, num_agents=4,
        n_per_agent=32, metric_fn=_cheap_metric, measure_hypergrad=False)
    assert np.array_equal(np.asarray(clean.trace), np.asarray(byz.trace))
    for a, b in zip(_leaves(clean.state.x), _leaves(byz.state.x)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_attack_changes_trajectory_and_inner_split_spares_dsgd():
    base = solve(_tiny_cfg(algo="gt-dsgd"), 3, 1, num_agents=4,
                 n_per_agent=32, metric_fn=_cheap_metric,
                 measure_hypergrad=False)
    hit = solve(_tiny_cfg(algo="gt-dsgd", byzantine=ByzantineConfig(
        kind="inner-outer-split", num_byzantine=1, scale=5.0)), 3, 1,
        num_agents=4, n_per_agent=32, metric_fn=_cheap_metric,
        measure_hypergrad=False)
    assert not np.array_equal(np.asarray(base.trace),
                              np.asarray(hit.trace))
    # d-sgd ships only x: the u-stream attack cannot touch it
    d_base = solve(_tiny_cfg(algo="d-sgd"), 3, 1, num_agents=4,
                   n_per_agent=32, metric_fn=_cheap_metric,
                   measure_hypergrad=False)
    d_hit = solve(_tiny_cfg(algo="d-sgd", byzantine=ByzantineConfig(
        kind="inner-outer-split", num_byzantine=1, scale=5.0)), 3, 1,
        num_agents=4, n_per_agent=32, metric_fn=_cheap_metric,
        measure_hypergrad=False)
    assert np.array_equal(np.asarray(d_base.trace),
                          np.asarray(d_hit.trace))


def test_guard_trips_and_surfaces_counters():
    res = solve(_tiny_cfg(algo="gt-dsgd",
                          byzantine=ByzantineConfig(kind="sign-flip",
                                                    num_byzantine=1,
                                                    scale=50.0),
                          guard=GuardConfig(nan=True, max_norm=10.0)),
                6, 2, num_agents=4, n_per_agent=32,
                metric_fn=_cheap_metric, measure_hypergrad=False)
    assert res.tripped_steps > 0
    assert 0 <= res.last_good_step <= 6
    for leaf in _leaves(res.state.x):
        assert np.all(np.isfinite(np.asarray(leaf)))
    # unguarded runs keep the default sentinels
    clean = solve(_tiny_cfg(algo="gt-dsgd"), 2, 1, num_agents=4,
                  n_per_agent=32, metric_fn=_cheap_metric,
                  measure_hypergrad=False)
    assert clean.tripped_steps == 0 and clean.last_good_step == -1


def test_guard_clean_run_never_trips():
    res = solve(_tiny_cfg(algo="interact",
                          guard=GuardConfig(nan=True, max_norm=1e6)),
                3, 1, num_agents=4, n_per_agent=32,
                metric_fn=_cheap_metric, measure_hypergrad=False)
    assert res.tripped_steps == 0
    assert res.last_good_step == 3


# -- sweep batching ----------------------------------------------------


def test_static_key_participation():
    base = _tiny_cfg(algo="interact")
    atk = dataclasses.replace(base, byzantine=ByzantineConfig(
        kind="sign-flip", num_byzantine=1))
    # padded: attack values are operands, structure splits groups
    assert atk.static_key(pad_to=8) == dataclasses.replace(
        base, byzantine=ByzantineConfig(kind="sign-flip",
                                        num_byzantine=2, scale=9.0)
    ).static_key(pad_to=8)
    assert atk.static_key(pad_to=8) != base.static_key(pad_to=8)
    assert atk.static_key(pad_to=8) != dataclasses.replace(
        base, byzantine=ByzantineConfig(kind="gaussian",
                                        num_byzantine=1)
    ).static_key(pad_to=8)
    assert atk.static_key(pad_to=8) != dataclasses.replace(
        base, byzantine=ByzantineConfig(combine="coordinate-median")
    ).static_key(pad_to=8)
    # non-padded: a seed-inheriting attack splits on the config seed
    # (the built engine bakes the schedule key as a constant)
    s0 = dataclasses.replace(atk, seed=0)
    s1 = dataclasses.replace(atk, seed=1)
    assert s0.static_key() != s1.static_key()
    pinned = ByzantineConfig(kind="sign-flip", num_byzantine=1, seed=5)
    assert (dataclasses.replace(s0, byzantine=pinned).static_key()
            == dataclasses.replace(s1, byzantine=pinned).static_key())
    # guards are trace-structural too
    assert base.static_key() != dataclasses.replace(
        base, guard=GuardConfig(nan=True)).static_key()


def test_padded_attack_grid_single_dispatch_and_bitwise_zero():
    def masked_metric(state, data, num_active):
        rows = _leaves(state.x)[0].shape[0]
        keep = jnp.arange(rows) < num_active
        return sum(jnp.sum(jnp.where(
            keep.reshape((-1,) + (1,) * (l.ndim - 1)), jnp.abs(l), 0.0))
            for l in _leaves(state.x))

    base = _tiny_cfg(algo="interact", num_agents=4)
    grid = expand_grid(
        base,
        byzantine=tuple(ByzantineConfig(kind="sign-flip", num_byzantine=nb,
                                        scale=5.0) for nb in (0, 1)),
        seed=(0, 1))
    res = sweep(grid, 2, 1, num_agents=4, n_per_agent=32,
                pad_agents=True, metric_fn=masked_metric)
    assert res.num_dispatches == 1

    clean = sweep(expand_grid(base, seed=(0, 1)), 2, 1, num_agents=4,
                  n_per_agent=32, pad_agents=True,
                  metric_fn=masked_metric)
    zero_rows = np.stack([res.trace_of(c) for c in grid
                          if c.byzantine.num_byzantine == 0])
    assert np.array_equal(zero_rows, clean.traces)
    attacked = np.stack([res.trace_of(c) for c in grid
                         if c.byzantine.num_byzantine == 1])
    assert not np.array_equal(attacked, clean.traces)
