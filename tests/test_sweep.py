"""The batched sweep engine: in-scan recording + vmap-over-experiments.

Contracts:

* ``run_traced``'s on-device trace is bit-identical to the legacy
  chunked ``run_recorded`` trace for every algorithm (same step bodies,
  same metric computation — only the dispatch boundary moves).
* A vmapped sweep reproduces the per-config ``run_traced`` runs over
  the same seeds: bitwise for the final trace entries up to batched
  ``dot_general`` reassociation, asserted at float32-tight tolerance
  and exactly equal initial entries.
* Grouping: static_key splits on algo/topology/backend, batches on
  seed/alpha/beta; step sizes batch into one dispatch.
* Donation safety: the caller's state/inits survive warmup,
  ``run_traced`` and ``sweep``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HypergradConfig,
    MLPMetaProblem,
    convergence_metric_fn,
    erdos_renyi_adjacency,
    init_head,
    init_mlp_backbone,
    laplacian_mixing,
    make_synthetic_agents,
)
from repro.solvers import (
    SolverConfig,
    expand_grid,
    make_solver,
    run_recorded,
    solve,
    sweep,
)

M, N = 4, 80
ALGOS = ("interact", "svr-interact", "gt-dsgd", "d-sgd")


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    data = make_synthetic_agents(key, num_agents=M, n_per_agent=N,
                                 d_in=8, num_classes=3)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=8)
    y0 = init_head(jax.random.PRNGKey(2), 8, 3)
    spec = laplacian_mixing(erdos_renyi_adjacency(M, 0.5, seed=3))
    hg = HypergradConfig(method="cg", cg_iters=8)
    # cheap but real metric: the eq.-11 computation at a small inner budget
    metric = convergence_metric_fn(prob, hg, data, inner_steps=20)
    return prob, x0, y0, data, spec, hg, metric


def _config(setup, algo, **kw):
    _, _, _, _, spec, hg, _ = setup
    base = dict(algo=algo, alpha=0.1, beta=0.1, batch_size=6, q=5,
                mixing=spec, hypergrad=hg, seed=7)
    base.update(kw)
    return SolverConfig(**base)


def _init(setup, cfg):
    prob, x0, y0, data, _, hg, _ = setup
    solver = make_solver(cfg)
    return solver, solver.init(None, prob, hg, x0, y0, data)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("num_steps,record_every", [(6, 3), (7, 3)])
def test_run_traced_bitwise_matches_run_recorded(setup, algo, num_steps,
                                                 record_every):
    """In-scan recording == the legacy chunked host loop, bit for bit —
    including the remainder-chunk case (7 % 3 != 0)."""
    _, _, _, data, _, _, metric = setup
    solver, state = _init(setup, _config(setup, algo))
    copy = jax.tree_util.tree_map(jnp.copy, state)
    _, legacy, _ = run_recorded(solver, copy, data, num_steps, record_every,
                                metric_fn=lambda st: float(metric(st)))
    _, traced = solver.run_traced(state, data, num_steps, record_every,
                                  metric)
    traced = np.asarray(traced)
    assert traced.shape == (len(legacy),)
    np.testing.assert_array_equal(
        np.asarray(legacy, traced.dtype), traced)


@pytest.mark.parametrize("algo", ALGOS)
def test_run_traced_final_state_matches_run(setup, algo):
    _, _, _, data, _, _, metric = setup
    solver, state = _init(setup, _config(setup, algo))
    via_run = solver.run(jax.tree_util.tree_map(jnp.copy, state), data, 5)
    via_traced, _ = solver.run_traced(state, data, 5, 2, metric)
    for a, b in zip(jax.tree_util.tree_leaves(via_run),
                    jax.tree_util.tree_leaves(via_traced)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_run_traced_without_metric_returns_empty_trace(setup):
    _, _, _, data, _, _, _ = setup
    solver, state = _init(setup, _config(setup, "interact"))
    out, trace = solver.run_traced(state, data, 4)
    assert np.asarray(trace).shape == (0,)
    assert int(out.t) == 4


@pytest.mark.parametrize("algo", ALGOS)
def test_sweep_matches_per_config_run_traced(setup, algo):
    """The vmapped group reproduces each config's solo run_traced.

    Batched ``dot_general`` may reassociate float reductions, so the
    comparison is exact-dtype allclose at float32-tight tolerance (and
    the shared initial metric must agree exactly).
    """
    prob, x0, y0, data, _, _, metric = setup
    configs = [_config(setup, algo, seed=s) for s in (0, 1, 2)]
    res = sweep(configs, 5, 2, problem=prob, x0=x0, y0=y0, data=data,
                metric_fn=metric)
    assert res.num_dispatches == 1
    assert res.traces.shape == (3, 4)   # records at steps 0,2,4 + final
    for i, cfg in enumerate(configs):
        solver, state = _init(setup, cfg)
        _, solo = solver.run_traced(state, data, 5, 2, metric)
        solo = np.asarray(solo)
        np.testing.assert_array_equal(solo[0], res.traces[i][0])
        np.testing.assert_allclose(solo, res.traces[i], rtol=2e-5)


def test_sweep_groups_by_static_key(setup):
    """seed/alpha/beta batch together; algo and topology split groups."""
    prob, x0, y0, data, spec, hg, metric = setup
    other = laplacian_mixing(erdos_renyi_adjacency(M, 0.9, seed=11))
    configs = (
        [_config(setup, "interact", seed=s, alpha=a)
         for s in (0, 1) for a in (0.1, 0.05)]          # 4 -> one group
        + [_config(setup, "gt-dsgd", seed=s) for s in (0, 1)]
        + [_config(setup, "interact", mixing=other)]    # new topology
    )
    res = sweep(configs, 3, 0, problem=prob, x0=x0, y0=y0, data=data)
    assert res.num_dispatches == 3
    assert [g.indices for g in res.groups] == [[0, 1, 2, 3], [4, 5], [6]]
    # value-fingerprinted mixing: a separately-built equal spec groups too
    same = laplacian_mixing(erdos_renyi_adjacency(M, 0.5, seed=3))
    assert (_config(setup, "interact").static_key()
            == _config(setup, "interact", mixing=same).static_key())


def test_sweep_step_sizes_are_a_batch_axis(setup):
    """One compiled program covers a learning-rate grid, and each row
    matches the config-bound solo run of that step size."""
    prob, x0, y0, data, _, _, metric = setup
    configs = [_config(setup, "interact", alpha=a, beta=a)
               for a in (0.1, 0.05, 0.01)]
    res = sweep(configs, 4, 2, problem=prob, x0=x0, y0=y0, data=data,
                metric_fn=metric)
    assert res.num_dispatches == 1
    for i, cfg in enumerate(configs):
        solver, state = _init(setup, cfg)
        _, solo = solver.run_traced(state, data, 4, 2, metric)
        np.testing.assert_allclose(np.asarray(solo), res.traces[i],
                                   rtol=2e-5)
    # different step sizes genuinely produce different trajectories
    assert not np.array_equal(res.traces[0], res.traces[2])


def test_sweep_sequential_comparison_and_result_shape(setup):
    prob, x0, y0, data, _, _, metric = setup
    configs = expand_grid(_config(setup, "gt-dsgd"), seed=(0, 1))
    res = sweep(configs, 3, 1, problem=prob, x0=x0, y0=y0, data=data,
                metric_fn=metric, compare_sequential=True,
                return_states=True)
    assert res.seconds > 0 and res.seconds_sequential > 0
    assert res.vmap_speedup is not None
    assert len(res.states) == 2
    assert int(res.states[0].t) == 3
    np.testing.assert_array_equal(res.trace_of(configs[1]), res.traces[1])


def test_sweep_default_setup_and_default_metric():
    """No problem/data supplied: the Section-6 default setup is built and
    the eq.-11 metric recorded (small steps to keep CI fast)."""
    res = sweep([SolverConfig(algo="d-sgd", batch_size=4, seed=s)
                 for s in (0, 1)], 2, 1, num_agents=3, n_per_agent=24)
    assert res.traces.shape == (2, 3)
    assert np.isfinite(res.traces).all()


def test_sweep_donation_safety_inputs_survive(setup):
    """sweep must not consume the caller's x0/y0/data/init state buffers:
    batched pipelines run un-donated, so the same inputs drive every
    group and remain usable afterwards."""
    prob, x0, y0, data, _, hg, metric = setup
    x_before = np.asarray(jax.tree_util.tree_leaves(x0)[0]).copy()
    configs = [_config(setup, "interact", seed=s) for s in (0, 1)]
    sweep(configs, 3, 0, problem=prob, x0=x0, y0=y0, data=data)
    x_after = np.asarray(jax.tree_util.tree_leaves(x0)[0])
    np.testing.assert_array_equal(x_before, x_after)
    # and the inputs still feed an eager init + step
    solver, state = _init(setup, configs[0])
    assert int(solver.step(state, data).t) == 1


def test_run_traced_donates_like_run(setup):
    """run_traced donates the incoming state (hot-loop semantics);
    warmup-style copies keep a caller's state usable."""
    _, _, _, data, _, _, metric = setup
    solver, state = _init(setup, _config(setup, "interact"))
    keep = jax.tree_util.tree_map(jnp.copy, state)
    solver.run_traced(state, data, 2, 1, metric)
    out, _ = solver.run_traced(keep, data, 2, 1, metric)   # keep usable
    assert int(out.t) == 2


def test_expand_grid_row_major_order(setup):
    grid = expand_grid(_config(setup, "interact"), seed=(0, 1),
                       alpha=(0.1, 0.2))
    assert [(c.seed, c.alpha) for c in grid] == [
        (0, 0.1), (0, 0.2), (1, 0.1), (1, 0.2)]


def test_solve_measure_hypergrad_defaults_to_recording(setup):
    """record_every=0 sweep-style calls skip the eager hypergrad
    accounting; recording calls keep it; both remain forcible."""
    prob, x0, y0, data, _, hg, _ = setup
    kw = dict(problem=prob, hg_cfg=hg, x0=x0, y0=y0, data=data)
    quiet = solve(_config(setup, "interact"), 2, 0, **kw)
    assert quiet.hvp_per_step == 0.0 and quiet.grad_per_step == 0.0
    forced = solve(_config(setup, "interact"), 2, 0,
                   measure_hypergrad=True, **kw)
    assert forced.hvp_per_step > 0
    recorded = solve(_config(setup, "interact"), 2, 1,
                     metric_fn=lambda st: 0.0, **kw)
    assert recorded.hvp_per_step > 0


def test_batch_values_and_batch_fields():
    cfg = SolverConfig(seed=3, alpha=0.2, beta=0.4)
    assert cfg.batch_values() == (3, 0.2, 0.4)
    assert SolverConfig.BATCH_FIELDS == ("seed", "alpha", "beta")
    # static_key ignores the batch fields, splits on everything else
    assert cfg.static_key() == SolverConfig().static_key()
    assert (SolverConfig(algo="d-sgd").static_key()
            != SolverConfig().static_key())
