"""HypergradEngine backends: cross-backend equivalence, counting, shims.

Covers the ISSUE-3 contract:
  * cg-linearized vs cg on the analytic quadratic and the MLP meta
    instance;
  * cholesky vs the analytic inverse on the quadratic, and its
    closed-form (``inner_hess_yy``) path vs the batched-identity AD path;
  * 5-step solver-trajectory parity per algorithm when *only* the
    hypergradient backend changes (1e-4);
  * the stochastic-Neumann dynamic trip count (measured HVP counter == k,
    expected (K-1)/2) and its bit-compatibility with the masked form;
  * the relative/absolute cg_solve tolerance flag + surfaced residual;
  * legacy ``repro.core.hypergrad`` entry points: DeprecationWarning and
    bit-compatibility.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

import repro.core.hypergrad as legacy_hg
from repro.core import (
    HypergradConfig,
    MLPMetaProblem,
    init_head,
    init_mlp_backbone,
    laplacian_mixing,
    erdos_renyi_adjacency,
    make_synthetic_agents,
)
from repro.hypergrad import (
    CgInfo,
    HypergradStats,
    available_backends,
    cg_solve,
    hvp_yy,
    hypergradient,
    hypergradient_with_stats,
    measure_counts,
    neumann_stochastic_apply,
)
from repro.solvers import SolverConfig, make_solver

from test_hypergrad import quad_problem


def _leaves_close(a, b, rtol=1e-5, atol=1e-6):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=rtol, atol=atol)


@pytest.fixture(scope="module")
def mlp_setup():
    key = jax.random.PRNGKey(0)
    data = make_synthetic_agents(key, num_agents=4, n_per_agent=120,
                                 d_in=8, num_classes=4)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=10)
    y0 = init_head(jax.random.PRNGKey(2), 10, 4)
    return prob, x0, y0, data


def _agent0(data):
    return ((data.inner_x[0], data.inner_y[0]),
            (data.outer_x[0], data.outer_y[0]))


def test_registry_has_all_five_backends():
    assert set(available_backends()) == {
        "cg", "cg-linearized", "neumann", "neumann-linearized", "cholesky"}


def test_unknown_backend_raises_with_listing():
    cfg = HypergradConfig(backend="qr")
    with pytest.raises(ValueError, match="cg-linearized"):
        cfg.resolve_backend()


def test_cg_linearized_matches_cg_on_quadratic():
    f, g, A, B, truth = quad_problem(jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (5,))
    y = jax.random.normal(jax.random.PRNGKey(5), (4,))
    ref = hypergradient(f, g, x, y,
                        HypergradConfig(method="cg", cg_iters=64,
                                        cg_tol=1e-12))
    lin = hypergradient(f, g, x, y,
                        HypergradConfig(backend="cg-linearized",
                                        cg_iters=64, cg_tol=1e-12))
    _leaves_close(ref, lin, rtol=1e-6, atol=1e-7)


def test_cg_linearized_matches_cg_on_mlp(mlp_setup):
    prob, x0, y0, data = mlp_setup
    ib, ob = _agent0(data)
    ref = hypergradient(prob.outer, prob.inner, x0, y0,
                        HypergradConfig(method="cg", cg_iters=64,
                                        cg_tol=1e-10),
                        f_args=(ob,), g_args=(ib,))
    lin = hypergradient(prob.outer, prob.inner, x0, y0,
                        HypergradConfig(backend="cg-linearized",
                                        cg_iters=64, cg_tol=1e-10),
                        f_args=(ob,), g_args=(ib,))
    _leaves_close(ref, lin, rtol=1e-5, atol=1e-6)


def test_cholesky_matches_analytic_inverse_on_quadratic():
    f, g, A, B, truth = quad_problem(jax.random.PRNGKey(6))
    x = jax.random.normal(jax.random.PRNGKey(7), (5,))
    y = jax.random.normal(jax.random.PRNGKey(8), (4,))
    # the quadratic's H_yy is the constant matrix A: cholesky solves it
    # exactly, so the full hypergradient equals the exact-inverse eq. (5)
    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    z = jnp.linalg.solve(A, gy)
    expected = gx - B @ z
    got = hypergradient(f, g, x, y, HypergradConfig(backend="cholesky"))
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                               rtol=1e-5, atol=1e-6)


def test_cholesky_closed_form_matches_batched_identity(mlp_setup):
    prob, x0, y0, data = mlp_setup
    ib, ob = _agent0(data)
    cfg = HypergradConfig(backend="cholesky")
    with_cf, st_cf = hypergradient_with_stats(
        prob.outer, prob.inner, x0, y0, cfg, f_args=(ob,), g_args=(ib,),
        inner_hess_yy=prob.inner_hess_yy)
    generic, st_ad = hypergradient_with_stats(
        prob.outer, prob.inner, x0, y0, cfg, f_args=(ob,), g_args=(ib,))
    _leaves_close(with_cf, generic, rtol=1e-4, atol=1e-5)
    d_y = ravel_pytree(y0)[0].shape[0]
    assert int(st_cf.hess_count) == 1 and int(st_cf.hvp_count) == 1
    assert int(st_ad.hess_count) == 0
    assert int(st_ad.hvp_count) == d_y + 1   # identity basis + cross term


@pytest.mark.parametrize("algo",
                         ["interact", "svr-interact", "gt-dsgd", "d-sgd"])
@pytest.mark.parametrize("backend", ["cg-linearized", "cholesky"])
def test_solver_trajectory_parity_across_backends(mlp_setup, algo, backend):
    """5 steps with only the hypergrad backend changed stay within 1e-4."""
    prob, x0, y0, data = mlp_setup
    spec = laplacian_mixing(erdos_renyi_adjacency(4, 0.5, seed=3))

    def run_with(hg):
        cfg = SolverConfig(algo=algo, alpha=0.1, beta=0.1, batch_size=6,
                           q=3, mixing=spec, hypergrad=hg, seed=7)
        solver = make_solver(cfg)
        state = solver.init(None, prob, hg, x0, y0, data)
        for _ in range(5):
            state = solver.step(state, data)
        return state

    ref = run_with(HypergradConfig(method="cg", cg_iters=32, cg_tol=1e-10))
    alt = run_with(HypergradConfig(backend=backend, cg_iters=32,
                                   cg_tol=1e-10))
    for la, lb in zip(jax.tree_util.tree_leaves(ref),
                      jax.tree_util.tree_leaves(alt)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-4, atol=1e-4)


def test_stochastic_neumann_counter_is_dynamic():
    """The chain executes exactly the sampled k HVPs (satellite 1)."""
    _, g, A, _, _ = quad_problem(jax.random.PRNGKey(9))
    b = jax.random.normal(jax.random.PRNGKey(10), (4,))
    x, y = jnp.zeros((5,)), jnp.zeros((4,))
    L = float(jnp.linalg.eigvalsh(A)[-1]) * 1.1
    K = 8
    matvec = lambda v: hvp_yy(g, x, y, v)
    counts = []
    for s in range(40):
        key = jax.random.PRNGKey(s)
        v, count = neumann_stochastic_apply(matvec, b, K, L, key)
        k = int(jax.random.randint(key, (), 0, K))
        assert int(count) == k
        counts.append(int(count))
        # value bit-identical to the legacy masked chain
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            v_legacy = legacy_hg.neumann_inverse_apply(
                g, x, y, b, k_terms=K, lipschitz_g=L, stochastic_k=True,
                key=key)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v_legacy))
    mean = sum(counts) / len(counts)
    assert abs(mean - (K - 1) / 2) < 1.5   # expected cost (K-1)/2


def test_neumann_k0_matches_reference_empty_sum():
    """skip_last must not add a phantom term when the sum is empty."""
    from repro.hypergrad import neumann_truncated_apply
    _, g, A, _, _ = quad_problem(jax.random.PRNGKey(20))
    b = jax.random.normal(jax.random.PRNGKey(21), (4,))
    x, y = jnp.zeros((5,)), jnp.zeros((4,))
    mv = lambda v: hvp_yy(g, x, y, v)
    for skip in (False, True):
        v, count = neumann_truncated_apply(mv, b, 0, 2.0, skip_last=skip)
        np.testing.assert_array_equal(np.asarray(v), np.zeros(4))
        assert int(count) == 0


def test_cg_solve_relative_vs_absolute_flag():
    _, g, A, _, _ = quad_problem(jax.random.PRNGKey(11))
    b = 1e-3 * jax.random.normal(jax.random.PRNGKey(12), (4,))
    x, y = jnp.zeros((5,)), jnp.zeros((4,))
    mv = lambda v: hvp_yy(g, x, y, v)
    # relative keeps iterating on a tiny rhs where absolute froze
    z_rel, info_rel = cg_solve(mv, b, 50, 1e-4, rel_tol=True,
                               return_info=True)
    z_abs, info_abs = cg_solve(mv, b, 50, 1e-4, rel_tol=False,
                               return_info=True)
    assert isinstance(info_rel, CgInfo)
    assert float(info_rel.residual_norm) <= 1e-4 * float(jnp.linalg.norm(b))
    assert int(info_abs.iterations) < int(info_rel.iterations)
    assert int(info_rel.matvecs) == 50   # frozen loop still runs the budget
    np.testing.assert_allclose(np.asarray(z_rel),
                               np.asarray(jnp.linalg.solve(A, b)),
                               rtol=1e-4)


def test_measured_counts_per_backend(mlp_setup):
    prob, x0, y0, data = mlp_setup
    ib, ob = _agent0(data)
    counts = {}
    for be in available_backends():
        cfg = HypergradConfig(backend=be, cg_iters=24, cg_tol=1e-10,
                              neumann_k=8, lipschitz_g=4.0)
        st = measure_counts(prob.outer, prob.inner, x0, y0, cfg,
                            f_args=(ob,), g_args=(ib,),
                            inner_hess_yy=prob.inner_hess_yy)
        assert isinstance(st, HypergradStats)
        counts[be] = st
    assert counts["cg"].hvp_count == 24 + 1        # frozen trip + cross
    assert counts["cg-linearized"].hvp_count < counts["cg"].hvp_count
    assert counts["neumann"].hvp_count == 8 + 1
    assert counts["neumann-linearized"].hvp_count == 7 + 1  # skips last
    assert counts["cholesky"].hess_count == 1      # closed form engaged
    for st in counts.values():
        assert st.grad_count >= 1


# ---------------------------------------------------------------------------
# Legacy shim contract: importable, warning, bit-compatible.
# ---------------------------------------------------------------------------

def test_legacy_entry_points_importable():
    for name in ("HypergradConfig", "hvp_yy", "hvp_xy", "cg_solve",
                 "neumann_inverse_apply", "hypergradient"):
        assert hasattr(legacy_hg, name)
    assert legacy_hg.HypergradConfig is HypergradConfig


def test_legacy_shims_warn_and_match(mlp_setup):
    prob, x0, y0, data = mlp_setup
    ib, ob = _agent0(data)
    cfg = HypergradConfig(method="cg", cg_iters=16)
    legacy_hg._warned.clear()
    with pytest.warns(DeprecationWarning):
        p_old = legacy_hg.hypergradient(prob.outer, prob.inner, x0, y0,
                                        cfg, f_args=(ob,), g_args=(ib,))
    p_new = hypergradient(prob.outer, prob.inner, x0, y0, cfg,
                          f_args=(ob,), g_args=(ib,))
    for la, lb in zip(jax.tree_util.tree_leaves(p_old),
                      jax.tree_util.tree_leaves(p_new)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_legacy_cg_solve_warns_and_keeps_absolute_semantics():
    _, g, A, _, _ = quad_problem(jax.random.PRNGKey(13))
    b = jax.random.normal(jax.random.PRNGKey(14), (4,))
    x, y = jnp.zeros((5,)), jnp.zeros((4,))
    mv = lambda v: hvp_yy(g, x, y, v)
    legacy_hg._warned.clear()
    with pytest.warns(DeprecationWarning):
        z_old = legacy_hg.cg_solve(mv, b, 40, 1e-6)
    z_new = cg_solve(mv, b, 40, 1e-6, rel_tol=False)
    np.testing.assert_array_equal(np.asarray(z_old), np.asarray(z_new))


def test_legacy_neumann_warns():
    _, g, A, _, _ = quad_problem(jax.random.PRNGKey(15))
    b = jax.random.normal(jax.random.PRNGKey(16), (4,))
    legacy_hg._warned.clear()
    with pytest.warns(DeprecationWarning):
        legacy_hg.neumann_inverse_apply(g, jnp.zeros((5,)), jnp.zeros((4,)),
                                        b, k_terms=4, lipschitz_g=8.0)
