"""System behaviour tests for INTERACT / SVR-INTERACT / baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HypergradConfig,
    MLPMetaProblem,
    convergence_metric,
    erdos_renyi_adjacency,
    init_dsgd_state,
    init_gt_dsgd_state,
    init_head,
    init_mlp_backbone,
    init_state,
    init_svr_state,
    laplacian_mixing,
    make_dsgd_step,
    make_gt_dsgd_step,
    make_interact_step,
    make_svr_interact_step,
    make_synthetic_agents,
    theorem1_step_sizes,
)

M_AGENTS = 5


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    data = make_synthetic_agents(key, num_agents=M_AGENTS, n_per_agent=200,
                                 d_in=16, num_classes=5)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 16, hidden=20)
    y0 = init_head(jax.random.PRNGKey(2), 20, 5)
    spec = laplacian_mixing(erdos_renyi_adjacency(M_AGENTS, 0.5, seed=3))
    hg = HypergradConfig(method="cg", cg_iters=24)
    return data, prob, x0, y0, spec, hg


def _run(step, state, data, iters):
    for _ in range(iters):
        state = step(state, data)
    return state


def _metric(prob, hg, state, data):
    rep = convergence_metric(prob, hg, state.x, state.y, 300, 0.5, data)
    return float(rep.total)


def test_interact_decreases_metric(setup):
    data, prob, x0, y0, spec, hg = setup
    st0 = init_state(prob, hg, x0, y0, data)
    step = make_interact_step(prob, hg, spec, alpha=0.3, beta=0.3)
    m0 = _metric(prob, hg, st0, data)
    st = _run(step, st0, data, 50)
    m1 = _metric(prob, hg, st, data)
    assert m1 < 0.1 * m0  # strong decrease after 50 full-gradient steps
    assert np.isfinite(m1)


def test_interact_consensus_error_shrinks(setup):
    data, prob, x0, y0, spec, hg = setup
    st = init_state(prob, hg, x0, y0, data)
    step = make_interact_step(prob, hg, spec, alpha=0.3, beta=0.3)
    st = _run(step, st, data, 60)
    rep = convergence_metric(prob, hg, st.x, st.y, 300, 0.5, data)
    assert float(rep.consensus_error) < 5e-3
    assert float(rep.inner_error) < 5e-2


def test_tracking_preserves_average_gradient_identity(setup):
    """Gradient-tracking invariant: u_bar_t == p_bar_t for all t.

    Averaging eq. (10) over agents with doubly-stochastic M telescopes to
    u_bar_t = u_bar_{t-1} + p_bar_t - p_bar_{t-1} and u_0 = p_0.
    """
    data, prob, x0, y0, spec, hg = setup
    st = init_state(prob, hg, x0, y0, data)
    step = make_interact_step(prob, hg, spec, alpha=0.2, beta=0.2)
    for _ in range(8):
        st = step(st, data)
        u_bar = jax.tree_util.tree_map(lambda l: l.mean(0), st.u)
        p_bar = jax.tree_util.tree_map(lambda l: l.mean(0), st.p_prev)
        for a, b in zip(jax.tree_util.tree_leaves(u_bar),
                        jax.tree_util.tree_leaves(p_bar)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


def test_svr_interact_converges(setup):
    data, prob, x0, y0, spec, hg = setup
    sst = init_svr_state(prob, hg, x0, y0, data, jax.random.PRNGKey(7))
    step = make_svr_interact_step(prob, hg, spec, alpha=0.3, beta=0.3, q=12)
    m0 = _metric(prob, hg, sst, data)
    sst = _run(step, sst, data, 50)
    m1 = _metric(prob, hg, sst, data)
    assert m1 < 0.2 * m0


def test_interact_beats_baselines(setup):
    """Fig. 2 qualitative claim: INTERACT/SVR < GT-DSGD and D-SGD on M."""
    data, prob, x0, y0, spec, hg = setup
    iters, bs = 40, 12

    st = _run(make_interact_step(prob, hg, spec, 0.3, 0.3),
              init_state(prob, hg, x0, y0, data), data, iters)
    sst = _run(make_svr_interact_step(prob, hg, spec, 0.3, 0.3, q=12),
               init_svr_state(prob, hg, x0, y0, data, jax.random.PRNGKey(7)),
               data, iters)
    gst = _run(make_gt_dsgd_step(prob, hg, spec, 0.3, 0.3, bs),
               init_gt_dsgd_state(prob, hg, x0, y0, data,
                                  jax.random.PRNGKey(8), bs), data, iters)
    dst = _run(make_dsgd_step(prob, hg, spec, 0.3, 0.3, bs),
               init_dsgd_state(x0, y0, M_AGENTS, jax.random.PRNGKey(9)),
               data, iters)

    m_int = _metric(prob, hg, st, data)
    m_svr = _metric(prob, hg, sst, data)
    m_gt = _metric(prob, hg, gst, data)
    m_d = _metric(prob, hg, dst, data)
    assert m_int < m_gt and m_int < m_d
    assert m_svr < m_gt and m_svr < m_d


def test_one_over_t_rate(setup):
    """Theorem 1: running average of M_t decays like O(1/T)."""
    data, prob, x0, y0, spec, hg = setup
    st = init_state(prob, hg, x0, y0, data)
    step = make_interact_step(prob, hg, spec, alpha=0.25, beta=0.25)
    metrics = []
    for t in range(60):
        metrics.append(_metric(prob, hg, st, data))
        st = step(st, data)
    avg = np.cumsum(metrics) / np.arange(1, len(metrics) + 1)
    # average metric at T=60 should be well below a C/T envelope fit at T=10
    c = avg[9] * 10
    assert avg[-1] <= c / len(avg) * 3.0  # slack factor 3 for constants


def test_theorem1_step_sizes_reasonable():
    a, b = theorem1_step_sizes(mu_g=0.5, L_g=4.0, lam=0.9, m=5)
    assert 0 < a < 1 and 0 < b <= 3 * 4.5 / 2.0
    # denser network (smaller lambda) admits a larger alpha (Remark 1)
    a2, _ = theorem1_step_sizes(mu_g=0.5, L_g=4.0, lam=0.2, m=5)
    assert a2 >= a


def test_interact_deterministic(setup):
    """Full-gradient INTERACT is exactly deterministic."""
    data, prob, x0, y0, spec, hg = setup
    step = make_interact_step(prob, hg, spec, 0.3, 0.3)
    s1 = _run(step, init_state(prob, hg, x0, y0, data), data, 5)
    s2 = _run(step, init_state(prob, hg, x0, y0, data), data, 5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.x),
                    jax.tree_util.tree_leaves(s2.x)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
