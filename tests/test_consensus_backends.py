"""Cross-backend equivalence of the ConsensusEngine API.

dense (matmul reference), pallas (fused kernel, interpret mode), and
ppermute (shard_map collectives on 8 forced host devices) must produce
identical mixed trees — for the ring AND the paper's Section-6
Erdős–Rényi topology (the latter previously impossible on the
distributed path) and a torus — and one full ``interact_step`` must
agree across the single-host backends.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.consensus import DenseEngine, PallasEngine, as_engine, make_engine
from repro.core import (
    erdos_renyi_adjacency, laplacian_mixing, mix_pytree, ring_mixing,
    torus_mixing)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

M_AGENTS = 8


def _specs():
    return {
        "ring": ring_mixing(M_AGENTS, self_weight=1.0 / 3.0),
        "erdos-renyi": laplacian_mixing(
            erdos_renyi_adjacency(M_AGENTS, 0.5, seed=11)),
        "torus": torus_mixing(2, 4),
    }


def _tree(key, m=M_AGENTS):
    """Leaf sizes chosen so the flattened D is NOT a block_d multiple."""
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": jax.random.normal(k1, (m, 37, 5)),
        "b": jax.random.normal(k2, (m, 131)),
        "nest": (jax.random.normal(k3, (m, 3)),),
    }


@pytest.mark.parametrize("topology", ["ring", "erdos-renyi", "torus"])
def test_dense_and_pallas_mix_agree(topology):
    spec = _specs()[topology]
    tree = _tree(jax.random.PRNGKey(0))
    dense = DenseEngine(spec)
    pallas = PallasEngine(spec, interpret=True)
    md, mp = dense.mix(tree), pallas.mix(tree)
    ref = mix_pytree(jnp.asarray(spec.matrix), tree)
    for a, b, r in zip(jax.tree_util.tree_leaves(md),
                       jax.tree_util.tree_leaves(mp),
                       jax.tree_util.tree_leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(r), atol=1e-5)
        np.testing.assert_allclose(np.asarray(b), np.asarray(r), atol=1e-5)


@pytest.mark.parametrize("topology", ["ring", "erdos-renyi"])
def test_dense_and_pallas_fused_step_agree(topology):
    spec = _specs()[topology]
    key = jax.random.PRNGKey(1)
    x = _tree(key)
    u = jax.tree_util.tree_map(lambda l: 0.5 * l, x)
    p = jax.tree_util.tree_map(lambda l: 0.1 * l, x)
    pp = jax.tree_util.tree_map(lambda l: 0.2 * l, x)
    xd, ud = DenseEngine(spec).step1_step3(x, u, p, pp, 0.3)
    xp, up = PallasEngine(spec).step1_step3(x, u, p, pp, 0.3)
    for a, b in zip(jax.tree_util.tree_leaves(xd),
                    jax.tree_util.tree_leaves(xp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(ud),
                    jax.tree_util.tree_leaves(up)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_as_engine_coerces_matrix():
    spec = _specs()["ring"]
    tree = _tree(jax.random.PRNGKey(2))
    got = as_engine(jnp.asarray(spec.matrix)).mix(tree)
    want = mix_pytree(jnp.asarray(spec.matrix), tree)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown consensus backend"):
        make_engine("carrier-pigeon", _specs()["ring"])


def test_full_interact_step_agrees_across_backends():
    """One full Algorithm-1 trajectory: dense vs pallas backends."""
    from repro.core import (
        HypergradConfig, MLPMetaProblem, init_head, init_mlp_backbone,
        init_state, make_interact_step, make_synthetic_agents)
    m = 5
    data = make_synthetic_agents(jax.random.PRNGKey(0), num_agents=m,
                                 n_per_agent=60, d_in=8, num_classes=3)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=10)
    y0 = init_head(jax.random.PRNGKey(2), 10, 3)
    spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.6, seed=3))
    hg = HypergradConfig(method="cg", cg_iters=16)

    # two independent (identical) states: the solver step closures donate
    # their input buffers, so the trajectories must not share storage
    st_d = init_state(prob, hg, x0, y0, data)
    st_p = init_state(prob, hg, x0, y0, data)
    step_d = make_interact_step(prob, hg, spec, 0.3, 0.3, backend="dense")
    step_p = make_interact_step(prob, hg, spec, 0.3, 0.3, backend="pallas")
    for _ in range(3):
        st_d = step_d(st_d, data)
        st_p = step_p(st_p, data)
    for a, b in zip(jax.tree_util.tree_leaves(st_d.x),
                    jax.tree_util.tree_leaves(st_p.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)
    for a, b in zip(jax.tree_util.tree_leaves(st_d.u),
                    jax.tree_util.tree_leaves(st_p.u)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def run_in_subprocess(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_ppermute_backend_matches_dense_all_topologies():
    """The distributed backend (shard_map on 8 forced host devices)
    reproduces the dense mixed trees for ring, ER, and torus graphs, and
    the fused step1_step3 agrees too."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.consensus import DenseEngine, PermuteEngine
        from repro.core import (erdos_renyi_adjacency, laplacian_mixing,
                                ring_mixing, torus_mixing)
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        specs = {
            "ring": ring_mixing(m, self_weight=1/3),
            "erdos-renyi": laplacian_mixing(
                erdos_renyi_adjacency(m, 0.5, seed=11)),
            "torus": torus_mixing(2, 4),
        }
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, 37, 5)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (m, 131))}
        u = jax.tree_util.tree_map(lambda l: 0.5 * l, tree)
        p = jax.tree_util.tree_map(lambda l: 0.1 * l, tree)
        pp = jax.tree_util.tree_map(lambda l: 0.2 * l, tree)
        for name, spec in specs.items():
            eng = PermuteEngine(spec, agent_axes=("data",))
            dense = DenseEngine(spec)
            fn = shard_map(lambda t: eng.mix(t), mesh=mesh,
                           in_specs=P("data"), out_specs=P("data"),
                           axis_names={"data"}, check_vma=False)
            with set_mesh(mesh):
                got = jax.jit(fn)(tree)
            want = dense.mix(tree)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
            fused = shard_map(
                lambda x_, u_, p_, pp_: eng.step1_step3(x_, u_, p_, pp_,
                                                        0.3),
                mesh=mesh, in_specs=(P("data"),) * 4,
                out_specs=(P("data"), P("data")), axis_names={"data"},
                check_vma=False)
            with set_mesh(mesh):
                xg, ug = jax.jit(fused)(tree, u, p, pp)
            xd, ud = dense.step1_step3(tree, u, p, pp, 0.3)
            for a, b in zip(jax.tree_util.tree_leaves(xg),
                            jax.tree_util.tree_leaves(xd)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
            for a, b in zip(jax.tree_util.tree_leaves(ug),
                            jax.tree_util.tree_leaves(ud)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
            print(name, "OK", eng.rounds_per_mix)
        print("BACKENDS_OK")
    """)
    assert "BACKENDS_OK" in out


def test_allgather_backend_matches_dense_all_topologies():
    """The mesh dense-matmul backend (all_gather inside shard_map on 8
    forced host devices) reproduces the dense mixed trees for ring, ER,
    and torus graphs, agrees on the fused step1_step3, and runs the
    robust (trimmed) combine exactly like the dense reference — the
    property ppermute cannot offer (no all-to-all access)."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.consensus import AllGatherEngine, DenseEngine
        from repro.core import (erdos_renyi_adjacency, laplacian_mixing,
                                ring_mixing, torus_mixing)
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        specs = {
            "ring": ring_mixing(m, self_weight=1/3),
            "erdos-renyi": laplacian_mixing(
                erdos_renyi_adjacency(m, 0.5, seed=11)),
            "torus": torus_mixing(2, 4),
        }
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, 37, 5)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (m, 131))}
        u = jax.tree_util.tree_map(lambda l: 0.5 * l, tree)
        p = jax.tree_util.tree_map(lambda l: 0.1 * l, tree)
        pp = jax.tree_util.tree_map(lambda l: 0.2 * l, tree)
        for name, spec in specs.items():
            eng = AllGatherEngine(spec, agent_axes=("data",))
            dense = DenseEngine(spec)
            fn = shard_map(lambda t: eng.mix(t), mesh=mesh,
                           in_specs=P("data"), out_specs=P("data"),
                           axis_names={"data"}, check_vma=False)
            with set_mesh(mesh):
                got = jax.jit(fn)(tree)
            want = dense.mix(tree)
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
            fused = shard_map(
                lambda x_, u_, p_, pp_: eng.step1_step3(x_, u_, p_, pp_,
                                                        0.3),
                mesh=mesh, in_specs=(P("data"),) * 4,
                out_specs=(P("data"), P("data")), axis_names={"data"},
                check_vma=False)
            with set_mesh(mesh):
                xg, ug = jax.jit(fused)(tree, u, p, pp)
            xd, ud = dense.step1_step3(tree, u, p, pp, 0.3)
            for a, b in zip(jax.tree_util.tree_leaves(xg),
                            jax.tree_util.tree_leaves(xd)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
            for a, b in zip(jax.tree_util.tree_leaves(ug),
                            jax.tree_util.tree_leaves(ud)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
            print(name, "OK")

        # robust combine: the gathered table gives all-to-all access, so
        # trimmed-mean must match the dense backend's exactly
        from repro.byzantine import ByzantineConfig
        byz = ByzantineConfig(combine="trimmed-mean")
        spec = specs["erdos-renyi"]
        engr = AllGatherEngine(spec, agent_axes=("data",), byzantine=byz)
        fn = shard_map(lambda t: engr._combine(t), mesh=mesh,
                       in_specs=P("data"), out_specs=P("data"),
                       axis_names={"data"}, check_vma=False)
        with set_mesh(mesh):
            got = jax.jit(fn)(tree)
        want = DenseEngine(spec, byzantine=byz)._combine(tree)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
        print("ALLGATHER_OK")
    """)
    assert "ALLGATHER_OK" in out


def test_allgather_compressed_mix_ef_matches_dense_bitwise():
    """int8+EF through the allgather backend: the wire math is the base
    (dense) implementation verbatim — one concatenated per-agent buffer
    — so under shard_map the mixed tree AND the EF state must match the
    dense backend exactly, not just within quantization tolerance."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.consensus import (AllGatherEngine, CompressionConfig,
                                     DenseEngine)
        from repro.core import erdos_renyi_adjacency, laplacian_mixing
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.5, seed=11))
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, 37, 5)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (m, 131))}
        zeros = jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), tree)
        ef = {"e": zeros, "ref": zeros}
        comp = CompressionConfig("int8")
        t0 = jnp.zeros((), jnp.int32)

        md, efd = DenseEngine(spec, compression=comp).mix_ef(tree, ef, t0)
        eng = AllGatherEngine(spec, agent_axes=("data",), compression=comp)
        fn = shard_map(lambda t, r: eng.mix_ef(t, r, t0), mesh=mesh,
                       in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")),
                       axis_names={"data"}, check_vma=False)
        with set_mesh(mesh):
            mg, efg = jax.jit(fn)(tree, ef)
        for a, b in zip(jax.tree_util.tree_leaves(mg),
                        jax.tree_util.tree_leaves(md)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(efg),
                        jax.tree_util.tree_leaves(efd)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6)
        print("ALLGATHER_EF_OK")
    """)
    assert "ALLGATHER_EF_OK" in out


def test_consensus_step_preserves_mixed_dtypes():
    """The fused op must not cast the tracker to x's leaf dtypes."""
    from repro.kernels.consensus_step import ops as cs_ops
    spec = _specs()["ring"]
    mix = jnp.asarray(spec.matrix, jnp.float32)
    m = M_AGENTS
    x = {"a": jnp.ones((m, 33), jnp.bfloat16), "b": jnp.ones((m, 7))}
    u = {"a": jnp.ones((m, 33)), "b": jnp.ones((m, 7))}
    x_new, u_new = cs_ops.consensus_step(mix, x, u, u, u, alpha=0.1)
    assert x_new["a"].dtype == jnp.bfloat16
    assert u_new["a"].dtype == jnp.float32   # u keeps its own dtype
    assert u_new["b"].dtype == jnp.float32


def test_dp_noise_independent_across_leaves():
    """Same-shaped leaves must get independent DP noise, otherwise a
    neighbour could difference two leaves and cancel the noise."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import ring_mixing
        from repro.sharding.collectives import ring_mix_tree
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = ring_mixing(m, self_weight=1/3)
        leaf = jax.random.normal(jax.random.PRNGKey(0), (m, 32))
        tree = {"a": leaf, "b": leaf}     # identical same-shaped leaves
        fn = shard_map(
            lambda t: ring_mix_tree(t, ("data",), 1/3, dp_sigma=0.1,
                                    dp_key=jax.random.PRNGKey(3)),
            mesh=mesh, in_specs=P("data"), out_specs=P("data"),
            axis_names={"data"}, check_vma=False)
        with set_mesh(mesh):
            got = jax.jit(fn)(tree)
        # identical inputs + identical noise would give identical outputs;
        # independent per-leaf noise must make them differ
        d = float(jnp.max(jnp.abs(got["a"] - got["b"])))
        assert d > 1e-4, d
        print("DP_LEAVES_OK", d)
    """)
    assert "DP_LEAVES_OK" in out


def test_psum_impl_matches_ppermute_impl():
    """The all-reduce fallback (partial-auto old-JAX bodies) is the same
    mixing matrix — identical results, including int8 compression."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.consensus import PermuteEngine
        from repro.core import erdos_renyi_adjacency, laplacian_mixing
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.5, seed=4))
        X = jax.random.normal(jax.random.PRNGKey(0), (m, 64))
        ids = jnp.arange(m, dtype=jnp.int32)
        for compress in (None, "int8"):
            outs = []
            for impl in ("ppermute", "psum"):
                eng = PermuteEngine(spec, agent_axes=("data",),
                                    compress=compress, impl=impl)
                fn = shard_map(
                    lambda t, ii: eng.mix(t, agent_index=ii[0]),
                    mesh=mesh, in_specs=(P("data"), P("data")),
                    out_specs=P("data"), axis_names={"data"},
                    check_vma=False)
                with set_mesh(mesh):
                    outs.append(jax.jit(fn)(X, ids))
            np.testing.assert_allclose(np.asarray(outs[0]),
                                       np.asarray(outs[1]), atol=1e-5)
        print("PSUM_IMPL_OK")
    """)
    assert "PSUM_IMPL_OK" in out


# -- compressed wire: cross-backend tolerance contract ----------------------
#
# dense/pallas compress one concatenated per-agent buffer, ppermute
# compresses per-leaf payloads — different scale granularity, so the
# backends agree within a quantization tolerance rather than bitwise.
# The `none` compressor must be exact everywhere (and its EF residual a
# true zero).


def test_none_compressor_ef_residual_exactly_zero():
    """Regression: the identity compressor's EF recursion must produce
    bit-exact zero residuals and the exact uncompressed combine."""
    from repro.consensus import CompressionConfig
    spec = _specs()["erdos-renyi"]
    tree = _tree(jax.random.PRNGKey(7))
    zeros = jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), tree)
    ef = {"e": zeros, "ref": zeros}
    for engine in (DenseEngine(spec, compression=CompressionConfig("none")),
                   PallasEngine(spec,
                                compression=CompressionConfig("none"))):
        # "none" is not wire-active, but the EF plumbing must still be
        # callable (mix_ef is the generic entry point for the step-core)
        mixed, ef_new = engine.mix_ef(tree, ef, t=jnp.zeros((), jnp.int32))
        want = engine.mix(tree)
        for a, b in zip(jax.tree_util.tree_leaves(mixed),
                        jax.tree_util.tree_leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # an inactive wire passes the state through untouched: residual
        # and public copy both stay exactly zero
        for r in jax.tree_util.tree_leaves(ef_new):
            assert np.all(np.asarray(r) == 0.0)


def test_int8_ef_mix_dense_within_quantization_tolerance():
    """int8+EF dense combine: within one quantization step of the clean
    reference, residual bounded by the per-row quantization scale."""
    from repro.consensus import CompressionConfig
    spec = _specs()["ring"]
    tree = _tree(jax.random.PRNGKey(3))
    zeros = jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), tree)
    eng = DenseEngine(spec, compression=CompressionConfig("int8"))
    mixed, ef_new = eng.mix_ef(tree, {"e": zeros, "ref": zeros},
                               t=jnp.zeros((), jnp.int32))
    want = DenseEngine(spec).mix(tree)
    # round one the innovation IS the value (ref = 0): max|row| / 127
    # bounds the elementwise quantization error; mixing is an average so
    # the combine inherits the bound
    bound = max(float(jnp.max(jnp.abs(l))) for l in
                jax.tree_util.tree_leaves(tree)) / 127.0 + 1e-6
    for a, b in zip(jax.tree_util.tree_leaves(mixed),
                    jax.tree_util.tree_leaves(want)):
        assert float(jnp.max(jnp.abs(a - b))) <= bound
    for r in jax.tree_util.tree_leaves(ef_new["e"]):
        assert float(jnp.max(jnp.abs(r))) <= bound


def test_int8_compression_dense_and_ppermute_tolerance_contract():
    """CompressionConfig("int8") on dense AND ppermute: both stay within
    one quantization step of the uncompressed dense reference, and the
    two compressed backends agree to the same tolerance."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.consensus import (CompressionConfig, DenseEngine,
                                     PermuteEngine)
        from repro.core import erdos_renyi_adjacency, laplacian_mixing
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.5, seed=11))
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, 37, 5)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (m, 131))}
        zeros = jax.tree_util.tree_map(
            lambda l: jnp.zeros(l.shape, jnp.float32), tree)
        ef = {"e": zeros, "ref": zeros}
        comp = CompressionConfig("int8")
        t0 = jnp.zeros((), jnp.int32)

        ref = DenseEngine(spec).mix(tree)
        md, _ = DenseEngine(spec, compression=comp).mix_ef(tree, ef, t0)

        eng = PermuteEngine(spec, agent_axes=("data",), compression=comp)
        fn = shard_map(lambda t, r: eng.mix_ef(t, r, t0), mesh=mesh,
                       in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")),
                       axis_names={"data"}, check_vma=False)
        with set_mesh(mesh):
            mp, efp = jax.jit(fn)(tree, ef)

        bound = max(float(jnp.max(jnp.abs(l)))
                    for l in jax.tree_util.tree_leaves(tree)) / 127.0 + 1e-6
        for a, b, r in zip(jax.tree_util.tree_leaves(md),
                           jax.tree_util.tree_leaves(mp),
                           jax.tree_util.tree_leaves(ref)):
            assert float(jnp.max(jnp.abs(a - r))) <= bound     # dense vs ref
            assert float(jnp.max(jnp.abs(b - r))) <= bound     # ppermute vs ref
            assert float(jnp.max(jnp.abs(a - b))) <= 2 * bound # cross-backend
        for r in jax.tree_util.tree_leaves(efp["e"]):
            assert float(jnp.max(jnp.abs(r))) <= bound         # EF bounded
        print("INT8_CONTRACT_OK")
    """)
    assert "INT8_CONTRACT_OK" in out


def test_dp_noise_dense_reference_tolerance_contract():
    """Legacy DP wire (ppermute) vs the clean dense reference: the
    perturbation is bounded by the noise scale times the off-diagonal
    mass (the self term mixes clean), on both ppermute and psum impls."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.consensus import DenseEngine, PermuteEngine
        from repro.core import erdos_renyi_adjacency, laplacian_mixing
        from repro.sharding.compat import shard_map, set_mesh

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.5, seed=11))
        X = jax.random.normal(jax.random.PRNGKey(0), (m, 64))
        ids = jnp.arange(m, dtype=jnp.int32)
        ref = DenseEngine(spec).mix(X)
        sigma = 0.05
        for impl in ("ppermute", "psum"):
            eng = PermuteEngine(spec, agent_axes=("data",),
                                dp_sigma=sigma, impl=impl)
            fn = shard_map(
                lambda t, ii: eng.mix(t, dp_key=jax.random.PRNGKey(5),
                                      agent_index=ii[0]),
                mesh=mesh, in_specs=(P("data"), P("data")),
                out_specs=P("data"), axis_names={"data"}, check_vma=False)
            with set_mesh(mesh):
                got = jax.jit(fn)(X, ids)
            diff = np.abs(np.asarray(got) - np.asarray(ref))
            assert diff.max() > 1e-5            # noise actually applied
            # 6-sigma on a weighted sum of <= m unit-variance Gaussians
            assert diff.max() < 6 * sigma * np.sqrt(m), diff.max()
        print("DP_CONTRACT_OK")
    """)
    assert "DP_CONTRACT_OK" in out


def test_sign1bit_ef_solver_paths_agree_dense_vs_pallas():
    """A compressed full-solver trajectory (sign1bit+EF) matches between
    the dense and pallas backends — the wire path composes through the
    same base mixes on both."""
    from repro.solvers import CompressionConfig, SolverConfig, solve
    comp = CompressionConfig("sign1bit", compress_after=1)
    kw = dict(num_steps=3, record_every=0, num_agents=4, n_per_agent=40)
    rd = solve(SolverConfig(algo="interact", alpha=0.05, beta=0.05,
                            backend="dense", compression=comp), **kw)
    rp = solve(SolverConfig(algo="interact", alpha=0.05, beta=0.05,
                            backend="pallas", compression=comp), **kw)
    for a, b in zip(jax.tree_util.tree_leaves(rd.state.x),
                    jax.tree_util.tree_leaves(rp.state.x)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)
    for a, b in zip(jax.tree_util.tree_leaves(rd.state.ef),
                    jax.tree_util.tree_leaves(rp.state.ef)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)
