"""Shared pytest fixtures.

``jax.clear_caches()`` runs after every test module: jaxlib 0.4.37's CPU
``backend_compile`` segfaults once a few hundred compiled executables
have accumulated across a full-suite run (each module passes standalone;
the crash moves with the collection order, landing on whichever
compile-heavy test runs ~280 tests in).  Dropping the compilation caches
at module boundaries bounds live compiler state at the footprint of one
module, at the cost of cross-module recompiles.
"""
import jax
import pytest


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    yield
    jax.clear_caches()
