"""Checkpoint store tests: exact round-trips, corruption detection,
fallback-to-newest-valid (docs/RESILIENCE.md).

The store is the foundation the resilience layer stands on, so the
properties under test are the load-bearing ones: bitwise round-trips of
arbitrary pytrees, loud failure on structure/shape/dtype mismatch, CRC
detection of bit-rot, atomic writes that never leave a partial file
under the final name, and step selection that skips damaged files.
"""
import json
import os
import pathlib
import tempfile
import typing
import zlib

import numpy as np
import pytest

from repro.checkpoint import (
    CorruptCheckpointError,
    latest_step,
    restore_latest,
    restore_pytree,
    restore_step,
    save_pytree,
    save_step,
    valid_steps,
    verify_checkpoint,
)


class Carry(typing.NamedTuple):
    x: dict
    y: np.ndarray
    t: np.ndarray


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return Carry(
        x={"w": rng.standard_normal((3, 4)).astype(np.float32),
           "b": [rng.standard_normal(4).astype(np.float64),
                 rng.integers(0, 9, (2,), dtype=np.int32)]},
        y=rng.standard_normal((2, 2)).astype(np.float32),
        t=np.asarray(7, np.int32))


def _leaves_equal(a, b):
    import jax
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for p, q in zip(la, lb):
        p, q = np.asarray(p), np.asarray(q)
        assert p.dtype == q.dtype and p.shape == q.shape
        assert p.tobytes() == q.tobytes()


def test_roundtrip_namedtuple_nested_bitwise(tmp_path):
    tree = _tree()
    p = tmp_path / "ck.npz"
    save_pytree(p, tree)
    got = restore_pytree(p, _tree(seed=1))   # template: structure only
    assert isinstance(got, Carry)
    _leaves_equal(got, tree)


def test_structure_mismatch_raises(tmp_path):
    p = tmp_path / "ck.npz"
    save_pytree(p, _tree())
    with pytest.raises(ValueError, match="structure mismatch"):
        restore_pytree(p, {"different": np.zeros(3)})


def test_shape_and_dtype_mismatch_raise(tmp_path):
    p = tmp_path / "ck.npz"
    save_pytree(p, {"a": np.zeros((3,), np.float32)})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_pytree(p, {"a": np.zeros((4,), np.float32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        restore_pytree(p, {"a": np.zeros((3,), np.float64)})


def test_crc_detects_bit_rot(tmp_path):
    """Flipped leaf bytes behind an intact manifest must be caught."""
    p = tmp_path / "ck.npz"
    save_pytree(p, {"a": np.arange(8, dtype=np.float32)})
    with np.load(p) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    arrays["leaf_0"] = arrays["leaf_0"] + 1.0   # stale CRC kept
    np.savez(p, **arrays)
    assert not verify_checkpoint(p)
    with pytest.raises(CorruptCheckpointError, match="CRC mismatch"):
        restore_pytree(p, {"a": np.zeros(8, np.float32)})


def test_truncated_archive_detected(tmp_path):
    p = tmp_path / "ck.npz"
    save_pytree(p, _tree())
    with open(p, "r+b") as fh:
        fh.truncate(os.path.getsize(p) // 3)
    assert not verify_checkpoint(p)
    with pytest.raises(CorruptCheckpointError):
        restore_pytree(p, _tree())


def test_manifest_dtype_record_detects_reinterpretation(tmp_path):
    """A leaf decoded under a different dtype than the manifest recorded
    is corruption, even if the template would accept it."""
    p = tmp_path / "ck.npz"
    save_pytree(p, {"a": np.arange(4, dtype=np.float32)})
    with np.load(p) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    manifest = json.loads(bytes(arrays["__manifest__"]).decode())
    manifest["dtypes"] = ["int32"]
    manifest["crcs"] = [zlib.crc32(arrays["leaf_0"].tobytes())]
    arrays["__manifest__"] = np.frombuffer(
        json.dumps(manifest).encode(), dtype=np.uint8)
    np.savez(p, **arrays)
    assert not verify_checkpoint(p)
    with pytest.raises(CorruptCheckpointError, match="manifest records"):
        restore_pytree(p, {"a": np.zeros(4, np.float32)})


def test_save_is_atomic_no_temp_residue(tmp_path):
    save_pytree(tmp_path / "ck.npz", _tree())
    save_pytree(tmp_path / "ck.npz", _tree(seed=1))   # overwrite in place
    assert sorted(q.name for q in tmp_path.iterdir()) == ["ck.npz"]
    got = restore_pytree(tmp_path / "ck.npz", _tree())
    _leaves_equal(got, _tree(seed=1))


def test_failed_save_leaves_previous_checkpoint_intact(tmp_path):
    class Unsaveable:
        def __array__(self, *a, **k):
            raise ValueError("not convertible")

    p = tmp_path / "ck.npz"
    save_pytree(p, _tree())
    with pytest.raises(ValueError, match="not convertible"):
        save_pytree(p, {"a": Unsaveable()})
    assert sorted(q.name for q in tmp_path.iterdir()) == ["ck.npz"]
    _leaves_equal(restore_pytree(p, _tree(seed=1)), _tree())


def test_latest_step_ordering_and_fallback(tmp_path):
    for s in (5, 10, 40):
        save_step(tmp_path, s, _tree(seed=s))
    assert latest_step(tmp_path) == 40
    # corrupt the newest: validated answer falls back, legacy does not
    with open(tmp_path / "step_00000040.npz", "r+b") as fh:
        fh.truncate(10)
    assert latest_step(tmp_path) == 10
    assert latest_step(tmp_path, validate=False) == 40
    assert valid_steps(tmp_path) == [5, 10]
    assert latest_step(tmp_path / "nope") is None


def test_stray_files_ignored(tmp_path):
    save_step(tmp_path, 3, _tree())
    (tmp_path / "step_final.npz").write_bytes(b"not a checkpoint")
    (tmp_path / "notes.txt").write_text("irrelevant")
    assert latest_step(tmp_path) == 3


def test_restore_step_fallback_warns_and_restores_older(tmp_path):
    save_step(tmp_path, 5, _tree(seed=5))
    save_step(tmp_path, 10, _tree(seed=10))
    with open(tmp_path / "step_00000010.npz", "r+b") as fh:
        fh.truncate(10)
    with pytest.raises(CorruptCheckpointError):
        restore_step(tmp_path, 10, _tree())
    with pytest.warns(UserWarning, match="fell back to step 5"):
        got = restore_step(tmp_path, 10, _tree(), fallback=True)
    _leaves_equal(got, _tree(seed=5))
    with pytest.raises(FileNotFoundError):
        restore_step(tmp_path, 4, _tree(), fallback=True)


def test_restore_latest_max_step_and_empty(tmp_path):
    assert restore_latest(tmp_path, _tree()) is None
    for s in (2, 4, 6):
        save_step(tmp_path, s, _tree(seed=s))
    tree, used = restore_latest(tmp_path, _tree())
    assert used == 6
    _leaves_equal(tree, _tree(seed=6))
    tree, used = restore_latest(tmp_path, _tree(), max_step=5)
    assert used == 4
    _leaves_equal(tree, _tree(seed=4))


def test_restore_latest_never_falls_back_past_wrong_template(tmp_path):
    """A *valid* checkpoint for a different run must raise, not be
    skipped — silently resuming the wrong experiment is worse than
    failing."""
    save_step(tmp_path, 1, _tree())
    save_step(tmp_path, 2, {"other": np.zeros(3, np.float32)})
    with pytest.raises(ValueError, match="structure mismatch"):
        restore_latest(tmp_path, _tree())


def test_version1_manifest_still_restores(tmp_path):
    """Pre-CRC checkpoints (bare path-list manifest) restore, minus the
    integrity checks."""
    p = tmp_path / "ck.npz"
    tree = {"a": np.arange(4, dtype=np.float32),
            "b": np.asarray(2, np.int32)}
    save_pytree(p, tree)
    with np.load(p) as data:
        arrays = {k: np.array(data[k]) for k in data.files}
    manifest = json.loads(bytes(arrays["__manifest__"]).decode())
    arrays["__manifest__"] = np.frombuffer(
        json.dumps(manifest["paths"]).encode(), dtype=np.uint8)
    np.savez(p, **arrays)
    got = restore_pytree(p, {"a": np.zeros(4, np.float32),
                             "b": np.asarray(0, np.int32)})
    _leaves_equal(got, tree)


def test_jax_arrays_roundtrip(tmp_path):
    import jax.numpy as jnp
    tree = {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "t": jnp.asarray(3, jnp.int32)}
    p = tmp_path / "ck.npz"
    save_pytree(p, tree)
    got = restore_pytree(p, tree)
    _leaves_equal(got, tree)
