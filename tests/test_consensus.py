"""Tests for mixing matrices and the consensus combine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    erdos_renyi_adjacency,
    laplacian_mixing,
    metropolis_mixing,
    mix_pytree,
    ring_mixing,
    second_eigenvalue,
    validate_mixing,
)


@pytest.mark.parametrize("m,p", [(5, 0.5), (10, 0.5), (5, 0.3), (5, 0.7), (8, 0.9)])
def test_laplacian_mixing_properties(m, p):
    adj = erdos_renyi_adjacency(m, p, seed=42)
    spec = laplacian_mixing(adj)
    validate_mixing(spec.matrix, adj)
    assert 0.0 <= spec.lam < 1.0  # connected graph => lambda < 1


@pytest.mark.parametrize("m", [4, 5, 16, 32])
def test_metropolis_mixing_properties(m):
    adj = erdos_renyi_adjacency(m, 0.4, seed=7)
    spec = metropolis_mixing(adj)
    validate_mixing(spec.matrix, adj)
    assert spec.lam < 1.0


@pytest.mark.parametrize("m", [2, 3, 4, 16, 32, 256])
def test_ring_mixing_analytic_lambda(m):
    spec = ring_mixing(m, self_weight=1.0 / 3.0)
    validate_mixing(spec.matrix)
    # analytic eigenvalues: w0 + 2*w1*cos(2 pi k/m)
    w0, w1 = 1.0 / 3.0, 1.0 / 3.0
    eigs = np.array([w0 + 2 * w1 * np.cos(2 * np.pi * k / m) for k in range(m)])
    eigs = np.sort(np.abs(eigs))[::-1]
    assert spec.lam == pytest.approx(eigs[1], abs=1e-9)


def test_ring_mixing_matches_ppermute_weights():
    spec = ring_mixing(8, self_weight=0.5)
    assert spec.neighbors == (-1, 1)
    assert spec.self_weight == pytest.approx(0.5)
    # row structure: self weight on diag, w1 on the two ring neighbours
    assert spec.matrix[0, 0] == pytest.approx(0.5)
    assert spec.matrix[0, 1] == pytest.approx(0.25)
    assert spec.matrix[0, 7] == pytest.approx(0.25)


def test_mix_pytree_matches_dense_matmul():
    m, d = 6, 13
    key = jax.random.PRNGKey(0)
    mat = jnp.asarray(ring_mixing(m).matrix)
    leaf = jax.random.normal(key, (m, d, 3))
    tree = {"a": leaf, "b": (leaf[..., 0], leaf[..., 1])}
    mixed = mix_pytree(mat, tree)
    expect = jnp.einsum("ij,jdk->idk", mat, leaf)
    np.testing.assert_allclose(np.asarray(mixed["a"]), np.asarray(expect), rtol=1e-6)


def test_consensus_contraction():
    """||Mx - 1 x_bar|| <= lambda ||x - 1 x_bar|| (Step-3 contraction)."""
    m = 10
    spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.5, seed=1))
    mat = jnp.asarray(spec.matrix)
    x = jax.random.normal(jax.random.PRNGKey(2), (m, 17))
    xbar = x.mean(axis=0, keepdims=True)
    before = jnp.linalg.norm(x - xbar)
    mixed = mat @ x
    after = jnp.linalg.norm(mixed - mixed.mean(axis=0, keepdims=True))
    assert float(after) <= spec.lam * float(before) + 1e-6


def test_mixing_preserves_mean():
    """Doubly-stochastic M preserves the agent average exactly."""
    m = 12
    spec = ring_mixing(m)
    x = jax.random.normal(jax.random.PRNGKey(3), (m, 9))
    mixed = jnp.asarray(spec.matrix) @ x
    np.testing.assert_allclose(np.asarray(mixed.mean(0)), np.asarray(x.mean(0)),
                               atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(m=st.integers(3, 24), sw=st.floats(0.1, 0.9))
def test_ring_mixing_property(m, sw):
    spec = ring_mixing(m, self_weight=sw)
    validate_mixing(spec.matrix)
    assert 0.0 <= spec.lam <= 1.0
    assert spec.self_weight == pytest.approx(sw)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(3, 12), p=st.floats(0.3, 1.0), seed=st.integers(0, 999))
def test_er_graph_connected_and_valid(m, p, seed):
    adj = erdos_renyi_adjacency(m, p, seed)
    spec = laplacian_mixing(adj)
    validate_mixing(spec.matrix, adj)
    assert spec.lam < 1.0 - 1e-9
