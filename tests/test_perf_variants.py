"""Correctness of the beyond-paper perf variants (EXPERIMENTS.md §Perf):
the optimized paths must agree with the paper-faithful reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as M
from repro.models import moe as Moe


# ---------------------------------------------------------------------------
# P2: blockwise attention == reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("win,cap", [(None, None), (64, None), (None, 30.0),
                                     (64, 50.0)])
def test_blockwise_attention_matches_ref(win, cap):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 200, 4, 32))
    k = jax.random.normal(ks[1], (2, 200, 2, 32))
    v = jax.random.normal(ks[2], (2, 200, 2, 32))
    pos = jnp.arange(200, dtype=jnp.int32)
    a = L.attention_blockwise(q, k, v, pos, pos, win, cap, block_k=64)
    b = L.attention_ref(q, k, v, pos, pos, win, cap)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_blockwise_gradients_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    pos = jnp.arange(64, dtype=jnp.int32)

    def loss_block(q_):
        return jnp.sum(L.attention_blockwise(q_, k, v, pos, pos,
                                             block_k=16) ** 2)

    def loss_ref(q_):
        return jnp.sum(L.attention_ref(q_, k, v, pos, pos) ** 2)

    g1 = jax.grad(loss_block)(q)
    g2 = jax.grad(loss_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               atol=1e-4, rtol=1e-4)


def test_model_forward_blockwise_equals_reference():
    cfg = get_config("gemma2-2b").reduced(vocab_size=128)
    params = M.init_params(cfg, jax.random.PRNGKey(2), with_head=True)
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 48), 0, 128)
    ref, _ = M.forward(cfg, params, tokens, impl="reference", remat=False)
    blk, _ = M.forward(cfg, params, tokens, impl="blockwise", remat=False)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(ref),
                               atol=5e-4, rtol=1e-3)


@settings(max_examples=6, deadline=None)
@given(s=st.integers(10, 120), block=st.sampled_from([16, 32, 64]),
       seed=st.integers(0, 99))
def test_blockwise_block_size_invariant(s, block, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, 2, 16))
    k = jax.random.normal(ks[1], (1, s, 2, 16))
    v = jax.random.normal(ks[2], (1, s, 2, 16))
    pos = jnp.arange(s, dtype=jnp.int32)
    a = L.attention_blockwise(q, k, v, pos, pos, block_k=block)
    b = L.attention_ref(q, k, v, pos, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


# ---------------------------------------------------------------------------
# P3: chunked MoE
# ---------------------------------------------------------------------------

def test_moe_chunked_equals_unchunked_when_no_drops():
    """With a generous capacity factor nothing is dropped, so per-chunk
    routing equals global routing exactly."""
    params = Moe.init_moe(jax.random.PRNGKey(4), 16, 32, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 64, 16))
    o1, _ = Moe.moe_ffn(params, x, num_experts=4, top_k=2,
                        capacity_factor=8.0)
    o2, _ = Moe.moe_ffn(params, x, num_experts=4, top_k=2,
                        capacity_factor=8.0, token_chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=1e-5, rtol=1e-5)


def test_moe_chunked_differentiable():
    params = Moe.init_moe(jax.random.PRNGKey(6), 16, 32, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 64, 16))

    def loss(p):
        out, aux = Moe.moe_ffn(p, x, num_experts=4, top_k=2,
                               token_chunk=16)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_moe_capacity_drops_bounded():
    """Even with drops, outputs stay finite and the drop rate is bounded
    by the capacity factor."""
    params = Moe.init_moe(jax.random.PRNGKey(8), 16, 32, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 64, 16))
    out, aux = Moe.moe_ffn(params, x, num_experts=4, top_k=2,
                           capacity_factor=0.5)
    assert bool(jnp.all(jnp.isfinite(out)))
    # a 0.5 capacity factor zeroes at most ~[1 - 0.5/imbalance] of tokens;
    # at least some tokens must still be routed
    assert float(jnp.mean(jnp.abs(out))) > 0


# ---------------------------------------------------------------------------
# P1: last-token prefill
# ---------------------------------------------------------------------------

def test_prefill_last_token_matches_full_forward():
    from repro.launch.serving import make_prefill_step
    cfg = get_config("llama3.2-3b").reduced(vocab_size=128)
    params = M.init_params(cfg, jax.random.PRNGKey(10), with_head=True)
    tokens = jax.random.randint(jax.random.PRNGKey(11), (2, 32), 0, 128)
    prefill = make_prefill_step(cfg)
    last = prefill(params, tokens)
    full, _ = M.forward(cfg, params, tokens, remat=False)
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               atol=1e-5, rtol=1e-5)
