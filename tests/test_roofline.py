"""Roofline machinery tests: cost-analysis semantics, collective parsing,
analytic parameter counts, term construction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.dryrun import parse_collectives
from repro.models import model as M
from repro.roofline.analysis import (
    HBM_BW, LINK_BW, PEAK_FLOPS, active_param_count, analytic_param_count,
    model_flops, normalize_cost_analysis, roofline_terms)


def test_xla_counts_scan_body_once():
    """The §Roofline trip-count correction rests on this XLA behaviour:
    cost_analysis FLOPs do NOT scale with scan length."""
    def flops_for(nlayers):
        cfg = get_config("smollm-360m").reduced(num_layers=nlayers,
                                                vocab_size=512)
        params = M.init_params(cfg, jax.random.PRNGKey(0), with_head=True)
        tokens = jnp.zeros((2, 64), jnp.int32)
        fn = jax.jit(lambda p, t: M.forward(cfg, p, t, remat=False)[0])
        cost = normalize_cost_analysis(
            fn.lower(params, tokens).compile().cost_analysis())
        return cost["flops"]

    assert flops_for(4) == flops_for(8)


def test_normalize_cost_analysis_handles_both_shapes():
    assert normalize_cost_analysis({"flops": 1.0}) == {"flops": 1.0}
    assert normalize_cost_analysis([{"flops": 2.0}]) == {"flops": 2.0}
    assert normalize_cost_analysis([]) == {}
    assert normalize_cost_analysis(None) == {}


def test_parse_collectives_counts_and_bytes():
    hlo = """
  %ag = bf16[16,128,256]{2,1,0} all-gather(%x), replica_groups={}
  %ar = f32[1024]{0} all-reduce(%y), to_apply=%add
  %cp = f32[4,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %rs = bf16[64]{0} reduce-scatter(%w), to_apply=%add
  %aa = f32[2,2]{1,0} all-to-all(%v), dimensions={0}
"""
    stats = parse_collectives(hlo)
    per = stats["per_op"]
    assert per["all-gather"]["count"] == 1
    assert per["all-gather"]["bytes"] == 16 * 128 * 256 * 2
    assert per["all-reduce"]["bytes"] == 1024 * 4
    assert per["collective-permute"]["bytes"] == 32 * 4
    # all-reduce weighted 2x in wire bytes
    expected_wire = (16 * 128 * 256 * 2 + 2 * 1024 * 4 + 32 * 4
                     + 64 * 2 + 4 * 4)
    assert stats["wire_bytes"] == expected_wire


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_analytic_param_count_matches_real_init(arch):
    """Config-derived N matches the actual initialised parameter count
    (within 2% — analytic skips norm scales / small biases)."""
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), with_head=True)
    real = M.param_count(params)
    analytic = analytic_param_count(cfg)
    assert abs(real - analytic) / real < 0.06, (real, analytic)


def test_active_params_less_than_total_for_moe():
    cfg = get_config("mixtral-8x7b")
    assert active_param_count(cfg) < analytic_param_count(cfg)
    ratio = active_param_count(cfg) / analytic_param_count(cfg)
    assert 0.25 < ratio < 0.65  # top-2 of 8 experts + dense trunk


def test_model_flops_shapes():
    cfg = get_config("smollm-360m")
    n = active_param_count(cfg)
    assert model_flops(cfg, "train", 4096, 256) == 6.0 * n * 4096 * 256
    assert model_flops(cfg, "decode", 32768, 128) == 2.0 * n * 128


def test_roofline_terms_dominance():
    cfg = get_config("smollm-360m")
    fake = {
        "arch": "smollm-360m", "shape": "decode_32k", "devices": 256,
        "cost": {"flops": 1e9, "bytes_accessed": 1e12},
        "collectives": {"wire_bytes": 1e6},
    }
    rep = roofline_terms(fake, cfg)
    assert rep.memory_s == pytest.approx(1e12 / HBM_BW)
    assert rep.compute_s == pytest.approx(1e9 / PEAK_FLOPS)
    assert rep.collective_s == pytest.approx(1e6 / LINK_BW)
    assert rep.dominant == "memory"


def test_roofline_correction_scales_compute_and_memory_only():
    cfg = get_config("smollm-360m")
    fake = {
        "arch": "smollm-360m", "shape": "train_4k", "devices": 256,
        "cost": {"flops": 1e12, "bytes_accessed": 1e10},
        "collectives": {"wire_bytes": 1e9},
    }
    r1 = roofline_terms(fake, cfg)
    r32 = roofline_terms(fake, cfg, scan_trip_correction=32.0)
    assert r32.compute_s == pytest.approx(32 * r1.compute_s)
    assert r32.memory_s == pytest.approx(32 * r1.memory_s)
    assert r32.collective_s == pytest.approx(r1.collective_s)
