"""Unit tests for the compressed-consensus wire layer.

Covers the compressor registry (value fidelity + bytes accounting), the
error-feedback recursion, the warmup-then-compress schedule, the
communication-interval cond, and a small end-to-end solver sanity check
that EF recovers the uncompressed trajectory's stationarity ballpark.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.consensus import (
    COMPRESSORS,
    CompressionConfig,
    DenseEngine,
    cumulative_wire_bytes,
    init_ef,
    make_compressor,
)
from repro.core import ring_mixing


def _spec(m=4):
    return ring_mixing(m)


# -- compressor registry ----------------------------------------------------


def test_registry_kinds_and_unknown():
    assert set(COMPRESSORS) == {"none", "int8", "sign1bit", "topk"}
    with pytest.raises(ValueError):
        make_compressor(CompressionConfig("fp4"))


def test_none_compressor_is_identity_with_zero_residual():
    c = make_compressor(CompressionConfig("none"))
    v = jax.random.normal(jax.random.PRNGKey(0), (257,))
    out, res = c.compress(v)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(v))
    assert np.all(np.asarray(res) == 0.0)
    assert c.bytes_on_wire(257) == 4 * 257


def test_int8_error_bound_and_bytes():
    c = make_compressor(CompressionConfig("int8"))
    v = jax.random.normal(jax.random.PRNGKey(1), (513,)) * 3.0
    out, res = c.compress(v)
    bound = float(jnp.max(jnp.abs(v))) / 127.0 + 1e-6
    assert float(jnp.max(jnp.abs(out - v))) <= bound
    np.testing.assert_allclose(np.asarray(res), np.asarray(v - out),
                               atol=1e-7)
    assert c.bytes_on_wire(513) == 513 + 4


def test_sign1bit_structure_and_bytes():
    c = make_compressor(CompressionConfig("sign1bit"))
    v = jax.random.normal(jax.random.PRNGKey(2), (100,))
    out, _ = c.compress(v)
    scale = float(jnp.mean(jnp.abs(v)))
    # every entry is +/- mean|v| (or 0 where v == 0)
    np.testing.assert_allclose(
        np.asarray(jnp.abs(out)[v != 0]), scale, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(jnp.sign(out)),
                                  np.asarray(jnp.sign(v)))
    assert c.bytes_on_wire(100) == math.ceil(100 / 8) + 4


def test_topk_keeps_largest_and_bytes():
    c = make_compressor(CompressionConfig("topk", topk_frac=0.1))
    v = jnp.arange(1.0, 51.0)  # 50 entries, top-5 are 46..50
    out, res = c.compress(v)
    nz = np.flatnonzero(np.asarray(out))
    assert set(nz.tolist()) == {45, 46, 47, 48, 49}
    np.testing.assert_allclose(np.asarray(out)[nz], np.asarray(v)[nz])
    np.testing.assert_allclose(np.asarray(res), np.asarray(v - out))
    assert c.bytes_on_wire(50) == 8 * 5
    with pytest.raises(ValueError):
        make_compressor(CompressionConfig("topk", topk_frac=0.0))


def test_compression_config_hashable_and_flags():
    assert hash(CompressionConfig("int8")) == hash(CompressionConfig("int8"))
    assert not CompressionConfig("none").active
    assert CompressionConfig("int8").active
    assert CompressionConfig("int8").uses_ef
    assert not CompressionConfig("int8", error_feedback=False).uses_ef
    assert not CompressionConfig("none").uses_ef


# -- EF state + engine wire behaviour ---------------------------------------


def test_init_ef_shapes_and_none():
    tree = {"a": jnp.ones((4, 3)), "b": jnp.ones((4,))}
    assert init_ef(CompressionConfig("none"), x=tree) is None
    assert init_ef(CompressionConfig("int8", error_feedback=False),
                   x=tree) is None
    ef = init_ef(CompressionConfig("int8"), x=tree, u=tree)
    assert set(ef) == {"x", "u"}
    assert set(ef["x"]) == {"e", "ref"}
    for leaf in jax.tree_util.tree_leaves(ef):
        assert leaf.dtype == jnp.float32
        assert np.all(np.asarray(leaf) == 0.0)


def test_warmup_keeps_residual_exactly_zero():
    eng = DenseEngine(_spec(), compression=CompressionConfig(
        "sign1bit", compress_after=5))
    tree = jax.random.normal(jax.random.PRNGKey(3), (4, 33))
    z = jnp.zeros((4, 33), jnp.float32)
    ef = {"e": z, "ref": z}
    ref = DenseEngine(_spec()).mix(tree)
    # inside warmup: exact mix, residual still exactly zero, public copy
    # tracks the iterate exactly
    mixed, ef_new = eng.mix_ef(tree, ef, t=jnp.asarray(2))
    np.testing.assert_allclose(np.asarray(mixed), np.asarray(ref),
                               atol=1e-6)
    assert np.all(np.asarray(ef_new["e"]) == 0.0)
    np.testing.assert_array_equal(np.asarray(ef_new["ref"]),
                                  np.asarray(tree))
    # past warmup: compression engages, residual becomes nonzero
    mixed2, ef2 = eng.mix_ef(tree, ef, t=jnp.asarray(5))
    assert float(jnp.max(jnp.abs(ef2["e"]))) > 0.0
    assert float(jnp.max(jnp.abs(mixed2 - ref))) > 0.0


def test_ef_accumulates_quantization_error():
    """Transmitting the same v twice with EF: c1 + c2 = 2v - r2, so the
    cumulative transmission error is one residual — strictly smaller
    than the no-feedback error 2*||v - c1|| of repeating c1."""
    c = make_compressor(CompressionConfig("sign1bit"))
    v = jax.random.normal(jax.random.PRNGKey(4), (512,))
    c1, r1 = c.compress(v)
    c2, r2 = c.compress(v + r1)
    np.testing.assert_allclose(np.asarray(c1 + c2), np.asarray(2 * v - r2),
                               atol=1e-5)
    assert (float(jnp.linalg.norm(r2))
            < 2 * float(jnp.linalg.norm(v - c1)))


def test_communication_interval_skips_and_freezes_residual():
    eng = DenseEngine(_spec(), compression=CompressionConfig("int8"),
                      communication_interval=3)
    tree = jax.random.normal(jax.random.PRNGKey(5), (4, 17))
    z = jnp.zeros((4, 17), jnp.float32)
    ef = {"e": z, "ref": z}
    ref = DenseEngine(_spec()).mix(tree)
    # t = 1: skip step -> identity, wire state frozen (nothing sent)
    mixed, ef_new = eng.mix_ef(tree, ef, t=jnp.asarray(1))
    np.testing.assert_array_equal(np.asarray(mixed), np.asarray(tree))
    assert np.all(np.asarray(ef_new["e"]) == 0.0)
    assert np.all(np.asarray(ef_new["ref"]) == 0.0)
    # t = 3: comm step -> compressed mix, wire state updates
    mixed3, ef3 = eng.mix_ef(tree, ef, t=jnp.asarray(3))
    assert float(jnp.max(jnp.abs(mixed3 - tree))) > 0.0
    bound = float(jnp.max(jnp.abs(tree))) / 127.0 + 1e-6
    assert float(jnp.max(jnp.abs(mixed3 - ref))) <= bound
    assert float(jnp.max(jnp.abs(ef3["e"]))) > 0.0
    with pytest.raises(ValueError):
        DenseEngine(_spec(), communication_interval=0)


def test_bytes_on_wire_per_tree():
    tree = {"a": jnp.ones((37, 5)), "b": jnp.ones((131,))}
    size = 37 * 5 + 131
    assert DenseEngine(_spec()).bytes_on_wire(tree) == 4 * size
    eng = DenseEngine(_spec(), compression=CompressionConfig("sign1bit"))
    assert eng.bytes_on_wire(tree) == math.ceil(size / 8) + 4


def test_cumulative_wire_bytes_schedule():
    comp = CompressionConfig("sign1bit", compress_after=2)
    size = 800
    cum = cumulative_wire_bytes(comp, size, num_steps=6, comms_per_step=2,
                                communication_interval=2)
    assert len(cum) == 7 and cum[0] == 0
    full = 2 * 4 * size
    small = 2 * (math.ceil(size / 8) + 4)
    # t=0 comm (warmup, full), t=1 skip, t=2 comm (compressed), t=3 skip...
    assert cum[1] - cum[0] == full
    assert cum[2] == cum[1]
    assert cum[3] - cum[2] == small
    assert cum[4] == cum[3]
    # uncompressed config: every step full
    cum0 = cumulative_wire_bytes(CompressionConfig("none"), size, 3)
    assert cum0 == [0, full, 2 * full, 3 * full]


# -- end-to-end solver sanity ------------------------------------------------


def test_solver_state_carries_ef_and_converges():
    from repro.solvers import SolverConfig, solve
    kw = dict(num_steps=25, record_every=5, num_agents=4, n_per_agent=60)
    ref = solve(SolverConfig(algo="interact", alpha=0.05, beta=0.05), **kw)
    comp = solve(SolverConfig(algo="interact", alpha=0.05, beta=0.05,
                              compression=CompressionConfig("sign1bit")),
                 **kw)
    assert ref.state.ef is None
    assert set(comp.state.ef) == {"x", "u"}
    # EF keeps the compressed run in the same stationarity ballpark
    assert comp.trace[-1] < 10.0 * ref.trace[-1] + 1e-3
    # and both actually make progress from the shared init
    assert comp.trace[-1] < comp.trace[0]
    # per-round wire bytes shrink by > 8x
    assert ref.bytes_per_round / comp.bytes_per_round > 8.0


def test_dsgd_carries_x_only_ef():
    from repro.solvers import SolverConfig, solve
    res = solve(SolverConfig(algo="d-sgd", alpha=0.05, beta=0.05,
                             compression=CompressionConfig("int8")),
                num_steps=5, record_every=0, num_agents=4, n_per_agent=40)
    assert set(res.state.ef) == {"x"}
