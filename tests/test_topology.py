"""The time-varying topology subsystem (docs/TOPOLOGY.md).

Contracts:

* Every matrix realized by every registered process is symmetric,
  doubly stochastic and nonnegative (``validate_mixing``) after the
  link-drop / straggler self-loop repair, and the edge mask is a
  symmetric off-diagonal subset of the base adjacency.
* Schedules are bit-reproducible from the seed, a longer period is a
  strict prefix extension, and ``p = 0`` reproduces the base matrix
  bitwise — so the static process is a no-op through the whole solver.
* The per-call ``matrix=`` operand agrees across dense / pallas /
  ppermute backends, and the sweep engine batches a failure-rate x
  seed grid into one dispatch (with an actionable error anywhere a
  stream cannot be a traced operand).
* Wire accounting prices per link: a dropped link ships zero bytes.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.consensus import DenseEngine, PallasEngine
from repro.consensus.compress import CompressionConfig
from repro.core import (
    HypergradConfig,
    MLPMetaProblem,
    erdos_renyi_adjacency,
    init_head,
    init_mlp_backbone,
    laplacian_mixing,
    make_synthetic_agents,
    validate_mixing,
)
from repro.sharding.collectives import permute_schedule
from repro.solvers import SolverConfig, expand_grid, make_solver, sweep
from repro.topology import (
    AdaptiveTopology,
    PermuteStreamTopology,
    StreamTopology,
    TopologyProcessConfig,
    adaptive_mixing,
    adjacency_of,
    attach_topology,
    available_topology_processes,
    make_topology_process,
    masked_mixing,
    realize_stream,
    stream_of,
    stream_wire_bytes,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

M = 6
SPEC = laplacian_mixing(erdos_renyi_adjacency(M, 0.5, seed=11))
STREAM_KINDS = ("static", "link-failure", "straggler", "random-gossip")


def _stream(kind, p=0.35, seed=3, steps=12, spec=SPEC, **kw):
    cfg = TopologyProcessConfig(kind=kind, p=p, **kw)
    return realize_stream(cfg, spec, seed, num_steps=steps)


# -- process properties ----------------------------------------------------

@pytest.mark.parametrize("kind", STREAM_KINDS)
def test_every_realized_matrix_is_a_valid_mixing(kind):
    """Section-4.1 properties hold for every step of every process."""
    adj = adjacency_of(SPEC)
    s = _stream(kind)
    assert s.matrices.shape == (12, M, M)
    for t in range(s.num_steps):
        mat, mask = s.matrices[t], s.edge_mask[t]
        validate_mixing(mat, adj)
        assert (mat >= 0).all()
        assert not mask.diagonal().any()
        assert (mask == mask.T).all()
        assert not (mask & (adj <= 0)).any()   # subset of the base graph


def test_masked_mixing_repair_any_symmetric_mask():
    """The repair rule is valid for arbitrary symmetric drops, and a
    no-drop mask reproduces the base bitwise (exact +0.0 diagonal)."""
    base = SPEC.matrix
    rng = np.random.default_rng(0)
    for _ in range(10):
        up = np.triu(rng.random((M, M)) < 0.5, k=1)
        keep = up | up.T
        validate_mixing(masked_mixing(base, keep), adjacency_of(SPEC))
        assert (masked_mixing(base, keep) >= 0).all()
    full = ~np.eye(M, dtype=bool)
    assert (masked_mixing(base, full) == base).all()


@pytest.mark.parametrize("kind", ["link-failure", "straggler"])
def test_p_zero_reproduces_base_bitwise(kind):
    s = _stream(kind, p=0.0)
    assert (s.matrices == SPEC.matrix[None]).all()
    assert (s.edge_mask == (adjacency_of(SPEC) > 0)[None]).all()


def test_streams_bit_reproducible_and_prefix_stable():
    """Step t depends only on (seed, t): same seed -> identical stream,
    longer stream -> strict prefix, different seed -> different draws."""
    a = _stream("link-failure", seed=5, steps=8)
    b = _stream("link-failure", seed=5, steps=8)
    assert (a.matrices == b.matrices).all()
    assert (a.edge_mask == b.edge_mask).all()
    longer = _stream("link-failure", seed=5, steps=16)
    assert (longer.matrices[:8] == a.matrices).all()
    other = _stream("link-failure", seed=6, steps=8)
    assert not (other.edge_mask == a.edge_mask).all()


def test_gossip_rounds_are_matchings():
    """At most one partner per agent per round; matched pairs average."""
    s = _stream("random-gossip", seed=1, steps=20)
    for t in range(s.num_steps):
        deg = s.edge_mask[t].sum(axis=1)
        assert deg.max() <= 1
        mat = s.matrices[t]
        for i, j in np.argwhere(s.edge_mask[t]):
            assert mat[i, j] == 0.5 and mat[i, i] == 0.5


def test_stream_padding_ghosts_are_identity_rows():
    s = _stream("link-failure", seed=2, steps=4)
    p = s.padded(9)
    assert (p.matrices[:, :M, :M] == s.matrices).all()
    assert (p.matrices[:, M:, :] == np.eye(9)[None, M:, :]).all()
    assert not p.edge_mask[:, M:, :].any()
    with pytest.raises(ValueError, match="cannot pad"):
        s.padded(3)
    assert 0.0 <= p.mean_spectral_gap <= 1.0


def test_registry_and_config_validation():
    assert set(STREAM_KINDS) <= set(available_topology_processes())
    with pytest.raises(ValueError, match="unknown topology process"):
        make_topology_process(TopologyProcessConfig(kind="smoke-signals"))
    with pytest.raises(ValueError, match="p must be in"):
        TopologyProcessConfig(kind="link-failure", p=1.5)
    with pytest.raises(ValueError, match="period must be"):
        TopologyProcessConfig(period=0)
    with pytest.raises(ValueError, match="tau must be"):
        TopologyProcessConfig(tau=0.0)
    with pytest.raises(ValueError, match="state-dependent"):
        realize_stream(TopologyProcessConfig(kind="adaptive"), SPEC, 0)


def test_wire_bytes_priced_per_link():
    """p = 0 prices every base link each round; all-dropped rounds are
    free; the totals compose with the communication interval."""
    size = 100
    links = int(adjacency_of(SPEC).sum())        # directed link count
    s0 = _stream("link-failure", p=0.0, steps=4)
    got = stream_wire_bytes(s0, None, size, 4)
    assert got == [2 * 4 * size * links * t for t in range(5)]
    dead = _stream("straggler", p=1.0, steps=4)
    assert stream_wire_bytes(dead, None, size, 4) == [0] * 5
    every2 = stream_wire_bytes(s0, CompressionConfig(), size, 4,
                               communication_interval=2)
    assert every2[-1] == got[-1] // 2


# -- in-scan runtimes ------------------------------------------------------

def test_adaptive_mixing_properties():
    """Symmetric, rows sum to 1, nonnegative, base-graph sparsity — and
    a zero adjacency row (a ghost-padded agent) yields an identity row."""
    adj = adjacency_of(SPEC)
    x2d = jax.random.normal(jax.random.PRNGKey(0), (M, 7))
    w = np.asarray(adaptive_mixing(x2d, jnp.asarray(adj, jnp.float32),
                                   tau=1.0), np.float64)
    validate_mixing(w, adj, atol=1e-5)
    assert (w >= -1e-7).all()
    ghost_adj = adj.copy()
    ghost_adj[-1, :] = ghost_adj[:, -1] = 0.0
    wg = np.asarray(adaptive_mixing(x2d, jnp.asarray(ghost_adj,
                                                     jnp.float32), 1.0))
    np.testing.assert_allclose(wg[-1], np.eye(M)[-1], atol=1e-6)


def test_adaptive_topology_needs_the_iterates():
    topo = AdaptiveTopology(adjacency_of(SPEC), tau=1.0)
    with pytest.raises(ValueError, match="adaptive topology"):
        topo.matrix_at(0, None)


def test_attach_topology_static_is_a_noop():
    eng = DenseEngine(SPEC)
    attach_topology(eng, TopologyProcessConfig(), SPEC, seed=0)
    assert eng.topology is None and stream_of(eng) is None
    assert eng.topology_matrix(None) is None    # no t needed when static


def test_stream_topology_wraps_by_period():
    s = _stream("link-failure", seed=4, steps=3)
    topo = StreamTopology(s.matrices)
    np.testing.assert_array_equal(np.asarray(topo.matrix_at(5)),
                                  np.asarray(topo.matrix_at(2)))
    eng = DenseEngine(SPEC)
    attach_topology(eng, TopologyProcessConfig(kind="link-failure", p=0.3,
                                               period=3), SPEC, seed=4)
    with pytest.raises(ValueError, match="step index"):
        eng.mix_ef({"w": jnp.zeros((M, 2))}, None, None)


def test_permute_stream_weights_match_matrices():
    sched = permute_schedule(SPEC)
    s = _stream("link-failure", seed=7, steps=5)
    topo = PermuteStreamTopology(sched, s.matrices)
    idx = np.arange(M)
    for t in (0, 3):
        pw = topo.matrix_at(t)
        np.testing.assert_allclose(np.asarray(pw.self_weights),
                                   s.matrices[t].diagonal(), atol=1e-6)
        for k, o in enumerate(sched.offsets):
            np.testing.assert_allclose(
                np.asarray(pw.weights)[k],
                s.matrices[t][idx, (idx + o) % M], atol=1e-6)


def test_permute_stream_rejects_stray_edges():
    """A stream placing weight off the base offsets cannot share the
    base ppermute schedule — it must fail loudly, not mix wrongly."""
    from repro.core import ring_mixing
    ring = ring_mixing(M)
    with pytest.raises(ValueError, match="outside the base schedule"):
        PermuteStreamTopology(permute_schedule(ring),
                              _stream("link-failure", p=0.0).matrices)


def test_adaptive_on_ppermute_raises():
    from repro.consensus import PermuteEngine
    eng = PermuteEngine(SPEC, agent_axes=("data",))
    with pytest.raises(ValueError, match="dense or pallas"):
        attach_topology(eng, TopologyProcessConfig(kind="adaptive"),
                        SPEC, seed=0)


# -- cross-backend parity --------------------------------------------------

def _tree(key, m=M):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (m, 11, 3)),
            "b": jax.random.normal(k2, (m, 17))}


def test_dense_and_pallas_agree_under_stream():
    """The fused pallas step resolves the same per-step matrix as the
    dense reference when both carry the same realized stream."""
    proc = TopologyProcessConfig(kind="link-failure", p=0.4, period=8)
    dense = attach_topology(DenseEngine(SPEC), proc, SPEC, seed=9)
    pallas = attach_topology(PallasEngine(SPEC, interpret=True), proc,
                             SPEC, seed=9)
    x = _tree(jax.random.PRNGKey(0))
    u = jax.tree_util.tree_map(lambda l: 0.5 * l, x)
    p = jax.tree_util.tree_map(lambda l: 0.1 * l, x)
    for t in (0, 3, 7):
        xd, ud = dense.step1_step3(x, u, p, p, 0.3, t=t)
        xp, up = pallas.step1_step3(x, u, p, p, 0.3, t=t)
        for a, b in zip(jax.tree_util.tree_leaves((xd, ud)),
                        jax.tree_util.tree_leaves((xp, up))):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)
    # the stream genuinely varies: step 0 and step 3 matrices differ
    st = stream_of(dense)
    assert not (st.matrices[0] == st.matrices[3]).all()


def run_in_subprocess(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_ppermute_matches_dense_under_stream():
    """The shared-offset-schedule form (per-step PermuteWeights) mixes
    identically to the dense gather of the same stream, on 8 forced
    host devices."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.consensus import DenseEngine, PermuteEngine
        from repro.core import erdos_renyi_adjacency, laplacian_mixing
        from repro.sharding.compat import shard_map, set_mesh
        from repro.topology import TopologyProcessConfig, attach_topology

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.5, seed=11))
        proc = TopologyProcessConfig(kind="link-failure", p=0.4, period=12)
        dense = attach_topology(DenseEngine(spec), proc, spec, seed=9)
        eng = attach_topology(PermuteEngine(spec, agent_axes=("data",)),
                              proc, spec, seed=9)
        tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (m, 13, 3)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (m, 29))}
        for t in (0, 3, 7, 11):
            fn = shard_map(
                lambda tr: eng.mix(tr, matrix=eng.topology.matrix_at(t)),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                axis_names={"data"}, check_vma=False)
            with set_mesh(mesh):
                got = jax.jit(fn)(tree)
            want = dense.mix(tree, matrix=dense.topology.matrix_at(t))
            for a, b in zip(jax.tree_util.tree_leaves(got),
                            jax.tree_util.tree_leaves(want)):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           atol=1e-5)
        print("STREAM_BACKENDS_OK")
    """)
    assert "STREAM_BACKENDS_OK" in out


# -- solver + sweep integration -------------------------------------------

@pytest.fixture(scope="module")
def setup():
    m = 4
    data = make_synthetic_agents(jax.random.PRNGKey(0), num_agents=m,
                                 n_per_agent=60, d_in=8, num_classes=3)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=8)
    y0 = init_head(jax.random.PRNGKey(2), 8, 3)
    spec = laplacian_mixing(erdos_renyi_adjacency(m, 0.8, seed=3))
    hg = HypergradConfig(method="cg", cg_iters=8)
    return prob, x0, y0, data, spec, hg


def _config(setup, **kw):
    _, _, _, _, spec, hg = setup
    base = dict(algo="interact", alpha=0.1, beta=0.1, batch_size=6, q=5,
                mixing=spec, hypergrad=hg, seed=7)
    base.update(kw)
    return SolverConfig(**base)


def test_static_process_is_bitwise_noop_through_solver(setup):
    prob, x0, y0, data, _, hg = setup
    traces = []
    for proc in (TopologyProcessConfig(),
                 TopologyProcessConfig(kind="static", p=0.0)):
        solver = make_solver(_config(setup, topology_process=proc))
        state = solver.init(None, prob, hg, x0, y0, data)
        _, tr = solver.run_traced(state, data, 4, 2, None)
        traces.append(np.asarray(tr))
    np.testing.assert_array_equal(traces[0], traces[1])


def test_solver_backends_agree_under_link_failure(setup):
    """End-to-end: dense and pallas solvers walk the same perturbed
    trajectory when the config carries a link-failure process."""
    prob, x0, y0, data, _, hg = setup
    proc = TopologyProcessConfig(kind="link-failure", p=0.3, period=8)
    finals = []
    for backend in ("dense", "pallas"):
        solver = make_solver(_config(setup, topology_process=proc,
                                     backend=backend))
        state = solver.init(None, prob, hg, x0, y0, data)
        state, _ = solver.run_traced(state, data, 3, 0, None)
        finals.append([np.asarray(l) for l in
                       jax.tree_util.tree_leaves(state.x)])
    for a, b in zip(*finals):
        np.testing.assert_allclose(a, b, atol=5e-5)


def test_static_key_groups_failure_rates_not_kinds(setup):
    lf = lambda p: TopologyProcessConfig(kind="link-failure", p=p)
    a = _config(setup, topology_process=lf(0.0))
    b = _config(setup, topology_process=lf(0.3), seed=8)
    c = _config(setup, topology_process=TopologyProcessConfig(
        kind="random-gossip"))
    assert a.static_key() == b.static_key()     # p/seed batch together
    assert a.static_key() != c.static_key()     # kind splits the group
    assert a.static_key() != _config(setup).static_key()


def test_sweep_batches_failure_grid_and_p0_matches_static(setup):
    """p x seed in ONE dispatch; the p = 0 row is bitwise the static
    baseline row; ``trace_of`` disambiguates rows that differ only in
    the process realization."""
    prob, x0, y0, data, _, _ = setup
    base = sweep([_config(setup)], 4, 2, problem=prob, x0=x0, y0=y0,
                 data=data)
    lf = lambda p: TopologyProcessConfig(kind="link-failure", p=p,
                                         period=4)
    configs = expand_grid(_config(setup),
                          topology_process=(lf(0.0), lf(0.5)),
                          seed=(7, 8))
    res = sweep(configs, 4, 2, problem=prob, x0=x0, y0=y0, data=data)
    assert res.num_dispatches == 1
    np.testing.assert_array_equal(res.traces[0], base.traces[0])
    assert not np.array_equal(res.traces[0], res.traces[2])  # p bites
    np.testing.assert_array_equal(res.trace_of(configs[2]),
                                  res.traces[2])
    np.testing.assert_array_equal(res.trace_of(configs[0]),
                                  res.traces[0])


def test_sweep_mixed_streams_off_dense_raise_actionably(setup):
    """pallas cannot take the stream as a traced vmap operand — mixing
    realizations there must name the offending configs, not silently
    run them all on one stream."""
    prob, x0, y0, data, _, _ = setup
    lf = lambda p: TopologyProcessConfig(kind="link-failure", p=p)
    configs = [_config(setup, backend="pallas", topology_process=lf(p))
               for p in (0.1, 0.4)]
    with pytest.raises(ValueError, match=r"configs\[1\].*p=0\.4"):
        sweep(configs, 3, 0, problem=prob, x0=x0, y0=y0, data=data)


def test_sweep_single_stream_bakes_on_pallas(setup):
    """One shared (p, seed) realization needs no traced operand: the
    pallas group bakes the stream and still batches the seeds."""
    prob, x0, y0, data, _, _ = setup
    proc = TopologyProcessConfig(kind="link-failure", p=0.3, seed=5,
                                 period=4)
    configs = [_config(setup, backend="pallas", topology_process=proc,
                       seed=s) for s in (7, 8)]
    res = sweep(configs, 3, 0, problem=prob, x0=x0, y0=y0, data=data)
    assert res.num_dispatches == 1
    assert all(np.isfinite(t).all() for t in res.traces)
