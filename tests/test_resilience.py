"""Fault-tolerance tests (docs/RESILIENCE.md): bitwise kill/resume for
every registry solver, the chaos fault harness, and self-healing sweeps.

The bitwise contract under test: a run killed at an arbitrary step and
resumed from its newest valid snapshot reproduces the uninterrupted
``run_traced`` metric trace bit for bit — dense backend in-process, the
ppermute backend through the distributed train step in an 8-device
subprocess (ppermute is mesh-native; it only runs under shard_map).
"""
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import numpy as np
import pytest

from repro.consensus import CompressionConfig
from repro.core import (
    HypergradConfig,
    MLPMetaProblem,
    convergence_metric_fn,
    erdos_renyi_adjacency,
    init_head,
    init_mlp_backbone,
    laplacian_mixing,
    make_synthetic_agents,
)
from repro.resilience import (
    FaultPlan,
    NonFiniteStateError,
    SimulatedKill,
    available_faults,
    chaos_run,
    make_fault,
    register_fault,
    resume,
    resume_run,
    run_resumable,
    snapshot,
)
from repro.solvers import SolverConfig, available_solvers, make_solver, sweep

M, N, BATCH, Q, SEED = 4, 60, 6, 5, 7
ITERS, REC = 12, 3
CKPT_EVERY = 5          # co-prime with REC: boundaries never align
KILL_AT = 7             # mid-chunk — the hard case

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    data = make_synthetic_agents(key, num_agents=M, n_per_agent=N,
                                 d_in=8, num_classes=3)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=8)
    y0 = init_head(jax.random.PRNGKey(2), 8, 3)
    spec = laplacian_mixing(erdos_renyi_adjacency(M, 0.5, seed=3))
    hg = HypergradConfig(method="cg", cg_iters=8)
    metric = convergence_metric_fn(prob, hg, data, inner_steps=40)
    return data, prob, x0, y0, spec, hg, metric


def _config(setup, algo, **overrides):
    _, _, _, _, spec, hg, _ = setup
    kw = dict(algo=algo, alpha=0.3, beta=0.3, batch_size=BATCH, q=Q,
              mixing=spec, hypergrad=hg, seed=SEED)
    kw.update(overrides)
    return SolverConfig(**kw)


def _fresh(setup, cfg):
    data, prob, x0, y0, _, _, _ = setup
    solver = make_solver(cfg)
    return solver, solver.init(None, prob, None, x0, y0, data)


def _ref_trace(setup, cfg):
    data, _, _, _, _, _, metric = setup
    solver, state = _fresh(setup, cfg)
    _, ref = solver.run_traced(state, data, ITERS, REC, metric)
    return np.asarray(jax.device_get(ref))


def _kill_then_resume(setup, cfg):
    """Kill at KILL_AT, resume from disk, return the stitched trace."""
    data, prob, x0, y0, _, _, metric = setup
    with tempfile.TemporaryDirectory() as ckpt:
        plan = FaultPlan([make_fault("kill", step=KILL_AT)], seed=0)
        solver, state = _fresh(setup, cfg)
        with pytest.raises(SimulatedKill):
            run_resumable(solver, state, data, ITERS, REC, metric,
                          checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt,
                          hooks=plan)
        # the kill landed at boundary 10, before its snapshot: only the
        # step-5 checkpoint may exist, so steps 5..12 get replayed
        rs = resume(cfg, ckpt, problem=prob, x0=x0, y0=y0, data=data)
        assert rs is not None and rs.step == CKPT_EVERY
        _, _, trace = resume_run(cfg, ckpt, ITERS, REC, metric,
                                 checkpoint_every=CKPT_EVERY,
                                 problem=prob, x0=x0, y0=y0, data=data)
    return np.asarray(trace)


@pytest.mark.parametrize("algo", sorted(available_solvers()))
def test_kill_resume_bitwise_dense(setup, algo):
    cfg = _config(setup, algo)
    ref = _ref_trace(setup, cfg)
    trace = _kill_then_resume(setup, cfg)
    assert trace.dtype == ref.dtype and trace.shape == ref.shape
    assert trace.tobytes() == ref.tobytes()


def test_kill_resume_bitwise_compressed_ef(setup):
    """The EF wire state {e, ref} rides in the carry: resume must
    restore it or the compressed trajectory forks."""
    cfg = _config(setup, "interact",
                  compression=CompressionConfig(kind="sign1bit",
                                                error_feedback=True))
    ref = _ref_trace(setup, cfg)
    trace = _kill_then_resume(setup, cfg)
    assert trace.tobytes() == ref.tobytes()


def test_ppermute_checkpoint_resume_bitwise():
    """ppermute parity runs through the distributed train step (the one
    end-to-end ppermute path; the engine requires shard_map), in a
    subprocess with 8 forced host devices: 4 uninterrupted steps vs
    2 steps -> checkpoint round-trip -> 2 steps must match bitwise."""
    code = textwrap.dedent("""
        import tempfile
        import jax, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import latest_step, restore_step, save_step
        from repro.configs import get_config
        from repro.sharding.compat import set_mesh
        from repro.sharding.partition import tree_shardings
        from repro.train.bilevel_lm import BilevelHyper
        from repro.train.step import (InteractConfig, init_train_state,
                                      make_train_step, train_state_specs)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("smollm-360m").reduced(
            vocab_size=128, num_layers=2, dtype="float32")
        hyper = BilevelHyper(mu_g=0.5, neumann_k=2, lipschitz_g=4.0,
                             ce_chunk=16, remat=False)
        icfg = InteractConfig(alpha=0.05, beta=0.3, hyper=hyper)
        m = 4
        state0 = init_train_state(cfg, jax.random.PRNGKey(0), m)
        shards = tree_shardings(mesh, train_state_specs(state0, mesh))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (m, 4, 32), 0,
                                    cfg.vocab_size)
        step = make_train_step(cfg, mesh, icfg)

        def advance(state, n):
            dstate = jax.device_put(state, shards)
            dtok = jax.device_put(tokens, NamedSharding(mesh, P("data")))
            with set_mesh(mesh):
                jstep = jax.jit(step)
                for _ in range(n):
                    dstate, _ = jstep(dstate, dtok)
            return jax.device_get(dstate)

        ref = advance(state0, 4)
        with tempfile.TemporaryDirectory() as d:
            mid = advance(state0, 2)
            save_step(d, 2, mid)
            assert latest_step(d) == 2
            restored = restore_step(d, 2, mid)
            got = advance(restored, 2)
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(got)):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
        print("PPERMUTE_RESUME_OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "PPERMUTE_RESUME_OK" in proc.stdout


# -- snapshot/resume edge cases -----------------------------------------


def test_resume_skips_corrupt_newest_snapshot(setup):
    data, prob, x0, y0, _, _, metric = setup
    cfg = _config(setup, "interact")
    solver, state = _fresh(setup, cfg)
    with tempfile.TemporaryDirectory() as ckpt:
        run_resumable(solver, state, data, ITERS, REC, metric,
                      checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt)
        # snapshots at 5, 10, 12; damage the newest archive
        newest = os.path.join(ckpt, f"step_{ITERS:08d}.npz")
        size = os.path.getsize(newest)
        with open(newest, "r+b") as fh:
            fh.truncate(size // 3)
        rs = resume(cfg, ckpt, problem=prob, x0=x0, y0=y0, data=data)
        assert rs is not None
        assert rs.step == 10   # newest *valid* snapshot
        assert int(np.asarray(rs.state.t)) == 10


def test_resume_refuses_wrong_config(setup):
    data, prob, x0, y0, _, _, _ = setup
    cfg = _config(setup, "interact")
    solver, state = _fresh(setup, cfg)
    with tempfile.TemporaryDirectory() as ckpt:
        run_resumable(solver, state, data, CKPT_EVERY,
                      checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt)
        other = _config(setup, "interact", alpha=0.11)
        with pytest.raises(ValueError, match="different config"):
            resume(other, ckpt, problem=prob, x0=x0, y0=y0, data=data)
        assert resume(other, ckpt, problem=prob, x0=x0, y0=y0,
                      data=data, strict=False) is None


def test_resume_empty_dir(setup):
    data, prob, x0, y0, _, _, _ = setup
    cfg = _config(setup, "interact")
    with tempfile.TemporaryDirectory() as ckpt:
        assert resume(cfg, ckpt, problem=prob, x0=x0, y0=y0,
                      data=data) is None
        with pytest.raises(ValueError, match="num_steps"):
            resume_run(cfg, ckpt, checkpoint_every=CKPT_EVERY,
                       problem=prob, x0=x0, y0=y0, data=data)


def test_nan_payload_detected_before_snapshot(setup):
    """A poisoned chunk must raise and must NOT land on disk."""
    data, _, _, _, _, _, metric = setup
    cfg = _config(setup, "interact")
    solver, state = _fresh(setup, cfg)
    plan = FaultPlan([make_fault("nan-payload", step=2)], seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        with pytest.raises(NonFiniteStateError):
            run_resumable(solver, state, data, ITERS, REC, metric,
                          checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt,
                          hooks=plan)
        assert not [f for f in os.listdir(ckpt) if f.endswith(".npz")]


def test_write_failure_absorbed_by_snapshot_retry(setup):
    data, _, _, _, _, _, metric = setup
    cfg = _config(setup, "interact")
    solver, state = _fresh(setup, cfg)
    plan = FaultPlan([make_fault("write-failure", step=0, count=2)],
                     seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        run_resumable(solver, state, data, CKPT_EVERY, REC, metric,
                      checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt,
                      hooks=plan, backoff=0.001)
        assert plan.count("write-failure") == 2   # retried, then landed
        assert os.path.exists(
            os.path.join(ckpt, f"step_{CKPT_EVERY:08d}.npz"))


def test_write_failure_beyond_retry_budget_raises(setup):
    data, _, _, _, _, _, metric = setup
    cfg = _config(setup, "interact")
    solver, state = _fresh(setup, cfg)
    plan = FaultPlan([make_fault("write-failure", step=0, count=10)],
                     seed=0)
    with tempfile.TemporaryDirectory() as ckpt:
        with pytest.raises(OSError):
            run_resumable(solver, state, data, CKPT_EVERY, REC, metric,
                          checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt,
                          hooks=plan, retries=2, backoff=0.001)


def test_fault_registry():
    kinds = available_faults()
    for kind in ("kill", "nan-payload", "corrupt-checkpoint",
                 "stale-checkpoint", "write-failure"):
        assert kind in kinds
    with pytest.raises(ValueError, match="unknown fault"):
        make_fault("fsck")
    with pytest.raises(ValueError, match="already registered"):
        register_fault("kill")(type("Impostor", (), {}))


def test_fault_plan_reset_rearms():
    plan = FaultPlan([make_fault("kill", step=3),
                      make_fault("write-failure", step=0, count=2)],
                     seed=0)
    with pytest.raises(SimulatedKill):
        plan.on_chunk_end(0, 5, None, 10)
    assert plan.on_chunk_end(5, 10, None, 10) is None   # one-shot
    plan.reset()
    assert plan.events == []
    with pytest.raises(SimulatedKill):
        plan.on_chunk_end(0, 5, None, 10)


# -- chaos campaign ------------------------------------------------------


def test_chaos_campaign_completes_bitwise(setup):
    data, prob, x0, y0, _, _, metric = setup
    cfg = _config(setup, "interact")
    ref = _ref_trace(setup, cfg)
    plan = FaultPlan([
        make_fault("kill", step=3),
        make_fault("kill", step=6),
        make_fault("kill", step=9),
        make_fault("nan-payload", step=4),
        make_fault("corrupt-checkpoint", step=6, mode="garbage"),
        make_fault("stale-checkpoint", step=8),
        make_fault("write-failure", step=3, count=2),
    ], seed=1)
    with tempfile.TemporaryDirectory() as ckpt:
        rep = chaos_run(cfg, plan, ITERS, REC,
                        checkpoint_every=CKPT_EVERY, ckpt_dir=ckpt,
                        metric_fn=metric, problem=prob, x0=x0, y0=y0,
                        data=data, backoff=0.001)
    assert rep.completed
    assert rep.kills >= 3
    assert rep.restarts >= 3
    assert rep.nonfinite_faults >= 1
    assert rep.write_retries >= 2
    assert rep.wasted_steps > 0
    assert rep.trace is not None and rep.trace.tobytes() == ref.tobytes()
    assert np.isclose(rep.final_metric, float(ref[-1]),
                      rtol=1e-6, atol=1e-9)


# -- self-healing sweeps -------------------------------------------------


def _sweep_grid(setup):
    return [_config(setup, "interact", alpha=0.2),
            _config(setup, "interact", alpha=0.3),
            _config(setup, "gt-dsgd")]


def test_sweep_resume_recomputes_only_missing_groups(setup):
    data, prob, x0, y0, _, _, metric = setup
    grid = _sweep_grid(setup)
    kw = dict(problem=prob, x0=x0, y0=y0, data=data, metric_fn=metric)
    clean = sweep(grid, ITERS, REC, **kw)
    with tempfile.TemporaryDirectory() as d:
        # mid-grid failure: only the interact group ever completed
        partial = sweep(grid[:2], ITERS, REC, resume_dir=d, **kw)
        assert [g.loaded for g in partial.groups] == [False]
        assert os.path.exists(os.path.join(d, "manifest.json"))
        full = sweep(grid, ITERS, REC, resume_dir=d, **kw)
        assert [g.loaded for g in full.groups] == [True, False]
        again = sweep(grid, ITERS, REC, resume_dir=d, **kw)
        assert [g.loaded for g in again.groups] == [True, True]
    assert full.traces.tobytes() == clean.traces.tobytes()
    assert again.traces.tobytes() == clean.traces.tobytes()


def test_sweep_resume_ignores_foreign_geometry(setup):
    """A manifest written for different sweep geometry must not be
    loaded — every group recomputes under the new fingerprint."""
    data, prob, x0, y0, _, _, metric = setup
    grid = _sweep_grid(setup)[:2]
    kw = dict(problem=prob, x0=x0, y0=y0, data=data, metric_fn=metric)
    with tempfile.TemporaryDirectory() as d:
        sweep(grid, ITERS, REC, resume_dir=d, **kw)
        other = sweep(grid, ITERS + REC, REC, resume_dir=d, **kw)
        assert [g.loaded for g in other.groups] == [False]


def test_sweep_resume_rejects_return_states(setup):
    data, prob, x0, y0, _, _, _ = setup
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(ValueError, match="return_states"):
            sweep(_sweep_grid(setup)[:1], ITERS, 0, problem=prob, x0=x0,
                  y0=y0, data=data, return_states=True, resume_dir=d)


# -- snapshot internals --------------------------------------------------


def test_snapshot_meta_and_padded_roundtrip(setup):
    data, prob, x0, y0, _, _, _ = setup
    cfg = _config(setup, "interact")
    solver, state = _fresh(setup, cfg)
    padded = np.full((ITERS,), np.nan, np.float32)
    padded[:4] = np.arange(4, dtype=np.float32)
    with tempfile.TemporaryDirectory() as ckpt:
        snapshot(solver, state, 0, ckpt, padded=padded,
                 total_steps=ITERS, record_every=REC)
        rs = resume(cfg, ckpt, problem=prob, x0=x0, y0=y0, data=data)
    assert rs.total_steps == ITERS and rs.record_every == REC
    assert rs.padded.tobytes() == padded.tobytes()
    assert rs.meta["algo"] == "interact"
