"""Per-kernel allclose validation against the pure-jnp oracles.

Sweeps shapes/dtypes per the assignment; kernels run in interpret mode on
CPU (the kernel body is the TPU program, executed in Python).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.rwkv6 import ops as wkv_ops, ref as wkv_ref
from repro.kernels.consensus_step import ops as cs_ops, ref as cs_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (batch, seq, heads, kv_heads, head_dim, causal, window, softcap, dtype)
    (2, 256, 4, 2, 64, True, None, None, jnp.float32),
    (1, 256, 8, 1, 128, True, None, None, jnp.float32),     # MQA
    (1, 256, 4, 4, 64, True, 128, None, jnp.float32),       # SWA
    (1, 192, 4, 2, 64, True, None, 50.0, jnp.float32),      # softcap
    (1, 256, 4, 2, 64, True, 64, 30.0, jnp.float32),        # SWA+softcap
    (2, 128, 4, 2, 64, False, None, None, jnp.float32),     # bidirectional
    (1, 200, 4, 2, 64, True, None, None, jnp.float32),      # padded seq
    (1, 256, 2, 2, 256, True, None, None, jnp.bfloat16),    # bf16, hd=256
    (1, 128, 4, 2, 32, True, None, None, jnp.bfloat16),
]


@pytest.mark.parametrize(
    "b,s,nh,nkv,hd,causal,win,cap,dtype", FLASH_CASES)
def test_flash_attention_matches_oracle(b, s, nh, nkv, hd, causal, win, cap,
                                        dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, nkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, nkv, hd), dtype)
    out = fa_ops.flash_attention(q, k, v, causal=causal, window=win,
                                 logit_softcap=cap)
    exp = fa_ref.attention_ref(q, k, v, causal=causal, window=win,
                               logit_softcap=cap)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)


def test_flash_attention_decode_offset():
    """q_offset path: 1 suffix query vs a longer kv prefix (decode)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    skv, hd = 256, 64
    q = jax.random.normal(ks[0], (1, 1, 4, hd))
    k = jax.random.normal(ks[1], (1, skv, 2, hd))
    v = jax.random.normal(ks[2], (1, skv, 2, hd))
    out = fa_ops.flash_attention(q, k, v, causal=True, q_offset=skv - 1)
    exp = fa_ref.attention_ref(q, k, v, causal=True, q_offset=skv - 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_flash_attention_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))
    outs = [
        fa_ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
        for bq, bk in [(64, 64), (128, 128), (32, 128), (128, 32)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    s=st.integers(16, 160),
    nh=st.sampled_from([2, 4]),
    group=st.sampled_from([1, 2]),
    hd=st.sampled_from([32, 64]),
    seed=st.integers(0, 100),
)
def test_flash_attention_property(s, nh, group, hd, seed):
    nkv = nh // group
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, nh, hd))
    k = jax.random.normal(ks[1], (1, s, nkv, hd))
    v = jax.random.normal(ks[2], (1, s, nkv, hd))
    out = fa_ops.flash_attention(q, k, v, causal=True)
    exp = fa_ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=3e-5)


def test_flash_attention_rows_sum_to_convex_combination():
    """Each output row is a convex combination of v rows (softmax weights)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32))
    k = jax.random.normal(ks[1], (1, 64, 2, 32))
    v = jnp.ones((1, 64, 2, 32))
    out = fa_ops.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.ones_like(out), atol=1e-5)


# ---------------------------------------------------------------------------
# rwkv6 / wkv
# ---------------------------------------------------------------------------

WKV_CASES = [
    # (batch, seq, heads, N, chunk, with_state, dtype)
    (2, 128, 2, 16, 32, False, jnp.float32),
    (1, 96, 4, 32, 32, False, jnp.float32),
    (2, 64, 2, 16, 16, True, jnp.float32),
    (1, 100, 2, 16, 32, False, jnp.float32),   # padding
    (1, 1, 2, 16, 32, True, jnp.float32),      # decode-like
    (1, 128, 2, 64, 64, False, jnp.float32),   # full head size
    (1, 64, 2, 16, 32, False, jnp.bfloat16),
]


def _wkv_inputs(b, s, h, n, dtype, seed=0, with_state=False):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (b, s, h, n), dtype)
    k = jax.random.normal(ks[1], (b, s, h, n), dtype)
    v = jax.random.normal(ks[2], (b, s, h, n), dtype)
    w = (jax.nn.sigmoid(jax.random.normal(ks[3], (b, s, h, n)) * 2.0 - 1.0)
         * 0.6 + 0.35).astype(dtype)
    u = (0.3 * jax.random.normal(ks[4], (h, n))).astype(dtype)
    st_ = (0.5 * jax.random.normal(ks[5], (b, h, n, n), jnp.float32)
           if with_state else None)
    return r, k, v, w, u, st_


@pytest.mark.parametrize("b,s,h,n,chunk,with_state,dtype", WKV_CASES)
def test_wkv6_matches_oracle(b, s, h, n, chunk, with_state, dtype):
    r, k, v, w, u, st_ = _wkv_inputs(b, s, h, n, dtype,
                                     with_state=with_state)
    out, sf = wkv_ops.wkv6(r, k, v, w, u, state=st_, chunk=chunk)
    exp, sf_exp = wkv_ref.wkv6_ref(r, k, v, w, u, state=st_)
    tol = 2e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sf_exp),
                               atol=tol, rtol=tol)


def test_wkv6_chunk_invariance():
    r, k, v, w, u, _ = _wkv_inputs(1, 128, 2, 16, jnp.float32, seed=5)
    outs = [wkv_ops.wkv6(r, k, v, w, u, chunk=c)[0] for c in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(o), np.asarray(outs[0]),
                                   atol=2e-4)


def test_wkv6_chained_chunks_equal_single_call():
    """Running two halves with state carry == one full call (prefill
    chunking invariant, used by long-context serving)."""
    r, k, v, w, u, _ = _wkv_inputs(1, 128, 2, 16, jnp.float32, seed=6)
    full, s_full = wkv_ops.wkv6(r, k, v, w, u)
    h1, s1 = wkv_ops.wkv6(r[:, :64], k[:, :64], v[:, :64], w[:, :64], u)
    h2, s2 = wkv_ops.wkv6(r[:, 64:], k[:, 64:], v[:, 64:], w[:, 64:], u,
                          state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], axis=1)),
                               np.asarray(full), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=2e-4)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(2, 80), h=st.sampled_from([1, 2]),
       n=st.sampled_from([8, 16]), seed=st.integers(0, 50))
def test_wkv6_property(s, h, n, seed):
    r, k, v, w, u, _ = _wkv_inputs(1, s, h, n, jnp.float32, seed=seed)
    out, _ = wkv_ops.wkv6(r, k, v, w, u, chunk=32)
    exp, _ = wkv_ref.wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-3)


# ---------------------------------------------------------------------------
# consensus step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,d,dtype", [
    (4, 512, jnp.float32), (8, 700, jnp.float32), (16, 2048, jnp.float32),
    (5, 123, jnp.float32), (8, 512, jnp.bfloat16),
])
def test_consensus_step_matches_oracle(m, d, dtype):
    from repro.core import ring_mixing
    mix = jnp.asarray(ring_mixing(m).matrix, jnp.float32)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    X = jax.random.normal(ks[0], (m, d), dtype)
    U = jax.random.normal(ks[1], (m, d), dtype)
    P = jax.random.normal(ks[2], (m, d), dtype)
    PP = jax.random.normal(ks[3], (m, d), dtype)
    xn, un = cs_ops.consensus_step(mix, X, U, P, PP, alpha=0.3)
    xo, uo = cs_ref.consensus_step_ref(mix, X, U, P, PP, alpha=0.3)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(xn, np.float32),
                               np.asarray(xo, np.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(un, np.float32),
                               np.asarray(uo, np.float32), atol=tol, rtol=tol)


def test_consensus_step_pytree():
    from repro.core import ring_mixing
    m = 6
    mix = jnp.asarray(ring_mixing(m).matrix, jnp.float32)
    key = jax.random.PRNGKey(1)
    tree = {"w": jax.random.normal(key, (m, 13, 7)),
            "b": jax.random.normal(key, (m, 99))}
    u = jax.tree_util.tree_map(lambda l: 0.1 * l, tree)
    p = jax.tree_util.tree_map(lambda l: 0.2 * l, tree)
    pp = jax.tree_util.tree_map(lambda l: 0.3 * l, tree)
    xn, un = cs_ops.consensus_step(mix, tree, u, p, pp, alpha=0.25)
    for key_ in tree:
        X = tree[key_].reshape(m, -1)
        xo, uo = cs_ref.consensus_step_ref(mix, X, u[key_].reshape(m, -1),
                                           p[key_].reshape(m, -1),
                                           pp[key_].reshape(m, -1), alpha=0.25)
        np.testing.assert_allclose(np.asarray(xn[key_].reshape(m, -1)),
                                   np.asarray(xo), atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(un[key_].reshape(m, -1)),
                                   np.asarray(uo), atol=1e-5, rtol=1e-5)


def test_consensus_kernel_in_interact_loop():
    """The fused kernel drives the same trajectory as mix_pytree-based
    INTERACT Step 1+3 (swap-in equivalence)."""
    from repro.core import ring_mixing, mix_pytree
    m = 8
    spec = ring_mixing(m)
    mix = jnp.asarray(spec.matrix, jnp.float32)
    key = jax.random.PRNGKey(2)
    x = {"p": jax.random.normal(key, (m, 50))}
    u = {"p": 0.5 * jax.random.normal(key, (m, 50))}
    p = {"p": 0.1 * jax.random.normal(key, (m, 50))}
    pp = {"p": 0.2 * jax.random.normal(key, (m, 50))}
    for _ in range(3):
        xk, uk = cs_ops.consensus_step(mix, x, u, p, pp, alpha=0.3)
        x_ref = jax.tree_util.tree_map(lambda mx, uu: mx - 0.3 * uu,
                                       mix_pytree(mix, x), u)
        u_ref = jax.tree_util.tree_map(lambda mu, pn, ppp: mu + pn - ppp,
                                       mix_pytree(mix, u), p, pp)
        np.testing.assert_allclose(np.asarray(xk["p"]), np.asarray(x_ref["p"]),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(uk["p"]), np.asarray(u_ref["p"]),
                                   atol=1e-5)
        x, u, pp = xk, uk, p
