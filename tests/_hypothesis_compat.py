"""Minimal vendored fallback for the ``hypothesis`` API this suite uses.

The real hypothesis is preferred (``pip install hypothesis``); when it is
unavailable (offline containers) the test modules fall back to this shim,
which replays a small deterministic set of examples per test instead of
true property-based search: the two boundary corners first, then a few
seeded pseudo-random draws.  Only the API surface the suite touches is
provided: ``given`` (keyword strategies), ``settings(max_examples=...,
deadline=...)``, and ``strategies.integers / floats / sampled_from /
booleans``.
"""
from __future__ import annotations

import inspect
import random
import zlib

_MAX_EXAMPLES_CAP = 5


class _Strategy:
    def low(self):
        raise NotImplementedError

    def high(self):
        raise NotImplementedError

    def draw(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value, max_value):
        self.min_value, self.max_value = int(min_value), int(max_value)

    def low(self):
        return self.min_value

    def high(self):
        return self.max_value

    def draw(self, rng):
        return rng.randint(self.min_value, self.max_value)


class _Floats(_Strategy):
    def __init__(self, min_value, max_value):
        self.min_value, self.max_value = float(min_value), float(max_value)

    def low(self):
        return self.min_value

    def high(self):
        return self.max_value

    def draw(self, rng):
        return rng.uniform(self.min_value, self.max_value)


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def low(self):
        return self.elements[0]

    def high(self):
        return self.elements[-1]

    def draw(self, rng):
        return rng.choice(self.elements)


class _Booleans(_SampledFrom):
    def __init__(self):
        super().__init__([False, True])


class _StrategiesNamespace:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Floats(min_value, max_value)

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)

    @staticmethod
    def booleans():
        return _Booleans()


strategies = _StrategiesNamespace()


def settings(max_examples=None, deadline=None, **_kw):
    """Records max_examples on the (possibly already given-wrapped) test."""
    del deadline

    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn

    return deco


def given(**param_strategies):
    """Replays a fixed example set: both boundary corners, then seeded
    pseudo-random draws (deterministic per test name)."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            declared = getattr(wrapper, "_compat_max_examples", None)
            n = min(declared or _MAX_EXAMPLES_CAP, _MAX_EXAMPLES_CAP)
            rng = random.Random(zlib.crc32(fn.__name__.encode()))
            for i in range(max(n, 1)):
                if i == 0:
                    draws = {k: s.low() for k, s in param_strategies.items()}
                elif i == 1:
                    draws = {k: s.high() for k, s in param_strategies.items()}
                else:
                    draws = {k: s.draw(rng)
                             for k, s in param_strategies.items()}
                fn(*args, **draws, **kwargs)

        # Present a zero-arg signature: the strategy params are filled in
        # here, not by pytest fixtures (functools.wraps would leak them).
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__signature__ = inspect.Signature()
        wrapper.hypothesis_compat_shim = True
        return wrapper

    return deco
