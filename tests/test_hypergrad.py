"""Hypergradient correctness: CG & Neumann vs. analytic quadratic oracle."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (
    HypergradConfig,
    cg_solve,
    hvp_xy,
    hvp_yy,
    hypergradient,
    neumann_inverse_apply,
)


def quad_problem(key, dx=5, dy=4, mu=0.5):
    """Analytic bilevel instance:

      g(x, y) = 0.5 y^T A y + x^T B y        (A symm PD => y*(x) = -A^-1 B^T x)
      f(x, y) = 0.5 ||y - c||^2 + 0.5||x||^2

    True hypergradient:
      l(x) = f(x, y*(x)),  grad l = x + (dy*/dx)^T (y* - c)
           = x - B A^{-1} (y*(x) - c).
    """
    k1, k2, k3 = jax.random.split(key, 3)
    w = jax.random.normal(k1, (dy, dy))
    A = w @ w.T / dy + mu * jnp.eye(dy)
    B = jax.random.normal(k2, (dx, dy)) / np.sqrt(dx)
    c = jax.random.normal(k3, (dy,))

    def g(x, y, _batch=None):
        return 0.5 * y @ A @ y + x @ B @ y

    def f(x, y, _batch=None):
        return 0.5 * jnp.sum((y - c) ** 2) + 0.5 * jnp.sum(x ** 2)

    def true_hypergrad(x):
        y_star = -jnp.linalg.solve(A, B.T @ x)
        return x - B @ jnp.linalg.solve(A, y_star - c), y_star

    return f, g, A, B, true_hypergrad


def test_hvp_yy_matches_matrix():
    f, g, A, B, _ = quad_problem(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5,))
    y = jax.random.normal(jax.random.PRNGKey(2), (4,))
    v = jax.random.normal(jax.random.PRNGKey(3), (4,))
    np.testing.assert_allclose(np.asarray(hvp_yy(g, x, y, v)),
                               np.asarray(A @ v), rtol=1e-5)


def test_hvp_xy_matches_matrix():
    f, g, A, B, _ = quad_problem(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5,))
    y = jax.random.normal(jax.random.PRNGKey(2), (4,))
    v = jax.random.normal(jax.random.PRNGKey(3), (4,))
    np.testing.assert_allclose(np.asarray(hvp_xy(g, x, y, v)),
                               np.asarray(B @ v), rtol=1e-5)


def test_cg_solve_spd():
    _, g, A, _, _ = quad_problem(jax.random.PRNGKey(4))
    b = jax.random.normal(jax.random.PRNGKey(5), (4,))
    x = jax.random.normal(jax.random.PRNGKey(6), (5,))
    y = jnp.zeros((4,))
    z = cg_solve(lambda v: hvp_yy(g, x, y, v), b, iters=50, tol=1e-10)
    np.testing.assert_allclose(np.asarray(z), np.asarray(jnp.linalg.solve(A, b)),
                               rtol=1e-4)


def test_hypergradient_cg_matches_analytic_at_ystar():
    f, g, A, B, truth = quad_problem(jax.random.PRNGKey(7))
    x = jax.random.normal(jax.random.PRNGKey(8), (5,))
    expected, y_star = truth(x)
    cfg = HypergradConfig(method="cg", cg_iters=64, cg_tol=1e-12)
    got = hypergradient(f, g, x, y_star, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected), rtol=1e-4)


def test_neumann_converges_to_cg_with_k():
    """Deterministic Neumann bias shrinks like (1 - mu/L)^K (Lemma 3)."""
    f, g, A, B, truth = quad_problem(jax.random.PRNGKey(9))
    x = jax.random.normal(jax.random.PRNGKey(10), (5,))
    expected, y_star = truth(x)
    L = float(jnp.linalg.eigvalsh(A)[-1]) * 1.05
    errs = []
    for K in (2, 8, 32, 128):
        cfg = HypergradConfig(method="neumann", neumann_k=K, lipschitz_g=L)
        got = hypergradient(f, g, x, y_star, cfg)
        errs.append(float(jnp.linalg.norm(got - expected)))
    assert errs[-1] < 1e-3
    assert errs == sorted(errs, reverse=True)  # monotone in K


def test_stochastic_neumann_unbiased_in_expectation():
    """E_k[(K/L)(I - A/L)^k b] equals the K-term truncated sum.

    The 3000 estimator draws run as ONE ``jit(vmap(...))`` program over a
    stacked key batch.  The original eager per-key loop compiled 3000
    separate executables, which historically crashed XLA's CPU
    backend_compile (SIGSEGV) on jaxlib 0.4.37 and still takes minutes —
    the blanket skip it earned hid the estimator's only unbiasedness
    check.  Root cause was the compile *count*, not the fori_loop body:
    a single compilation of the vmapped estimator is fast and stable.
    Revisit the single-compile workaround if jaxlib moves past 0.4.x.
    """
    _, g, A, _, _ = quad_problem(jax.random.PRNGKey(11))
    b = jax.random.normal(jax.random.PRNGKey(12), (4,))
    x = jnp.zeros((5,))
    y = jnp.zeros((4,))
    L = float(jnp.linalg.eigvalsh(A)[-1]) * 1.1
    K = 6
    det = neumann_inverse_apply(g, x, y, b, k_terms=K, lipschitz_g=L)

    @jax.jit
    def estimate_all(keys):
        one = lambda k: neumann_inverse_apply(
            g, x, y, b, k_terms=K, lipschitz_g=L, stochastic_k=True, key=k)
        return jnp.mean(jax.vmap(one)(keys), axis=0)

    keys = jax.vmap(jax.random.PRNGKey)(jnp.arange(3000))
    mean = estimate_all(keys)
    np.testing.assert_allclose(np.asarray(mean), np.asarray(det),
                               atol=5e-2, rtol=0.1)


def test_hypergradient_pytree_params():
    """Hypergradient works on nested pytrees (the MLP case)."""
    def g(x, y, batch=None):
        (w1, b1) = x[0]
        wy, by = y
        h = jnp.tanh(batch @ w1 + b1)
        return jnp.sum((h @ wy + by) ** 2) / batch.shape[0] + 0.5 * (
            jnp.sum(wy ** 2) + jnp.sum(by ** 2))

    def f(x, y, batch=None):
        (w1, b1) = x[0]
        wy, by = y
        h = jnp.tanh(batch @ w1 + b1)
        return jnp.mean((h @ wy + by - 1.0) ** 2)

    key = jax.random.PRNGKey(13)
    batch = jax.random.normal(key, (32, 6))
    x = [(jax.random.normal(jax.random.PRNGKey(14), (6, 8)) * 0.3,
          jnp.zeros((8,)))]
    y = (jax.random.normal(jax.random.PRNGKey(15), (8, 3)) * 0.3,
         jnp.zeros((3,)))
    cfg_cg = HypergradConfig(method="cg", cg_iters=64, cg_tol=1e-12)
    cfg_ne = HypergradConfig(method="neumann", neumann_k=256, lipschitz_g=8.0)
    p_cg = hypergradient(f, g, x, y, cfg_cg, f_args=(batch,), g_args=(batch,))
    p_ne = hypergradient(f, g, x, y, cfg_ne, f_args=(batch,), g_args=(batch,))
    for a, b in zip(jax.tree_util.tree_leaves(p_cg),
                    jax.tree_util.tree_leaves(p_ne)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-2, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), dx=st.integers(2, 8), dy=st.integers(2, 8))
def test_hypergradient_matches_finite_difference(seed, dx, dy):
    """Property: grad_bar f at y*(x) == finite-difference of l(x)."""
    f, g, A, B, truth = quad_problem(jax.random.PRNGKey(seed), dx=dx, dy=dy)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (dx,))
    expected, y_star = truth(x)
    cfg = HypergradConfig(method="cg", cg_iters=96, cg_tol=1e-12)
    got = hypergradient(f, g, x, y_star, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(expected),
                               rtol=1e-3, atol=1e-5)
