"""Unified Solver API: registry construction, legacy parity, shims.

Parity contract: each registry solver reproduces its legacy
``init_*_state`` + ``make_*_step`` trajectory bit-for-bit over 5 steps —
both through the per-step ``solver.step`` and the scan-compiled
``solver.run`` — and the deprecated ``make_*_step`` shims still work but
emit ``DeprecationWarning``.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HypergradConfig,
    MLPMetaProblem,
    erdos_renyi_adjacency,
    init_dsgd_state,
    init_gt_dsgd_state,
    init_head,
    init_mlp_backbone,
    init_state,
    init_svr_state,
    laplacian_mixing,
    make_dsgd_step,
    make_gt_dsgd_step,
    make_interact_step,
    make_svr_interact_step,
    make_synthetic_agents,
)
from repro.solvers import (
    Solver,
    SolverConfig,
    TopologyConfig,
    available_solvers,
    make_solver,
)

M, N, BATCH, Q, SEED = 4, 80, 6, 5, 7
STEPS = 5


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    data = make_synthetic_agents(key, num_agents=M, n_per_agent=N,
                                 d_in=8, num_classes=3)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), 8, hidden=8)
    y0 = init_head(jax.random.PRNGKey(2), 8, 3)
    spec = laplacian_mixing(erdos_renyi_adjacency(M, 0.5, seed=3))
    hg = HypergradConfig(method="cg", cg_iters=8)
    return data, prob, x0, y0, spec, hg


def _config(setup, algo):
    _, _, _, _, spec, hg = setup
    return SolverConfig(algo=algo, alpha=0.1, beta=0.1, batch_size=BATCH,
                        q=Q, mixing=spec, hypergrad=hg, seed=SEED)


def _legacy(setup, algo):
    """(initial state, step_fn) via the deprecated entry points."""
    data, prob, x0, y0, spec, hg = setup
    key = jax.random.PRNGKey(SEED)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        if algo == "interact":
            return (init_state(prob, hg, x0, y0, data),
                    make_interact_step(prob, hg, spec, 0.1, 0.1))
        if algo == "svr-interact":
            return (init_svr_state(prob, hg, x0, y0, data, key),
                    make_svr_interact_step(prob, hg, spec, 0.1, 0.1, q=Q,
                                           batch_size=BATCH))
        if algo == "gt-dsgd":
            return (init_gt_dsgd_state(prob, hg, x0, y0, data, key, BATCH),
                    make_gt_dsgd_step(prob, hg, spec, 0.1, 0.1, BATCH))
        if algo == "d-sgd":
            return (init_dsgd_state(x0, y0, M, key),
                    make_dsgd_step(prob, hg, spec, 0.1, 0.1, BATCH))
    raise ValueError(algo)


def _assert_trees_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_all_four_algorithms_registered():
    assert set(available_solvers()) == {
        "interact", "svr-interact", "gt-dsgd", "d-sgd"}


def test_all_four_constructible_and_protocol_shaped(setup):
    data, prob, x0, y0, _, hg = setup
    for algo in available_solvers():
        solver = make_solver(_config(setup, algo))
        assert isinstance(solver, Solver)
        state = solver.init(None, prob, hg, x0, y0, data)
        state = solver.step(state, data)
        assert int(state.t) == 1
        assert solver.samples_per_step(N) > 0
        assert solver.communications_per_step in (1, 2)


def test_unknown_algorithm_raises():
    with pytest.raises(ValueError, match="unknown algorithm"):
        make_solver(SolverConfig(algo="nope"))


@pytest.mark.parametrize("algo",
                         ["interact", "svr-interact", "gt-dsgd", "d-sgd"])
def test_registry_matches_legacy_bit_for_bit(setup, algo):
    data, prob, x0, y0, _, hg = setup
    legacy_state, legacy_fn = _legacy(setup, algo)
    for _ in range(STEPS):
        legacy_state = legacy_fn(legacy_state, data)

    solver = make_solver(_config(setup, algo))
    state = solver.init(None, prob, hg, x0, y0, data)
    for _ in range(STEPS):
        state = solver.step(state, data)
    _assert_trees_equal(legacy_state, state)


@pytest.mark.parametrize("algo",
                         ["interact", "svr-interact", "gt-dsgd", "d-sgd"])
def test_scan_run_matches_step_loop(setup, algo):
    data, prob, x0, y0, _, hg = setup
    solver = make_solver(_config(setup, algo))
    looped = solver.init(None, prob, hg, x0, y0, data)
    for _ in range(STEPS):
        looped = solver.step(looped, data)

    scanned = solver.init(None, prob, hg, x0, y0, data)
    scanned = solver.run(scanned, data, STEPS)
    _assert_trees_equal(looped, scanned)


def test_warmup_does_not_consume_state(setup):
    data, prob, x0, y0, _, hg = setup
    solver = make_solver(_config(setup, "interact"))
    state = solver.init(None, prob, hg, x0, y0, data)
    solver.warmup(state, data, 2)
    # state must still be usable (donation took a copy, not the original)
    state = solver.run(state, data, 2)
    assert int(state.t) == 2


def test_warmup_copy_does_not_alias_caller_state(setup):
    """Regression: the warmup "copy" must be a real copy.  tree_map with
    ``jnp.array`` can return the *same* buffer on some JAX versions, and
    donating an alias invalidates the caller's state.  After warmup every
    leaf must still be readable and hold its original value."""
    data, prob, x0, y0, _, hg = setup
    solver = make_solver(_config(setup, "svr-interact"))
    state = solver.init(None, prob, hg, x0, y0, data)
    before = [np.asarray(l).copy()
              for l in jax.tree_util.tree_leaves(state)]
    solver.warmup(state, data)          # step-path warmup (donated copy)
    solver.warmup(state, data, 3)       # scan-path warmup
    after = jax.tree_util.tree_leaves(state)
    for b, a in zip(before, after):
        assert not getattr(a, "is_deleted", lambda: False)(), \
            "warmup donated the caller's buffer"
        np.testing.assert_array_equal(b, np.asarray(a))


def test_deprecated_shims_warn(setup):
    data, prob, x0, y0, spec, hg = setup
    with pytest.warns(DeprecationWarning):
        make_interact_step(prob, hg, spec, 0.1, 0.1)
    with pytest.warns(DeprecationWarning):
        make_svr_interact_step(prob, hg, spec, 0.1, 0.1, q=Q)
    with pytest.warns(DeprecationWarning):
        make_gt_dsgd_step(prob, hg, spec, 0.1, 0.1, BATCH)
    with pytest.warns(DeprecationWarning):
        make_dsgd_step(prob, hg, spec, 0.1, 0.1, BATCH)


def test_sample_and_communication_accounting(setup):
    per = {
        "interact": (float(N), 2),
        "svr-interact": (N / Q + 2 * BATCH, 2),
        "gt-dsgd": (float(BATCH), 2),
        "d-sgd": (float(BATCH), 1),
    }
    for algo, (samples, comms) in per.items():
        solver = make_solver(_config(setup, algo))
        assert solver.samples_per_step(N) == pytest.approx(samples)
        assert solver.communications_per_step == comms


def test_config_defaults_follow_paper():
    cfg = SolverConfig(algo="svr-interact")
    # q = |S| = ceil(sqrt(n)) (Corollary 4)
    assert cfg.resolve_q(600) == 25
    assert cfg.resolve_batch(600) == 25
    assert SolverConfig(q=10).resolve_batch(600) == 10


def test_topology_config_realises_all_kinds():
    for kind in ("ring", "erdos-renyi", "torus"):
        spec = TopologyConfig(kind=kind).mixing_spec(8)
        assert spec.matrix.shape == (8, 8)
        np.testing.assert_allclose(spec.matrix.sum(axis=0), 1.0, atol=1e-9)
    with pytest.raises(ValueError):
        TopologyConfig(kind="star").mixing_spec(8)


def test_train_config_roundtrips_through_solver_config():
    from repro.train.step import InteractConfig
    ic = InteractConfig(alpha=0.05, beta=0.3, topology="erdos-renyi",
                        p_connect=0.4, consensus_compress="int8",
                        dp_sigma=0.1, q=7)
    back = InteractConfig.from_solver_config(ic.solver_config())
    assert back.alpha == ic.alpha and back.beta == ic.beta
    assert back.topology == ic.topology and back.p_connect == ic.p_connect
    assert back.consensus_compress == "int8" and back.dp_sigma == 0.1
    assert back.q == 7
    np.testing.assert_allclose(ic.mixing_spec(5).matrix,
                               back.mixing_spec(5).matrix)


def test_train_config_rejects_explicit_mixing(setup):
    """An explicit MixingSpec cannot drive the mesh runtime: the LM path
    realises the graph from the declarative topology, so silently
    ignoring ``mixing`` would train over the wrong network."""
    from repro.train.step import InteractConfig
    _, _, _, _, spec, _ = setup
    with pytest.raises(ValueError, match="mixing"):
        InteractConfig.from_solver_config(SolverConfig(mixing=spec))


def test_gt_dsgd_default_batch_consistent_between_init_and_step(setup):
    """Regression: with batch_size=None the initial tracker gradients and
    the step closure must resolve the same ceil(sqrt(n)) batch size."""
    data, prob, x0, y0, spec, hg = setup
    n = data.inner_x.shape[1] + data.outer_x.shape[1]
    cfg = SolverConfig(algo="gt-dsgd", alpha=0.1, beta=0.1, mixing=spec,
                       hypergrad=hg, seed=SEED)
    solver = make_solver(cfg)
    state = solver.init(None, prob, hg, x0, y0, data)
    legacy = init_gt_dsgd_state(prob, hg, x0, y0, data,
                                jax.random.PRNGKey(SEED),
                                cfg.resolve_batch(n))
    _assert_trees_equal(legacy, state)
