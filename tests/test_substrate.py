"""Substrate tests: data pipeline, optimizers, checkpointing, bilevel LM."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline container: vendored deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.checkpoint import (
    latest_step, restore_pytree, restore_step, save_pytree, save_step)
from repro.configs import get_config
from repro.data.synthetic import TokenTaskStream
from repro.models import model as M
from repro.optim.optimizers import (
    adam, adamw, clip_by_global_norm, cosine_schedule, momentum, sgd,
    warmup_linear)
from repro.train.bilevel_lm import BilevelHyper, chunked_ce, local_grads


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_token_stream_deterministic():
    s = TokenTaskStream(vocab_size=512, num_agents=4, seed=3)
    a = s.agent_batch(1, 7, batch=2, seq_len=32)
    b = s.agent_batch(1, 7, batch=2, seq_len=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_token_stream_heterogeneous_across_agents():
    s = TokenTaskStream(vocab_size=4096, num_agents=4, seed=3)
    batches = [np.asarray(s.agent_batch(i, 0, 8, 128)) for i in range(4)]
    means = [b.mean() for b in batches]
    assert np.std(means) > 10  # distinct vocab bands per agent


def test_token_stream_bounds():
    s = TokenTaskStream(vocab_size=100, num_agents=2, seed=0)
    b = np.asarray(s.global_batch(0, 4, 64))
    assert b.shape == (2, 4, 64)
    assert b.min() >= 0 and b.max() < 100


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_min(opt, steps=300):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
    for _ in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree_util.tree_map(jnp.add, params, upd)
    return float(loss(params))


@pytest.mark.parametrize("opt", [
    sgd(0.1), momentum(0.05), momentum(0.05, nesterov=True),
    adam(0.1), adamw(0.1, weight_decay=0.0)])
def test_optimizers_minimize_quadratic(opt):
    assert _quad_min(opt) < 1e-3


def test_clip_by_global_norm():
    grads = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(norm) == pytest.approx(20.0)
    total = jnp.sqrt(sum(jnp.sum(l ** 2)
                         for l in jax.tree_util.tree_leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, 100)
    assert float(cos(0)) == pytest.approx(1.0)
    assert float(cos(100)) == pytest.approx(0.1)
    wu = warmup_linear(2.0, 10)
    assert float(wu(0)) == pytest.approx(0.2)
    assert float(wu(9)) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": [(jnp.arange(6.0).reshape(2, 3), jnp.zeros(3))],
            "step": jnp.asarray(7, jnp.int32)}
    save_pytree(tmp_path / "ck.npz", tree)
    back = restore_pytree(tmp_path / "ck.npz", tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    save_pytree(tmp_path / "ck.npz", {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_pytree(tmp_path / "ck.npz", {"b": jnp.zeros(3)})


def test_step_checkpoints(tmp_path):
    for s in (5, 10):
        save_step(tmp_path, s, {"x": jnp.full((2,), float(s))})
    assert latest_step(tmp_path) == 10
    back = restore_step(tmp_path, 10, {"x": jnp.zeros(2)})
    assert float(back["x"][0]) == 10.0


# ---------------------------------------------------------------------------
# bilevel LM problem
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_setup():
    cfg = get_config("smollm-360m").reduced(vocab_size=128, num_layers=2,
                                            dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0), with_head=False)
    head = M.init_head(cfg, jax.random.PRNGKey(1))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                cfg.vocab_size)
    return cfg, params, head, tokens


def test_chunked_ce_matches_dense(lm_setup):
    cfg, params, head, tokens = lm_setup
    feats, _ = M.features(cfg, params, tokens, remat=False)
    ce = chunked_ce(cfg, head, feats, tokens, chunk=7)  # awkward chunk
    logits = M.head_logits(cfg, head, feats).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    dense = -jnp.mean(jnp.take_along_axis(logp, tokens[:, 1:, None],
                                          axis=-1))
    assert float(ce) == pytest.approx(float(dense), rel=1e-5)


def test_chunked_ce_chunk_invariance(lm_setup):
    cfg, params, head, tokens = lm_setup
    feats, _ = M.features(cfg, params, tokens, remat=False)
    vals = [float(chunked_ce(cfg, head, feats, tokens, chunk=c))
            for c in (1, 8, 31, 124)]
    np.testing.assert_allclose(vals, vals[0], rtol=1e-5)


def test_local_grads_finite_and_shaped(lm_setup):
    cfg, params, head, tokens = lm_setup
    hyper = BilevelHyper(mu_g=0.5, neumann_k=3, lipschitz_g=4.0,
                         ce_chunk=16, remat=False)
    p, v, ce = local_grads(cfg, hyper, params, head,
                           tokens[:2], tokens[2:])
    assert v.shape == head.shape
    assert bool(jnp.isfinite(ce))
    for leaf in jax.tree_util.tree_leaves(p):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    # hypergradient differs from the plain outer gradient (correction != 0)
    from repro.train.bilevel_lm import outer_loss
    gx_plain = jax.grad(
        lambda x: outer_loss(cfg, hyper, x, head, tokens[2:]))(params)
    diffs = [float(jnp.max(jnp.abs(a - b)))
             for a, b in zip(jax.tree_util.tree_leaves(p),
                             jax.tree_util.tree_leaves(gx_plain))]
    assert max(diffs) > 0


def test_hypergradient_reduces_to_plain_grad_when_decoupled(lm_setup):
    """With mu -> infinity the inner solution ~0 is x-independent, so the
    correction term vanishes and p == grad_x f."""
    cfg, params, head, tokens = lm_setup
    hyper = BilevelHyper(mu_g=1e6, neumann_k=8, lipschitz_g=1e6 * 1.5,
                         ce_chunk=16, remat=False)
    p, _, _ = local_grads(cfg, hyper, params, jnp.zeros_like(head),
                          tokens[:2], tokens[2:])
    from repro.train.bilevel_lm import outer_loss
    gx = jax.grad(lambda x: outer_loss(cfg, hyper, x, jnp.zeros_like(head),
                                       tokens[2:]))(params)
    for a, b in zip(jax.tree_util.tree_leaves(p),
                    jax.tree_util.tree_leaves(gx)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-3)
