"""Distributed-runtime tests on 8 forced host devices.

The 8-device forcing must happen before jax initialises, so these tests
run in a subprocess with XLA_FLAGS set (the main test process keeps the
default single device per the assignment).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str) -> str:
    code = textwrap.dedent(body)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def test_ring_mix_matches_dense_mixing_matrix():
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.sharding.collectives import ring_mix_leaf
        from repro.sharding.compat import shard_map, set_mesh
        from repro.core import ring_mixing

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = ring_mixing(m, self_weight=1/3)
        x = jax.random.normal(jax.random.PRNGKey(0), (m, 16))
        fn = shard_map(lambda t: ring_mix_leaf(t, ("data",), 1/3),
                           mesh=mesh, in_specs=P("data"),
                           out_specs=P("data"), axis_names={"data"})
        with set_mesh(mesh):
            got = jax.jit(fn)(x)
        want = jnp.asarray(spec.matrix, jnp.float32) @ x
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
        print("RING_OK")
    """)
    assert "RING_OK" in out


def test_distributed_interact_matches_reference_trajectory():
    """The shard_map/ppermute train step must produce the same iterates as
    a single-host dense-mixing reference implementation of Algorithm 1."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.core import ring_mixing, mix_pytree
        from repro.sharding.compat import set_mesh
        from repro.sharding.partition import tree_shardings
        from repro.train.bilevel_lm import BilevelHyper, local_grads
        from repro.train.step import (InteractConfig, init_train_state,
                                      make_train_step, train_state_specs)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("smollm-360m").reduced(vocab_size=128, num_layers=2,
                                                dtype="float32")
        hyper = BilevelHyper(mu_g=0.5, neumann_k=2, lipschitz_g=4.0,
                             ce_chunk=16, remat=False)
        icfg = InteractConfig(alpha=0.05, beta=0.3, hyper=hyper)
        m = 4
        state = init_train_state(cfg, jax.random.PRNGKey(0), m)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (m, 4, 32), 0,
                                    cfg.vocab_size)

        # ---- distributed trajectory
        dstate = jax.device_put(
            state, tree_shardings(mesh, train_state_specs(state, mesh)))
        dtok = jax.device_put(tokens, NamedSharding(mesh, P("data")))
        step = make_train_step(cfg, mesh, icfg)
        with set_mesh(mesh):
            jstep = jax.jit(step)
            for _ in range(2):
                dstate, _ = jstep(dstate, dtok)

        # ---- reference: dense mixing matrix + per-agent local_grads
        spec = ring_mixing(m, self_weight=icfg.self_weight)
        mat = jnp.asarray(spec.matrix, jnp.float32)
        rstate = state
        for _ in range(2):
            x_mixed = mix_pytree(mat, rstate.x)
            u_mixed = mix_pytree(mat, rstate.u)
            x_new = jax.tree_util.tree_map(
                lambda mx, u: mx - icfg.alpha * u, x_mixed, rstate.u)
            y_new = rstate.y - icfg.beta * rstate.v
            ps, vs = [], []
            for i in range(m):
                xi = jax.tree_util.tree_map(lambda l: l[i], x_new)
                p, v, _ = local_grads(cfg, hyper, xi, y_new[i],
                                      tokens[i, :2], tokens[i, 2:])
                ps.append(p); vs.append(v)
            p_new = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *ps)
            v_new = jnp.stack(vs)
            u_new = jax.tree_util.tree_map(
                lambda mu, pn, pp: mu + pn - pp, u_mixed, p_new,
                rstate.p_prev)
            rstate = rstate._replace(x=x_new, y=y_new, u=u_new, v=v_new,
                                     p_prev=p_new, t=rstate.t + 1)

        for a, b in zip(jax.tree_util.tree_leaves(dstate.x),
                        jax.tree_util.tree_leaves(rstate.x)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-4, rtol=1e-3)
        for a, b in zip(jax.tree_util.tree_leaves(dstate.u),
                        jax.tree_util.tree_leaves(rstate.u)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-3, rtol=5e-3)
        print("TRAJECTORY_OK")
    """)
    assert "TRAJECTORY_OK" in out


def test_dryrun_single_combo_small_mesh():
    """The dry-run machinery end-to-end on a 4x2 mesh with a reduced
    config: lower, compile, memory/cost analysis, collective parse."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.launch.dryrun import parse_collectives
        from repro.launch.serving import make_serve_step
        from repro.models import model as M
        from repro.sharding.compat import set_mesh
        from repro.sharding.partition import cache_specs, tree_specs, tree_shardings

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("mixtral-8x7b").reduced(vocab_size=128)
        params_sh = jax.eval_shape(
            lambda k: M.init_params(cfg, k, with_head=True),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_shard = tree_shardings(mesh, tree_specs(params_sh, 2))
        cache = jax.eval_shape(lambda: M.init_cache(cfg, batch=8, max_len=64))
        c_shard = tree_shardings(mesh, cache_specs(cache, mesh, 8))
        serve = make_serve_step(cfg)
        jitted = jax.jit(serve, in_shardings=(
            p_shard, NamedSharding(mesh, P("data")), c_shard,
            NamedSharding(mesh, P())),
            out_shardings=(NamedSharding(mesh, P("data")), c_shard))
        with set_mesh(mesh):
            lowered = jitted.lower(
                params_sh, jax.ShapeDtypeStruct((8, 1), jnp.int32), cache,
                jax.ShapeDtypeStruct((), jnp.int32))
            compiled = lowered.compile()
        from repro.roofline.analysis import normalize_cost_analysis
        cost = normalize_cost_analysis(compiled.cost_analysis())
        assert cost["flops"] > 0
        mem = compiled.memory_analysis()
        assert mem.argument_size_in_bytes > 0
        stats = parse_collectives(compiled.as_text())
        print("DRYRUN_OK", stats["wire_bytes"] >= 0)
    """)
    assert "DRYRUN_OK" in out


def _run_two_process(body: str, devices_per_process: int = 2,
                     timeout: float = 600.0):
    """Spawn TWO coordinator-wired jax processes running ``body`` — a
    real ``jax.distributed`` run (gloo CPU collectives) on localhost,
    configured through the REPRO_* env vars ``initialize_from_env``
    reads (docs/DISTRIBUTED.md)."""
    import socket
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    code = textwrap.dedent(body)
    procs = []
    for pid in range(2):
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"
        env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                            f"{devices_per_process}")
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        env["REPRO_COORDINATOR"] = f"127.0.0.1:{port}"
        env["REPRO_NUM_PROCESSES"] = "2"
        env["REPRO_PROCESS_ID"] = str(pid)
        procs.append(subprocess.Popen(
            [sys.executable, "-c", code], env=env,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True))
    outs = []
    try:
        for proc in procs:
            out, err = proc.communicate(timeout=timeout)
            assert proc.returncode == 0, err[-4000:]
            outs.append(out)
    finally:
        for proc in procs:
            if proc.poll() is None:
                proc.kill()
    return outs


def test_two_process_init_and_cross_process_collectives():
    """2 processes x 2 devices: ``initialize_from_env`` brings the gloo
    runtime up, the 4-agent mesh spans both processes, and psum/ppermute
    inside shard_map agree with the host-side reference — collectives
    really cross the process boundary (each process only holds half the
    agents)."""
    outs = _run_two_process("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch import distributed as D
        from repro.sharding.compat import shard_map, set_mesh

        assert D.initialize_from_env()
        assert jax.process_count() == 2
        assert jax.device_count() == 4
        mesh = D.agent_mesh(4)
        host = np.arange(8, dtype=np.float32).reshape(4, 2) + 1.0
        x = D.shard_host_tree(mesh, host, 4)
        gather = D._make_gather(mesh)

        fn = shard_map(lambda t: jax.lax.psum(t, "data"), mesh=mesh,
                       in_specs=(P("data"),), out_specs=P(),
                       axis_names=set(mesh.axis_names), check_vma=False)
        with set_mesh(mesh):
            got = np.asarray(jax.device_get(jax.jit(fn)(x)))
        np.testing.assert_allclose(got, host.sum(axis=0, keepdims=True),
                                   atol=1e-6)

        perm = [(i, (i + 1) % 4) for i in range(4)]
        fn2 = shard_map(lambda t: jax.lax.ppermute(t, "data", perm),
                        mesh=mesh, in_specs=(P("data"),),
                        out_specs=P("data"),
                        axis_names=set(mesh.axis_names), check_vma=False)
        with set_mesh(mesh):
            got2 = np.asarray(gather(jax.jit(fn2)(x)))
        np.testing.assert_allclose(got2, np.roll(host, 1, axis=0),
                                   atol=1e-6)
        D.shutdown()
        print("TWO_PROC_OK", jax.process_index())
    """)
    assert all("TWO_PROC_OK" in out for out in outs)


def test_agent_mesh_divisibility_error_is_actionable():
    out = run_in_subprocess("""
        from repro.launch.distributed import agent_mesh
        try:
            agent_mesh(3)     # 3 does not divide the 8 forced devices
            raise SystemExit("expected ValueError")
        except ValueError as e:
            msg = str(e)
            assert "does not divide" in msg, msg
            assert "divisors" in msg, msg
            assert "--devices-per-process" in msg, msg
        mesh = agent_mesh(4)  # 4 agents x 2 model shards
        assert mesh.shape["data"] == 4 and mesh.shape["model"] == 2
        print("AGENT_MESH_OK")
    """)
    assert "AGENT_MESH_OK" in out


def test_launch_local_end_to_end():
    """The localhost driver end to end: 2 processes x 2 devices through
    run_section6, result JSON carries the measured-communication
    read-out and a finite stationarity metric."""
    import json
    import tempfile
    out_path = os.path.join(tempfile.mkdtemp(prefix="launch_test_"),
                            "result.json")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "launch_local.py"),
         "--processes", "2", "--devices-per-process", "2",
         "--agents", "4", "--steps", "4", "--record-every", "4",
         "--n-per-agent", "24", "--metric-inner-steps", "20",
         "--out", out_path],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    with open(out_path) as fh:
        res = json.load(fh)
    assert res["num_processes"] == 2
    assert res["num_devices"] == 4
    assert res["num_agents"] == 4
    import math
    assert math.isfinite(res["final_metric"])
    assert res["measured_wire_bytes"] == res["priced_wire_bytes"]
    assert res["round_latency_us"] > 0
    assert len(res["digest"]) == 64     # sha256 hex of the final iterates


def test_multipod_mesh_shapes():
    out = run_in_subprocess("""
        import os
        # simulate enough devices for shape checks only (8 < 512: expect error)
        from repro.launch.mesh import make_production_mesh
        try:
            make_production_mesh()
        except RuntimeError as e:
            assert "512" in str(e) or "256" in str(e) or "devices" in str(e)
            print("MESH_GUARD_OK")
    """)
    assert "MESH_GUARD_OK" in out


def test_agents_per_pod_mode():
    """P6 layout: shard_map over 'pod' only, state FSDP-sharded over data,
    trajectory finite and consensus active across the 2 pod-agents."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.sharding.compat import set_mesh
        from repro.sharding.partition import tree_shardings
        from repro.train.bilevel_lm import BilevelHyper
        from repro.train.step import (InteractConfig, init_train_state,
                                      make_train_step, train_state_specs)

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = get_config("smollm-360m").reduced(vocab_size=128, num_layers=2,
                                                dtype="float32")
        hyper = BilevelHyper(mu_g=0.5, neumann_k=2, lipschitz_g=4.0,
                             ce_chunk=16, remat=False, batch_shard=True)
        icfg = InteractConfig(alpha=0.05, beta=0.3, hyper=hyper)
        m = 2  # agents = pods
        state = init_train_state(cfg, jax.random.PRNGKey(0), m)
        specs = train_state_specs(state, mesh, agent_mode="pods")
        # layer leaves must be sharded over data too (FSDP)
        layer_specs = jax.tree_util.tree_leaves(
            specs.x["layers"], is_leaf=lambda x: isinstance(x, P))
        assert any("data" in str(sp) for sp in layer_specs), layer_specs
        dstate = jax.device_put(state, tree_shardings(mesh, specs))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (m, 4, 32), 0,
                                    cfg.vocab_size)
        dtok = jax.device_put(tokens, NamedSharding(mesh, P("pod")))
        step = make_train_step(cfg, mesh, icfg, agent_mode="pods")
        with set_mesh(mesh):
            jstep = jax.jit(step)
            for _ in range(2):
                dstate, metrics = jstep(dstate, dtok)
        leaf = jax.tree_util.tree_leaves(dstate.x)[0]
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
        assert bool(jnp.isfinite(metrics["outer_ce"]))
        print("PODS_OK", float(metrics["outer_ce"]))
    """)
    assert "PODS_OK" in out


def test_distributed_svr_interact_runs():
    """Distributed SVR-INTERACT: finite trajectory, refresh cadence, and
    agreement with INTERACT on refresh steps (same full-gradient math)."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.sharding.compat import set_mesh
        from repro.sharding.partition import tree_shardings
        from repro.train.bilevel_lm import BilevelHyper
        from repro.train.step import InteractConfig
        from repro.train.svr_step import (init_svr_train_state,
                                          make_svr_train_step,
                                          svr_train_state_specs)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = get_config("smollm-360m").reduced(vocab_size=128, num_layers=2,
                                                dtype="float32")
        hyper = BilevelHyper(mu_g=0.5, neumann_k=2, lipschitz_g=4.0,
                             ce_chunk=16, remat=False)
        icfg = InteractConfig(alpha=0.05, beta=0.3, hyper=hyper)
        m = 4
        state = init_svr_train_state(cfg, jax.random.PRNGKey(0), m)
        specs = svr_train_state_specs(state, mesh)
        state = jax.device_put(state, tree_shardings(mesh, specs))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (m, 4, 32), 0,
                                    cfg.vocab_size)
        tokens = jax.device_put(tokens, NamedSharding(mesh, P("data")))
        step = make_svr_train_step(cfg, mesh, icfg, q=3)
        with set_mesh(mesh):
            jstep = jax.jit(step)
            refreshes = []
            for _ in range(4):
                state, metrics = jstep(state, tokens)
                refreshes.append(float(metrics["refresh"]))
                assert bool(jnp.isfinite(metrics["outer_ce"]))
        assert refreshes == [0.0, 0.0, 1.0, 0.0]  # t=1,2,3,4 with q=3
        leaf = jax.tree_util.tree_leaves(state.x)[0]
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
        print("SVR_DIST_OK")
    """)
    assert "SVR_DIST_OK" in out


def test_compressed_and_dp_consensus():
    """Paper future-work hooks: int8-compressed and DP-noised consensus
    still drive the trajectory (bounded perturbation, tracking absorbs)."""
    out = run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.sharding.collectives import (ring_mix_leaf, quantize_int8,
                                                dequantize_int8)
        from repro.sharding.compat import shard_map, set_mesh
        from repro.core import ring_mixing

        # quantize/dequantize round-trip error bounded by scale/2
        x = jax.random.normal(jax.random.PRNGKey(0), (64,)) * 3.0
        q, s = quantize_int8(x)
        err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
        assert float(err) <= float(s) * 0.5 + 1e-6

        mesh = jax.make_mesh((8,), ("data",))
        m = 8
        spec = ring_mixing(m, self_weight=1/3)
        X = jax.random.normal(jax.random.PRNGKey(1), (m, 32))

        def run(**kw):
            fn = shard_map(
                lambda t: ring_mix_leaf(t, ("data",), 1/3, **kw),
                mesh=mesh, in_specs=P("data"), out_specs=P("data"),
                axis_names={"data"}, check_vma=False)
            with set_mesh(mesh):
                return jax.jit(fn)(X)

        exact = jnp.asarray(spec.matrix, jnp.float32) @ X
        got_q = run(compress="int8")
        # int8 error small relative to payload magnitude
        rel = float(jnp.max(jnp.abs(got_q - exact))) / float(jnp.max(jnp.abs(exact)))
        assert rel < 0.05, rel

        got_dp = run(dp_sigma=0.1, dp_key=jax.random.PRNGKey(2))
        # noised but unbiased-ish: distinct from exact yet close
        d = float(jnp.max(jnp.abs(got_dp - exact)))
        assert 0.0 < d < 1.0, d
        print("COMPRESS_DP_OK")
    """)
    assert "COMPRESS_DP_OK" in out
