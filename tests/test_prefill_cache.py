"""Fused prefill -> decode continuation consistency.

prefill() must populate the decode caches (KV ring buffers, mamba h +
conv tail, rwkv wkv/token-shift states) exactly as if the prompt had been
decoded token by token — across every mixer family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M

ARCHS = ["llama3.2-3b", "mixtral-8x7b", "rwkv6-3b",
         "jamba-1.5-large-398b", "gemma2-2b", "qwen3-14b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_matches_stepwise_decode(arch):
    cfg = get_config(arch).reduced(num_prefix_tokens=0, frontend="none")
    params = M.init_params(cfg, jax.random.PRNGKey(0), with_head=True)
    T, D = 12, 5
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, T + D), 0,
                                cfg.vocab_size)

    cacheA = M.init_cache(cfg, batch=2, max_len=T + D)
    logA, cacheA = M.prefill(cfg, params, params["head"], tokens[:, :T],
                             cacheA)
    outsA = [logA]
    for t in range(T, T + D):
        lg, cacheA = M.decode_step(cfg, params, params["head"],
                                   tokens[:, t:t + 1], cacheA,
                                   jnp.asarray(t, jnp.int32))
        outsA.append(lg[:, 0])

    cacheB = M.init_cache(cfg, batch=2, max_len=T + D)
    outsB = []
    for t in range(T + D):
        lg, cacheB = M.decode_step(cfg, params, params["head"],
                                   tokens[:, t:t + 1], cacheB,
                                   jnp.asarray(t, jnp.int32))
        outsB.append(lg[:, 0])

    for a, b in zip(outsA, outsB[T - 1:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)


def test_prefill_into_swa_ring_longer_than_window():
    """Prompt longer than the sliding window: the ring layout must place
    the last `window` keys so decode continues correctly."""
    cfg = get_config("mixtral-8x7b").reduced(sliding_window=8, num_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(2), with_head=True)
    T, D = 20, 4  # T > window
    tokens = jax.random.randint(jax.random.PRNGKey(3), (1, T + D), 0,
                                cfg.vocab_size)
    cacheA = M.init_cache(cfg, batch=1, max_len=8)
    _, cacheA = M.prefill(cfg, params, params["head"], tokens[:, :T], cacheA)
    outsA = []
    for t in range(T, T + D):
        lg, cacheA = M.decode_step(cfg, params, params["head"],
                                   tokens[:, t:t + 1], cacheA,
                                   jnp.asarray(t, jnp.int32))
        outsA.append(lg[:, 0])
    cacheB = M.init_cache(cfg, batch=1, max_len=8)
    outsB = []
    for t in range(T + D):
        lg, cacheB = M.decode_step(cfg, params, params["head"],
                                   tokens[:, t:t + 1], cacheB,
                                   jnp.asarray(t, jnp.int32))
        outsB.append(lg[:, 0])
    for a, b in zip(outsA, outsB[T:]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-3, rtol=1e-3)
