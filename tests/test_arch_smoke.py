"""Per-architecture smoke tests (assignment requirement).

For each assigned architecture: instantiate a REDUCED variant of the same
family (2 layers / 1 period, d_model <= 512, <= 4 experts) and run one
forward + one train step on CPU asserting output shapes and no NaNs, plus
one decode step against a KV/state cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M

BATCH, SEQ = 2, 16


def _inputs(small, key):
    tokens = jax.random.randint(key, (BATCH, SEQ), 0, small.vocab_size)
    prefix = None
    if small.frontend != "none" and small.num_prefix_tokens:
        fd = small.frontend_dim or small.d_model
        prefix = 0.1 * jax.random.normal(key, (BATCH, small.num_prefix_tokens, fd))
    return tokens, prefix


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    cfg.validate()
    # spot-check the assigned dimensions are encoded exactly
    expect = {
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expect


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_limits(arch):
    small = get_config(arch).reduced()
    assert small.d_model <= 512
    assert small.num_experts <= 4
    assert small.num_layers <= 8  # <= 1 period for hybrids, 2 layers else
    small.validate()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    small = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(small, key, with_head=True)
    tokens, prefix = _inputs(small, key)
    logits, aux = M.forward(small, params, tokens, prefix_embed=prefix)
    total = SEQ + (small.num_prefix_tokens if prefix is not None else 0)
    assert logits.shape == (BATCH, total, small.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    """One SGD step on the LM loss: grads finite, params move."""
    small = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = M.init_params(small, key, with_head=True)
    tokens, prefix = _inputs(small, key)

    def loss_fn(p):
        logits, aux = M.forward(small, p, tokens, prefix_embed=prefix)
        return M.lm_loss(small, logits, tokens, aux)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-2 * g,
                                        params, grads)
    loss2 = loss_fn(new_params)
    assert bool(jnp.isfinite(loss2))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_matches_forward(arch):
    small = get_config(arch).reduced(num_prefix_tokens=0, frontend="none")
    key = jax.random.PRNGKey(2)
    params = M.init_params(small, key, with_head=True)
    T = 8
    tokens = jax.random.randint(key, (BATCH, T), 0, small.vocab_size)
    full_logits, _ = M.forward(small, params, tokens, remat=False,
                               moe_impl="exact")
    cache = M.init_cache(small, batch=BATCH, max_len=32)
    outs = []
    for t in range(T):
        logits, cache = M.decode_step(small, params, params["head"],
                                      tokens[:, t:t + 1], cache,
                                      jnp.asarray(t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-4, rtol=1e-3)


def test_sliding_window_cache_is_bounded():
    """SWA archs allocate only `window` cache slots (long_500k feasibility)."""
    small = get_config("mixtral-8x7b").reduced()
    assert small.sliding_window == 64
    cache = M.init_cache(small, batch=1, max_len=4096)
    k = cache[0]["attn"]["k"]  # (periods, batch, slots, kv, hd)
    assert k.shape[2] == 64


def test_ring_buffer_decode_beyond_window():
    """Decode past the window: ring buffer wraps, output stays correct."""
    small = get_config("mixtral-8x7b").reduced(sliding_window=8,
                                               num_layers=2)
    key = jax.random.PRNGKey(3)
    params = M.init_params(small, key, with_head=True)
    T = 20  # > window
    tokens = jax.random.randint(key, (1, T), 0, small.vocab_size)
    full_logits, _ = M.forward(small, params, tokens, remat=False,
                               moe_impl="exact")
    cache = M.init_cache(small, batch=1, max_len=8)
    for t in range(T):
        logits, cache = M.decode_step(small, params, params["head"],
                                      tokens[:, t:t + 1], cache,
                                      jnp.asarray(t, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-4, rtol=1e-3)


def test_gemma2_long_context_window_mode():
    """long_context_mode='window' bounds every layer's cache (DESIGN.md §4)."""
    import dataclasses
    small = get_config("gemma2-2b").reduced()
    windowed = dataclasses.replace(small, long_context_mode="window")
    cache = M.init_cache(windowed, batch=1, max_len=100_000)
    # both period positions (local AND the formerly-global layer) bounded
    for pos in range(2):
        k = cache[pos]["attn"]["k"]
        assert k.shape[2] <= windowed.local_window

    native = M.init_cache(small, batch=1, max_len=1000)
    assert native[0]["attn"]["k"].shape[2] <= small.local_window  # local
    assert native[1]["attn"]["k"].shape[2] == 1000                # global
