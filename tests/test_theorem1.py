"""Property tests for the Theorem-1 admissible step sizes.

``theorem1_step_sizes`` computes conservative (alpha, beta) from the
problem constants (mu_g, L_g, lambda, m).  The theorem's bounds are all
strictly positive for valid constants, shrink under a ``safety`` factor,
and the ``one_minus = max(1 - lam, 1e-3)`` clamp keeps them finite as
the network approaches disconnection (lam -> 1).
"""
import itertools
import math

import pytest

from repro.core import theorem1_step_sizes

GRID = list(itertools.product(
    (0.1, 0.5, 2.0),          # mu_g
    (1.0, 4.0, 32.0),         # L_g (>= mu_g enforced per-case below)
    (0.05, 0.5, 0.9, 0.999),  # lam
    (2, 5, 64),               # m
))


@pytest.mark.parametrize("mu_g,L_g,lam,m", GRID)
def test_alpha_beta_positive_and_finite(mu_g, L_g, lam, m):
    if L_g < mu_g:
        pytest.skip("L_g >= mu_g required for a valid problem")
    alpha, beta = theorem1_step_sizes(mu_g, L_g, lam, m)
    assert math.isfinite(alpha) and math.isfinite(beta)
    assert alpha > 0 and beta > 0
    assert alpha <= 1.0  # the explicit cap in the bound list


@pytest.mark.parametrize("safety", [0.9, 0.5, 0.1])
def test_safety_shrinks_both_monotonically(safety):
    a1, b1 = theorem1_step_sizes(0.5, 4.0, 0.9, 5, safety=1.0)
    a2, b2 = theorem1_step_sizes(0.5, 4.0, 0.9, 5, safety=safety)
    assert 0 < a2 < a1 and 0 < b2 < b1
    # beta scales linearly in safety; alpha only monotonically (safety
    # also shrinks beta's contraction rate r inside alpha's bounds)
    assert b2 == pytest.approx(safety * b1, rel=1e-9)


def test_safety_ordering_across_levels():
    alphas, betas = zip(*(theorem1_step_sizes(0.5, 4.0, 0.9, 5, safety=s)
                          for s in (1.0, 0.75, 0.5, 0.25, 0.1)))
    assert all(a1 > a2 for a1, a2 in zip(alphas, alphas[1:]))
    assert all(b1 > b2 for b1, b2 in zip(betas, betas[1:]))


@pytest.mark.parametrize("lam", [1.0 - 1e-4, 1.0 - 1e-9, 1.0])
def test_lam_to_one_guard_never_nonfinite(lam):
    """one_minus is clamped at 1e-3: a (nearly) disconnected network
    must degrade the step sizes, not blow them up to 0/inf/nan."""
    alpha, beta = theorem1_step_sizes(0.5, 4.0, lam, 5)
    assert math.isfinite(alpha) and math.isfinite(beta)
    assert alpha > 0 and beta > 0
    # the clamp makes lam -> 1 equivalent to one_minus = 1e-3 exactly
    a_clamped, _ = theorem1_step_sizes(0.5, 4.0, 1.0 - 1e-3, 5)
    assert alpha == pytest.approx(a_clamped, rel=1e-6)


def test_denser_network_admits_larger_alpha():
    # Remark 1: smaller lambda (better connectivity) -> larger alpha
    a_dense, _ = theorem1_step_sizes(0.5, 4.0, 0.2, 5)
    a_sparse, _ = theorem1_step_sizes(0.5, 4.0, 0.95, 5)
    assert a_dense >= a_sparse
