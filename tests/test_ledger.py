"""CommsLedger: measured bytes-on-wire == the priced analytic model.

The ledger records wire-stream templates at trace time and replays the
engine's deterministic warmup/interval schedule on the host
(consensus/ledger.py).  For the matrix backends the measured per-agent
bytes must equal ``cumulative_wire_bytes`` EXACTLY — same schedule, same
per-round payload — for every compressor kind; ``solve`` surfaces the
same numbers on ``SolveResult``.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.consensus import (
    CompressionConfig,
    attach_ledger,
    cumulative_wire_bytes,
    make_engine,
    time_round_us,
)
from repro.core import ring_mixing
from repro.solvers import SolverConfig
from repro.solvers.api import solve

M = 5
ENTRIES = 7 * 6 + 88   # per-agent payload entries of _tree


def _tree(seed: int = 0):
    ka, kb = jax.random.split(jax.random.PRNGKey(seed))
    return [jax.random.normal(ka, (M, 7, 6)),
            {"w": jax.random.normal(kb, (M, 88))}]


@pytest.mark.parametrize("kind,compress_after,interval", [
    ("none", 0, 1),
    ("int8", 0, 1),
    ("int8", 3, 2),       # warmup + silenced rounds
    ("sign1bit", 2, 1),
])
def test_measured_equals_priced_exactly(kind, compress_after, interval):
    steps = 9
    cfg = CompressionConfig(kind=kind, compress_after=compress_after)
    engine = make_engine("dense", ring_mixing(M), compression=cfg,
                         communication_interval=interval)
    ledger = attach_ledger(engine)
    # one trace records the stream template; the host replays the
    # schedule, so a single call prices any number of steps
    fn = jax.jit(lambda tr, t: engine.mix_ef(tr, None, t)[0])
    fn(_tree(), jnp.asarray(0))
    ledger.commit_steps(steps)
    priced = cumulative_wire_bytes(cfg, ENTRIES, steps, comms_per_step=1,
                                   communication_interval=interval)[-1]
    assert ledger.measured_wire_bytes == priced
    assert ledger.streams["x"].entries == ENTRIES


def test_retrace_does_not_double_count():
    cfg = CompressionConfig(kind="int8")
    engine = make_engine("dense", ring_mixing(M), compression=cfg)
    ledger = attach_ledger(engine)
    fn = jax.jit(lambda tr, t: engine.mix_ef(tr, None, t)[0])
    fn(_tree(0), jnp.asarray(0))
    fn(_tree(1), jnp.asarray(0))        # cache hit
    fn(jax.tree_util.tree_map(lambda l: l.astype(jnp.float32), _tree(2)),
       jnp.asarray(1))
    assert len(ledger.streams) == 1     # idempotent per-stream key
    ledger.commit_steps(4)
    priced = cumulative_wire_bytes(cfg, ENTRIES, 4, comms_per_step=1)[-1]
    assert ledger.measured_wire_bytes == priced


def test_attach_before_trace_contract():
    """A ledger attached after the step was already traced sees nothing
    (jit cache replays the compiled program) — the documented contract is
    attach-then-trace, and benches attach right after build."""
    engine = make_engine("dense", ring_mixing(M))
    first = attach_ledger(engine)
    fn = jax.jit(lambda tr: engine.mix_ef(tr, None, 0)[0])
    fn(_tree())
    assert first.streams
    late = attach_ledger(engine)
    fn(_tree())                          # cache hit: no retrace
    assert not late.streams
    assert late.measured_wire_bytes == 0.0


def test_solve_exposes_measured_columns():
    """``solve`` attaches a ledger and reports measured bytes + latency:
    the tracking algorithms ship TWO streams (x and u) per step, D-SGD
    one, at identical per-stream payloads."""
    steps = 4
    results = {}
    for algo in ("interact", "d-sgd"):
        cfg = SolverConfig(algo=algo, alpha=0.1, beta=0.1,
                           mixing=ring_mixing(4), seed=3)
        results[algo] = solve(cfg, steps, num_agents=4, n_per_agent=40)
    di, dd = results["interact"], results["d-sgd"]
    assert di.measured_wire_bytes and di.measured_wire_bytes > 0
    assert di.measured_wire_bytes == 2 * dd.measured_wire_bytes
    assert di.measured_wire_bytes == 2 * steps * dd.bytes_per_round
    assert di.round_latency_us and di.round_latency_us > 0


def test_time_round_us_positive():
    engine = make_engine("dense", ring_mixing(M))
    tree = _tree()
    us = time_round_us(jax.jit(lambda tr: engine.mix(tr)), tree, reps=3)
    assert us > 0
