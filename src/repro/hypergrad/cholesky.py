"""Direct small-head backend: materialise H_yy, factor once, solve.

The Section-6 inner problem is a small strongly-convex head (the paper's
20-hidden-unit backbone with a linear head + ridge: d_y <= ~210), so the
inverse of eq. (5) does not need an iterative solver at all: build the
(d_y, d_y) Hessian, ``cho_factor`` once, ``cho_solve`` — exact to solver
precision, replacing the reference's 32 *sequential* matvecs with one
dense factorisation.

Two ways to obtain H_yy:

* a problem-provided closed form (``BilevelProblem.inner_hess_yy``, e.g.
  the softmax-CE + ridge head Hessian of ``MLPMetaProblem``): one
  structured evaluation, no AD loop at all — this is what makes the
  backend a fast path on CPU (one Hessian evaluation costs about as much
  as a handful of HVPs, versus d_y replayed tangents);
* generically, one batched HVP against the d_y-dim identity basis on the
  ``jax.linearize``d tangent (d_y counted HVP evaluations, fully
  batched — no sequential loop, but the FLOPs still scale with d_y, so
  prefer the closed form when the problem offers one).

When H_yy is exact, CG needs up to d_y iterations for the same exactness
guarantee; see docs/HYPERGRAD.md for the measured crossover against the
fixed-iteration reference.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.custom_batching import custom_vmap
from jax.flatten_util import ravel_pytree

from repro.hypergrad.config import HypergradConfig
from repro.hypergrad.engine import HypergradEngine, register_backend
from repro.hypergrad.operator import HypergradStats, LinearOperator

__all__ = ["CholeskyEngine", "cho_factor_solve"]

# Above this agent count the custom batching rule switches from an
# unrolled sequence of LAPACK solves to a lax.map (one trace, sequential
# execution) to keep compile time bounded.
_UNROLL_MAX = 8


@custom_vmap
def cho_factor_solve(H: jax.Array, b: jax.Array) -> jax.Array:
    """``cho_solve(cho_factor(H), b)`` with a vmap-safe batching rule.

    XLA:CPU lowers *batched* triangular solves to a blocked kernel that
    is an order of magnitude slower than the unbatched LAPACK path (a
    single (105, 105) solve: ~20us unbatched vs ~1.4ms inside vmap), so
    the solvers' per-agent ``vmap`` would eat the entire direct-solve
    win.  The custom rule evaluates the batch as ``axis_size`` unbatched
    factor+solve calls instead — unrolled for small agent counts,
    ``lax.map`` beyond — each hitting the fast LAPACK kernels.
    """
    return jax.scipy.linalg.cho_solve(jax.scipy.linalg.cho_factor(H), b)


@cho_factor_solve.def_vmap
def _cho_factor_solve_vmap(axis_size, in_batched, H, b):
    H_b, b_b = in_batched
    Hs = H if H_b else jnp.broadcast_to(H, (axis_size,) + H.shape)
    bs = b if b_b else jnp.broadcast_to(b, (axis_size,) + b.shape)
    if axis_size <= _UNROLL_MAX:
        out = jnp.stack([cho_factor_solve(Hs[i], bs[i])
                         for i in range(axis_size)])
    else:
        out = jax.lax.map(lambda hb: cho_factor_solve(*hb), (Hs, bs))
    return out, True


@register_backend("cholesky")
class CholeskyEngine(HypergradEngine):
    """Materialise-and-factor H_yy for small inner problems."""

    def solve(self, g, x, y, b, cfg: HypergradConfig, g_args, key,
              inner_hess_yy=None):
        b_flat, unravel = ravel_pytree(b)
        d = b_flat.shape[0]
        stats = HypergradStats.zero()
        if inner_hess_yy is not None:
            H = inner_hess_yy(x, y, *g_args)
            if H.shape != (d, d):
                raise ValueError(
                    f"inner_hess_yy returned {H.shape}, expected ({d}, {d})"
                    " in ravel_pytree(y) ordering")
            stats = stats._replace(hess_count=jnp.int32(1))
        else:
            grad_y = lambda yy: jax.grad(g, argnums=1)(x, yy, *g_args)
            _, hvp_lin = jax.linearize(grad_y, y)
            op = LinearOperator(
                lambda vf: ravel_pytree(hvp_lin(unravel(vf)))[0])
            rows, count = op.apply_basis(jnp.eye(d, dtype=b_flat.dtype),
                                         jnp.zeros((), jnp.int32))
            # rows[i] = H e_i; symmetrise away AD round-off before potrf.
            H = 0.5 * (rows + rows.T)
            stats = stats._replace(hvp_count=count,
                                   grad_count=jnp.int32(1))
        if cfg.cholesky_jitter:
            H = H + cfg.cholesky_jitter * jnp.eye(d, dtype=H.dtype)
        z_flat = cho_factor_solve(H, b_flat)
        return unravel(z_flat), stats
