"""Hypergradient engines: one `hypergradient(...)` surface, five backends.

The per-step cost of every INTERACT variant is dominated by the
hypergradient of eq. (5)/(22); this package makes the inverse application
pluggable (mirroring ``repro.consensus`` / ``repro.solvers``) and makes
its cost *measured*: every matvec flows through a counted
``LinearOperator`` and ``hypergradient_with_stats`` returns per-call
``hvp_count`` / ``grad_count`` / ``hess_count``.

    from repro.hypergrad import HypergradConfig, hypergradient

    cfg = HypergradConfig(backend="cg-linearized")       # or "cholesky", ...
    p = hypergradient(f, g, x, y, cfg, f_args=(fb,), g_args=(gb,))

Backends: ``cg`` / ``neumann`` (seed references, bit-compatible),
``cg-linearized`` / ``neumann-linearized`` (linearize-once replay, flat
space, early exit / dynamic trip count), ``cholesky`` (materialise the
small-head H_yy, factor once).  See docs/HYPERGRAD.md.

``repro.core.hypergrad`` remains as a deprecation shim over this package.
"""
from repro.hypergrad.config import HypergradConfig
from repro.hypergrad.engine import (
    HypergradEngine,
    available_backends,
    get_backend,
    hvp_xy,
    hvp_yy,
    hypergradient,
    hypergradient_with_stats,
    measure_counts,
    measure_problem_counts,
    register_backend,
)
from repro.hypergrad.operator import HypergradStats, LinearOperator
from repro.hypergrad.cg import CgInfo, cg_solve
from repro.hypergrad.neumann import (
    neumann_inverse_apply,
    neumann_stochastic_apply,
    neumann_truncated_apply,
)

__all__ = [
    "CgInfo",
    "HypergradConfig",
    "HypergradEngine",
    "HypergradStats",
    "LinearOperator",
    "available_backends",
    "cg_solve",
    "get_backend",
    "hvp_xy",
    "hvp_yy",
    "hypergradient",
    "hypergradient_with_stats",
    "measure_counts",
    "measure_problem_counts",
    "neumann_inverse_apply",
    "neumann_stochastic_apply",
    "neumann_truncated_apply",
    "register_backend",
]
