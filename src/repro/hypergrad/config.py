"""`HypergradConfig`: how the inner-Hessian inverse of eq. (5)/(22) is applied.

The canonical home of the config that used to live in
``repro.core.hypergrad`` (still importable from there, and from
``repro.core``, unchanged).  New in the engine refactor:

* ``backend`` — the ``HypergradEngine`` registry name.  ``None`` keeps the
  legacy behaviour of deriving the backend from ``method`` ("cg" /
  "neumann"), so every existing config keeps meaning exactly what it
  meant.  Set it to ``"cg-linearized"`` / ``"neumann-linearized"`` /
  ``"cholesky"`` to opt into the fast paths (see docs/HYPERGRAD.md).
* ``cg_rel_tol`` — the CG freeze/stop test compares ``sqrt(rs)`` against
  ``tol * ||b||`` instead of the legacy absolute ``tol``.  Defaults to
  ``False`` so the ``cg`` reference backend stays bit-compatible with the
  seed implementation (it is the cross-backend correctness oracle); the
  standalone ``repro.hypergrad.cg_solve`` function defaults to the
  relative test.
* ``cholesky_jitter`` — optional diagonal regulariser added to the
  materialised ``H_yy`` before factorisation (0 by default: the inner
  problem is mu_g-strongly convex so H is PD on its own).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["HypergradConfig"]


@dataclasses.dataclass(frozen=True)
class HypergradConfig:
    """How to apply the inner-Hessian inverse.

    Attributes:
      method: "cg" (deterministic solve) or "neumann" (paper eq. 22).
        Legacy selector, kept for compatibility; ``backend`` wins when set.
      cg_iters / cg_tol: CG budget for the deterministic path.  For the
        reference ``cg`` backend this is the fixed trip count (the
        tolerance only freezes the iterate); for ``cg-linearized`` it is
        the iteration *cap* of the early-exit loop.
      neumann_k: K, the truncation order of eq. (22).
      lipschitz_g: L_g, the gradient-Lipschitz constant of g used to scale
        the Neumann series ((I - H/L_g) must be a contraction).
      stochastic_k: if True, draw k ~ U{0..K-1} and use the unbiased
        (K/L_g)-scaled single product of eq. (22); if False use the full
        truncated sum (deterministic bias (1 - mu/L)^K, Lemma 3).
      backend: ``HypergradEngine`` registry name ("cg", "cg-linearized",
        "neumann", "neumann-linearized", "cholesky").  ``None`` derives
        the name from ``method``.  Validated against the registry by
        ``resolve_backend()``.
      cg_rel_tol: relative (``tol * ||b||``) instead of absolute CG
        residual test, honored by both the ``cg`` freeze test and the
        ``cg-linearized`` early exit (so swapping backends changes cost,
        not solve quality).  False preserves the seed numerics of the
        ``cg`` oracle backend.
      cholesky_jitter: diagonal added to H_yy before ``cho_factor``.
    """

    method: Literal["cg", "neumann"] = "cg"
    cg_iters: int = 32
    cg_tol: float = 1e-8
    neumann_k: int = 8
    lipschitz_g: float = 1.0
    stochastic_k: bool = False
    backend: str | None = None
    cg_rel_tol: bool = False
    cholesky_jitter: float = 0.0

    def resolve_backend(self) -> str:
        """The registry name this config selects, validated.

        Raises ``ValueError`` (listing the registered backends) when
        ``backend`` — or the legacy ``method`` fallback — is unknown, so
        misconfiguration fails at solver build time, not mid-trace.
        """
        from repro.hypergrad.engine import available_backends
        name = self.backend if self.backend is not None else self.method
        if name not in available_backends():
            raise ValueError(
                f"unknown hypergradient backend {name!r}; "
                f"choose from {available_backends()}")
        return name
