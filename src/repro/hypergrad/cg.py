"""Conjugate-gradient backends: the seed reference and linearize-once.

``cg_solve`` is the canonical CG entry point.  Two loop disciplines:

* fixed trip count (``early_exit=False``, the seed semantics): a
  ``fori_loop`` always executes ``iters`` matvecs; the tolerance only
  *freezes* the iterate (alpha = beta = 0 once the residual is small).
  Deterministic cost — appropriate for lowering on TPU — but every
  post-convergence iteration still pays a full HVP.
* early exit (``early_exit=True``): a ``while_loop`` that stops at the
  tolerance, so converged systems stop paying for matvecs.  Under
  ``vmap`` the loop runs until every lane converges (lane values are
  select-frozen, and each lane's matvec counter stops with it).

The residual test defaults to *relative* (``sqrt(rs) > tol * ||b||``);
``rel_tol=False`` restores the seed's absolute test bit-for-bit (the
``repro.core.hypergrad.cg_solve`` shim pins that flag).

Backends registered here:

* ``cg`` — seed reference: per-matvec forward-over-reverse HVP, fixed
  trip count, absolute tolerance unless ``cfg.cg_rel_tol``.  Kept
  bit-compatible as the cross-backend correctness oracle.
* ``cg-linearized`` — ``jax.linearize`` on ``grad_y g(x, .)`` once per
  call, so every CG matvec is a cheap JVP replay of the cached tangent
  (no primal recomputation even where XLA's loop-invariant code motion
  cannot hoist it), run in the flat raveled space with the early-exit
  loop.  On the Section-6 instance CG converges in ~8 matvecs, so the
  early exit alone is a ~2x per-call win over the frozen 32-iteration
  reference (see benchmarks/bench_hypergrad.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.hypergrad.config import HypergradConfig
from repro.hypergrad.engine import (HypergradEngine, hvp_yy,
                                    register_backend)
from repro.hypergrad.operator import (HypergradStats, LinearOperator,
                                      as_operator, flat_dot, tree_axpy)

__all__ = ["CgInfo", "cg_solve", "CgEngine", "CgLinearizedEngine"]


class CgInfo(NamedTuple):
    """Solve diagnostics surfaced alongside the CG solution.

    residual_norm: final ||b - A x|| (recurrence residual).
    iterations:    productive iterations (post-freeze / post-exit steps
                   excluded).
    matvecs:       matvecs actually executed — equals ``iterations`` for
                   the early-exit loop, the full trip count for the
                   frozen loop.
    """

    residual_norm: jax.Array
    iterations: jax.Array
    matvecs: jax.Array


def _threshold(b, tol: float, rel_tol: bool):
    if not rel_tol:
        return tol
    return tol * jnp.sqrt(flat_dot(b, b))


def _cg_frozen(op: LinearOperator, b, iters: int, tol, count0):
    """Seed CG: fixed ``iters`` trip count, tolerance freezes the iterate.

    Bit-compatible with the historical ``core.hypergrad.cg_solve`` when
    ``tol`` is the raw absolute tolerance.
    """
    x0 = jax.tree_util.tree_map(jnp.zeros_like, b)

    def body(_, carry):
        x, r, p, rs, its, count = carry
        ap, count = op.apply_counted(p, count)
        denom = flat_dot(p, ap)
        alpha = jnp.where(denom > 0, rs / jnp.maximum(denom, 1e-30), 0.0)
        active = jnp.sqrt(rs) > tol
        alpha = jnp.where(active, alpha, 0.0)
        x = tree_axpy(alpha, p, x)
        r = tree_axpy(-alpha, ap, r)
        rs_new = flat_dot(r, r)
        beta = jnp.where(active, rs_new / jnp.maximum(rs, 1e-30), 0.0)
        p = tree_axpy(beta, p, r)
        rs = jnp.where(active, rs_new, rs)
        its = its + active.astype(jnp.int32)
        return x, r, p, rs, its, count

    rs0 = flat_dot(b, b)
    zero = jnp.zeros((), jnp.int32)
    x, _, _, rs, its, count = jax.lax.fori_loop(
        0, iters, body, (x0, b, b, rs0, zero, count0))
    return x, CgInfo(residual_norm=jnp.sqrt(rs), iterations=its,
                     matvecs=count - count0), count


def _cg_early_exit(op: LinearOperator, b, iters: int, tol, count0):
    """Early-exit CG on a flat vector ``b``: stops at the tolerance."""

    def cond(carry):
        k, x, r, p, rs, count = carry
        return (k < iters) & (jnp.sqrt(rs) > tol)

    def body(carry):
        k, x, r, p, rs, count = carry
        ap, count = op.apply_counted(p, count)
        denom = p @ ap
        alpha = jnp.where(denom > 0, rs / jnp.maximum(denom, 1e-30), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = r @ r
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        return k + 1, x, r, p, rs_new, count

    rs0 = b @ b
    k, x, _, _, rs, count = jax.lax.while_loop(
        cond, body, (jnp.zeros((), jnp.int32), jnp.zeros_like(b), b, b,
                     rs0, count0))
    return x, CgInfo(residual_norm=jnp.sqrt(rs), iterations=k,
                     matvecs=count - count0), count


def cg_solve(matvec: Callable, b, iters: int, tol: float, *,
             rel_tol: bool = True, early_exit: bool = False,
             return_info: bool = False):
    """Conjugate gradients for SPD ``matvec`` on pytrees.

    ``rel_tol`` scales the residual test by ``||b||`` (default; pass
    ``False`` for the seed's absolute test).  ``early_exit`` swaps the
    fixed-trip frozen loop for a ``while_loop`` that stops at tolerance
    (requires a flat array ``b``).  ``return_info`` additionally returns
    a ``CgInfo`` with the final residual norm and iteration/matvec
    counts.
    """
    op = as_operator(matvec)
    thresh = _threshold(b, tol, rel_tol)
    zero = jnp.zeros((), jnp.int32)
    if early_exit:
        x, info, _ = _cg_early_exit(op, b, iters, thresh, zero)
    else:
        x, info, _ = _cg_frozen(op, b, iters, thresh, zero)
    return (x, info) if return_info else x


@register_backend("cg")
class CgEngine(HypergradEngine):
    """Seed CG reference: fixed trip count, per-matvec HVP (the oracle)."""

    def solve(self, g, x, y, b, cfg: HypergradConfig, g_args, key,
              inner_hess_yy=None):
        op = LinearOperator(lambda v: hvp_yy(g, x, y, v, *g_args))
        thresh = _threshold(b, cfg.cg_tol, cfg.cg_rel_tol)
        z, _info, count = _cg_frozen(op, b, cfg.cg_iters, thresh,
                                     jnp.zeros((), jnp.int32))
        return z, HypergradStats.zero()._replace(hvp_count=count)


@register_backend("cg-linearized")
class CgLinearizedEngine(HypergradEngine):
    """Linearize-once CG with early exit in the flat raveled space."""

    def solve(self, g, x, y, b, cfg: HypergradConfig, g_args, key,
              inner_hess_yy=None):
        grad_y = lambda yy: jax.grad(g, argnums=1)(x, yy, *g_args)
        _, hvp_lin = jax.linearize(grad_y, y)   # one grad_y g primal pass
        b_flat, unravel = ravel_pytree(b)
        op = LinearOperator(
            lambda vf: ravel_pytree(hvp_lin(unravel(vf)))[0])
        # same tolerance semantics the cg oracle freezes at, so swapping
        # backends changes the cost, not the solve quality
        thresh = _threshold(b_flat, cfg.cg_tol, cfg.cg_rel_tol)
        z_flat, _info, count = _cg_early_exit(op, b_flat, cfg.cg_iters,
                                              thresh,
                                              jnp.zeros((), jnp.int32))
        stats = HypergradStats.zero()._replace(
            hvp_count=count, grad_count=jnp.int32(1))
        return unravel(z_flat), stats
