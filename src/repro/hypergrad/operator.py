"""`LinearOperator`: the counted matvec every backend's solve flows through.

The paper states its complexity results (Theorems 1-2, Corollaries 2/4)
in *gradient and Hessian-vector evaluations*, so the engines measure them
instead of inferring them: a ``LinearOperator`` wraps a matvec and
threads an evaluation counter through the solver loop carries.  Because
the counter lives *inside* the traced computation it is exact even when
the trip count is data-dependent (the early-exit CG of ``cg-linearized``,
the stochastic-k Neumann chain) and even under ``vmap`` over agents
(each lane counts its own evaluations).

Shared pytree arithmetic helpers live here too — one copy, used by every
backend (they were module-private in the old ``core/hypergrad.py``).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "HypergradStats",
    "LinearOperator",
    "as_operator",
    "flat_dot",
    "tree_axpy",
    "tree_scale",
    "tree_sub",
]


class HypergradStats(NamedTuple):
    """Measured evaluation counts of one hypergradient call.

    hvp_count:  Hessian-vector products against the inner loss g — both
                the H_yy solve matvecs and the single H_xy cross term.
    grad_count: first-order gradient evaluations (the joint grad_{x,y} f
                counts once; a linearization primal pass counts one
                grad_y g).
    hess_count: full H_yy materialisations (the cholesky backend's
                structured closed form; 0 everywhere else).

    All three are int32 scalars traced through the computation (per-lane
    under vmap), so they report what actually executed.
    """

    hvp_count: jax.Array
    grad_count: jax.Array
    hess_count: jax.Array

    @classmethod
    def zero(cls) -> "HypergradStats":
        z = jnp.zeros((), jnp.int32)
        return cls(hvp_count=z, grad_count=z, hess_count=z)


class LinearOperator:
    """A linear map with evaluation accounting.

    ``op(v)`` applies the map; ``op.apply_counted(v, count)`` returns
    ``(A v, count + cost)`` for threading through ``fori_loop`` /
    ``while_loop`` carries; ``op.apply_basis(V, count)`` applies the map
    to a stacked basis (rows of ``V``) via ``vmap`` and charges one
    evaluation per row — the cholesky backend's batched identity HVP.
    """

    def __init__(self, matvec: Callable, cost: int = 1):
        self.matvec = matvec
        self.cost = cost

    def __call__(self, v):
        return self.matvec(v)

    def apply_counted(self, v, count: jax.Array):
        return self.matvec(v), count + jnp.int32(self.cost)

    def apply_basis(self, basis: jax.Array, count: jax.Array):
        rows = jax.vmap(self.matvec)(basis)
        return rows, count + jnp.int32(self.cost * basis.shape[0])


def as_operator(matvec) -> LinearOperator:
    """Coerce a bare matvec callable to a unit-cost ``LinearOperator``."""
    if isinstance(matvec, LinearOperator):
        return matvec
    return LinearOperator(matvec)


def flat_dot(a, b) -> jax.Array:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(la, lb) for la, lb in zip(leaves_a, leaves_b))


def tree_axpy(alpha, x, y):
    """alpha * x + y, leaf-wise."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


def tree_sub(x, y):
    return jax.tree_util.tree_map(lambda xi, yi: xi - yi, x, y)
