"""The `HypergradEngine` API: one pluggable backend behind eq. (5)/(22).

Every algorithm obtains its outer gradient through the approximate
hypergradient of eq. (5),

    grad_bar f(x, y) = grad_x f(x, y)
        - H_xy(g)(x, y) [H_yy(g)(x, y)]^{-1} grad_y f(x, y),

and the whole per-step cost is dominated by how the inverse is applied.
A ``HypergradEngine`` owns exactly that piece — ``solve(...)`` returns
``z ~= [H_yy g]^{-1} grad_y f`` plus measured evaluation counts — while
the shared ``hypergradient`` surface owns the invariant parts (the joint
grad of f, the single H_xy cross term, the final subtraction), so every
backend is interchangeable and bit-comparable.

Backends (see ``available_backends`` / docs/HYPERGRAD.md):

    cg                  seed CG, fixed trip count, per-matvec HVP —
                        the correctness oracle (bit-compatible).
    cg-linearized       ``jax.linearize`` once, flat-space CG with an
                        early-exit ``while_loop`` at relative tolerance.
    neumann             seed eq.-(22) chain; the stochastic form now runs
                        a dynamic k-trip loop (expected (K-1)/2 HVPs).
    neumann-linearized  linearize-once replay of the product chain.
    cholesky            materialise H_yy (small heads), factor once,
                        ``cho_solve`` — exact to solver precision.

Mirrors the ``ConsensusEngine`` / ``@register_solver`` registries of
PRs 1-2: adding a backend is one ``@register_backend`` class.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.hypergrad.config import HypergradConfig
from repro.hypergrad.operator import HypergradStats, flat_dot, tree_sub

__all__ = [
    "HypergradEngine",
    "available_backends",
    "get_backend",
    "register_backend",
    "hvp_yy",
    "hvp_xy",
    "hypergradient",
    "hypergradient_with_stats",
    "measure_counts",
    "measure_problem_counts",
]


def hvp_yy(g: Callable, x, y, v, *args):
    """H_yy(g)(x, y) @ v via forward-over-reverse."""
    grad_y = lambda yy: jax.grad(g, argnums=1)(x, yy, *args)
    return jax.jvp(grad_y, (y,), (v,))[1]


def hvp_xy(g: Callable, x, y, v, *args):
    """H_xy(g)(x, y) @ v  =  grad_x <grad_y g(x, y), v>."""
    def inner(xx):
        gy = jax.grad(g, argnums=1)(xx, y, *args)
        return flat_dot(gy, v)

    return jax.grad(inner)(x)


class HypergradEngine:
    """Base class: apply the inner-Hessian inverse, counting evaluations.

    ``solve`` returns ``(z, stats)`` where ``z ~= [H_yy g]^{-1} b`` and
    ``stats`` counts only the solve's own evaluations (the shared
    ``hypergradient`` surface adds the H_xy cross term and the grad-f
    pass).  ``inner_hess_yy`` is an optional problem-provided closed form
    for the flat H_yy (see ``repro.core.bilevel.BilevelProblem``); only
    the cholesky backend consumes it.
    """

    name = "base"

    def solve(self, g: Callable, x, y, b, cfg: HypergradConfig,
              g_args: tuple, key, inner_hess_yy: Callable | None = None):
        raise NotImplementedError


_REGISTRY: dict[str, HypergradEngine] = {}


def register_backend(name: str) -> Callable[[type], type]:
    """Class decorator: register a (stateless) engine under ``name``."""

    def deco(cls: type) -> type:
        existing = _REGISTRY.get(name)
        if existing is not None and type(existing) is not cls:
            raise ValueError(f"hypergradient backend {name!r} already "
                             f"registered ({type(existing).__name__})")
        cls.name = name
        _REGISTRY[name] = cls()
        return cls

    return deco


def _populate() -> None:
    # Engines live in sibling modules; importing them registers them.
    from repro.hypergrad import cg as _cg            # noqa: F401
    from repro.hypergrad import cholesky as _chol    # noqa: F401
    from repro.hypergrad import neumann as _neu      # noqa: F401


def available_backends() -> tuple[str, ...]:
    """Registered backend names, sorted."""
    _populate()
    return tuple(sorted(_REGISTRY))


def get_backend(name: str) -> HypergradEngine:
    """Look a backend up by registry name."""
    _populate()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown hypergradient backend {name!r}; "
            f"choose from {tuple(sorted(_REGISTRY))}") from None


def hypergradient_with_stats(
    f: Callable,
    g: Callable,
    x,
    y,
    cfg: HypergradConfig,
    f_args: tuple = (),
    g_args: tuple = (),
    key: jax.Array | None = None,
    inner_hess_yy: Callable | None = None,
):
    """grad_bar f(x, y) of eq. (5)/(22) plus measured evaluation counts.

    ``f(x, y, *f_args)`` is the outer loss, ``g(x, y, *g_args)`` the inner
    (mu_g-strongly-convex in y).  Returns ``(p, HypergradStats)`` where
    ``p`` is a pytree like x and the stats count this call's gradient /
    HVP / Hessian evaluations (Definition-1 accounting, measured inside
    the trace — see docs/HYPERGRAD.md).
    """
    engine = get_backend(cfg.resolve_backend())
    gx, gy = jax.grad(f, argnums=(0, 1))(x, y, *f_args)
    z, stats = engine.solve(g, x, y, gy, cfg, g_args, key, inner_hess_yy)
    correction = hvp_xy(g, x, y, z, *g_args)
    p = tree_sub(gx, correction)
    stats = stats._replace(hvp_count=stats.hvp_count + 1,   # H_xy cross term
                           grad_count=stats.grad_count + 1)  # grad_{x,y} f
    return p, stats


def hypergradient(
    f: Callable,
    g: Callable,
    x,
    y,
    cfg: HypergradConfig,
    f_args: tuple = (),
    g_args: tuple = (),
    key: jax.Array | None = None,
    inner_hess_yy: Callable | None = None,
):
    """The approximate hypergradient grad_bar f(x, y) of eq. (5)/(22).

    Same contract as the historical ``repro.core.hypergrad.hypergradient``
    (bit-compatible for the ``cg`` / ``neumann`` reference backends at
    identical configs); ``hypergradient_with_stats`` additionally returns
    the measured evaluation counts.
    """
    p, _ = hypergradient_with_stats(f, g, x, y, cfg, f_args=f_args,
                                    g_args=g_args, key=key,
                                    inner_hess_yy=inner_hess_yy)
    return p


def measure_counts(
    f: Callable,
    g: Callable,
    x,
    y,
    cfg: HypergradConfig,
    f_args: tuple = (),
    g_args: tuple = (),
    key: jax.Array | None = None,
    inner_hess_yy: Callable | None = None,
) -> HypergradStats:
    """Run one hypergradient call and return its counts as python ints.

    This *executes* the estimator (so data-dependent trip counts — the
    early-exit CG, the stochastic-k Neumann chain — report what actually
    ran); ``solve`` and the bench harness use it to attach measured
    per-step ``hvp_count`` / ``grad_count`` to their results.

    For a stochastic-k config with no explicit ``key``, the sampled trip
    count is averaged over a small fixed key set (rounded), so the
    reported cost reflects the estimator's expected (K-1)/2 HVPs rather
    than one arbitrary draw; pass a ``key`` to measure a single draw.
    """
    def one(k):
        _, stats = hypergradient_with_stats(f, g, x, y, cfg, f_args=f_args,
                                            g_args=g_args, key=k,
                                            inner_hess_yy=inner_hess_yy)
        return stats

    if cfg.stochastic_k and key is None:
        samples = [one(jax.random.PRNGKey(s)) for s in range(16)]
        mean = lambda field: round(
            sum(int(getattr(s, field)) for s in samples) / len(samples))
        return HypergradStats(hvp_count=mean("hvp_count"),
                              grad_count=mean("grad_count"),
                              hess_count=mean("hess_count"))
    stats = one(key)
    return HypergradStats(hvp_count=int(stats.hvp_count),
                          grad_count=int(stats.grad_count),
                          hess_count=int(stats.hess_count))


def measure_problem_counts(problem, cfg: HypergradConfig, x0, y0, data,
                           agent: int = 0,
                           key: jax.Array | None = None) -> HypergradStats:
    """``measure_counts`` on one agent's slice of stacked ``AgentData``.

    ``problem`` is any object with ``outer`` / ``inner`` losses and an
    optional ``inner_hess_yy`` (``repro.core.bilevel.BilevelProblem``);
    the shared convention used by ``solve``, the bench harness, and the
    examples to attach measured per-call accounting.
    """
    return measure_counts(
        problem.outer, problem.inner, x0, y0, cfg,
        f_args=((data.outer_x[agent], data.outer_y[agent]),),
        g_args=((data.inner_x[agent], data.inner_y[agent]),),
        key=key, inner_hess_yy=getattr(problem, "inner_hess_yy", None))
