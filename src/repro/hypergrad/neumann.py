"""Neumann-series backends: the paper's eq. (22) estimator.

Deterministic form:   (1/L) sum_{j=0}^{K-1} (I - H/L)^j b
Stochastic form:      (K/L) (I - H/L)^k b,  k ~ U{0..K-1}

The stochastic chain runs with a *dynamic trip count* — ``fori_loop`` up
to the sampled ``k`` — so its expected cost is (K-1)/2 HVPs instead of
the seed's always-K masked loop (the masked form computed every HVP and
discarded the late ones).  The values are bit-identical: the executed
prefix of the product chain is the same op sequence.  Under ``vmap``
over agents the batched loop runs to the largest sampled ``k`` with
done lanes select-frozen, and each lane's counter reports its own k.

Backends registered here:

* ``neumann`` — the seed estimator over pytrees, HVP rebuilt per term
  (kept value-compatible as the reference).
* ``neumann-linearized`` — ``jax.linearize`` on ``grad_y g(x, .)`` once,
  the product chain replays the cached tangent in the flat raveled
  space, and the deterministic sum skips the seed's wasted K-th HVP
  (whose output was discarded), so it executes K-1 HVPs for the same
  value.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.hypergrad.config import HypergradConfig
from repro.hypergrad.engine import (HypergradEngine, hvp_yy,
                                    register_backend)
from repro.hypergrad.operator import (HypergradStats, LinearOperator,
                                      as_operator, tree_scale, tree_sub)

__all__ = [
    "neumann_truncated_apply",
    "neumann_stochastic_apply",
    "neumann_inverse_apply",
    "NeumannEngine",
    "NeumannLinearizedEngine",
]


def neumann_truncated_apply(matvec: Callable, b, k_terms: int,
                            lipschitz_g: float, *, unroll: bool = False,
                            skip_last: bool = False):
    """(1/L) sum_{j<K} (I - H/L)^j b, counting executed HVPs.

    Returns ``(value, hvp_count)``.  ``skip_last`` omits the K-th HVP
    whose output the truncated sum discards (K-1 HVPs, same value — used
    by the linearized backend); the default keeps the seed's executed-op
    sequence for bit-compatibility.  ``unroll`` replaces the
    ``fori_loop`` with a python loop (old-JAX shard_map compatibility,
    see repro/train/bilevel_lm.py).
    """
    op = as_operator(matvec)
    L = lipschitz_g
    zero = jax.tree_util.tree_map(jnp.zeros_like, b)
    if k_terms <= 0:   # empty sum: match the reference loop exactly
        return zero, jnp.zeros((), jnp.int32)
    k_hvps = k_terms - 1 if skip_last else k_terms

    def body(_, carry):
        v, acc, count = carry
        acc = jax.tree_util.tree_map(jnp.add, acc, v)
        hv, count = op.apply_counted(v, count)
        v = tree_sub(v, tree_scale(1.0 / L, hv))
        return v, acc, count

    count0 = jnp.zeros((), jnp.int32)
    if unroll:
        carry = (b, zero, count0)
        for i in range(k_hvps):
            carry = body(i, carry)
    else:
        carry = jax.lax.fori_loop(0, k_hvps, body, (b, zero, count0))
    v, acc, count = carry
    if skip_last:  # the final term joins the sum without a closing HVP
        acc = jax.tree_util.tree_map(jnp.add, acc, v)
    return tree_scale(1.0 / L, acc), count


def neumann_stochastic_apply(matvec: Callable, b, k_terms: int,
                             lipschitz_g: float, key: jax.Array):
    """(K/L) (I - H/L)^k b with k ~ U{0..K-1}, dynamic trip count.

    Returns ``(value, hvp_count)`` with ``hvp_count == k`` — the loop
    executes exactly the sampled number of HVPs (expected (K-1)/2)
    instead of masking out late terms of an always-K loop.
    """
    op = as_operator(matvec)
    L = lipschitz_g
    k = jax.random.randint(key, (), 0, k_terms)

    def body(_, carry):
        v, count = carry
        hv, count = op.apply_counted(v, count)
        return tree_sub(v, tree_scale(1.0 / L, hv)), count

    v, count = jax.lax.fori_loop(0, k, body,
                                 (b, jnp.zeros((), jnp.int32)))
    return tree_scale(float(k_terms) / L, v), count


def neumann_inverse_apply(
    g: Callable,
    x,
    y,
    b,
    *args,
    k_terms: int,
    lipschitz_g: float,
    stochastic_k: bool = False,
    key: jax.Array | None = None,
):
    """Approximate [H_yy g]^{-1} b with the Neumann series of eq. (22).

    Canonical successor of ``repro.core.hypergrad.neumann_inverse_apply``
    (same signature, bit-identical values; the stochastic path now costs
    the sampled k HVPs instead of always K).
    """
    matvec = lambda v: hvp_yy(g, x, y, v, *args)
    if stochastic_k:
        if key is None:
            raise ValueError("stochastic_k requires a PRNG key")
        v, _ = neumann_stochastic_apply(matvec, b, k_terms, lipschitz_g,
                                        key)
        return v
    v, _ = neumann_truncated_apply(matvec, b, k_terms, lipschitz_g)
    return v


@register_backend("neumann")
class NeumannEngine(HypergradEngine):
    """Seed eq.-(22) estimator: HVP rebuilt per term (the reference)."""

    def solve(self, g, x, y, b, cfg: HypergradConfig, g_args, key,
              inner_hess_yy=None):
        matvec = LinearOperator(lambda v: hvp_yy(g, x, y, v, *g_args))
        if cfg.stochastic_k:
            if key is None:
                raise ValueError("stochastic_k requires a PRNG key")
            z, count = neumann_stochastic_apply(
                matvec, b, cfg.neumann_k, cfg.lipschitz_g, key)
        else:
            z, count = neumann_truncated_apply(
                matvec, b, cfg.neumann_k, cfg.lipschitz_g)
        return z, HypergradStats.zero()._replace(hvp_count=count)


@register_backend("neumann-linearized")
class NeumannLinearizedEngine(HypergradEngine):
    """Linearize-once replay of the eq.-(22) product chain."""

    def solve(self, g, x, y, b, cfg: HypergradConfig, g_args, key,
              inner_hess_yy=None):
        grad_y = lambda yy: jax.grad(g, argnums=1)(x, yy, *g_args)
        _, hvp_lin = jax.linearize(grad_y, y)   # one grad_y g primal pass
        b_flat, unravel = ravel_pytree(b)
        op = LinearOperator(
            lambda vf: ravel_pytree(hvp_lin(unravel(vf)))[0])
        if cfg.stochastic_k:
            if key is None:
                raise ValueError("stochastic_k requires a PRNG key")
            z_flat, count = neumann_stochastic_apply(
                op, b_flat, cfg.neumann_k, cfg.lipschitz_g, key)
        else:
            z_flat, count = neumann_truncated_apply(
                op, b_flat, cfg.neumann_k, cfg.lipschitz_g,
                skip_last=True)
        stats = HypergradStats.zero()._replace(
            hvp_count=count, grad_count=jnp.int32(1))
        return unravel(z_flat), stats
