"""Pure-jnp sequential oracle for the WKV6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Token-by-token scan.

    r, k, v, w: (batch, seq, heads, N); u: (heads, N).
    state: (batch, heads, N, N), k-major (state[b,h,i,j] ~ k_i v_j).
    """
    b, s, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), dtype=jnp.float32)
    r32, k32, v32, w32 = (t.astype(jnp.float32) for t in (r, k, v, w))
    u32 = u.astype(jnp.float32)

    def step(st, ts):
        rt, kt, vt, wt = ts
        kv = kt[..., :, None] * vt[..., None, :]
        att = st + u32[None, :, :, None] * kv
        ot = jnp.einsum("bhn,bhnm->bhm", rt, att)
        st = wt[..., :, None] * st + kv
        return st, ot

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r32, k32, v32, w32))
    final, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), final
