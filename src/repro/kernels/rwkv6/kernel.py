"""Pallas TPU kernel for the WKV6 recurrence (RWKV-6 "Finch").

Chunked formulation of the data-dependent-decay linear attention:

  S_t = diag(w_t) S_{t-1} + k_t v_t^T,   o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

A sequential scan serializes seq_len steps on the VPU; instead we split the
sequence into chunks of C tokens and compute per chunk (all MXU matmuls):

  inter-chunk:  o_t += (r_t * W_t) S_0            W_t = prod_{j<t} w_j
  intra-chunk:  o_t += sum_{s<t} [(r_t * W_t / W_{s+1}) . k_s] v_s
                       + (r_t * u . k_t) v_t      (bonus diagonal)
  state:        S_C = diag(W_C) S_0 + sum_s diag(W_C / W_{s+1}) k_s v_s

Decay products are kept in log space (w in (0,1) => log w < 0) so the
ratios W_t / W_{s+1} = exp(cum_t - cum_{s+1}) <= 1 never overflow.

Grid: (batch, heads, num_chunks) with the chunk dimension sequential
("arbitrary"), carrying the (N, N) state in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params

DEFAULT_CHUNK = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_final_ref,
                 state_ref, *, chunk: int, head_size: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)   # (C, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, 0, :].astype(jnp.float32)      # (N,)

    logw = jnp.log(jnp.maximum(w, 1e-38))                    # (C, N) <= 0
    cum = jnp.cumsum(logw, axis=0)                           # inclusive
    cum_excl = cum - logw                                    # exclusive: sum_{j<t}

    state = state_ref[...]                                   # (N, N) k-major

    # ----- inter-chunk: o_t += (r_t * W_t) @ S0
    r_decayed = r * jnp.exp(cum_excl)
    o = jax.lax.dot_general(r_decayed, state, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # ----- intra-chunk: A[t, s] = sum_n r[t,n] k[s,n] exp(cum_excl[t]-cum[s])
    #                   (strictly lower triangular), bonus on the diagonal.
    # ratio exp(cum_excl[t] - cum[s]) <= 1 for s < t; clamp the masked upper
    # triangle before exp to avoid overflow there.
    diff = cum_excl[:, None, :] - cum[None, :, :]            # (C, C, N)
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    ratio = jnp.exp(jnp.where(tri[..., None], diff, -1e30))  # 0 when masked
    A = jnp.einsum("tn,sn,tsn->ts", r, k, ratio)
    bonus = jnp.sum(r * u[None, :] * k, axis=1)              # (C,)
    A = A + jnp.diag(bonus)
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)

    # ----- state update: S = diag(exp(cum_C)) S0 + sum_s diag(exp(cum_C - cum_s)) k_s v_s
    total = cum[-1]                                          # (N,)
    k_scaled = k * jnp.exp(total[None, :] - cum)             # (C, N)
    state_ref[...] = state * jnp.exp(total)[:, None] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ci == nc - 1)
    def _emit_state():
        s_final_ref[0, 0, :, :] = state_ref[...]


def wkv6_kernel(
    r: jax.Array,  # (batch, seq, heads, N)
    k: jax.Array,
    v: jax.Array,
    w: jax.Array,  # decays in (0, 1)
    u: jax.Array,  # (heads, N) bonus
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Returns (out (b, s, h, N), final_state (b, h, N, N))."""
    b, s, h, n = r.shape
    assert s % chunk == 0, (s, chunk)
    grid = (b, h, s // chunk)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, head_size=n)
    io_spec = pl.BlockSpec((1, chunk, 1, n), lambda bi, hi, ci: (bi, ci, hi, 0))

    out, s_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            io_spec, io_spec, io_spec, io_spec,
            pl.BlockSpec((1, 1, n), lambda bi, hi, ci: (0, hi, 0)),
        ],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, n, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, h, n), r.dtype),
            jax.ShapeDtypeStruct((b, h, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(r, k, v, w, u[None])
    return out, s_final
