"""jit'd public wrapper for the WKV6 kernel (padding + initial state).

An incoming recurrent state (decode/chunked prefill) is folded in by
prepending nothing — the kernel starts from zero state — so ``wkv6``
handles it by running the kernel and then correcting the output with the
closed-form inter-segment term:

    o_t += (r_t * W_t) @ S_in,    S_out += diag(prod_t w_t) S_in

computed in plain jnp (cheap: one (seq, N) cumprod + one matmul).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import DEFAULT_CHUNK, wkv6_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, state: jax.Array | None = None,
         chunk: int = DEFAULT_CHUNK, interpret: bool = True
         ) -> tuple[jax.Array, jax.Array]:
    b, s, h, n = r.shape
    chunk = min(chunk, s) if s % min(chunk, s) == 0 else 1 if s == 1 else chunk
    pad = (-s) % chunk
    if pad:
        zeros = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r_, k_, v_ = zeros(r), zeros(k), zeros(v)
        # pad decays with 1.0 so the state is untouched by padded steps
        w_ = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                     constant_values=1.0)
    else:
        r_, k_, v_, w_ = r, k, v, w

    out, s_final = wkv6_kernel(r_, k_, v_, w_, u, chunk=chunk,
                               interpret=interpret)
    out = out[:, :s]

    if state is not None:
        logw = jnp.log(jnp.maximum(w.astype(jnp.float32), 1e-38))
        cum_excl = jnp.cumsum(logw, axis=1) - logw            # (b, s, h, n)
        r_decayed = r.astype(jnp.float32) * jnp.exp(cum_excl)
        extra = jnp.einsum("bshn,bhnm->bshm", r_decayed, state)
        out = (out.astype(jnp.float32) + extra).astype(r.dtype)
        total = jnp.sum(logw, axis=1)                         # (b, h, n)
        s_final = s_final + state * jnp.exp(total)[..., None]
    return out, s_final
