"""jit'd public wrapper for the flash attention kernel.

Handles padding of q/kv lengths to block multiples and picks block sizes
that keep the working set inside VMEM:

  VMEM bytes/step ~ block_q*hd*4 (q) + 2*block_k*hd*4 (k, v)
                  + block_q*hd*4 (acc) + block_q*block_k*4 (s/p tile)
  with (128, 128) and hd=256: ~0.6 MB — comfortably under the ~16 MB/core
  budget, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention_kernel)


def _pad_to(x: jax.Array, length: int, axis: int) -> jax.Array:
    pad = length - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "logit_softcap", "q_offset",
                     "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_softcap: float | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    b, sq, nh, hd = q.shape
    skv = k.shape[1]
    block_q = min(block_q, max(8, sq))
    block_k = min(block_k, max(8, skv))
    sq_p = -(-sq // block_q) * block_q
    skv_p = -(-skv // block_k) * block_k
    qp = _pad_to(q, sq_p, 1)
    kp = _pad_to(k, skv_p, 1)
    vp = _pad_to(v, skv_p, 1)
    # Padded kv columns must never be attended to.  Causal masking already
    # hides them from real rows when q and kv are co-indexed; for the
    # decode path (q_offset > 0) the window/causal mask built from global
    # positions does the same because padded cols have col > real rows only
    # when col > q_offset + sq - 1 >= every real row.
    out = flash_attention_kernel(
        qp, kp, vp, causal=causal, window=window,
        logit_softcap=logit_softcap, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out[:, :sq]
