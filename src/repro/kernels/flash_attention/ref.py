"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # (batch, q_len, num_heads, head_dim)
    k: jax.Array,  # (batch, kv_len, num_kv_heads, head_dim)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_softcap: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Exact softmax GQA attention in fp32."""
    b, sq, nh, hd = q.shape
    _, skv, nkv, _ = k.shape
    group = nh // nkv
    qg = q.reshape(b, sq, nkv, group, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)

    row = jnp.arange(sq, dtype=jnp.int32)[:, None] + q_offset
    col = jnp.arange(skv, dtype=jnp.int32)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= row >= col
    if window is not None:
        mask &= row - col < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible keys (possible with window+offset) -> zeros
    any_visible = jnp.any(mask, axis=-1)
    p = jnp.where(any_visible[None, None, None, :, None], p, 0.0)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(b, sq, nh, hd).astype(q.dtype)
