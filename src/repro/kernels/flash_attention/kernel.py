"""Pallas TPU flash-attention kernel (GQA / causal / SWA / softcap).

Blockwise streaming-softmax attention with explicit VMEM tiling:

  grid = (batch, q_heads, num_q_blocks, num_kv_blocks)   kv innermost
  q block:  (BLOCK_Q, head_dim)  VMEM
  k,v blocks: (BLOCK_K, head_dim) VMEM (indexed by kv head = h // group)
  scratch: running (acc, m, l) in VMEM, persisted across the kv grid dim.

The online-softmax recurrence (Dao et al.) is adapted to the MXU: the two
matmuls per block (q k^T and p v) are jnp.dot on (BLOCK_Q, head_dim) x
(head_dim, BLOCK_K) tiles — multiples of 128 on the contracting and output
dims for MXU alignment (head_dim 64 archs use 64, still lane-aligned).

Causal + sliding-window masking is done with global row/col indices built
from the block coordinates; fully-masked kv blocks are skipped via
``pl.when`` so SWA costs O(seq * window), not O(seq^2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int | None,
                  logit_softcap: float | None, block_q: int, block_k: int,
                  q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Global positions of this block's rows/cols.  q_offset supports
    # decode/suffix queries whose absolute position starts mid-sequence.
    row = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    col = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Block-level skip: is any (row, col) pair in this tile visible?
    row_last = (qi + 1) * block_q - 1 + q_offset
    col_first = ki * block_k
    visible = jnp.bool_(True)
    if causal:
        visible = jnp.logical_and(visible, col_first <= row_last)
    if window is not None:
        row_first = qi * block_q + q_offset
        col_last = (ki + 1) * block_k - 1
        visible = jnp.logical_and(visible, col_last > row_first - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, row >= col)
        if window is not None:
            mask = jnp.logical_and(mask, row - col < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_prev + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,  # (batch, q_len, num_heads, head_dim)
    k: jax.Array,  # (batch, kv_len, num_kv_heads, head_dim)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    logit_softcap: float | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """pallas_call wrapper.  Sequence lengths must be block multiples
    (ops.py pads).  ``interpret=True`` executes on CPU for validation;
    on TPU pass ``interpret=False``."""
    b, sq, nh, hd = q.shape
    _, skv, nkv, _ = k.shape
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    group = nh // nkv
    scale = 1.0 / (hd ** 0.5)

    grid = (b, nh, sq // block_q, skv // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        logit_softcap=logit_softcap, block_q=block_q, block_k=block_k,
        q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda bi, hi, qi, ki: (bi, ki, hi // group, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda bi, hi, qi, ki: (bi, qi, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, sq, nh, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
