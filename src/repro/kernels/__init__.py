# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
"""Shared Pallas version compatibility."""
from jax.experimental.pallas import tpu as _pltpu

# Renamed across JAX releases: TPUCompilerParams (0.4.x) -> CompilerParams.
compiler_params = getattr(_pltpu, "CompilerParams", None)
if compiler_params is None:
    compiler_params = _pltpu.TPUCompilerParams

__all__ = ["compiler_params"]
