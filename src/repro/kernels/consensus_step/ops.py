"""jit'd wrapper: apply the fused consensus step to a whole pytree.

Ravels every agent's subtree to a flat (m, D) matrix via
``jax.flatten_util.ravel_pytree`` (vmapped over the leading agent dim),
runs the kernel once over the concatenated parameter vector — the kernel
itself zero-pads D up to the tile size — and unravels back.  This is the
implementation layer of the ``pallas`` consensus backend
(``repro/consensus/pallas.py``).
"""
from __future__ import annotations

import functools

import jax
from jax.flatten_util import ravel_pytree

from repro.kernels.consensus_step.kernel import (
    DEFAULT_BLOCK_D, consensus_mix_kernel, consensus_step_kernel)

__all__ = ["consensus_mix", "consensus_step", "flatten_agents",
           "unflatten_agents"]


def flatten_agents(tree):
    """(m, ...)-leaved pytree -> ((m, D) matrix, per-agent unravel fn)."""
    one_agent = jax.tree_util.tree_map(lambda l: l[0], tree)
    _, unravel = ravel_pytree(one_agent)
    flat = jax.vmap(lambda t: ravel_pytree(t)[0])(tree)
    return flat, unravel


def unflatten_agents(flat, unravel):
    return jax.vmap(unravel)(flat)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def consensus_mix(mix: jax.Array, tree, *, block_d: int = DEFAULT_BLOCK_D,
                  interpret: bool = True):
    """Bare combine ``x_i <- sum_j M_ij x_j`` over a pytree (one matmul)."""
    X, unravel = flatten_agents(tree)
    X_out = consensus_mix_kernel(mix, X, block_d=block_d,
                                 interpret=interpret)
    return unflatten_agents(X_out, unravel)


@functools.partial(jax.jit, static_argnames=("alpha", "block_d", "interpret"))
def consensus_step(mix: jax.Array, x_tree, u_tree, p_tree, pprev_tree, *,
                   alpha: float, block_d: int = DEFAULT_BLOCK_D,
                   interpret: bool = True):
    """Returns (x_tree', u_tree') after one fused eq.(6)+(10) update."""
    X, unravel_x = flatten_agents(x_tree)
    # u gets its own unravel: for mixed-dtype trees, x's unravel would
    # silently cast the tracker to x's leaf dtypes on the way back.
    U, unravel_u = flatten_agents(u_tree)
    P, _ = flatten_agents(p_tree)
    PP, _ = flatten_agents(pprev_tree)

    X_out, U_out = consensus_step_kernel(mix, X, U, P, PP, alpha=alpha,
                                         block_d=block_d,
                                         interpret=interpret)
    return (unflatten_agents(X_out, unravel_x),
            unflatten_agents(U_out, unravel_u))
