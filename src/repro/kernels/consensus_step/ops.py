"""jit'd wrapper: apply the fused consensus step to a whole pytree.

Flattens every leaf (m, ...) to (m, D), pads D to the tile size, runs the
kernel once over the concatenated parameter vector, and unflattens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.consensus_step.kernel import (
    DEFAULT_BLOCK_D, consensus_step_kernel)


@functools.partial(jax.jit, static_argnames=("alpha", "block_d", "interpret"))
def consensus_step(mix: jax.Array, x_tree, u_tree, p_tree, pprev_tree, *,
                   alpha: float, block_d: int = DEFAULT_BLOCK_D,
                   interpret: bool = True):
    """Returns (x_tree', u_tree') after one fused eq.(6)+(10) update."""
    leaves_x, treedef = jax.tree_util.tree_flatten(x_tree)
    leaves_u = treedef.flatten_up_to(u_tree)
    leaves_p = treedef.flatten_up_to(p_tree)
    leaves_pp = treedef.flatten_up_to(pprev_tree)
    m = leaves_x[0].shape[0]

    def flat(leaves):
        return jnp.concatenate([l.reshape(m, -1) for l in leaves], axis=1)

    X, U, P, PP = flat(leaves_x), flat(leaves_u), flat(leaves_p), flat(leaves_pp)
    d = X.shape[1]
    bd = min(block_d, d)
    pad = (-d) % bd
    if pad:
        X, U, P, PP = (jnp.pad(t, ((0, 0), (0, pad))) for t in (X, U, P, PP))

    X_out, U_out = consensus_step_kernel(mix, X, U, P, PP, alpha=alpha,
                                         block_d=bd, interpret=interpret)
    X_out, U_out = X_out[:, :d], U_out[:, :d]

    def unflat(mat, template):
        out, off = [], 0
        for l in template:
            size = l[0].size
            out.append(mat[:, off:off + size].reshape(l.shape))
            off += size
        return out

    x_new = treedef.unflatten(unflat(X_out, leaves_x))
    u_new = treedef.unflatten(unflat(U_out, leaves_u))
    return x_new, u_new
