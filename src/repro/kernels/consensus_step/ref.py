"""Pure-jnp oracle for the fused consensus + tracking step."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def consensus_step_ref(mix: jax.Array, x: jax.Array, u: jax.Array,
                       p: jax.Array, p_prev: jax.Array, *, alpha: float
                       ) -> tuple[jax.Array, jax.Array]:
    mix32 = mix.astype(jnp.float32)
    x32, u32 = x.astype(jnp.float32), u.astype(jnp.float32)
    x_out = mix32 @ x32 - alpha * u32
    u_out = mix32 @ u32 + p.astype(jnp.float32) - p_prev.astype(jnp.float32)
    return x_out.astype(x.dtype), u_out.astype(u.dtype)
