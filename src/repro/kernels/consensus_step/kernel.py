"""Pallas kernel fusing the INTERACT consensus + tracking updates.

One iteration of the paper's core op (eqs. 6 and 10), fused so the agent
dimension stays VMEM-resident and x/u/p stream through once:

    x_out = M @ x - alpha * u            (consensus + descent)
    u_out = M @ u + p - p_prev           (gradient tracking)

Layout: parameters are flattened to (m, D); the grid tiles D.  The m x m
mixing matrix (m <= a few hundred agents) lives in VMEM for every tile, and
both matmuls hit the MXU with the (m, BD) tiles.  This is the single-host
m-agent simulator's hot loop; on the distributed runtime the same update is
expressed with ppermute (repro/sharding/collectives.py) instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 512


def _consensus_kernel(mix_ref, x_ref, u_ref, p_ref, pprev_ref,
                      xout_ref, uout_ref, *, alpha: float):
    mix = mix_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    pp = pprev_ref[...].astype(jnp.float32)

    mx = jax.lax.dot_general(mix, x, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    mu = jax.lax.dot_general(mix, u, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    xout_ref[...] = (mx - alpha * u).astype(xout_ref.dtype)
    uout_ref[...] = (mu + p - pp).astype(uout_ref.dtype)


def consensus_step_kernel(
    mix: jax.Array,     # (m, m) doubly-stochastic
    x: jax.Array,       # (m, D) outer iterates
    u: jax.Array,       # (m, D) tracked gradients
    p: jax.Array,       # (m, D) new local hypergradients
    p_prev: jax.Array,  # (m, D)
    *,
    alpha: float,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    m, d = x.shape
    assert d % block_d == 0, (d, block_d)
    grid = (d // block_d,)

    kernel = functools.partial(_consensus_kernel, alpha=alpha)
    tile = pl.BlockSpec((m, block_d), lambda i: (0, i))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, m), lambda i: (0, 0)),
                  tile, tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((m, d), x.dtype),
                   jax.ShapeDtypeStruct((m, d), u.dtype)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(mix, x, u, p, p_prev)
