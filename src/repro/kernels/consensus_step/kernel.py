"""Pallas kernel fusing the INTERACT consensus + tracking updates.

One iteration of the paper's core op (eqs. 6 and 10), fused so the agent
dimension stays VMEM-resident and x/u/p stream through once:

    x_out = M @ x - alpha * u            (consensus + descent)
    u_out = M @ u + p - p_prev           (gradient tracking)

Layout: parameters are flattened to (m, D); the grid tiles D.  The m x m
mixing matrix (m <= a few hundred agents) lives in VMEM for every tile, and
both matmuls hit the MXU with the (m, BD) tiles.  This is the single-host
m-agent simulator's hot loop; on the distributed runtime the same update is
expressed with ppermute (repro/sharding/collectives.py) instead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compiler_params

DEFAULT_BLOCK_D = 512


def _consensus_kernel(mix_ref, x_ref, u_ref, p_ref, pprev_ref,
                      xout_ref, uout_ref, *, alpha: float):
    mix = mix_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    pp = pprev_ref[...].astype(jnp.float32)

    mx = jax.lax.dot_general(mix, x, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    mu = jax.lax.dot_general(mix, u, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    xout_ref[...] = (mx - alpha * u).astype(xout_ref.dtype)
    uout_ref[...] = (mu + p - pp).astype(uout_ref.dtype)


def _mix_kernel(mix_ref, x_ref, out_ref):
    mix = mix_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    out_ref[...] = jax.lax.dot_general(
        mix, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def consensus_mix_kernel(
    mix: jax.Array,     # (m, m) doubly-stochastic
    x: jax.Array,       # (m, D)
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = True,
) -> jax.Array:
    """Bare combine ``M @ x`` — the mix-only half of the fused kernel,
    for callers that need eq. (6)/(10)'s combine without the tracking
    update (one matmul, two streams instead of five)."""
    m, d = x.shape
    bd = min(block_d, max(d, 1))
    pad = (-d) % bd
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    dp = d + pad
    tile = pl.BlockSpec((m, bd), lambda i: (0, i))
    out = pl.pallas_call(
        _mix_kernel,
        grid=(dp // bd,),
        in_specs=[pl.BlockSpec((m, m), lambda i: (0, 0)), tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((m, dp), x.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(mix, x)
    return out[:, :d] if pad else out


def consensus_step_kernel(
    mix: jax.Array,     # (m, m) doubly-stochastic
    x: jax.Array,       # (m, D) outer iterates
    u: jax.Array,       # (m, D) tracked gradients
    p: jax.Array,       # (m, D) new local hypergradients
    p_prev: jax.Array,  # (m, D)
    *,
    alpha: float,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    m, d = x.shape
    # Zero-pad D up to the tile multiple (real models rarely flatten to a
    # multiple of block_d); the pad lanes mix to zero and are sliced off.
    bd = min(block_d, max(d, 1))
    pad = (-d) % bd
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad)))
        x, u, p, p_prev = padf(x), padf(u), padf(p), padf(p_prev)
    dp = d + pad
    grid = (dp // bd,)

    kernel = functools.partial(_consensus_kernel, alpha=alpha)
    tile = pl.BlockSpec((m, bd), lambda i: (0, i))

    x_out, u_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, m), lambda i: (0, 0)),
                  tile, tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((m, dp), x.dtype),
                   jax.ShapeDtypeStruct((m, dp), u.dtype)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(mix, x, u, p, p_prev)
    if pad:
        x_out, u_out = x_out[:, :d], u_out[:, :d]
    return x_out, u_out
