"""End-to-end training driver: decentralized bilevel LM training.

Runs real INTERACT iterations (not a dry-run) on whatever devices exist —
the same code path scales from 1 CPU to the production mesh.  For CPU use,
pick a reduced config (``--reduced``).

Example (the deliverable-scale run: ~100M-param model, few hundred steps):

  PYTHONPATH=src python -m repro.launch.train \
      --arch smollm-360m --reduced --steps 300 --agents 4 \
      --per-agent-batch 4 --seq-len 256 --ckpt-dir /tmp/interact_ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpoint import latest_step, restore_step, save_step
from repro.configs import ARCH_IDS, get_config
from repro.data.synthetic import TokenTaskStream
from repro.launch.mesh import agent_axes, make_production_mesh
from repro.sharding.partition import tree_shardings
from repro.train.bilevel_lm import BilevelHyper
from repro.sharding.compat import set_mesh
from repro.train.step import (
    InteractConfig, init_train_state, make_train_step, train_state_specs)


def make_host_mesh(num_agents: int):
    """A mesh over however many real devices exist: agents on 'data'."""
    devs = jax.devices()
    n = len(devs)
    model = max(1, n // num_agents)
    data = min(num_agents, n)
    if data * model > n:
        model = 1
    import numpy as np
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devs[:data * model])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="smollm-360m")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--per-agent-batch", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--alpha", type=float, default=0.02)
    ap.add_argument("--beta", type=float, default=0.5)
    ap.add_argument("--neumann-k", type=int, default=3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=1024, dtype="float32")

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        mesh = make_host_mesh(args.agents)
    a_axes = agent_axes(mesh)
    m = int(np.prod([mesh.shape[a] for a in a_axes]))
    aent = a_axes if len(a_axes) > 1 else a_axes[0]
    print(f"mesh {dict(mesh.shape)}; {m} agents; arch {cfg.name} "
          f"({'reduced' if args.reduced else 'full'})")

    icfg = InteractConfig(
        alpha=args.alpha, beta=args.beta,
        hyper=BilevelHyper(mu_g=0.1, neumann_k=args.neumann_k,
                           lipschitz_g=2.0,
                           ce_chunk=min(512, args.seq_len),
                           remat=not args.reduced))

    state = init_train_state(cfg, jax.random.PRNGKey(0), m)
    specs = train_state_specs(state, mesh)
    state = jax.device_put(state, tree_shardings(mesh, specs))

    start = 0
    if args.ckpt_dir:
        # latest_step validates (skips corrupt/truncated files); the
        # fallback covers a file rotting between the two calls — a
        # crashed run resumes from the newest checkpoint that actually
        # restores (docs/RESILIENCE.md).
        last = latest_step(args.ckpt_dir)
        if last is not None:
            print(f"restoring step {last} from {args.ckpt_dir}")
            state = jax.device_put(
                restore_step(args.ckpt_dir, last, state, fallback=True),
                tree_shardings(mesh, specs))
            start = last

    stream = TokenTaskStream(vocab_size=cfg.vocab_size, num_agents=m, seed=7)
    step_fn = make_train_step(cfg, mesh, icfg)
    tok_shard = NamedSharding(mesh, P(aent))

    with set_mesh(mesh):
        jstep = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        for t in range(start, args.steps):
            tokens = jax.device_put(
                stream.global_batch(t, args.per_agent_batch, args.seq_len),
                tok_shard)
            state, metrics = jstep(state, tokens)
            if (t + 1) % args.log_every == 0:
                ce = float(metrics["outer_ce"])
                gn = float(metrics["grad_norm"])
                dt = (time.time() - t0) / args.log_every
                print(f"step {t + 1:5d}  outer_ce {ce:.4f}  "
                      f"tracked_grad_norm {gn:.3e}  {dt:.2f}s/step")
                t0 = time.time()
            if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
                save_step(args.ckpt_dir, t + 1, jax.device_get(state))
                print(f"checkpointed step {t + 1}")

    print("done.")


if __name__ == "__main__":
    main()
