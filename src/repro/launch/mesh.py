"""Production meshes.

Single pod:  (16, 16)      axes ("data", "model")    — 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

The *agent* axis of the paper (the peer-to-peer network) is the data axis,
extended across pods in the multi-pod mesh: agents = pod-major ring, so
only the two ring edges crossing the pod boundary use DCI (DESIGN.md §3).

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "agent_axes", "agent_count", "model_axis"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — the "
            "dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def agent_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that together form the paper's agent ring."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def agent_count(mesh) -> int:
    n = 1
    for ax in agent_axes(mesh):
        n *= mesh.shape[ax]
    return n


def model_axis(mesh) -> str:
    return "model"
