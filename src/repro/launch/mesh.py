"""Production meshes.

Single pod:  (16, 16)      axes ("data", "model")    — 256 chips (v5e pod)
Multi-pod:   (2, 16, 16)   axes ("pod", "data", "model") — 512 chips

The *agent* axis of the paper (the peer-to-peer network) is the data axis,
extended across pods in the multi-pod mesh: agents = pod-major ring, so
only the two ring edges crossing the pod boundary use DCI (DESIGN.md §3).

``shape=`` overrides the hard-coded pod shapes for anything smaller —
the localhost multi-process driver (scripts/launch_local.py) and the CI
smoke runs build e.g. a ``(8,)`` mesh over 2 processes x 4 forced host
devices.  Axis names default by rank: ``("data",)``, ``("data",
"model")``, ``("pod", "data", "model")``.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state.
"""
from __future__ import annotations

from typing import Sequence

import jax

__all__ = ["make_production_mesh", "agent_axes", "agent_count", "model_axis"]

_DEFAULT_AXES = {1: ("data",), 2: ("data", "model"),
                 3: ("pod", "data", "model")}


def make_production_mesh(*, multi_pod: bool = False,
                         shape: Sequence[int] | None = None,
                         axis_names: Sequence[str] | None = None):
    """Build the device mesh, hard-failing on a device shortfall.

    Without ``shape`` this is the fixed 256-chip pod (512 with
    ``multi_pod``).  ``shape`` overrides it with any validated shape
    (rank 1-3, positive dims); ``axis_names`` must match its rank and
    defaults to the rank's conventional names.  In a multi-process run
    ``jax.devices()`` spans every process, so the same call on every
    process yields the same global mesh.
    """
    if shape is None:
        if axis_names is not None:
            raise ValueError("axis_names= requires an explicit shape=")
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    else:
        if multi_pod:
            raise ValueError("pass either multi_pod=True or shape=, not both")
        shape = tuple(int(s) for s in shape)
        if not shape or any(s < 1 for s in shape):
            raise ValueError(f"mesh shape must be positive dims, got {shape}")
        if axis_names is None:
            axes = _DEFAULT_AXES.get(len(shape))
            if axes is None:
                raise ValueError(
                    f"no default axis names for a rank-{len(shape)} mesh; "
                    "pass axis_names=")
        else:
            axes = tuple(axis_names)
            if len(axes) != len(shape):
                raise ValueError(
                    f"axis_names {axes} does not match mesh shape {shape}")
    import numpy as np
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)} — the "
            "dry-run launcher must set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={need} before any jax import (or pass a smaller "
            "shape=)")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def agent_axes(mesh) -> tuple[str, ...]:
    """Mesh axes that together form the paper's agent ring."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def agent_count(mesh) -> int:
    n = 1
    for ax in agent_axes(mesh):
        n *= mesh.shape[ax]
    return n


def model_axis(mesh) -> str:
    return "model"
