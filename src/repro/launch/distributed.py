"""Multi-process mesh launch path: ``jax.distributed`` + shard_map solver.

The simulator (``repro.solvers``) runs every agent in one process; the
mesh backends (ppermute, allgather) already mix *inside* ``shard_map``
but the repo never stood up an actual multi-process run.  This module
closes that gap:

* ``initialize`` / ``initialize_from_env`` — ``jax.distributed``
  bring-up (gloo CPU collectives), idempotent, driven by CLI args or the
  ``REPRO_COORDINATOR`` / ``REPRO_NUM_PROCESSES`` / ``REPRO_PROCESS_ID``
  env vars the localhost driver (scripts/launch_local.py) exports.
* ``agent_mesh`` — the global device mesh over ``jax.devices()`` (which
  spans every process after initialize), agents on the ``data`` axis.
* ``run_section6`` — the paper's Section-6 synthetic instance stepped by
  the registry INTERACT solver whose raw step body is wrapped in a
  *full-manual* shard_map over the mesh (the old-JAX partitioner cannot
  lower collectives inside partially-manual bodies — sharding/compat),
  with the eq.-11 stationarity metric recorded host-side and a
  ``CommsLedger`` measuring the bytes the compiled program actually
  ships (docs/DISTRIBUTED.md).

Everything here must run in lockstep on every process: the same
``run_section6`` call with the same arguments, so each process computes
the identical host-side setup (same seeds) and participates in every
collective.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_production_mesh
from repro.sharding.compat import set_mesh, shard_map

__all__ = [
    "DistributedConfig",
    "agent_mesh",
    "initialize",
    "initialize_from_env",
    "run_section6",
    "shard_host_tree",
    "shutdown",
]

ENV_COORDINATOR = "REPRO_COORDINATOR"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"
ENV_PROCESS_ID = "REPRO_PROCESS_ID"

_initialized = False


@dataclasses.dataclass
class DistributedConfig:
    """Where this process sits in the multi-process run."""

    coordinator: str = "127.0.0.1:12355"
    num_processes: int = 1
    process_id: int = 0


def initialize(config: DistributedConfig) -> bool:
    """``jax.distributed.initialize`` for this process (idempotent).

    Must run before anything touches jax device state (``jax.devices``,
    any computation) — the backend is finalised on first use.  Selects
    the gloo CPU collectives implementation so cross-process psum /
    ppermute / all_gather lower on the CPU backend.  Returns True when a
    distributed runtime is (now) up.
    """
    global _initialized
    if _initialized:
        return True
    jax.config.update("jax_cpu_collectives_implementation", "gloo")
    jax.distributed.initialize(
        coordinator_address=config.coordinator,
        num_processes=int(config.num_processes),
        process_id=int(config.process_id))
    _initialized = True
    return True


def initialize_from_env() -> bool:
    """Initialize from ``REPRO_*`` env vars; no-op without them.

    The localhost driver exports them for every worker; single-process
    callers (tests, the simulator) simply never set them.
    """
    coord = os.environ.get(ENV_COORDINATOR)
    nproc = int(os.environ.get(ENV_NUM_PROCESSES, "0") or 0)
    if coord is None or nproc < 1:
        return False
    return initialize(DistributedConfig(
        coordinator=coord, num_processes=nproc,
        process_id=int(os.environ.get(ENV_PROCESS_ID, "0"))))


def shutdown() -> None:
    """Tear the distributed runtime down (idempotent)."""
    global _initialized
    if _initialized:
        jax.distributed.shutdown()
        _initialized = False


def agent_mesh(num_agents: int):
    """The global mesh with agents on ``data``: ``(m,)`` or ``(m, k)``.

    ``jax.devices()`` spans every process after ``initialize``; the
    device count must be a multiple of ``num_agents`` (the surplus
    becomes the model axis).  Raises an actionable error otherwise.
    """
    n = len(jax.devices())
    m = int(num_agents)
    if m < 1 or n < m or n % m:
        raise ValueError(
            f"num_agents={m} does not divide the {n} mesh devices — pick "
            f"m from the divisors of {n}, or relaunch with "
            f"--devices-per-process so processes x devices is a multiple "
            f"of m (scripts/launch_local.py)")
    model = n // m
    shape = (m,) if model == 1 else (m, model)
    return make_production_mesh(shape=shape)


def _leaf_spec(leaf, num_agents: int):
    nd = getattr(leaf, "ndim", 0)
    shaped = nd and leaf.shape[0] == num_agents
    return P("data") if shaped else P()


def shard_host_tree(mesh, tree, num_agents: int):
    """Host (numpy) tree -> global jax.Arrays on ``mesh``.

    Leaves with a leading agent dim go ``P("data")``, everything else
    replicated.  Every process must hold the identical host tree (same
    seeds) and call this in lockstep; each fills only its addressable
    shards (``jax.make_array_from_callback``).
    """

    def put(leaf):
        host = np.asarray(leaf)
        sharding = NamedSharding(mesh, _leaf_spec(host, num_agents))
        return jax.make_array_from_callback(
            host.shape, sharding, lambda idx, h=host: h[idx])

    return jax.tree_util.tree_map(put, tree)


def _spec_tree(tree, num_agents: int):
    return jax.tree_util.tree_map(
        lambda l: _leaf_spec(l, num_agents), tree)


def _make_gather(mesh):
    """Host-gather closure: ``P("data")``-sharded tree -> full numpy.

    A jitted identity with replicated ``out_shardings`` — XLA inserts
    the cross-process all-gather; every process gets the same bytes.
    One closure per mesh so repeated metric evaluations reuse the
    compile (jit caches per input structure).
    """
    rep = NamedSharding(mesh, P())
    ident = jax.jit(lambda t: t, out_shardings=rep)

    def gather(tree):
        return jax.tree_util.tree_map(
            np.asarray, jax.device_get(ident(tree)))

    return gather


def _digest(host_tree) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(host_tree):
        h.update(np.ascontiguousarray(leaf).tobytes())
    return h.hexdigest()


def run_section6(*, num_agents: int = 8, num_steps: int = 30,
                 record_every: int = 10, backend: str = "allgather",
                 compression=None, communication_interval: int = 1,
                 seed: int = 0, n_per_agent: int = 80, d_in: int = 8,
                 hidden: int = 8, classes: int = 3,
                 alpha: float = 0.1, beta: float = 0.1,
                 metric_inner_steps: int = 120,
                 metric_inner_lr: float = 0.5,
                 latency_reps: int = 5) -> dict:
    """Section-6 INTERACT on the device mesh, measured end to end.

    Builds the synthetic instance and the registry solver identically on
    every process, shards state/data over ``agent_mesh(num_agents)``,
    wraps the solver's raw step in full-manual shard_map, scans it in
    record_every chunks, and evaluates the eq.-11 stationarity metric
    host-side between chunks on the gathered iterates — the *same*
    ``convergence_metric`` computation the single-process baseline runs,
    so matched runs agree to float tolerance and identical-program runs
    agree bitwise (the ``digest`` field).

    A ``CommsLedger`` is attached before the trace, so the returned
    ``measured_wire_bytes`` is what the compiled program shipped;
    ``priced_wire_bytes`` is the broadcast model
    (``cumulative_wire_bytes``) and ``per_link_priced_bytes`` the
    ppermute unicast model — the ``check_distributed`` gate reconciles
    measured against the model matching the backend.

    Returns a JSON-ready dict (identical on every process apart from
    ``round_latency_us``, which is this process's own timing).
    """
    from repro.consensus import attach_ledger, cumulative_wire_bytes, \
        time_round_us
    from repro.core import convergence_metric
    from repro.solvers import SolverConfig, make_solver
    from repro.solvers.api import default_setup

    if backend not in ("allgather", "ppermute"):
        raise ValueError(
            f"the mesh runner drives the shard_map backends "
            f"('allgather', 'ppermute'), got {backend!r}")

    m = int(num_agents)
    mesh = agent_mesh(m)
    problem, x0, y0, data = default_setup(
        seed, num_agents=m, n_per_agent=n_per_agent, d_in=d_in,
        hidden=hidden, classes=classes)

    config = SolverConfig(
        algo="interact", alpha=alpha, beta=beta, num_agents=m,
        backend=backend, backend_opts={"agent_axes": ("data",)},
        compression=compression,
        communication_interval=communication_interval, seed=seed)
    solver = make_solver(config)
    state = solver.init(None, problem, None, x0, y0, data)
    ledger = attach_ledger(solver._engine)

    host_state = jax.tree_util.tree_map(np.asarray, jax.device_get(state))
    host_data = jax.tree_util.tree_map(np.asarray, jax.device_get(data))
    gstate = shard_host_tree(mesh, host_state, m)
    gdata = shard_host_tree(mesh, host_data, m)

    sspec = _spec_tree(host_state, m)
    dspec = _spec_tree(host_data, m)
    manual = set(mesh.axis_names)
    raw = solver._raw_step
    smap_step = shard_map(raw, mesh=mesh, in_specs=(sspec, dspec),
                          out_specs=sspec, axis_names=manual,
                          check_vma=False)

    def chunk(s, d, length):
        def body(c, _):
            return smap_step(c, d), None

        out, _ = jax.lax.scan(body, s, xs=None, length=length)
        return out

    jchunk = jax.jit(chunk, static_argnums=2, donate_argnums=0)
    gather = _make_gather(mesh)

    def metric(gs) -> float:
        host = gather({"x": gs.x, "y": gs.y})
        rep = convergence_metric(problem, solver._hg_cfg, host["x"],
                                 host["y"], metric_inner_steps,
                                 metric_inner_lr, data)
        return float(rep.total)

    step_chunk = record_every if record_every else num_steps
    lengths = [step_chunk] * (num_steps // step_chunk)
    if num_steps % step_chunk:
        lengths.append(num_steps % step_chunk)

    trace = []
    with set_mesh(mesh):
        for length in lengths:
            trace.append(metric(gstate))
            gstate = jchunk(gstate, gdata, length)
        final_metric = metric(gstate)
        trace.append(final_metric)

        engine = solver._engine
        xspec = _spec_tree(host_state.x, m)
        mix_fn = jax.jit(shard_map(
            lambda t: engine.mix(t), mesh=mesh, in_specs=(xspec,),
            out_specs=xspec, axis_names=manual, check_vma=False))
        ledger.observe_latency(
            time_round_us(mix_fn, gstate.x, reps=latency_reps))

        host_x = gather(gstate.x)

    ledger.commit_steps(num_steps)
    payload_entries = sum(
        int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(x0))
    priced = cumulative_wire_bytes(
        engine.compression, payload_entries, num_steps,
        comms_per_step=solver.communications_per_step,
        communication_interval=communication_interval)[-1]
    per_agent_payload = jax.tree_util.tree_map(lambda l: l[0], host_state.x)
    per_link = (solver.communications_per_step * num_steps
                * engine.bytes_on_wire(per_agent_payload))

    return {
        "backend": backend,
        "num_agents": m,
        "num_processes": jax.process_count(),
        "num_devices": len(jax.devices()),
        "mesh_shape": dict(mesh.shape),
        "num_steps": num_steps,
        "compression": engine.compression.kind,
        "final_metric": final_metric,
        "trace": trace,
        "digest": _digest(host_x),
        "measured_wire_bytes": ledger.measured_wire_bytes,
        "priced_wire_bytes": float(priced),
        "per_link_priced_bytes": float(per_link),
        "round_latency_us": ledger.round_latency_us,
        "ledger": ledger.summary(),
    }
