"""Serving steps: prefill (full-sequence forward) and decode (one token).

Serving is standard single-copy inference — no agent semantics: params are
replicated across the data axes and sharded on ``model``; the request
batch shards across the data axes.  Decode state (KV caches / SSM states)
shards per ``repro.sharding.partition.cache_specs`` — batch over data when
possible, the cache *sequence* over data for the single-request long_500k
shape.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models.base import ArchConfig

__all__ = ["make_prefill_step", "make_serve_step"]


def make_prefill_step(cfg: ArchConfig, *, attn_impl: str = "reference",
                      seq_shard: bool = False):
    """prefill(params, tokens[, prefix]) -> last-token logits.

    The full-sequence forward; in production the same pass also emits the
    KV cache (pure stores, fused by XLA) — the compute/communication
    profile analysed by the roofline is this forward.
    """

    def prefill(params, tokens, prefix=None):
        # Only the last position's logits are needed to start decoding:
        # slice features BEFORE the head matmul so the (batch, seq, vocab)
        # logits tensor never exists (perf iteration P1, EXPERIMENTS.md).
        act_spec = None
        if seq_shard:
            from jax.sharding import PartitionSpec as P
            act_spec = P(None, "model", None)
        feats, _aux = M.features(cfg, params, tokens, prefix_embed=prefix,
                                 impl=attn_impl, remat=False,
                                 act_spec=act_spec)
        head = params["head"] if "head" in params else params["embed"].T
        return M.head_logits(cfg, head, feats[:, -1:, :])[:, 0, :]

    return prefill


def make_serve_step(cfg: ArchConfig, *, attn_impl: str = "reference"):
    """serve(params, token, cache, position) -> (logits, new_cache).

    ONE new token per request against a seq_len-deep cache (the assigned
    decode_32k / long_500k shapes).
    """

    def serve(params, token, cache, position):
        head = params["head"] if "head" in params else None
        logits, new_cache = M.decode_step(cfg, params, head, token, cache,
                                          position, impl=attn_impl)
        return logits[:, 0, :], new_cache

    return serve
