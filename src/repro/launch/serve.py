"""Serving driver: prefill a prompt batch, then decode tokens.

The production-mesh path is exercised by the dry-run; this driver runs
real decoding on whatever devices exist (reduced configs on CPU).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
      --batch 4 --prompt-len 64 --new-tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.launch.serving import make_serve_step
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_prefix_tokens=0, frontend="none",
                          dtype="float32")
    key = jax.random.PRNGKey(args.seed)
    params = M.init_params(cfg, key, with_head=True)
    print(f"{cfg.name}: {M.param_count(params):,} params "
          f"({'reduced' if args.reduced else 'full'})")

    max_len = args.prompt_len + args.new_tokens
    cache = M.init_cache(cfg, batch=args.batch, max_len=max_len)
    serve = jax.jit(make_serve_step(cfg))

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = serve(params, prompts[:, t:t + 1], cache,
                              jnp.asarray(t, jnp.int32))
    print(f"prefill {args.prompt_len} tokens x {args.batch}: "
          f"{time.time() - t0:.2f}s")

    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [tok]
    t0 = time.time()
    for t in range(args.prompt_len, max_len - 1):
        logits, cache = serve(params, tok, cache, jnp.asarray(t, jnp.int32))
        if args.temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(
                k, logits / args.temperature, axis=-1)[:, None]
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None]
        out.append(tok)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"decoded {gen.shape[1]} x {args.batch} in {dt:.2f}s "
          f"({args.batch * gen.shape[1] / max(dt, 1e-9):.0f} tok/s)")
    for i in range(min(args.batch, 4)):
        print(f"  req {i}: {list(map(int, gen[i][:16]))}")


if __name__ == "__main__":
    main()
