"""Multi-pod dry-run: lower + compile every (architecture x input shape).

For each combination this driver builds the production mesh, constructs
ShapeDtypeStruct inputs (no allocation), lowers the appropriate step
(train_step for train_4k, prefill/serve for the inference shapes),
compiles it, and records:

  * compiled.memory_analysis()  — proves the program fits per-device HBM
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline
  * collective statistics parsed from the optimized HLO — wire bytes per
    collective kind for the roofline's communication term.

Results are written as JSON under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-360m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
from __future__ import annotations

# The dry-run needs 512 placeholder devices; jax locks the device count at
# first init, so this MUST precede every jax import (including repro.*).
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import re
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch import input_specs as specs_mod
from repro.launch.mesh import agent_axes, make_production_mesh
from repro.launch.serving import make_prefill_step, make_serve_step
from repro.roofline.analysis import normalize_cost_analysis
from repro.sharding.compat import set_mesh
from repro.models.base import ArchConfig
from repro.sharding.partition import (
    cache_specs, leaf_spec, tree_shardings, tree_specs)
from repro.train.bilevel_lm import BilevelHyper
from repro.train.step import (
    InteractConfig, make_train_step, train_state_specs)

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"=\s*(?P<dtype>[a-z0-9]+)\[(?P<shape>[0-9,]*)\][^ ]*\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# Wire-byte weights per collective (ring algorithms, per participating
# chip): all-reduce moves ~2x the tensor, the others ~1x.
_WIRE_WEIGHT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collectives(hlo_text: str) -> dict[str, Any]:
    stats: dict[str, dict[str, float]] = {}
    for mt in COLLECTIVE_RE.finditer(hlo_text):
        op = mt.group("op")
        shape = mt.group("shape")
        numel = 1
        if shape:
            for d in shape.split(","):
                if d:
                    numel *= int(d)
        nbytes = numel * _DTYPE_BYTES.get(mt.group("dtype"), 4)
        ent = stats.setdefault(op, {"count": 0, "bytes": 0.0})
        ent["count"] += 1
        ent["bytes"] += nbytes
    total_wire = sum(_WIRE_WEIGHT[k] * v["bytes"] for k, v in stats.items())
    return {"per_op": stats, "wire_bytes": total_wire}


OPT_MOE_CHUNK = 8192


def optimized_config(cfg: ArchConfig) -> ArchConfig:
    """Beyond-paper perf variant (EXPERIMENTS.md §Perf): chunked MoE
    dispatch (P3), expert-parallel pinning when E % 16 == 0 (P5);
    blockwise attention (P2) and selective sequence sharding (P4) are
    threaded via attn_impl / seq_shard below."""
    import dataclasses
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, moe_token_chunk=OPT_MOE_CHUNK,
            expert_parallel=cfg.num_experts % 16 == 0)
    if cfg.family == "hybrid":
        cfg = dataclasses.replace(cfg, mamba_seq_chunk=512)  # P7
    return cfg


def _train_hyper(cfg: ArchConfig, opt: bool,
                 agent_mode: str = "rows") -> InteractConfig:
    return InteractConfig(
        alpha=1e-2, beta=0.5,
        hyper=BilevelHyper(mu_g=0.1, neumann_k=2, lipschitz_g=2.0,
                           ce_chunk=512, remat=True,
                           attn_impl="blockwise" if opt else "reference",
                           seq_shard=opt and agent_mode == "rows"
                           and cfg.family in ("dense", "vlm", "audio"),
                           batch_shard=agent_mode == "pods",
                           microbatch=4 if opt else 1))


def lower_train(cfg: ArchConfig, mesh, opt: bool = False,
                agent_mode: str = "rows"):
    if opt:
        cfg = optimized_config(cfg)
    icfg = _train_hyper(cfg, opt, agent_mode)
    step = make_train_step(cfg, mesh, icfg, agent_mode=agent_mode)
    if agent_mode == "pods":
        from repro.train.step import init_train_state
        m_agents = mesh.shape.get("pod", 1)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        state_sh = jax.eval_shape(
            lambda k: init_train_state(cfg, k, m_agents), key)
    else:
        state_sh = specs_mod.state_shapes(cfg, mesh)
    st_specs = train_state_specs(state_sh, mesh, agent_mode=agent_mode)
    st_shardings = tree_shardings(mesh, st_specs)
    if agent_mode == "pods":
        sd = specs_mod.SHAPES["train_4k"]
        m_agents = mesh.shape.get("pod", 1)
        inputs = {"tokens": jax.ShapeDtypeStruct(
            (m_agents, sd.global_batch // m_agents, sd.seq_len), jnp.int32)}
        tok_shard = NamedSharding(mesh, P("pod", "data"))
        a_axes = ("pod",)
        aent = "pod"
    else:
        inputs = specs_mod.train_inputs(cfg, mesh)
        a_axes = agent_axes(mesh)
        aent = a_axes if len(a_axes) > 1 else a_axes[0]
        tok_shard = NamedSharding(mesh, P(aent))
    args = [state_sh, inputs["tokens"]]
    in_shardings = [st_shardings, tok_shard]
    if "prefix" in inputs:
        args.append(inputs["prefix"])
        in_shardings.append(NamedSharding(mesh, P(aent)))
    jitted = jax.jit(
        step,
        in_shardings=tuple(in_shardings),
        out_shardings=(st_shardings,
                       {"outer_ce": NamedSharding(mesh, P()),
                        "grad_norm": NamedSharding(mesh, P())}),
        donate_argnums=(0,),
    )
    with set_mesh(mesh):
        return jitted.lower(*args)


def lower_prefill(cfg: ArchConfig, mesh, opt: bool = False):
    if opt:
        cfg = optimized_config(cfg)
    data_axes = agent_axes(mesh)  # batch over data (+pod)
    dent = data_axes if len(data_axes) > 1 else data_axes[0]
    params_sh = specs_mod.params_shapes(cfg, with_head=True)
    p_specs = tree_specs(params_sh, mesh.shape["model"])
    p_shardings = tree_shardings(mesh, p_specs)
    inputs = specs_mod.prefill_inputs(cfg)
    # P4 refuted for prefill (wire regression, EXPERIMENTS.md): never here.
    fn = make_prefill_step(cfg, attn_impl="blockwise" if opt else "reference",
                           seq_shard=False)
    args = [params_sh, inputs["tokens"]]
    in_sh = [p_shardings, NamedSharding(mesh, P(dent))]
    if "prefix" in inputs:
        args.append(inputs["prefix"])
        in_sh.append(NamedSharding(mesh, P(dent)))
    jitted = jax.jit(fn, in_shardings=tuple(in_sh),
                     out_shardings=NamedSharding(mesh, P(dent, "model")))
    with set_mesh(mesh):
        return jitted.lower(*args)


def lower_decode(cfg: ArchConfig, mesh, shape: str, opt: bool = False):
    if opt:
        cfg = optimized_config(cfg)
    if shape == "long_500k":
        cfg = specs_mod.long_context_config(cfg)
    sd = specs_mod.SHAPES[shape]
    params_sh = specs_mod.params_shapes(cfg, with_head=True)
    p_shardings = tree_shardings(mesh, tree_specs(params_sh,
                                                  mesh.shape["model"]))
    inputs = specs_mod.decode_inputs(cfg, shape)
    c_specs = cache_specs(inputs["cache"], mesh, sd.global_batch)
    c_shardings = tree_shardings(mesh, c_specs)
    data_axes = agent_axes(mesh)
    dent = data_axes if len(data_axes) > 1 else data_axes[0]
    batch_shardable = sd.global_batch % int(
        np.prod([mesh.shape[a] for a in data_axes])) == 0
    tok_spec = P(dent) if batch_shardable else P()
    fn = make_serve_step(cfg)
    jitted = jax.jit(
        fn,
        in_shardings=(p_shardings, NamedSharding(mesh, tok_spec),
                      c_shardings, NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, tok_spec), c_shardings),
        donate_argnums=(2,),
    )
    with set_mesh(mesh):
        return jitted.lower(params_sh, inputs["token"], inputs["cache"],
                            inputs["position"])


def run_one(arch: str, shape: str, multi_pod: bool,
            save: bool = True, opt: bool = False,
            agent_mode: str = "rows") -> dict[str, Any]:
    cfg = get_config(arch)
    ok, why = specs_mod.shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "skipped": True, "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    if shape == "train_4k":
        lowered = lower_train(cfg, mesh, opt=opt, agent_mode=agent_mode)
    elif shape == "prefill_32k":
        lowered = lower_prefill(cfg, mesh, opt=opt)
    else:
        lowered = lower_decode(cfg, mesh, shape, opt=opt)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    n_dev = int(np.prod(list(mesh.shape.values())))
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": dict(mesh.shape),
        "devices": n_dev,
        "skipped": False,
        "optimized": opt,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "transcendentals": cost.get("transcendentals"),
        },
        "collectives": coll,
    }
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        tag = "multipod" if multi_pod else "pod"
        if opt:
            tag += "-opt"
        if agent_mode == "pods":
            tag += "-agentpods"
        out = RESULTS_DIR / f"{arch}__{shape}__{tag}.json"
        out.write_text(json.dumps(result, indent=2))
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", choices=list(specs_mod.SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--opt", action="store_true",
                    help="lower the beyond-paper optimized variant")
    ap.add_argument("--agents-per-pod", action="store_true",
                    help="P6 layout: agents = pods, FSDP inside the pod "
                         "(requires --multi-pod)")
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in specs_mod.SHAPES:
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos = [(args.arch, args.shape)]

    failures = []
    for arch, shape in combos:
        try:
            res = run_one(arch, shape, args.multi_pod, opt=args.opt,
                          agent_mode="pods" if args.agents_per_pod
                          else "rows")
        except Exception as e:  # keep sweeping; report at the end
            failures.append((arch, shape, repr(e)[:300]))
            print(f"[FAIL] {arch} x {shape}: {e!r}"[:400], flush=True)
            continue
        if res.get("skipped"):
            print(f"[SKIP] {arch} x {shape}: {res['reason']}")
            continue
        mem = res["memory"]
        arg_gb = (mem["argument_size_bytes"] or 0) / 2**30
        tmp_gb = (mem["temp_size_bytes"] or 0) / 2**30
        print(f"[OK] {arch} x {shape} ({'2x16x16' if args.multi_pod else '16x16'}): "
              f"compile {res['compile_s']}s, args {arg_gb:.2f} GiB/dev, "
              f"temps {tmp_gb:.2f} GiB/dev, flops {res['cost']['flops']:.3e}, "
              f"wire {res['collectives']['wire_bytes'] / 2**30:.3f} GiB",
              flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for arch, shape, err in failures:
            print(f"  {arch} x {shape}: {err}")
        raise SystemExit(1)
    print("\nall combinations lowered and compiled.")


if __name__ == "__main__":
    main()
