"""ShapeDtypeStruct stand-ins for every (architecture x input shape) pair.

No device allocation: everything here is ``jax.ShapeDtypeStruct`` (weights
and state via ``jax.eval_shape`` over the real initialisers), ready for
``jax.jit(...).lower()``.

Assigned input shapes:

  train_4k     seq=4096    global_batch=256   (training, INTERACT step)
  prefill_32k  seq=32768   global_batch=32    (inference prefill)
  decode_32k   seq=32768   global_batch=128   (decode: 1 token + cache)
  long_500k    seq=524288  global_batch=1     (long-context decode)

long_500k applies only to sub-quadratic-state archs (DESIGN.md §4):
rwkv6-3b, jamba-1.5-large-398b, mixtral-8x7b (SWA), gemma2-2b (window
long-context mode).  ``shape_applicable`` encodes the skips.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.base import ArchConfig
from repro.launch.mesh import agent_count

__all__ = ["SHAPES", "ShapeDef", "shape_applicable", "train_inputs",
           "prefill_inputs", "decode_inputs", "state_shapes",
           "LONG_CONTEXT_OK", "long_context_config"]


@dataclasses.dataclass(frozen=True)
class ShapeDef:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeDef("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeDef("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeDef("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeDef("long_500k", 524288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic decode state).
LONG_CONTEXT_OK = {
    "rwkv6-3b": "recurrent O(1) state",
    "jamba-1.5-large-398b": "mamba state + 1:8 attention with cache",
    "mixtral-8x7b": "sliding-window attention, cache bounded at 4096",
    "gemma2-2b": "local layers SWA; global layers forced to window mode",
}


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    if shape != "long_500k":
        return True, ""
    if cfg.name in LONG_CONTEXT_OK:
        return True, LONG_CONTEXT_OK[cfg.name]
    return False, ("full-attention architecture without a sliding-window "
                   "variant; unbounded KV cache fails the sub-quadratic "
                   "gate (DESIGN.md §4)")


def long_context_config(cfg: ArchConfig) -> ArchConfig:
    """gemma2's long_500k deviation: window every attention layer."""
    if cfg.name == "gemma2-2b":
        return dataclasses.replace(cfg, long_context_mode="window")
    return cfg


def _itoken(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _prefix_struct(cfg: ArchConfig, batch: int):
    if cfg.frontend == "none" or not cfg.num_prefix_tokens:
        return None
    fd = cfg.frontend_dim or cfg.d_model
    return jax.ShapeDtypeStruct((batch, cfg.num_prefix_tokens, fd),
                                jnp.dtype(cfg.dtype))


def train_inputs(cfg: ArchConfig, mesh) -> dict[str, Any]:
    sd = SHAPES["train_4k"]
    m = agent_count(mesh)
    per_agent = sd.global_batch // m
    out = {"tokens": _itoken((m, per_agent, sd.seq_len))}
    prefix = _prefix_struct(cfg, per_agent)
    if prefix is not None:
        out["prefix"] = jax.ShapeDtypeStruct(
            (m,) + prefix.shape, prefix.dtype)
    return out


def prefill_inputs(cfg: ArchConfig) -> dict[str, Any]:
    sd = SHAPES["prefill_32k"]
    seq = sd.seq_len - (cfg.num_prefix_tokens
                        if cfg.frontend != "none" else 0)
    out = {"tokens": _itoken((sd.global_batch, seq))}
    prefix = _prefix_struct(cfg, sd.global_batch)
    if prefix is not None:
        out["prefix"] = prefix
    return out


def decode_inputs(cfg: ArchConfig, shape: str) -> dict[str, Any]:
    sd = SHAPES[shape]
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, batch=sd.global_batch,
                             max_len=sd.seq_len))
    return {
        "token": _itoken((sd.global_batch, 1)),
        "cache": cache,
        "position": jax.ShapeDtypeStruct((), jnp.int32),
    }


def state_shapes(cfg: ArchConfig, mesh):
    """TrainState shapes via eval_shape (no allocation)."""
    from repro.train.step import init_train_state
    m = agent_count(mesh)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        lambda k: init_train_state(cfg, k, m), key)


def params_shapes(cfg: ArchConfig, with_head: bool = True):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k, with_head=with_head), key)
