"""Pure-JAX optimizers (no optax dependency in this container).

The paper's algorithms use plain (tracked) gradient steps; these are the
substrate for the non-bilevel examples and for inner-problem solvers.
Each optimizer is (init, update) on arbitrary pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["sgd", "momentum", "adam", "adamw", "clip_by_global_norm",
           "cosine_schedule", "warmup_linear"]


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, state)


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


def sgd(lr: float) -> Optimizer:
    def init(_params):
        return ()

    def update(grads, state, _params=None):
        return _tmap(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return _tmap(jnp.zeros_like, params)

    def update(grads, vel, _params=None):
        vel = _tmap(lambda v, g: beta * v + g, vel, grads)
        if nesterov:
            upd = _tmap(lambda v, g: -lr * (beta * v + g), vel, grads)
        else:
            upd = _tmap(lambda v: -lr * v, vel)
        return upd, vel

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        return AdamState(_tmap(jnp.zeros_like, params),
                         _tmap(jnp.zeros_like, params),
                         jnp.zeros((), jnp.int32))

    def update(grads, state, _params=None):
        count = state.count + 1
        mu = _tmap(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = _tmap(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        upd = _tmap(lambda m, n: -lr * (m / c1) / (jnp.sqrt(n / c2) + eps),
                    mu, nu)
        return upd, AdamState(mu, nu, count)

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    base = adam(lr, b1, b2, eps)

    def update(grads, state, params):
        upd, state = base.update(grads, state, params)
        upd = _tmap(lambda u, p: u - lr * weight_decay * p, upd, params)
        return upd, state

    return Optimizer(base.init, update)


def clip_by_global_norm(grads, max_norm: float):
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads))
    norm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return _tmap(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                 grads), norm


def cosine_schedule(base_lr: float, total_steps: int,
                    final_frac: float = 0.1):
    def lr(step):
        t = jnp.clip(step / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return lr


def warmup_linear(base_lr: float, warmup_steps: int):
    def lr(step):
        return base_lr * jnp.minimum(1.0, (step + 1) / warmup_steps)
    return lr
