"""llama3.2-3b [dense] — small llama3.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B family card]
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b",
    family="dense",
    source="hf:meta-llama/Llama-3.2-1B",
    num_layers=28,
    d_model=3072,
    d_ff=8192,
    vocab_size=128_256,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=500_000.0,
)
