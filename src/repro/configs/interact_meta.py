"""The paper's own experimental model: two-hidden-layer MLP (20 units)
meta-learning task (Section 6).  Not a transformer; used by the
paper-faithful reproduction in repro/core + benchmarks.
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="interact-meta-mlp",
    family="dense",
    source="paper section 6",
    num_layers=2,
    d_model=20,
    d_ff=20,
    vocab_size=10,
    num_heads=1,
    num_kv_heads=1,
    head_dim=20,
    dtype="float32",
)
