"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.  [arXiv:2401.04088]
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    source="arXiv:2401.04088",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=32_000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=1_000_000.0,
    sliding_window=4096,
    num_experts=8,
    experts_per_token=2,
)
