"""Architecture registry: every assigned config plus the paper's own task.

``get_config(name)`` returns the full-size ArchConfig; ``--arch <id>`` in
the launchers resolves through this registry.
"""
from __future__ import annotations

import importlib

from repro.models.base import ArchConfig

ARCH_IDS = (
    "gemma2-2b",
    "qwen3-14b",
    "mixtral-8x7b",
    "jamba-1.5-large-398b",
    "musicgen-medium",
    "rwkv6-3b",
    "smollm-360m",
    "paligemma-3b",
    "dbrx-132b",
    "llama3.2-3b",
)

_MODULES = {
    "gemma2-2b": "gemma2_2b",
    "qwen3-14b": "qwen3_14b",
    "mixtral-8x7b": "mixtral_8x7b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
    "musicgen-medium": "musicgen_medium",
    "rwkv6-3b": "rwkv6_3b",
    "smollm-360m": "smollm_360m",
    "paligemma-3b": "paligemma_3b",
    "dbrx-132b": "dbrx_132b",
    "llama3.2-3b": "llama3_2_3b",
    "interact-meta-mlp": "interact_meta",
}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg: ArchConfig = mod.CONFIG
    cfg.validate()
    return cfg


def all_configs() -> dict[str, ArchConfig]:
    return {name: get_config(name) for name in ARCH_IDS}
