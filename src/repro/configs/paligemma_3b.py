"""paligemma-3b [vlm] — SigLIP vision encoder + gemma decoder, MQA.

18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.  [arXiv:2407.07726]
SigLIP is a STUB per the assignment: ``input_specs()`` provides 256
precomputed patch embeddings (1152-d, SigLIP-So400m width), projected by a
learned linear into the decoder; the language model is fully built.
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    source="arXiv:2407.07726",
    num_layers=18,
    d_model=2048,
    d_ff=16384,
    vocab_size=257_216,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    rope_theta=10_000.0,
    frontend="vision",
    num_prefix_tokens=256,
    frontend_dim=1152,
)
