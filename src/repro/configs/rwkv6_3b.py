"""rwkv6-3b "Finch" [ssm] — attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536.  [arXiv:2404.05892]
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    d_ff=8960,
    vocab_size=65_536,
    num_heads=40,       # d_model / head_size
    num_kv_heads=40,
    rwkv_head_size=64,
)
