"""musicgen-medium [audio] — decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (kv=24, MHA) d_ff=6144 vocab=2048.  [arXiv:2306.05284]
The EnCodec tokenizer / conditioning encoder is a STUB per the assignment:
``input_specs()`` provides precomputed conditioning frame embeddings
(num_prefix_tokens) of frontend_dim; the decoder itself is fully built.
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    d_ff=6144,
    vocab_size=2048,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    rope_theta=10_000.0,
    frontend="audio",
    num_prefix_tokens=64,
    frontend_dim=768,
)
