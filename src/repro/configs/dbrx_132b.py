"""dbrx-132b [moe] — 16 experts top-4, fine-grained MoE.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352.
[hf:databricks/dbrx-base]
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    d_ff=10752,
    vocab_size=100_352,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=500_000.0,
    num_experts=16,
    experts_per_token=4,
)
