"""qwen3-14b [dense] — qk_norm, GQA.  40L d_model=5120 40H (kv=8)
d_ff=17408 vocab=151936.  [hf:Qwen/Qwen3-8B family card]
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=40,
    d_model=5120,
    d_ff=17408,
    vocab_size=151_936,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=1_000_000.0,
    qk_norm=True,
)
