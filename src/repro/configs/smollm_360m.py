"""smollm-360m [dense] — llama-architecture small model.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M family card]
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-360m",
    family="dense",
    source="hf:HuggingFaceTB/SmolLM-135M",
    num_layers=32,
    d_model=960,
    d_ff=2560,
    vocab_size=49_152,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    rope_theta=10_000.0,
)
