"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.  [arXiv:2403.19887]
MoE applied every other layer (moe_every=2), attention 1 layer in 8.
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    source="arXiv:2403.19887",
    num_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab_size=65_536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    rope_theta=10_000.0,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_every=8,
    mamba_d_state=16,
    mamba_d_conv=4,
    mamba_expand=2,
)
