"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4, head_dim=256) d_ff=9216 vocab=256000.
[arXiv:2408.00118]
"""
from repro.models.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    d_ff=9216,
    vocab_size=256_000,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    rope_theta=10_000.0,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    local_global=True,
    local_window=4096,
)
