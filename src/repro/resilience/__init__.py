"""Fault-tolerant resumable runtime (docs/RESILIENCE.md).

Three layers, built on the crash-safe ``repro.checkpoint`` store:

* ``snapshot`` / ``resume`` — bitwise capture of the complete scan
  carry for every registry solver, with a config fingerprint so a
  snapshot can only continue the experiment it came from.
* ``run_resumable`` / ``resume_run`` — the checkpoint-chunked runner
  behind ``SolverBase.run(..., checkpoint_every=...)``: killed at any
  step, resumed, the metric trace is bitwise-equal to the
  uninterrupted scan.
* ``FaultPlan`` / ``chaos_run`` — seeded fault injection (process
  kills, NaN wire payloads, corrupt/stale checkpoints, transient write
  failures) and the recovery loop that survives all of it with zero
  manual intervention.
"""
from repro.resilience.chaos import ChaosReport, chaos_run
from repro.resilience.faults import (Fault, FaultPlan, available_faults,
                                     make_fault, register_fault)
from repro.resilience.runner import (GuardTripFault, NonFiniteStateError,
                                     SimulatedKill, resume_run,
                                     run_resumable)
from repro.resilience.snapshot import (Resumed, config_fingerprint, resume,
                                       snapshot)

__all__ = [
    "ChaosReport",
    "Fault",
    "FaultPlan",
    "GuardTripFault",
    "NonFiniteStateError",
    "Resumed",
    "SimulatedKill",
    "available_faults",
    "chaos_run",
    "config_fingerprint",
    "make_fault",
    "register_fault",
    "resume",
    "resume_run",
    "run_resumable",
    "snapshot",
]
