"""``chaos_run``: survive a seeded fault plan with zero intervention.

The harness loops ``resume_run`` under a ``FaultPlan`` until the run
reaches its final step, treating every injected failure as a real
operations event:

* ``SimulatedKill`` → the process died; rebuild the solver from config
  (a fresh "process") and resume from the newest valid snapshot.
* ``NonFiniteStateError`` → a poisoned chunk was caught before its
  snapshot landed; resume from the last clean boundary and replay.
* ``GuardTripFault`` → the divergence guard fired inside a chunk; roll
  back to the previous checkpoint and retry, at most
  ``max_guard_retries`` times per boundary — a *persistent* adversary
  re-trips deterministically on replay, at which point the in-scan
  guard containment (PR 8) is accepted and the run moves on.  This is
  the shared reporting path the guards and the checkpoint rollback were
  promised: both kinds of rollback surface in one ``ChaosReport``.

Because every restart goes through ``resume`` (newest *valid* snapshot,
corrupt/stale files skipped) the same loop also absorbs the on-disk
faults: truncated archives, CRC-failing garbage, deleted checkpoints,
transient write errors.  The final trace obeys the bitwise-resume
contract — equal to the uninterrupted ``run_traced`` trace — which
``tests/test_resilience.py`` asserts and ``bench_resilience`` gates.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro.checkpoint import latest_step
from repro.resilience.faults import FaultPlan
from repro.resilience.runner import (GuardTripFault, NonFiniteStateError,
                                     SimulatedKill, resume_run)

__all__ = ["ChaosReport", "chaos_run"]


@dataclasses.dataclass
class ChaosReport:
    """What a chaos campaign produced, for tests and the bench gate."""

    completed: bool                 # reached the final step
    restarts: int                   # recovery cycles (any fault kind)
    kills: int                      # SimulatedKill firings survived
    nonfinite_faults: int           # poisoned chunks caught + replayed
    guard_rollbacks: int            # GuardTripFault checkpoint rollbacks
    guard_accepted: int             # boundaries where in-scan containment
                                    # was accepted after retries
    write_retries: int              # injected OSErrors absorbed by backoff
    wasted_steps: int               # replayed work across all restarts
    wall_time_s: float
    trace: np.ndarray | None        # run_traced-layout metric trace
    final_metric: float | None
    tripped_steps: int              # guard counters of the final state
    last_good_step: int
    state: Any = None
    events: list = dataclasses.field(default_factory=list)


def chaos_run(config, plan: FaultPlan, num_steps: int,
              record_every: int = 0, *, checkpoint_every: int, ckpt_dir,
              metric_fn=None, problem=None, hg_cfg=None, x0=None,
              y0=None, data=None, num_agents: int = 5,
              n_per_agent: int = 600, max_restarts: int = 20,
              max_guard_retries: int = 2, retries: int = 3,
              backoff: float = 0.02) -> ChaosReport:
    """Drive ``config`` through ``num_steps`` while ``plan`` injects
    faults; recover until the run completes (or ``max_restarts``).

    Defaults mirror ``repro.solvers.solve``: no problem given runs the
    paper's Section-6 instance, and ``record_every > 0`` with no
    ``metric_fn`` records the eq.-11 stationarity metric.  Each restart
    rebuilds the solver from config — a genuinely fresh process image —
    and resumes from the newest snapshot that restores cleanly.
    """
    from repro.solvers.api import default_setup

    if problem is None or data is None or x0 is None or y0 is None:
        problem, x0, y0, data = default_setup(
            config.seed, num_agents=config.resolve_num_agents(num_agents),
            n_per_agent=n_per_agent)
    if metric_fn is None and record_every:
        from repro.core import convergence_metric_fn
        metric_fn = convergence_metric_fn(
            problem, hg_cfg if hg_cfg is not None else config.hypergrad,
            data)

    guard_active = config.guard.active
    guard_retries: dict[int, int] = {}
    ignore_below = -1
    restarts = kills = nonfinite = rollbacks = accepted = wasted = 0
    completed = False
    solver = state = trace = None
    t0 = time.perf_counter()

    while True:
        start = latest_step(ckpt_dir) or 0
        try:
            solver, state, trace = resume_run(
                config, ckpt_dir, num_steps, record_every, metric_fn,
                checkpoint_every=checkpoint_every, problem=problem,
                hg_cfg=hg_cfg, x0=x0, y0=y0, data=data, hooks=plan,
                raise_on_guard_trip=guard_active,
                guard_ignore_below=ignore_below, retries=retries,
                backoff=backoff)
            completed = True
            break
        except SimulatedKill as exc:
            kills += 1
            wasted += exc.step - start
            plan.log("recover", after="kill", lost=exc.step - start)
        except NonFiniteStateError as exc:
            nonfinite += 1
            wasted += exc.step - start
            plan.log("recover", after="non-finite", lost=exc.step - start)
        except GuardTripFault as exc:
            rollbacks += 1
            wasted += exc.step - start
            n_tries = guard_retries.get(exc.step, 0) + 1
            guard_retries[exc.step] = n_tries
            if n_tries >= max_guard_retries:
                # deterministic replay re-trips a persistent adversary:
                # accept the in-scan guard containment and move on
                accepted += 1
                ignore_below = exc.step
            plan.log("recover", after="guard-trip", boundary=exc.step,
                     attempt=n_tries, accepted=n_tries >= max_guard_retries)
        restarts += 1
        if restarts > max_restarts:
            break

    wall = time.perf_counter() - t0
    guard = getattr(state, "guard", None) if state is not None else None
    final = None
    if trace is not None and np.size(trace):
        final = float(np.asarray(trace)[-1])
    return ChaosReport(
        completed=completed, restarts=restarts, kills=kills,
        nonfinite_faults=nonfinite, guard_rollbacks=rollbacks,
        guard_accepted=accepted, write_retries=plan.count("write-failure"),
        wasted_steps=int(wasted), wall_time_s=wall,
        trace=None if trace is None else np.asarray(trace),
        final_metric=final,
        tripped_steps=0 if guard is None else int(guard["tripped"]),
        last_good_step=-1 if guard is None else int(guard["last_good"]),
        state=state, events=list(plan.events))
