"""Bitwise solver snapshots: the complete scan carry, on disk.

A snapshot is everything a resumed run needs to reproduce the
uninterrupted trajectory bit for bit:

* the solver state pytree — iterates ``x``/``y``, tracked gradients
  ``u``/``v``, the SVR anchors ``x_prev``/``y_prev``/``p_prev``, the
  error-feedback compression state ``ef = {stream: {e, ref}}``, the
  divergence-guard counters, the sampling ``key``, and the step counter
  ``t``.  The step counter is also the *topology-process position* (the
  stream gathers ``matrices[t % T]``) and the *Byzantine schedule
  position* (per-round keys fold ``t``), so those subsystems need no
  separate record — they are pure functions of ``(config, t)``.
* the partial metric column of a traced run (``padded``), so the stitched
  trace equals the single-scan ``run_traced`` output bitwise.
* a sidecar JSON with the run geometry (total steps, record cadence) and
  a fingerprint of the ``SolverConfig``, so resuming against the wrong
  config fails loudly instead of silently continuing a different
  experiment.

Saves go through ``repro.checkpoint`` (atomic replace + per-leaf CRC32)
and retry transient write failures with exponential backoff — the
``write-failure`` chaos fault (docs/RESILIENCE.md) is absorbed here.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pathlib
import tempfile
import time
from typing import Any

import numpy as np

from repro.checkpoint import (CorruptCheckpointError, restore_pytree,
                              save_step)
from repro.checkpoint.checkpoint import _all_steps, _step_path

__all__ = ["Resumed", "config_fingerprint", "resume", "snapshot",
           "snapshot_meta_path", "tree_fingerprint", "write_json_atomic"]

META_FORMAT = 1


def config_fingerprint(config) -> str:
    """Stable hex fingerprint of everything that shapes the trajectory.

    ``static_key()`` covers every trace-static field (algorithm,
    topology, backend, hypergrad, wire, Byzantine, guard) and
    ``batch_values()`` the per-experiment dynamics (seed, alpha, beta) —
    together they pin the run a snapshot belongs to.
    """
    key = repr((type(config).__name__, config.static_key(),
                config.batch_values()))
    return hashlib.sha256(key.encode()).hexdigest()[:16]


def snapshot_meta_path(ckpt_dir, step: int) -> pathlib.Path:
    return pathlib.Path(ckpt_dir) / f"step_{step:08d}.json"


def tree_fingerprint(tree) -> str:
    """Content hash of a pytree's leaves (dtype + shape + bytes).

    The sweep resume manifest uses this to pin cached group results to
    the exact problem data / initial points they were computed on.
    """
    import jax
    h = hashlib.sha256()
    for leaf in jax.tree_util.tree_leaves(tree):
        arr = np.asarray(jax.device_get(leaf))
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


def write_json_atomic(path: pathlib.Path, obj: dict) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(obj, fh)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def snapshot(solver, state, step: int, ckpt_dir, *, padded=None,
             total_steps: int | None = None, record_every: int = 0,
             retries: int = 3, backoff: float = 0.02,
             on_write_attempt=None) -> pathlib.Path:
    """Persist the solver carry (and partial trace) at global ``step``.

    Retries ``OSError`` with exponential backoff (``backoff * 2**k``
    seconds): transient filesystem hiccups — or the chaos harness's
    injected ``write-failure`` fault via ``on_write_attempt(step,
    attempt)`` — never kill the run; a persistently failing disk
    re-raises after the last attempt.
    """
    payload: dict[str, Any] = {"state": state}
    if padded is not None:
        payload["padded"] = np.asarray(padded)
    meta = {
        "format": META_FORMAT,
        "algo": solver.config.algo,
        "config_fp": config_fingerprint(solver.config),
        "step": int(step),
        "total_steps": None if total_steps is None else int(total_steps),
        "record_every": int(record_every),
        "has_padded": padded is not None,
        "padded_dtype": (None if padded is None
                         else str(np.asarray(padded).dtype)),
    }
    last_exc: OSError | None = None
    for attempt in range(retries + 1):
        try:
            if on_write_attempt is not None:
                on_write_attempt(int(step), attempt)
            save_step(ckpt_dir, int(step), payload)
            write_json_atomic(snapshot_meta_path(ckpt_dir, int(step)),
                              meta)
            return _step_path(ckpt_dir, int(step))
        except OSError as exc:
            last_exc = exc
            if attempt < retries:
                time.sleep(backoff * (2 ** attempt))
    raise last_exc


@dataclasses.dataclass
class Resumed:
    """What ``resume`` hands back: a freshly built solver positioned at
    the snapshot."""

    solver: Any
    state: Any
    step: int                      # global step the state sits at
    padded: np.ndarray | None      # partial metric column (traced runs)
    total_steps: int | None        # run geometry recorded at save time
    record_every: int
    meta: dict


def _read_meta(ckpt_dir, step: int) -> dict | None:
    try:
        with open(snapshot_meta_path(ckpt_dir, step)) as fh:
            return json.load(fh)
    except (OSError, ValueError):
        return None


def resume(config, ckpt_dir, *, problem=None, hg_cfg=None, x0=None,
           y0=None, data=None, num_agents: int = 5,
           n_per_agent: int = 600, max_step: int | None = None,
           strict: bool = True) -> Resumed | None:
    """Rebuild the solver for ``config`` and restore its newest valid
    snapshot from ``ckpt_dir`` (``None`` when no snapshot restores).

    Walks the checkpoint steps newest-first and skips anything broken —
    missing/unparseable sidecar, truncated archive, CRC failure — so a
    directory that survived a crash or a chaos fault plan resumes from
    the newest snapshot that is actually whole.  A snapshot whose
    recorded config fingerprint disagrees with ``config`` raises under
    ``strict`` (resuming a different experiment is never recoverable by
    falling back) and is skipped otherwise.

    The problem instance defaults to the paper's Section-6 setup exactly
    as ``repro.solvers.solve`` does — resume MUST be given the same
    problem/data as the original run or the restored trajectory
    diverges from the uninterrupted one.
    """
    from repro.solvers.api import default_setup, make_solver

    steps = _all_steps(ckpt_dir)
    if max_step is not None:
        steps = [s for s in steps if s <= max_step]
    if not steps:
        return None

    if problem is None or data is None or x0 is None or y0 is None:
        problem, x0, y0, data = default_setup(
            config.seed, num_agents=config.resolve_num_agents(num_agents),
            n_per_agent=n_per_agent)
    solver = make_solver(config)
    template = solver.init(None, problem, hg_cfg, x0, y0, data)
    fp = config_fingerprint(config)

    for step in reversed(steps):
        meta = _read_meta(ckpt_dir, step)
        if meta is None:
            continue
        if meta.get("config_fp") != fp:
            if strict:
                raise ValueError(
                    f"snapshot at step {step} in {ckpt_dir} belongs to a "
                    f"different config (fingerprint "
                    f"{meta.get('config_fp')!r} != {fp!r}); refusing to "
                    f"resume a different experiment (strict=False skips)")
            continue
        like: dict[str, Any] = {"state": template}
        if meta.get("has_padded"):
            like["padded"] = np.full(
                (int(meta["total_steps"]),), np.nan,
                np.dtype(meta["padded_dtype"]))
        try:
            payload = restore_pytree(_step_path(ckpt_dir, step), like)
        except (CorruptCheckpointError, OSError):
            continue
        return Resumed(solver=solver, state=payload["state"],
                       step=int(meta["step"]),
                       padded=payload.get("padded"),
                       total_steps=meta.get("total_steps"),
                       record_every=int(meta.get("record_every", 0)),
                       meta=meta)
    return None
