"""Seeded fault registry for the chaos harness (docs/RESILIENCE.md).

Faults are small objects registered under a string kind via
``@register_fault`` and instantiated through ``make_fault(kind, ...)``;
a ``FaultPlan`` bundles several of them plus a seed and acts as the
hooks object the resumable runner calls at chunk boundaries:

* ``on_chunk_end(start, end, state, total)`` — may mutate the carry
  (NaN/Inf payload injection) or raise ``SimulatedKill`` (process kill).
* ``on_write_attempt(step, attempt)`` — may raise ``OSError`` (transient
  write failure; absorbed by the snapshot retry + exponential backoff).
* ``on_saved(step, ckpt_dir)`` — may damage what just landed on disk
  (truncate / garbage-overwrite / delete the newest checkpoint).

Each fault fires once per plan lifetime (``fired``), so a killed-and-
resumed run replays the lost chunk clean — which is exactly the recovery
the harness is probing.  Plans re-arm via ``plan.reset()`` for reuse
across runs, and every firing is appended to ``plan.events`` for the
chaos report.  Randomness (garbage bytes) comes from a per-plan
``np.random.default_rng(seed)``: same plan, same damage.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np

from repro.resilience.runner import SimulatedKill
from repro.resilience.snapshot import snapshot_meta_path

__all__ = ["Fault", "FaultPlan", "available_faults", "make_fault",
           "register_fault"]

_FAULTS: dict[str, type] = {}


def register_fault(kind: str) -> Callable[[type], type]:
    """Class decorator: register a Fault implementation under ``kind``."""

    def deco(cls: type) -> type:
        existing = _FAULTS.get(kind)
        if existing is not None and existing is not cls:
            raise ValueError(f"fault {kind!r} already registered "
                             f"({existing.__name__})")
        _FAULTS[kind] = cls
        cls.kind = kind
        return cls

    return deco


def available_faults() -> tuple[str, ...]:
    return tuple(sorted(_FAULTS))


def make_fault(kind: str, **kwargs) -> "Fault":
    try:
        cls = _FAULTS[kind]
    except KeyError:
        raise ValueError(f"unknown fault {kind!r}; choose from "
                         f"{available_faults()}") from None
    return cls(**kwargs)


class Fault:
    """Base fault: schedule (``step``), one-shot arming, no-op hooks."""

    kind: str | None = None

    def __init__(self, step: int = 0):
        self.step = int(step)
        self.fired = False

    def reset(self) -> None:
        self.fired = False

    # hook surface (plan passes itself for logging / rng access)
    def on_chunk_end(self, plan, start, end, state, total):
        return None

    def on_write_attempt(self, plan, step, attempt):
        return None

    def on_saved(self, plan, step, ckpt_dir):
        return None


class FaultPlan:
    """An ordered bundle of faults + a seed: the runner's hooks object."""

    def __init__(self, faults=(), seed: int = 0):
        self.faults = list(faults)
        self.seed = int(seed)
        self.rng = np.random.default_rng(self.seed)
        self.events: list[dict[str, Any]] = []

    def reset(self) -> None:
        """Re-arm every fault and clear the event log (rng re-seeded)."""
        self.rng = np.random.default_rng(self.seed)
        self.events.clear()
        for f in self.faults:
            f.reset()

    def log(self, kind: str, **info) -> None:
        self.events.append({"kind": kind, **info})

    def count(self, kind: str) -> int:
        return sum(1 for e in self.events if e["kind"] == kind)

    # -- runner hooks ----------------------------------------------------
    def on_chunk_end(self, start, end, state, total):
        for f in self.faults:
            mutated = f.on_chunk_end(self, start, end, state, total)
            if mutated is not None:
                state = mutated
        return state

    def on_write_attempt(self, step, attempt):
        for f in self.faults:
            f.on_write_attempt(self, step, attempt)

    def on_saved(self, step, ckpt_dir):
        for f in self.faults:
            f.on_saved(self, step, ckpt_dir)


@register_fault("kill")
class KillFault(Fault):
    """SIGKILL the process once step ``step`` has been reached — raised
    at the first chunk boundary past it, *before* that boundary's
    snapshot lands, so the whole chunk is lost."""

    def on_chunk_end(self, plan, start, end, state, total):
        if not self.fired and self.step <= end:
            self.fired = True
            plan.log("kill", at=end, scheduled=self.step)
            raise SimulatedKill(end)
        return None


@register_fault("nan-payload")
class NanPayloadFault(Fault):
    """Poison the outer iterate with NaN/Inf once ``step`` is reached —
    what a corrupted wire payload that slipped past the guards does.
    Detected by the runner's finiteness check before the snapshot, so
    the checkpoint directory stays clean and the chunk is replayed."""

    def __init__(self, step: int = 0, value: float = float("nan"),
                 field: str = "x", count: int = 3):
        super().__init__(step)
        self.value = float(value)
        self.field = field
        self.count = int(count)

    def on_chunk_end(self, plan, start, end, state, total):
        if self.fired or self.step > end:
            return None
        self.fired = True
        tree = getattr(state, self.field)
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        poisoned = np.array(jax.device_get(leaves[0]))
        poisoned.flat[:min(self.count, poisoned.size)] = self.value
        leaves = [poisoned] + leaves[1:]
        plan.log("nan-payload", at=end, field=self.field,
                 value=self.value)
        return state._replace(
            **{self.field: jax.tree_util.tree_unflatten(treedef, leaves)})


@register_fault("corrupt-checkpoint")
class CorruptCheckpointFault(Fault):
    """Damage the checkpoint that just landed: ``mode='truncate'`` keeps
    the first third of the file (a mid-write kill with no atomic
    replace); ``mode='garbage'`` flips 64 bytes in the middle (bit-rot —
    caught by the per-leaf CRC32).  Resume must fall back to the
    previous snapshot."""

    def __init__(self, step: int = 0, mode: str = "garbage"):
        super().__init__(step)
        if mode not in ("garbage", "truncate"):
            raise ValueError(f"unknown corruption mode {mode!r}")
        self.mode = mode

    def on_saved(self, plan, step, ckpt_dir):
        if self.fired or step < self.step:
            return
        self.fired = True
        from repro.checkpoint.checkpoint import _step_path
        path = _step_path(ckpt_dir, step)
        size = path.stat().st_size
        if self.mode == "truncate":
            with open(path, "r+b") as fh:
                fh.truncate(max(1, size // 3))
        else:
            with open(path, "r+b") as fh:
                fh.seek(size // 2)
                fh.write(plan.rng.bytes(min(64, max(1, size // 4))))
        plan.log("corrupt-checkpoint", at=step, mode=self.mode)


@register_fault("stale-checkpoint")
class StaleCheckpointFault(Fault):
    """Delete the checkpoint that just landed (archive + sidecar): the
    directory now ends at an older snapshot, as if the newest save never
    happened — resume replays the gap."""

    def on_saved(self, plan, step, ckpt_dir):
        if self.fired or step < self.step:
            return
        self.fired = True
        from repro.checkpoint.checkpoint import _step_path
        _step_path(ckpt_dir, step).unlink(missing_ok=True)
        snapshot_meta_path(ckpt_dir, step).unlink(missing_ok=True)
        plan.log("stale-checkpoint", at=step)


@register_fault("write-failure")
class WriteFailureFault(Fault):
    """Transient filesystem failure: the first ``count`` snapshot write
    attempts at/after ``step`` raise ``OSError``.  With ``count`` below
    the snapshot retry budget the run never notices beyond the backoff
    sleeps; the firings are logged for the chaos report."""

    def __init__(self, step: int = 0, count: int = 2):
        super().__init__(step)
        self.count = int(count)
        self.remaining = int(count)

    def reset(self) -> None:
        super().reset()
        self.remaining = self.count

    def on_write_attempt(self, plan, step, attempt):
        if step >= self.step and self.remaining > 0:
            self.remaining -= 1
            self.fired = True
            plan.log("write-failure", at=step, attempt=attempt)
            raise OSError("injected transient write failure")
