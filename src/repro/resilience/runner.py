"""The resumable runner: checkpoint-chunked scans, bitwise-equal traces.

``run_resumable`` cuts a solver run into ``checkpoint_every``-aligned
chunks of the *same* jitted scan body (``SolverBase._chunk_fn``: one
compile per distinct chunk length), snapshots the complete carry after
each chunk, and stitches the per-chunk metric columns back into the
exact ``run_traced`` trace layout.  Because the chunk scan offsets its
index by the global start step, metric recording fires on the same
global boundaries whatever the run was cut into — chunked vs unchunked,
killed-and-resumed vs uninterrupted, the trace is bitwise equal (the
parity discipline PRs 4–8 established; asserted per algorithm × backend
in tests/test_resilience.py).

Fault surface (see docs/RESILIENCE.md): hooks fire at chunk boundaries
(``on_chunk_end`` may mutate state or raise ``SimulatedKill``), around
snapshot writes (``on_write_attempt`` → retry/backoff in
``repro.resilience.snapshot``) and after them (``on_saved`` → corrupt /
delete injection).  Non-finite state and fresh divergence-guard trips
are detected *before* the snapshot lands, so a poisoned chunk never
contaminates the checkpoint directory — the run resumes from the last
clean boundary.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.resilience.snapshot import Resumed, resume, snapshot

__all__ = ["GuardTripFault", "NonFiniteStateError", "SimulatedKill",
           "resume_run", "run_resumable"]


class SimulatedKill(RuntimeError):
    """The chaos harness's process kill: raised at a chunk boundary
    *before* the snapshot lands, so everything since the previous
    checkpoint is lost — exactly what SIGKILL costs a real run."""

    def __init__(self, step: int):
        super().__init__(f"simulated process kill at step {step}")
        self.step = step


class NonFiniteStateError(RuntimeError):
    """The carry went NaN/Inf during a chunk (e.g. an injected wire
    payload the guards did not contain).  Raised before the snapshot, so
    the checkpoint directory only ever holds finite states."""

    def __init__(self, step: int):
        super().__init__(f"non-finite solver state at step {step}")
        self.step = step


class GuardTripFault(RuntimeError):
    """The divergence guard tripped during this chunk.  Surfaced as a
    resumable fault so checkpoint rollback and guard rollback share one
    recovery path (``chaos_run`` retries the chunk a bounded number of
    times, then accepts the in-scan containment)."""

    def __init__(self, step: int, trips: int):
        super().__init__(f"divergence guard tripped {trips}x in the chunk "
                         f"ending at step {step}")
        self.step = step
        self.trips = trips


def _state_is_finite(state) -> bool:
    for leaf in jax.tree_util.tree_leaves(state):
        arr = np.asarray(jax.device_get(leaf))
        if np.issubdtype(arr.dtype, np.floating) and \
                not np.isfinite(arr).all():
            return False
    return True


def _guard_trips(state) -> int | None:
    guard = getattr(state, "guard", None)
    if guard is None:
        return None
    return int(guard["tripped"])


def run_resumable(solver, state, data, num_steps: int,
                  record_every: int = 0, metric_fn=None, *,
                  checkpoint_every: int, ckpt_dir,
                  start_step: int | None = None, padded=None,
                  hooks=None, raise_on_guard_trip: bool = False,
                  guard_ignore_below: int = -1, retries: int = 3,
                  backoff: float = 0.02):
    """Advance ``num_steps`` from ``state``, snapshotting every
    ``checkpoint_every`` steps into ``ckpt_dir``.

    ``start_step`` is the global step the incoming carry sits at
    (defaults to ``state.t``); chunk boundaries land on global multiples
    of ``checkpoint_every``, so a resumed run re-aligns with the
    boundaries the original run used.  ``padded`` is the full-length
    per-step metric column being assembled across resumes (restored by
    ``repro.resilience.resume``); ``None`` allocates a fresh NaN column.

    Returns ``(state, trace, padded)`` with ``trace`` laid out exactly
    like ``run_traced`` — metric before steps ``0, record_every, ...``
    plus the final iterate — or an empty array when ``metric_fn`` is
    None.  Bitwise contract: ``trace`` equals the single-scan
    ``run_traced`` output provided the whole column was produced by this
    chunked runner from step 0 (possibly across kills/resumes).
    """
    if ckpt_dir is None:
        raise ValueError("checkpointed runs need ckpt_dir")
    if checkpoint_every <= 0:
        raise ValueError("checkpoint_every must be positive")
    if solver._chunk_fn is None:
        raise RuntimeError("call init()/build() before run_resumable()")

    if start_step is None:
        start_step = int(np.asarray(getattr(state, "t", 0)))
    start = int(start_step)
    total = start + int(num_steps)
    record_mod = int(record_every) if record_every else total
    if metric_fn is not None:
        dtype = np.dtype(jax.eval_shape(metric_fn, state).dtype)
        if padded is None:
            padded = np.full((total,), np.nan, dtype)
        else:
            padded = np.asarray(padded)
            if padded.shape != (total,):
                raise ValueError(
                    f"padded column has shape {padded.shape}, run "
                    f"geometry needs ({total},)")

    on_write = getattr(hooks, "on_write_attempt", None) \
        if hooks is not None else None
    trips_at_ckpt = _guard_trips(state)

    cur = start
    while cur < total:
        end = min(total, (cur // checkpoint_every + 1) * checkpoint_every)
        length = end - cur
        if metric_fn is None:
            new_state = solver._run_fn(state, data, length)
        else:
            new_state, vals = solver._chunk_fn(state, data, length,
                                               record_mod, metric_fn, cur)
            padded[cur:end] = np.asarray(jax.device_get(vals))
        state = new_state
        cur = end
        if hooks is not None:
            mutated = hooks.on_chunk_end(cur - length, cur, state, total)
            if mutated is not None:
                state = mutated
        # validate BEFORE snapshotting: a poisoned or freshly-tripped
        # chunk must never land in the checkpoint directory
        if not _state_is_finite(state):
            raise NonFiniteStateError(cur)
        if raise_on_guard_trip:
            trips = _guard_trips(state)
            if trips is not None and trips_at_ckpt is not None \
                    and trips > trips_at_ckpt and cur > guard_ignore_below:
                raise GuardTripFault(cur, trips - trips_at_ckpt)
        snapshot(solver, state, cur, ckpt_dir, padded=padded,
                 total_steps=total, record_every=record_every,
                 retries=retries, backoff=backoff,
                 on_write_attempt=on_write)
        trips_at_ckpt = _guard_trips(state)
        if hooks is not None:
            hooks.on_saved(cur, ckpt_dir)

    if metric_fn is None:
        return state, np.zeros((0,), np.float32), padded
    final = np.asarray(jax.device_get(solver.metric_eval(metric_fn, state)))
    trace = np.concatenate([padded[::record_mod],
                            final.reshape(1).astype(padded.dtype)])
    return state, trace, padded


def resume_run(config, ckpt_dir, num_steps: int | None = None,
               record_every: int | None = None, metric_fn=None, *,
               checkpoint_every: int, problem=None, hg_cfg=None,
               x0=None, y0=None, data=None, num_agents: int = 5,
               n_per_agent: int = 600, hooks=None,
               raise_on_guard_trip: bool = False,
               guard_ignore_below: int = -1, max_step: int | None = None,
               retries: int = 3, backoff: float = 0.02):
    """Finish (or freshly start) a checkpointed run for ``config``.

    Restores the newest valid snapshot in ``ckpt_dir`` (falling back past
    corrupt / truncated / stale files, see ``repro.resilience.resume``)
    and drives ``run_resumable`` to the run's recorded ``total_steps`` —
    or from step 0 when the directory holds nothing restorable, in which
    case ``num_steps`` (the TOTAL length of the run) is required.
    ``num_steps`` / ``record_every``, when given, override the snapshot's
    recorded geometry.

    Returns ``(solver, state, trace)``; ``trace`` follows the
    ``run_traced`` layout and is bitwise-equal to the uninterrupted run.
    """
    from repro.solvers.api import default_setup, make_solver

    if problem is None or data is None or x0 is None or y0 is None:
        problem, x0, y0, data = default_setup(
            config.seed, num_agents=config.resolve_num_agents(num_agents),
            n_per_agent=n_per_agent)

    rs: Resumed | None = resume(config, ckpt_dir, problem=problem,
                                hg_cfg=hg_cfg, x0=x0, y0=y0, data=data,
                                max_step=max_step)
    if rs is None:
        if num_steps is None:
            raise ValueError("empty/unrestorable checkpoint dir and no "
                             "num_steps: nothing to resume, nothing to "
                             "start")
        solver = make_solver(config)
        state = solver.init(None, problem, hg_cfg, x0, y0, data)
        start, padded = 0, None
        total = int(num_steps)
        rec = int(record_every or 0)
    else:
        solver, state, start, padded = rs.solver, rs.state, rs.step, \
            rs.padded
        total = int(num_steps if num_steps is not None
                    else rs.total_steps)
        rec = int(record_every if record_every is not None
                  else rs.record_every)

    state, trace, padded = run_resumable(
        solver, state, data, total - start, rec, metric_fn,
        checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir,
        start_step=start, padded=padded, hooks=hooks,
        raise_on_guard_trip=raise_on_guard_trip,
        guard_ignore_below=guard_ignore_below, retries=retries,
        backoff=backoff)
    return solver, state, trace
