"""Time-varying topology subsystem: mixing matrices as a per-step process.

``TopologyProcessConfig`` (carried by ``SolverConfig.topology_process``)
declares the process; the registry realises ``(T, m, m)`` matrix streams
with per-step active-edge masks (``process``); the engine runtimes
gather the round's matrix inside the solver scans (``runtime``).
See docs/TOPOLOGY.md.
"""
from repro.topology.process import (
    TopologyProcessConfig,
    TopologyStream,
    adjacency_of,
    available_topology_processes,
    make_topology_process,
    masked_mixing,
    realize_stream,
    register_topology_process,
    stream_wire_bytes,
)
from repro.topology.runtime import (
    AdaptiveTopology,
    PermuteStreamTopology,
    StreamTopology,
    adaptive_mixing,
    agents_matrix,
    attach_topology,
    stream_of,
)

__all__ = [
    "AdaptiveTopology",
    "PermuteStreamTopology",
    "StreamTopology",
    "TopologyProcessConfig",
    "TopologyStream",
    "adaptive_mixing",
    "adjacency_of",
    "agents_matrix",
    "attach_topology",
    "available_topology_processes",
    "make_topology_process",
    "masked_mixing",
    "realize_stream",
    "register_topology_process",
    "stream_of",
    "stream_wire_bytes",
]
