"""Engine-side topology runtimes: the per-step matrix inside the scan.

A consensus engine with a time-varying topology carries one of these on
``engine.topology``; ``ConsensusEngine.topology_matrix(t, tree)``
resolves the round's mixing matrix through it and threads the result
into the combine as a per-call operand (``mix(..., matrix=...)``), so
the matrix stream is effectively a scan input — gathered by the step
index ``t % T`` — and the whole run stays one compile.

Three runtimes, matching the backend families:

    StreamTopology         dense / pallas: the realized (T, m, m)
                           stream as a device array, ``matrices[t % T]``.
    AdaptiveTopology       dense / pallas: the Dada-style matrix
                           computed from the iterates per step
                           (``adaptive_mixing``); state-dependent, so
                           there is nothing to precompute.
    PermuteStreamTopology  ppermute: the ROADMAP's batching form — one
                           *shared offset schedule* (the base graph's
                           ppermute rounds) with per-step weights.
                           Realized matrices only ever remove or
                           reweight base edges, so the base offsets
                           cover every round; a dropped edge is a zero
                           weight on its offset.  Yields a
                           ``collectives.PermuteWeights`` override per
                           step.

``attach_topology`` picks the right runtime for a built engine; solver
construction calls it (``repro.solvers.api.SolverBase.build``) whenever
``SolverConfig.topology_process`` is non-static.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.collectives import PermuteWeights
from repro.topology.process import (
    TopologyProcessConfig,
    TopologyStream,
    adjacency_of,
    make_topology_process,
    realize_stream,
)

__all__ = [
    "AdaptiveTopology",
    "PermuteStreamTopology",
    "StreamTopology",
    "adaptive_mixing",
    "agents_matrix",
    "attach_topology",
    "stream_of",
]


def agents_matrix(tree) -> jax.Array:
    """Flatten a per-agent pytree to (m, D) f32 — the similarity input."""
    leaves = jax.tree_util.tree_leaves(tree)
    m = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)


def adaptive_mixing(x2d: jax.Array, adjacency: jax.Array,
                    tau: float) -> jax.Array:
    """Similarity-reweighted Metropolis matrix (Dada-style), in-trace.

    ``s_ij = adj_ij * exp(-||x_i - x_j||^2 / tau)`` plays the degree's
    role in the Metropolis rule: ``W_ij = s_ij / (1 + max(r_i, r_j))``
    with ``r_i = sum_j s_ij``, diagonal ``1 - sum_j W_ij``.  Symmetric
    (s and max are), rows sum to 1 by construction, and nonnegative
    because ``sum_j W_ij <= r_i / (1 + r_i) < 1`` — so the Section-4.1
    properties hold for every iterate, including ghost-padded ones
    (a zero adjacency row yields an identity row).
    """
    sq = jnp.sum(x2d * x2d, axis=1)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * (x2d @ x2d.T), 0.0)
    s = adjacency * jnp.exp(-d2 / tau)
    r = jnp.sum(s, axis=1)
    w = s / (1.0 + jnp.maximum(r[:, None], r[None, :]))
    return w + jnp.diag(1.0 - jnp.sum(w, axis=1))


class StreamTopology:
    """A realized stream as a device array, gathered by step index."""

    def __init__(self, matrices):
        self.matrices = jnp.asarray(matrices, jnp.float32)
        self.period = self.matrices.shape[0]

    def matrix_at(self, t, tree=None):
        del tree
        return self.matrices[jnp.asarray(t) % self.period]


class AdaptiveTopology:
    """State-dependent matrix: computed from the mixed tree per step."""

    def __init__(self, adjacency, tau: float):
        self.adjacency = jnp.asarray(adjacency, jnp.float32)
        self.tau = float(tau)

    def matrix_at(self, t, tree=None):
        del t
        if tree is None:
            raise ValueError(
                "the adaptive topology computes its matrix from the "
                "iterates; this engine path cannot supply them — mix "
                "through step1_step3 / mix_ef, or pass matrix= yourself")
        return adaptive_mixing(agents_matrix(tree), self.adjacency,
                               self.tau)


class PermuteStreamTopology:
    """Per-step weights on the base schedule's shared offsets (ppermute).

    Precomputes ``weights[t, k, i] = M_t[i, (i + offsets[k]) % m]`` and
    the per-step diagonals from the realized stream; ``matrix_at``
    gathers the step's ``PermuteWeights`` override.  Streams stay numpy
    until gathered so shard_map bodies close over constants, exactly
    like the base ``PermuteSchedule``.
    """

    def __init__(self, schedule, matrices: np.ndarray):
        mats = np.asarray(matrices, dtype=np.float64)
        m = schedule.num_agents
        if mats.shape[1:] != (m, m):
            raise ValueError(
                f"stream is {mats.shape[1:]} but the schedule mixes "
                f"{m} agents")
        idx = np.arange(m)
        covered = np.zeros((m, m), dtype=bool)
        np.fill_diagonal(covered, True)
        for o in schedule.offsets:
            covered[idx, (idx + o) % m] = True
        stray = np.abs(mats[:, ~covered]).max(initial=0.0)
        if stray > 1e-12:
            raise ValueError(
                "realized topology stream places weight on edges outside "
                "the base schedule's offsets — time-varying ppermute "
                "shares the base offset schedule and can only drop or "
                "reweight its edges")
        self.offsets = schedule.offsets
        self.weights = np.stack(
            [mats[:, idx, (idx + o) % m] for o in schedule.offsets],
            axis=1) if schedule.offsets else np.zeros((mats.shape[0], 0, m))
        self.self_weights = np.diagonal(mats, axis1=1, axis2=2).copy()
        self.matrices = mats
        self.period = mats.shape[0]

    def matrix_at(self, t, tree=None):
        del tree
        k = jnp.asarray(t) % self.period
        return PermuteWeights(
            weights=jnp.asarray(self.weights, jnp.float32)[k],
            self_weights=jnp.asarray(self.self_weights, jnp.float32)[k],
            matrix=jnp.asarray(self.matrices, jnp.float32)[k])


def attach_topology(engine, config: TopologyProcessConfig, mixing,
                    seed: int):
    """Install the runtime matching ``config`` on a built engine.

    No-op for the static process (the engines stay bitwise identical to
    the fixed-matrix path).  Stream processes additionally leave the
    realized host-side ``TopologyStream`` on ``engine.topology_stream``
    for wire / spectral-gap accounting.  ``seed`` is the fallback
    (``SolverConfig.seed``) when the process config carries none.
    """
    if config.is_static:
        return engine
    process = make_topology_process(config)
    if process.state_dependent:
        if engine.name == "ppermute":
            raise ValueError(
                "the adaptive topology needs the full similarity matrix "
                "per step, which a shard_map agent slice cannot compute; "
                "use the dense or pallas backend")
        engine.topology = AdaptiveTopology(adjacency_of(mixing),
                                           config.tau)
        return engine
    stream = realize_stream(config, mixing, config.resolve_seed(seed))
    engine.topology_stream = stream
    if engine.name == "ppermute":
        engine.topology = PermuteStreamTopology(engine.schedule,
                                                stream.matrices)
    else:
        engine.topology = StreamTopology(stream.matrices)
    return engine


def stream_of(engine) -> TopologyStream | None:
    """The host-side realized stream attached by ``attach_topology``."""
    return getattr(engine, "topology_stream", None)
