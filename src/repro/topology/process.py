"""Time-varying topologies: mixing matrices as a per-step process.

Every run used to mix with one fixed doubly-stochastic matrix, but the
paper's motivating settings — peer-to-peer meta-learning over unreliable
networks — have churn: gossip pairs, dropped links, stragglers.
INTERACT's O(eps^-1) communication claim only needs the *expected*
connectivity, so the mixing matrix becomes a per-step input to the
solver scans: a ``TopologyProcess`` realises a ``(num_steps, m, m)``
matrix stream (plus a per-step active-edge mask) from the base
``MixingSpec``, and the consensus engines gather ``stream[t % T]``
inside the scan (``repro.topology.runtime``).

Registered processes (``@register_topology_process``):

    static         wraps today's ``MixingSpec`` — a bitwise no-op: the
                   engines are left untouched, every trace is identical
                   to the fixed-matrix path.
    link-failure   per-edge symmetric Bernoulli(p) drops with doubly-
                   stochastic self-loop repair: a dead link's weight
                   folds onto BOTH endpoints' self weights, so the
                   matrix stays doubly stochastic, symmetric and
                   nonnegative — graceful degradation, never a NaN or a
                   stall.  ``p = 0`` reproduces the base matrix bitwise.
    straggler      each agent independently skips the round with
                   probability p; all its links fold to self weight
                   (the outer-product mask under the same repair rule).
    random-gossip  a random maximal matching of the base edges per
                   round; matched pairs average (weight 1/2), everyone
                   else holds (weight 1).
    adaptive       Dada-style Metropolis reweighting from per-step
                   agent similarity — state-dependent, so it has no
                   precomputed stream; the engines compute the matrix
                   from the iterates inside the scan
                   (``repro.topology.runtime.adaptive_mixing``).

Reproducibility contract: step t of a stream depends only on
``(seed, t)`` — ``np.random.default_rng([seed, t])`` per step — so the
same ``SolverConfig.seed`` realises bit-identical schedules on every
backend and for every stream length (a longer ``period`` is a strict
prefix extension, never a reshuffle).

Wire accounting lives here too: ``stream_wire_bytes`` prices each round
per *link* from the edge mask (a dropped link costs zero bytes),
composing with the compression layer's warmup / interval schedules —
see docs/TOPOLOGY.md for how this unicast model relates to the
broadcast model of ``consensus.cumulative_wire_bytes``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.consensus.compress import CompressionConfig, make_compressor
from repro.core.consensus import MixingSpec, second_eigenvalue

__all__ = [
    "TopologyProcessConfig",
    "TopologyStream",
    "adjacency_of",
    "available_topology_processes",
    "make_topology_process",
    "masked_mixing",
    "realize_stream",
    "register_topology_process",
    "stream_wire_bytes",
]

_EDGE_TOL = 1e-12


@dataclasses.dataclass(frozen=True)
class TopologyProcessConfig:
    """Declarative time-varying topology carried by ``SolverConfig``.

    Attributes:
      kind: registered process name — "static" | "link-failure" |
        "straggler" | "random-gossip" | "adaptive"
        (see ``available_topology_processes()``).
      p: the per-round drop probability (link-failure: per edge;
        straggler: per agent).  Ignored by static / gossip / adaptive.
      period: realized stream length T.  Engines index ``t % T``, so a
        run longer than the period replays the schedule; benches that
        want a fresh draw every step set ``period = num_steps``.
      tau: adaptive similarity temperature (``exp(-||x_i - x_j||^2 /
        tau)``); larger tau flattens the reweighting toward Metropolis.
      seed: stream seed; ``None`` inherits ``SolverConfig.seed``, which
        is what makes schedules bit-reproducible from the one config
        field across backends.
    """

    kind: str = "static"
    p: float = 0.0
    period: int = 64
    tau: float = 1.0
    seed: int | None = None

    def __post_init__(self):
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"topology process p must be in [0, 1], "
                             f"got {self.p}")
        if self.period < 1:
            raise ValueError(f"topology period must be >= 1, got "
                             f"{self.period}")
        if self.tau <= 0.0:
            raise ValueError(f"adaptive tau must be > 0, got {self.tau}")

    @property
    def is_static(self) -> bool:
        return self.kind == "static"

    @property
    def state_dependent(self) -> bool:
        """Matrix computed from the iterates in-scan (no stream)."""
        return make_topology_process(self).state_dependent

    def structural_key(self) -> tuple:
        """The trace-*shape* facts: what enters ``static_key``.

        ``p`` and ``seed`` only change the stream's *values*, never the
        compiled program — the sweep engine hands per-config streams in
        as vmap operands — so a failure-rate x algorithm grid batches
        into one program per algorithm (docs/TOPOLOGY.md).
        """
        return (self.kind, self.period, self.tau)

    def resolve_seed(self, fallback: int) -> int:
        return fallback if self.seed is None else self.seed


@dataclasses.dataclass(frozen=True)
class TopologyStream:
    """A realized matrix process: ``(T, m, m)`` matrices + edge mask.

    Attributes:
      matrices:  (T, m, m) float64 — each a symmetric doubly-stochastic
        nonnegative mixing matrix (the repair rule guarantees it).
      edge_mask: (T, m, m) bool — the round's *active* links
        (off-diagonal, symmetric).  This is what drives the wire
        accounting: an inactive link ships zero bytes.
    """

    matrices: np.ndarray
    edge_mask: np.ndarray

    @property
    def num_steps(self) -> int:
        return int(self.matrices.shape[0])

    @property
    def num_agents(self) -> int:
        return int(self.matrices.shape[1])

    def spectral_gaps(self) -> np.ndarray:
        """Per-step ``1 - lambda`` of each realized matrix (lambda =
        max{|lambda_2|, |lambda_m|}, the paper's mixing rate)."""
        return np.asarray([1.0 - second_eigenvalue(mat)
                           for mat in self.matrices])

    @property
    def mean_spectral_gap(self) -> float:
        """Measured mean spectral gap of the realized matrices — the
        per-row connectivity column of ``BENCH_topology.json``."""
        return float(self.spectral_gaps().mean())

    def active_out_degree(self) -> np.ndarray:
        """(T, m) directed links each agent serves per round."""
        return self.edge_mask.sum(axis=2)

    def padded(self, pad_to: int) -> "TopologyStream":
        """Ghost-pad every matrix to ``pad_to`` agents (identity rows).

        Same semantics as ``core.consensus.pad_mixing``: ghost agents
        are consensus fixed points, active combines bitwise unchanged —
        which is what lets the padded sweep stack streams of different
        network sizes into one vmap operand.
        """
        T, m = self.matrices.shape[:2]
        if pad_to < m:
            raise ValueError(f"cannot pad {m} agents down to {pad_to}")
        mats = np.tile(np.eye(pad_to), (T, 1, 1))
        mats[:, :m, :m] = self.matrices
        mask = np.zeros((T, pad_to, pad_to), dtype=bool)
        mask[:, :m, :m] = self.edge_mask
        return TopologyStream(matrices=mats, edge_mask=mask)


def adjacency_of(mixing: MixingSpec | np.ndarray,
                 tol: float = _EDGE_TOL) -> np.ndarray:
    """The base graph's 0/1 adjacency: off-diagonal nonzero weights."""
    mat = np.asarray(getattr(mixing, "matrix", mixing), dtype=np.float64)
    adj = (np.abs(mat) > tol).astype(np.float64)
    np.fill_diagonal(adj, 0.0)
    return adj


def masked_mixing(base: np.ndarray, keep: np.ndarray) -> np.ndarray:
    """The doubly-stochastic self-loop repair rule.

    Zero out the off-diagonal entries where ``keep`` (a symmetric
    boolean mask) is False and fold the dropped mass back onto the
    diagonal: ``M'[i, i] = M[i, i] + sum_j dropped M[i, j]``.  Because
    the drops are symmetric and every off-diagonal weight of a valid
    mixing matrix is nonnegative, the result is symmetric, doubly
    stochastic and nonnegative for ANY symmetric mask — a dead link
    becomes lazy self-weight, never a NaN or a stall.

    With nothing dropped the diagonal is the *original* diagonal plus an
    exact 0.0, so ``p = 0`` schedules reproduce the base matrix bitwise.
    """
    base = np.asarray(base, dtype=np.float64)
    keep = np.asarray(keep, dtype=bool)
    off = base.copy()
    np.fill_diagonal(off, 0.0)
    dropped = np.where(keep, 0.0, off)
    out = np.where(keep, off, 0.0)
    np.fill_diagonal(out, np.diagonal(base) + dropped.sum(axis=1))
    return out


def _step_rng(seed: int, t: int) -> np.random.Generator:
    """Step t's generator — depends only on (seed, t), never on T."""
    return np.random.default_rng([int(seed) & 0xFFFFFFFF, int(t)])


# -- the registry ---------------------------------------------------------

_PROCESSES: dict[str, type] = {}


def register_topology_process(name: str) -> Callable[[type], type]:
    """Class decorator: register a ``TopologyProcess`` under ``name``."""

    def deco(cls: type) -> type:
        existing = _PROCESSES.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(f"topology process {name!r} already "
                             f"registered ({existing.__name__})")
        _PROCESSES[name] = cls
        cls.name = name
        return cls

    return deco


def available_topology_processes() -> tuple[str, ...]:
    """Registered process names, sorted."""
    return tuple(sorted(_PROCESSES))


def make_topology_process(config: TopologyProcessConfig):
    """Instantiate the registered process for ``config.kind``."""
    try:
        cls = _PROCESSES[config.kind]
    except KeyError:
        raise ValueError(
            f"unknown topology process {config.kind!r}; "
            f"choose from {available_topology_processes()}") from None
    return cls(config)


class TopologyProcess:
    """Base class: realise a matrix stream from the base ``MixingSpec``.

    ``state_dependent`` processes (adaptive) compute the matrix from the
    iterates inside the scan instead — ``realize`` is unavailable for
    them and the engines attach an in-trace runtime
    (``repro.topology.runtime``).
    """

    state_dependent = False

    def __init__(self, config: TopologyProcessConfig):
        self.config = config

    def _step_matrix(self, base: np.ndarray, adj: np.ndarray,
                     rng: np.random.Generator
                     ) -> tuple[np.ndarray, np.ndarray]:
        """One round: ``(matrix, edge_keep_mask)``; both (m, m)."""
        raise NotImplementedError

    def realize(self, mixing: MixingSpec | np.ndarray, seed: int,
                num_steps: int | None = None) -> TopologyStream:
        """The ``(T, m, m)`` stream; ``T = num_steps or config.period``."""
        if self.state_dependent:
            raise ValueError(
                f"topology process {self.name!r} is state-dependent: the "
                "matrix is computed from the iterates inside the scan "
                "and has no precomputable stream")
        base = np.asarray(getattr(mixing, "matrix", mixing),
                          dtype=np.float64)
        adj = adjacency_of(base)
        T = int(num_steps) if num_steps is not None else self.config.period
        mats = np.empty((T,) + base.shape)
        mask = np.empty((T,) + base.shape, dtype=bool)
        for t in range(T):
            mats[t], keep = self._step_matrix(base, adj, _step_rng(seed, t))
            mask[t] = keep & (adj > 0)
            np.fill_diagonal(mask[t], False)
        return TopologyStream(matrices=mats, edge_mask=mask)


@register_topology_process("static")
class StaticProcess(TopologyProcess):
    """The fixed-matrix baseline: every round is the base matrix.

    As a ``SolverConfig.topology_process`` this is a bitwise no-op — the
    engines are left untouched (no stream attached, no gather in the
    scan), so the compiled program is literally the fixed-matrix one.
    ``realize`` still works (a constant stream) for accounting parity
    in the benches.
    """

    def _step_matrix(self, base, adj, rng):
        return base.copy(), adj > 0


@register_topology_process("link-failure")
class LinkFailureProcess(TopologyProcess):
    """Per-edge symmetric Bernoulli(p) drops + self-loop repair."""

    def _step_matrix(self, base, adj, rng):
        m = base.shape[0]
        # symmetric draw: one Bernoulli per undirected edge
        up = rng.random((m, m)) >= self.config.p
        keep = np.triu(up, k=1)
        keep = keep | keep.T
        return masked_mixing(base, keep), keep


@register_topology_process("straggler")
class StragglerProcess(TopologyProcess):
    """Agents skip a round with probability p; links fold to self."""

    def _step_matrix(self, base, adj, rng):
        active = rng.random(base.shape[0]) >= self.config.p
        keep = np.outer(active, active)
        return masked_mixing(base, keep), keep


@register_topology_process("random-gossip")
class RandomGossipProcess(TopologyProcess):
    """A random maximal matching of the base edges per round.

    Matched pairs average (``W_ii = W_jj = W_ij = 1/2``); unmatched
    agents hold their value.  One exchange per agent per round at most —
    the minimal-bandwidth end of the topology spectrum.
    """

    def _step_matrix(self, base, adj, rng):
        m = base.shape[0]
        edges = np.argwhere(np.triu(adj, k=1) > 0)
        rng.shuffle(edges)
        mat = np.eye(m)
        keep = np.zeros((m, m), dtype=bool)
        used = np.zeros(m, dtype=bool)
        for i, j in edges:
            if used[i] or used[j]:
                continue
            used[i] = used[j] = True
            mat[i, i] = mat[j, j] = 0.5
            mat[i, j] = mat[j, i] = 0.5
            keep[i, j] = keep[j, i] = True
        return mat, keep


@register_topology_process("adaptive")
class AdaptiveProcess(TopologyProcess):
    """Dada-style similarity reweighting — state-dependent (no stream).

    Per step the engines compute Metropolis weights from the per-agent
    similarities ``s_ij = exp(-||x_i - x_j||^2 / tau)`` over the base
    edges (``repro.topology.runtime.adaptive_mixing``): agents whose
    iterates agree mix strongly, outliers are damped toward self —
    symmetric, doubly stochastic and nonnegative by construction.
    """

    state_dependent = True


def realize_stream(config: TopologyProcessConfig,
                   mixing: MixingSpec | np.ndarray, seed: int,
                   num_steps: int | None = None) -> TopologyStream:
    """Realize ``config``'s stream over ``mixing`` (seed already
    resolved: pass ``config.resolve_seed(solver_seed)``)."""
    return make_topology_process(config).realize(mixing, seed, num_steps)


def stream_wire_bytes(stream: TopologyStream,
                      compression: CompressionConfig | None,
                      size: int, num_steps: int,
                      comms_per_step: int = 2,
                      communication_interval: int = 1) -> list[int]:
    """Network-total cumulative wire bytes after 0..num_steps steps,
    priced per *link* from the edge mask.

    Each comm round every agent unicasts one payload per active outgoing
    link (``edge_mask[t % T]``), so a dropped link costs zero bytes —
    gossip rounds are cheap, dense static rounds expensive.  Composes
    with the compression layer exactly like
    ``consensus.cumulative_wire_bytes``: the first ``compress_after``
    mixes ship full f32, steps with ``t % interval != 0`` ship nothing.
    ``size`` is the per-payload entry count.  Returns length
    ``num_steps + 1`` (entry t = bytes after t steps).

    This is the *unicast* model (per-link pricing); the broadcast model
    of ``SolveResult.bytes_per_round`` charges one payload per agent per
    round regardless of degree — see docs/TOPOLOGY.md.
    """
    compression = compression or CompressionConfig()
    compressor = make_compressor(compression)
    full = 4 * size
    packed = compressor.bytes_on_wire(size)
    links = stream.edge_mask.sum(axis=(1, 2))       # directed, per round
    T = stream.num_steps
    out, total = [0], 0
    for t in range(num_steps):
        if t % communication_interval == 0:
            per_payload = (full if t < compression.compress_after
                           else packed)
            total += int(comms_per_step * per_payload * links[t % T])
        out.append(total)
    return out
