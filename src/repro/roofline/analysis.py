"""Three-term roofline analysis from the dry-run artifacts.

    compute term    = HLO_FLOPs    / (peak_FLOP/s)          [per chip]
    memory term     = HLO_bytes    / (HBM_bw)               [per chip]
    collective term = wire_bytes   / (link_bw)              [per chip]

Sources: ``compiled.cost_analysis()`` (per-device SPMD module) for FLOPs
and bytes; collective wire bytes parsed from the optimized HLO
(repro.launch.dryrun.parse_collectives) with ring weights (all-reduce 2x).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.

Caveat measured in-tree (tests/test_roofline.py): XLA's cost analysis
counts a while-loop body ONCE, not times its trip count.  Our models run
layers as a scan over periods, so raw FLOPs/bytes would undercount by
~num_periods.  ``scan_corrected_*`` multiplies the dominant loop's share
back in using the known period count; both raw and corrected numbers are
reported.

MODEL_FLOPS uses the standard 6*N*D (dense train), 2*N*D (inference
forward), with N_active for MoE — the "useful FLOPs" yardstick; the ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy overhead.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any

import numpy as np

from repro.models.base import ArchConfig

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / ICI link

__all__ = [
    "analytic_param_count", "active_param_count", "model_flops",
    "normalize_cost_analysis", "roofline_terms", "RooflineReport",
    "load_dryrun", "report_table",
]


def normalize_cost_analysis(cost) -> dict:
    """Normalize ``compiled.cost_analysis()`` across JAX versions.

    Newer JAX returns a flat dict; older versions return a one-element
    list of dicts (one per executable program).  Always returns a dict so
    callers can index properties (``"flops"``, ``"bytes accessed"``, ...)
    without version checks.
    """
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def _attn_params(cfg: ArchConfig) -> int:
    hd = cfg.resolved_head_dim
    return cfg.d_model * hd * (cfg.num_heads * 2 + cfg.num_kv_heads * 2)


def _mlp_params(cfg: ArchConfig) -> int:
    return 3 * cfg.d_model * cfg.d_ff


def _moe_params(cfg: ArchConfig) -> int:
    return (cfg.num_experts * 3 * cfg.d_model * cfg.d_ff
            + cfg.d_model * cfg.num_experts)


def _mamba_params(cfg: ArchConfig) -> int:
    d_in = cfg.mamba_expand * cfg.d_model
    return (cfg.d_model * 2 * d_in                 # in_proj
            + cfg.mamba_d_conv * d_in              # conv
            + d_in * (2 * cfg.mamba_d_state + 1)   # B, C, dt_raw
            + d_in * (cfg.mamba_d_state + 3)       # A, dt proj, D, bias
            + d_in * cfg.d_model)                  # out_proj


def _rwkv_params(cfg: ArchConfig) -> int:
    d = cfg.d_model
    return 6 * d * d + 2 * d * cfg.d_ff + d * d + 10 * d


def analytic_param_count(cfg: ArchConfig, active: bool = False) -> int:
    """Backbone + head parameter count from the config alone."""
    total = cfg.vocab_size * cfg.d_model * 2  # embed + (untied) head
    if cfg.frontend != "none" and cfg.num_prefix_tokens:
        total += (cfg.frontend_dim or cfg.d_model) * cfg.d_model
    for spec in cfg.layer_pattern():
        n_of_this = cfg.num_layers // len(cfg.layer_pattern())
        layer = 0
        if spec.mixer == "attn":
            layer += _attn_params(cfg)
        elif spec.mixer == "mamba":
            layer += _mamba_params(cfg)
        elif spec.mixer == "rwkv":
            layer += _rwkv_params(cfg)
        if spec.ffn == "dense" and spec.mixer != "rwkv":
            layer += _mlp_params(cfg)
        elif spec.ffn == "moe":
            if active:
                frac = cfg.experts_per_token / cfg.num_experts
                layer += int(_moe_params(cfg) * frac)
            else:
                layer += _moe_params(cfg)
        total += layer * n_of_this
    return total


def active_param_count(cfg: ArchConfig) -> int:
    return analytic_param_count(cfg, active=True)


def model_flops(cfg: ArchConfig, shape_kind: str, seq_len: int,
                global_batch: int) -> float:
    """Useful model FLOPs for the whole step, all chips.

    train:    6 * N_active * D  (fwd 2ND + bwd 4ND), D = global tokens.
              The INTERACT step runs ~2 fwd+bwd passes (outer + cross) on
              half the batch each + 1 forward => ~1.25x of a plain step;
              we report plain 6ND as the conventional yardstick.
    prefill:  2 * N_active * D
    decode:   2 * N_active * B  (one token per request)
    """
    n = active_param_count(cfg)
    if shape_kind == "train":
        d = seq_len * global_batch
        return 6.0 * n * d
    if shape_kind == "prefill":
        return 2.0 * n * seq_len * global_batch
    return 2.0 * n * global_batch


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    scan_corrected: bool
    raw: dict

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} "
                f"{self.compute_s:10.3e} {self.memory_s:10.3e} "
                f"{self.collective_s:10.3e}  {self.dominant:10s} "
                f"{self.useful_ratio:6.2f}")


def roofline_terms(result: dict, cfg: ArchConfig,
                   scan_trip_correction: float | None = None
                   ) -> RooflineReport:
    """Build the three terms from one dry-run JSON record."""
    from repro.launch.input_specs import SHAPES
    sd = SHAPES[result["shape"]]
    devices = result["devices"]
    flops_dev = float(result["cost"]["flops"] or 0.0)
    bytes_dev = float(result["cost"]["bytes_accessed"] or 0.0)
    wire_dev = float(result["collectives"]["wire_bytes"] or 0.0)

    corr = 1.0
    corrected = False
    if scan_trip_correction and scan_trip_correction > 1.0:
        corr = scan_trip_correction
        corrected = True

    compute_s = flops_dev * corr / PEAK_FLOPS
    memory_s = bytes_dev * corr / HBM_BW
    collective_s = wire_dev / LINK_BW

    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, sd.kind, sd.seq_len, sd.global_batch)
    hlo_total = flops_dev * corr * devices
    ratio = mf / hlo_total if hlo_total else float("nan")

    return RooflineReport(
        arch=result["arch"], shape=result["shape"], devices=devices,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf, hlo_flops_total=hlo_total,
        useful_ratio=ratio, scan_corrected=corrected, raw=result)


def load_dryrun(results_dir: str | pathlib.Path, tag: str = "pod"
                ) -> list[dict]:
    out = []
    for p in sorted(pathlib.Path(results_dir).glob(f"*__{tag}.json")):
        out.append(json.loads(p.read_text()))
    return out


def report_table(reports: list[RooflineReport]) -> str:
    header = (f"{'arch':22s} {'shape':12s} {'compute_s':>10s} "
              f"{'memory_s':>10s} {'collect_s':>10s}  {'dominant':10s} "
              f"{'useful':>6s}")
    lines = [header, "-" * len(header)]
    lines += [r.row() for r in reports]
    return "\n".join(lines)
