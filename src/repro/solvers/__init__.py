"""Unified Solver API: one registry, one config, one runner.

The paper's Section-6 experiments are head-to-head sweeps of INTERACT,
SVR-INTERACT, GT-DSGD and D-SGD; this package gives all four (and any
future algorithm) a single surface:

    from repro.solvers import SolverConfig, make_solver, solve

    solver = make_solver(SolverConfig(algo="interact", alpha=0.3))
    state  = solver.init(None, problem, hg_cfg, x0, y0, data)
    state  = solver.run(state, data, 100)        # lax.scan, one dispatch

    # or the whole Section-6 experiment in one call:
    result = solve(SolverConfig(algo="svr-interact"), 100, record_every=5)

    # or a whole seeds x step-sizes grid as one vmapped XLA program:
    result = sweep(expand_grid(SolverConfig(), seed=range(8)), 100,
                   record_every=5)

See docs/SOLVERS.md for the protocol, the registry, and how to add a
fifth algorithm as a drop-in entry; docs/SWEEPS.md for the batched
sweep engine (vmap grouping, in-scan recording cost model).
"""
from repro.solvers.api import (
    SolveResult,
    Solver,
    SolverBase,
    available_solvers,
    default_setup,
    make_solver,
    register_solver,
    run_recorded,
    solve,
)
from repro.byzantine import ByzantineConfig, GuardConfig
from repro.consensus.compress import CompressionConfig
from repro.solvers.config import SolverConfig, TopologyConfig
from repro.solvers.sweep import SweepGroup, SweepResult, expand_grid, sweep

# Importing the implementation modules populates the registry.
from repro.solvers import baselines as _baselines    # noqa: F401
from repro.solvers import interact as _interact      # noqa: F401
from repro.solvers import svr_interact as _svr       # noqa: F401

__all__ = [
    "ByzantineConfig",
    "CompressionConfig",
    "GuardConfig",
    "SolveResult",
    "Solver",
    "SolverBase",
    "SolverConfig",
    "SweepGroup",
    "SweepResult",
    "TopologyConfig",
    "available_solvers",
    "default_setup",
    "expand_grid",
    "make_solver",
    "register_solver",
    "run_recorded",
    "solve",
    "sweep",
]
