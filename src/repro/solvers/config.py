"""`SolverConfig`: the one configuration object behind every algorithm.

Everything the four Section-6 algorithms used to take positionally —
algorithm name, step sizes, minibatch / refresh period, consensus backend
plus backend options, network topology, hypergradient configuration, and
the RNG seed — lives in a single frozen dataclass consumed by
``repro.solvers.make_solver`` (single-host simulator) and accepted by
``repro.train.make_train_step`` / ``make_svr_train_step`` (distributed LM
runtime), so one config drives both paths.

``TopologyConfig`` describes the communication graph declaratively
(kind + parameters); it materialises into a ``MixingSpec`` once the agent
count is known.  A pre-built ``MixingSpec`` can be supplied instead via
``SolverConfig.mixing`` — it wins over ``topology`` when set.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

import numpy as np

from repro.byzantine import ByzantineConfig, GuardConfig
from repro.consensus.compress import CompressionConfig
from repro.core.consensus import (
    MixingSpec,
    erdos_renyi_adjacency,
    laplacian_mixing,
    ring_mixing,
    torus_mixing,
)
from repro.hypergrad import HypergradConfig
from repro.topology.process import TopologyProcessConfig

__all__ = ["SolverConfig", "TopologyConfig"]


@dataclasses.dataclass(frozen=True)
class TopologyConfig:
    """Declarative communication graph: realised per agent count m.

    kind:       "ring" | "erdos-renyi" | "torus".
    p_connect:  ER edge probability.
    seed:       ER graph sample seed.
    self_weight: ring mixing w0 (lambda then analytic).
    """

    kind: str = "erdos-renyi"
    p_connect: float = 0.5
    seed: int = 0
    self_weight: float = 1.0 / 3.0

    def mixing_spec(self, m: int) -> MixingSpec:
        """The configured topology's mixing matrix for ``m`` agents."""
        if self.kind == "ring":
            return ring_mixing(m, self_weight=self.self_weight)
        if self.kind == "erdos-renyi":
            return laplacian_mixing(
                erdos_renyi_adjacency(m, self.p_connect, self.seed))
        if self.kind == "torus":
            rows = int(m ** 0.5)
            while rows > 1 and m % rows:
                rows -= 1
            return torus_mixing(rows, m // rows)
        raise ValueError(f"unknown topology {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """Unified configuration for all registry solvers.

    Attributes:
      algo: registry name — "interact" | "svr-interact" | "gt-dsgd" |
        "d-sgd" (see ``repro.solvers.available_solvers()``).
      alpha / beta: outer / inner step sizes (Theorem-1 bounds apply).
      batch_size: minibatch size |S| for the stochastic algorithms;
        ``None`` defaults to the paper's ceil(sqrt(n)) at init time.
      q: SVR-INTERACT full-refresh period; ``None`` -> ceil(sqrt(n)).
      num_agents: the network size m for declarative topologies.  When
        set it wins over any m derived from data shapes, making the
        config self-contained — which is what lets the sweep engine
        realise per-config networks for an m-sweep (and ghost-pad them
        into one program under ``pad_agents=True``, docs/SWEEPS.md).
        ``None``: m comes from the data, as before.
      mixing: explicit ``MixingSpec``; overrides ``topology`` when set.
      topology: declarative graph, realised once m is known.
      backend: consensus backend — "dense" | "pallas" | "ppermute" |
        "allgather" (the mesh backends run inside ``shard_map``; see
        docs/DISTRIBUTED.md for the multi-process launch path).
      backend_opts: extra kwargs for ``repro.consensus.make_engine``
        (e.g. ``interpret`` for pallas, ``compress``/``dp_sigma`` for
        ppermute).
      hypergrad: how the inner-Hessian inverse is applied (eq. 5 / 22);
        its ``backend`` field selects the ``HypergradEngine`` ("cg",
        "cg-linearized", "neumann", "neumann-linearized", "cholesky" —
        validated against the registry at solver build time, see
        docs/HYPERGRAD.md).
      compression: wire compression of consensus payloads
        (``repro.consensus.CompressionConfig``: none / int8 / sign1bit /
        topk, error feedback, warmup) — see docs/CONSENSUS.md.
      communication_interval: local descent steps between consensus
        mixes (1 = mix every step, the paper's algorithms); larger
        values trade consensus error for wire traffic.  Implemented as
        a predicate on the step index inside the scan, so the program
        stays one compile.
      topology_process: how the realised mixing matrix evolves over
        steps (``repro.topology.TopologyProcessConfig``: static /
        link-failure / straggler / random-gossip / adaptive) — the
        time-varying layer ON TOP of the base graph from ``topology`` /
        ``mixing``.  The default static process is a bitwise no-op.
        See docs/TOPOLOGY.md.
      byzantine: Byzantine attack injection + robust aggregation
        (``repro.byzantine.ByzantineConfig``: attack kind / attacker
        count / combine rule).  The default — no attack, ``weighted``
        combine — is a bitwise no-op.  See docs/BYZANTINE.md.
      guard: in-scan divergence trip-wires
        (``repro.byzantine.GuardConfig``: NaN/Inf detection,
        iterate-norm bound, ``jnp.where`` rollback-to-last-good);
        counters surface through ``SolveResult.tripped_steps`` /
        ``last_good_step``.  Inactive by default.
      seed: PRNG seed for the stochastic solvers' sampling streams (and
        the fallback seed of the topology process's link schedule and
        the Byzantine attack schedule).
    """

    algo: str = "interact"
    alpha: float = 0.3
    beta: float = 0.3
    batch_size: int | None = None
    q: int | None = None
    num_agents: int | None = None
    mixing: MixingSpec | None = None
    topology: TopologyConfig = TopologyConfig()
    backend: str = "dense"
    backend_opts: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    hypergrad: HypergradConfig = HypergradConfig()
    compression: CompressionConfig = CompressionConfig()
    communication_interval: int = 1
    topology_process: TopologyProcessConfig = TopologyProcessConfig()
    byzantine: ByzantineConfig = ByzantineConfig()
    guard: GuardConfig = GuardConfig()
    seed: int = 0

    def mixing_spec(self, m: int | None = None) -> MixingSpec:
        """The mixing matrix: explicit ``mixing`` if set, else topology(m).

        ``num_agents`` (when set) wins over the caller-supplied ``m``.
        """
        if self.mixing is not None:
            return self.mixing
        m = self.num_agents if self.num_agents is not None else m
        if m is None:
            raise ValueError(
                "SolverConfig has no explicit mixing; the agent count m is "
                "required to realise the declarative topology (set "
                "num_agents or pass m)")
        return self.topology.mixing_spec(m)

    def resolve_num_agents(self, m: int | None = None) -> int | None:
        """The config's network size: ``num_agents``, else the explicit
        mixing's size, else the caller's default (data-derived) ``m``."""
        if self.num_agents is not None:
            return self.num_agents
        if self.mixing is not None:
            return self.mixing.num_agents
        return m

    def resolve_q(self, n: int | None = None) -> int:
        """Refresh period: explicit ``q`` or the paper's ceil(sqrt(n))."""
        if self.q is not None:
            return self.q
        if n is None:
            raise ValueError("q unset and per-agent sample count n unknown")
        return int(math.ceil(math.sqrt(n)))

    def resolve_batch(self, n: int | None = None) -> int:
        """Minibatch size: explicit ``batch_size`` or |S| = q (paper)."""
        if self.batch_size is not None:
            return self.batch_size
        return self.resolve_q(n)

    # -- static / batch split (the sweep engine's grouping contract) ------
    #
    # Two configs can share one compiled XLA program — and therefore ride
    # the same vmap batch — exactly when everything the trace depends on
    # matches: algorithm, topology/mixing, consensus backend (+opts),
    # hypergrad config, and the resolved batch/q.  ``seed``, ``alpha``
    # and ``beta`` only enter the computation as array *values* (the PRNG
    # key and two scalars), so they are the batch axes.

    BATCH_FIELDS = ("seed", "alpha", "beta")

    def static_key(self, pad_to: int | None = None) -> tuple:
        """Hashable fingerprint of every trace-static field.

        Configs with equal ``static_key()`` compile to the same program
        and are grouped onto one ``jax.vmap`` dispatch by
        ``repro.solvers.sweep``; the ``BATCH_FIELDS`` (seed, alpha,
        beta) are deliberately excluded — they become the mapped axis.
        An explicit ``MixingSpec`` is fingerprinted by value (matrix
        bytes), not identity, so two separately-built equal topologies
        still share a group.

        ``pad_to`` is the padded-agent grouping mode (docs/SWEEPS.md):
        the network fields — ``topology`` / ``mixing`` / ``num_agents``
        — leave the static fingerprint entirely, replaced by the common
        padded size.  Configs that differ only in network size or
        topology then share a key: under ``sweep(..., pad_agents=True)``
        their mixing matrices are ghost-padded to ``pad_to`` and become
        a stacked vmap operand instead of a compile-time constant.
        """
        opts = tuple(sorted(self.backend_opts.items()))
        wire = (self.compression, self.communication_interval)
        # The topology process contributes only its STRUCTURE (kind,
        # period, tau): the failure probability ``p`` and the stream seed
        # enter the trace as realized matrix *values* — a stacked vmap
        # operand, like the padded mixing matrices — so a failure-rate ×
        # algorithm grid batches into one program per algorithm.
        proc = self.topology_process.structural_key()
        if pad_to is not None:
            # Byzantine grids batch under padding: only the structure
            # (attack kind, combine rule, trim) must match — the
            # attacker count, scale and schedule key are vmap operands,
            # so a num_byzantine sweep is one dispatch per algorithm.
            byz = self.byzantine.structural_key()
            return (self.algo, self.batch_size, self.q, ("padded", pad_to),
                    self.backend, opts, self.hypergrad, wire, proc, byz,
                    self.guard)
        mix = None
        if self.mixing is not None:
            mat = np.asarray(self.mixing.matrix)
            mix = (mat.shape, mat.tobytes(), float(self.mixing.lam),
                   tuple(self.mixing.neighbors), tuple(self.mixing.weights))
        # Non-padded groups key on the FULL Byzantine config plus the
        # resolved attack seed: the built engine bakes the attack
        # operands in as constants, and a seed-inheriting attack
        # (ByzantineConfig.seed=None) must never share one schedule
        # across a seed grid.
        byz = (self.byzantine,
               self.byzantine.resolve_seed(self.seed)
               if self.byzantine.attack_active else None)
        return (self.algo, self.batch_size, self.q, self.num_agents, mix,
                self.topology, self.backend, opts, self.hypergrad, wire,
                proc, byz, self.guard)

    def batch_values(self) -> tuple[int, float, float]:
        """The per-experiment dynamic values: ``(seed, alpha, beta)``."""
        return (self.seed, self.alpha, self.beta)
