"""Registry entry for INTERACT (Algorithm 1).

Full local gradients every iteration: n IFO calls per agent per step
(Definition 1), two consensus rounds (Steps 1 and 3).  The math lives in
``repro.core.interact``; this class binds it to the Solver protocol.
"""
from __future__ import annotations

from repro.byzantine import init_guard
from repro.core.interact import init_state, interact_step
from repro.solvers.api import SolverBase, register_solver

__all__ = ["InteractSolver"]


@register_solver("interact")
class InteractSolver(SolverBase):
    """Deterministic INTERACT: full gradient pass (eqs. 8-9) each step."""

    def _init_state(self, key, problem, hg_cfg, x0, y0, data):
        # Algorithm 1 is deterministic; the key is unused.
        return init_state(problem, hg_cfg, x0, y0, data,
                          compression=self.config.compression,
                          guard=init_guard(self.config.guard))

    def _make_param_step(self, problem, hg_cfg, engine, n):
        def step(state, data, alpha, beta):
            return interact_step(problem, hg_cfg, engine, alpha, beta,
                                 state, data)

        return step

    def samples_per_step(self, n: int) -> float:
        return float(n)
