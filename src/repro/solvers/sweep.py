"""The batched sweep engine: vmap-over-experiments + in-scan recording.

Every Section-6 figure is a *grid* — algorithms x network sizes x
topologies x seeds x step sizes.  Executing the grid one config at a
time pays a Python loop, a fresh XLA dispatch, and an eager metric
round-trip per cell.  This module runs the grid as a handful of compiled
XLA programs instead:

1.  Configs are **grouped** by ``SolverConfig.static_key()`` — everything
    the compiled trace depends on (algo, topology, backend, hypergrad
    config, batch/q).  Within a group only the ``BATCH_FIELDS`` (seed,
    alpha, beta) differ, and those enter the computation as array
    values.

2.  Each group compiles **one** program: ``jax.vmap`` over the entire
    ``init -> run_traced`` pipeline (state init from the per-experiment
    PRNG key, ``num_steps`` solver iterations under ``lax.scan``, the
    convergence metric recorded in-scan every ``record_every`` steps via
    ``lax.cond``).  An 8-seed x 4-algorithm Figure-2 grid is 4 XLA
    dispatches, not 32 Python loops.

3.  ``pad_agents=True`` additionally collapses groups that differ only
    in *network size or topology*: every mixing matrix is ghost-padded
    to a common ``pad_to`` (identity self-loop rows — still doubly
    stochastic, active agents' combines bitwise unchanged), states and
    data are padded along the agent axis, and the padded matrix /
    active-agent count become vmap operands instead of compile-time
    constants.  An m x topology x algorithm grid then compiles one
    program per algorithm instead of one per (m, topology) cell.

Usage::

    from repro.solvers import SolverConfig, expand_grid, sweep

    configs = expand_grid(SolverConfig(algo="interact"),
                          seed=range(8), alpha=(0.3, 0.1))
    result = sweep(configs, num_steps=40, record_every=5)
    result.traces          # (16, 9) on-device metric traces
    result.num_dispatches  # 1: one group, one compiled program

    grid = expand_grid(SolverConfig(algo="interact"),
                       num_agents=(4, 8), seed=range(4))
    result = sweep(grid, 40, 5, pad_agents=True)
    result.num_dispatches  # 1: both network sizes share one padded program

See docs/SWEEPS.md for the grouping semantics, the padding semantics
(ghost rows, metric masking, FLOPs-vs-dispatch trade-off), and the
recording cost model.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import pathlib
import time
from collections.abc import Mapping
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bilevel import AgentData, pad_agent_data
from repro.core.consensus import pad_mixing
from repro.solvers.api import _traced_scan, default_setup, make_solver
from repro.solvers.config import SolverConfig

__all__ = ["SweepGroup", "SweepResult", "expand_grid", "sweep"]


def expand_grid(base: SolverConfig = SolverConfig(),
                **axes: Sequence) -> list[SolverConfig]:
    """The cartesian grid of ``dataclasses.replace(base, ...)`` configs.

    ``expand_grid(base, seed=range(8), alpha=(0.3, 0.1))`` yields 16
    configs in row-major order (later axes vary fastest).  Any
    ``SolverConfig`` field is a valid axis; sweeping only the
    ``BATCH_FIELDS`` (seed / alpha / beta) keeps the whole grid in one
    vmap group, other axes split it into one group per distinct
    ``static_key()`` — except ``num_agents`` / ``topology`` / ``mixing``
    axes under ``sweep(..., pad_agents=True)``, which batch too.
    """
    names = list(axes)
    out = []
    for values in itertools.product(*(axes[k] for k in names)):
        out.append(dataclasses.replace(base, **dict(zip(names, values))))
    return out


@dataclasses.dataclass
class SweepGroup:
    """One vmap group: the configs that shared a compiled program."""

    indices: list[int]          # positions into the sweep's config list
    config: SolverConfig        # the group's representative (static fields)
    seconds: float              # batched wall-clock (post-warmup when
                                # measured, else first run incl. compile)
    pad_to: int | None = None   # padded agent count (padded groups only)
    num_active: tuple[int, ...] | None = None   # per-config active m
    loaded: bool = False        # True: traces came from the resume_dir
                                # manifest, not a fresh dispatch


@dataclasses.dataclass
class SweepResult:
    """What ``sweep`` returns.

    ``traces[i]`` is config ``i``'s metric trace in the legacy
    ``run_recorded`` layout (metric before steps 0, record_every, ...,
    plus the final iterate); rows are aligned with the *input* config
    order regardless of grouping.  ``states`` holds the final solver
    states stacked per group (leading axis = group size) when
    ``return_states=True``, else None — in a padded sweep their agent
    axis is ``pad_to`` wide and rows past a config's ``num_active`` are
    ghost agents.
    """

    configs: list[SolverConfig]
    traces: np.ndarray                   # (num_configs, num_records)
    groups: list[SweepGroup]
    seconds: float                       # batched wall-clock (see measure)
    seconds_sequential: float | None     # same grid, one config at a time
    measured: bool = False               # True: seconds exclude compile
    states: list[Any] | None = None
    pad_to: int | None = None            # set when pad_agents batched

    @property
    def num_dispatches(self) -> int:
        return len(self.groups)

    @property
    def vmap_speedup(self) -> float | None:
        """Sequential / batched wall-clock (None unless both measured)."""
        if self.seconds_sequential is None:
            return None
        return self.seconds_sequential / max(self.seconds, 1e-12)

    def trace_of(self, config: SolverConfig) -> np.ndarray:
        """The trace row of the first config matching ``config``.

        Matches by ``(static_key, batch_values, topology_process)``
        rather than dataclass equality — an explicit ``MixingSpec``
        holds a numpy matrix, for which ``==`` is elementwise.  The
        topology process is matched by value because its stream
        parameters (p, seed) are deliberately NOT in the static key —
        they batch as vmap operands — yet distinguish experiments.
        """
        want = (config.static_key(), config.batch_values(),
                config.topology_process)
        for i, c in enumerate(self.configs):
            if c is config or (c.static_key(), c.batch_values(),
                               c.topology_process) == want:
                return self.traces[i]
        raise KeyError(config)

    def group_traces(self, group: SweepGroup) -> np.ndarray:
        return self.traces[np.asarray(group.indices)]


def _group_by_static_key(configs: Sequence[SolverConfig],
                         pad_to: int | None = None):
    """Order-preserving grouping: static_key -> list of config indices."""
    groups: dict[tuple, list[int]] = {}
    for i, cfg in enumerate(configs):
        groups.setdefault(cfg.static_key(pad_to=pad_to), []).append(i)
    return list(groups.values())


class _SweepResume:
    """The self-healing sweep's completion manifest (docs/RESILIENCE.md).

    ``resume_dir/manifest.json`` maps a *group fingerprint* — a hash of
    the sweep geometry (num_steps, record_every, padding, problem data /
    initial-point content) plus every member config's static key, batch
    values, and topology process — to the ``group_<fp>.npz`` file
    holding that group's traces (written through the crash-safe
    ``repro.checkpoint`` store: atomic replace, per-leaf CRC32).  The
    manifest is rewritten atomically after *each* group completes, so a
    sweep killed mid-grid re-queues exactly the failed / missing groups
    on the next invocation and loads the finished ones bitwise — cached
    arrays, not recomputation.  A group whose cached file is corrupt or
    whose fingerprint no longer matches is simply recomputed.
    """

    MANIFEST = "manifest.json"

    def __init__(self, root, base_key: str, configs):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.base_key = base_key
        self.configs = configs
        self.manifest: dict = {"version": 1, "groups": {}}
        try:
            with open(self.root / self.MANIFEST) as fh:
                loaded = json.load(fh)
            if isinstance(loaded, dict) and "groups" in loaded:
                self.manifest = loaded
        except (OSError, ValueError):
            pass    # no/corrupt manifest: every group recomputes

    def group_fp(self, indices) -> str:
        tags = [repr((self.configs[i].static_key(),
                      self.configs[i].batch_values(),
                      self.configs[i].topology_process))
                for i in indices]
        return hashlib.sha256(
            repr((self.base_key, tags)).encode()).hexdigest()[:16]

    def load(self, fp: str):
        """``(traces, seconds)`` for a completed group, else ``None``."""
        from repro.checkpoint import CorruptCheckpointError, restore_pytree
        entry = self.manifest["groups"].get(fp)
        if entry is None:
            return None
        like = {"traces": np.zeros(tuple(entry["trace_shape"]),
                                   np.dtype(entry["trace_dtype"]))}
        try:
            tree = restore_pytree(self.root / entry["file"], like)
        except (CorruptCheckpointError, OSError, ValueError):
            return None     # damaged cache: recompute this group
        return np.asarray(tree["traces"]), float(entry.get("seconds", 0.0))

    def store(self, fp: str, indices, traces: np.ndarray,
              seconds: float) -> None:
        from repro.checkpoint import save_pytree
        from repro.resilience.snapshot import write_json_atomic
        traces = np.asarray(traces)
        fname = f"group_{fp}.npz"
        save_pytree(self.root / fname, {"traces": traces})
        self.manifest["groups"][fp] = {
            "file": fname,
            "indices": [int(i) for i in indices],
            "trace_shape": list(traces.shape),
            "trace_dtype": str(traces.dtype),
            "seconds": float(seconds),
        }
        write_json_atomic(self.root / self.MANIFEST, self.manifest)


def _experiment_fn(solver, data, num_steps: int, record_every: int,
                   metric_fn):
    """The pure per-experiment pipeline: ``(key, alpha, beta, x0, y0)``
    -> ``(final_state, trace)``.

    Traceable end to end (init included), so it can be ``jax.vmap``-ped
    over stacked keys / step sizes / inits.  Solvers that predate the
    parameterised step hook fall back to the config-bound body — their
    groups are keyed on alpha/beta by the caller, so the ignored scalars
    are constant within a group.
    """
    problem, hg_cfg = solver._problem, solver._hg_cfg
    param = solver._param_step
    if param is None:
        raw = solver._raw_step          # config-bound alpha/beta

        def param(state, d, _a, _b):
            return raw(state, d)

    def one(key, alpha, beta, x0, y0):
        state = solver._init_state(key, problem, hg_cfg, x0, y0, data)
        return _traced_scan(param, state, data, num_steps, record_every,
                            metric_fn, alpha, beta)

    return one


def _attach_traced_topology(engine, config: SolverConfig, matrix):
    """Install the group's topology runtime on an in-trace engine.

    ``matrix`` is the experiment's (possibly traced / ghost-padded)
    mixing matrix; stream matrices arrive separately as the traced
    ``stream`` operand of the experiment fn, adaptive adjacency is
    derived from ``matrix`` right here (ghost rows are identity, so
    their adjacency row is zero and the Dada rule yields an identity
    row — padding-safe).
    """
    from repro.topology.runtime import AdaptiveTopology

    proc = config.topology_process
    if proc.is_static or not proc.state_dependent:
        return
    m = matrix.shape[-1]
    adjacency = ((jnp.abs(matrix) > 1e-12)
                 & ~jnp.eye(m, dtype=bool)).astype(jnp.float32)
    engine.topology = AdaptiveTopology(adjacency, proc.tau)


def _stream_experiment_fn(solver, data, n, num_steps: int,
                          record_every: int, metric_fn):
    """Per-experiment pipeline with the *matrix stream* as a vmap operand.

    ``(key, alpha, beta, x0, y0, stream)`` -> ``(final_state, trace)``
    where ``stream`` is the experiment's realized ``(T, m, m)`` topology
    stream.  The dense engine is constructed inside the trace and the
    stream attached as a traced ``StreamTopology``, so a failure-rate ×
    seed grid over one algorithm compiles a single program — the
    per-step matrices are array values, exactly like the padded sweep's
    mixing-matrix operand.
    """
    from repro.byzantine import guard_param_step
    from repro.consensus.dense import DenseEngine
    from repro.topology.runtime import StreamTopology

    problem, hg_cfg = solver._problem, solver._hg_cfg

    def one(key, alpha, beta, x0, y0, stream):
        engine = DenseEngine(
            solver._engine.matrix, compression=solver.config.compression,
            communication_interval=solver.config.communication_interval,
            byzantine=solver.config.byzantine)
        if solver._engine.byz_values is not None:
            # the built engine carries the group's resolved attack key
            # (part of the static key, so it is constant within a group)
            engine.byz_values = dict(solver._engine.byz_values)
        engine.topology = StreamTopology(stream)
        param = solver._make_param_step(problem, hg_cfg, engine, n)
        if solver.config.guard.active:
            param = guard_param_step(param, solver.config.guard)
        state = solver._init_state(key, problem, hg_cfg, x0, y0, data)
        return _traced_scan(param, state, data, num_steps, record_every,
                            metric_fn, alpha, beta)

    return one


def _padded_experiment_fn(solver, n: int, num_steps: int,
                          record_every: int, masked_metric_fn,
                          data_stack, with_stream: bool = False,
                          with_byz: bool = False):
    """Per-experiment pipeline with the *network* as vmap operands.

    ``(key, alpha, beta, x0, y0, matrix, num_active, data_idx[, stream]
    [, byz])`` -> ``(final_state, trace)``.  The dense consensus engine
    is constructed inside the trace from the experiment's ghost-padded
    mixing matrix, so one compiled program serves every network size /
    topology in the group; ``masked_metric_fn(state, data, num_active)``
    keeps ghost agents out of the recorded metric.

    ``data_stack`` holds the group's *unique* padded datasets (leading
    axis = number of distinct networks, not experiments); each
    experiment gathers its row via the mapped ``data_idx``, so device
    memory scales with distinct sizes rather than grid cells (an
    S-seed sweep would otherwise carry S identical dataset copies).

    ``with_stream=True`` adds a trailing ghost-padded ``(T, m, m)``
    topology-stream operand (time-varying topologies batch like the
    mixing matrix does); the state-dependent adaptive process instead
    derives its adjacency from the padded matrix in-trace.

    ``with_byz=True`` adds the Byzantine attack operands ``{"
    num_byzantine", "scale", "key"}`` — the attack *structure* (kind /
    combine rule / trim) is in the static key, its *values* batch like
    seeds do, so an attacker-count x seed grid is one dispatch.  The
    traced ``num_active`` doubles as the mask bound that keeps attacks
    off ghost rows.
    """
    from repro.byzantine import guard_param_step
    from repro.consensus.dense import DenseEngine
    from repro.topology.runtime import StreamTopology

    problem, hg_cfg = solver._problem, solver._hg_cfg

    def one(key, alpha, beta, x0, y0, matrix, num_active, data_idx,
            stream=None, byz=None):
        data = jax.tree_util.tree_map(lambda l: l[data_idx], data_stack)
        # wire options ride along: per-agent (row-wise) compression keeps
        # ghost-padded combines exact, so compressed configs batch too
        engine = DenseEngine(
            matrix, compression=solver.config.compression,
            communication_interval=solver.config.communication_interval,
            byzantine=solver.config.byzantine)
        engine.num_active = num_active
        if byz is not None:
            engine.byz_values = dict(byz)
        if stream is not None:
            engine.topology = StreamTopology(stream)
        else:
            _attach_traced_topology(engine, solver.config, matrix)
        param = solver._make_param_step(problem, hg_cfg, engine, n)
        if solver.config.guard.active:
            param = guard_param_step(param, solver.config.guard)
        state = solver._init_state(key, problem, hg_cfg, x0, y0, data)
        metric_fn = None
        if masked_metric_fn is not None:
            def metric_fn(st):
                return masked_metric_fn(st, data, num_active)
        return _traced_scan(param, state, data, num_steps, record_every,
                            metric_fn, alpha, beta)

    # vmap needs a fixed positional arity: expose exactly the operands
    # this group batches (stream and/or byz ride at the end, in order).
    if with_stream and with_byz:
        def one_stream_byz(key, alpha, beta, x0, y0, matrix, num_active,
                           data_idx, stream, byz):
            return one(key, alpha, beta, x0, y0, matrix, num_active,
                       data_idx, stream=stream, byz=byz)
        return one_stream_byz
    if with_stream:
        def one_stream(key, alpha, beta, x0, y0, matrix, num_active,
                       data_idx, stream):
            return one(key, alpha, beta, x0, y0, matrix, num_active,
                       data_idx, stream=stream)
        return one_stream
    if with_byz:
        def one_byz(key, alpha, beta, x0, y0, matrix, num_active,
                    data_idx, byz):
            return one(key, alpha, beta, x0, y0, matrix, num_active,
                       data_idx, byz=byz)
        return one_byz

    def one_plain(key, alpha, beta, x0, y0, matrix, num_active,
                  data_idx):
        return one(key, alpha, beta, x0, y0, matrix, num_active,
                   data_idx)
    return one_plain


def _mixed_m_error(configs, indices, need_m: int, have: str) -> ValueError:
    """The network-size-mismatch diagnostic, naming the offending keys.

    Before padding existed this surfaced as an XLA shape error (or a
    silent split into singleton groups); now it names each offending
    config's static key and points at the two fixes.
    """
    lines = [f"  configs[{i}]: static_key={configs[i].static_key()!r}"
             for i in indices]
    all_ms = sorted({c.resolve_num_agents(need_m) or need_m
                     for c in configs})
    return ValueError(
        f"sweep group needs m={need_m} agents but {have}; the grid spans "
        f"network sizes {all_ms}, which compile one program per size. "
        "Pass pad_agents=True to ghost-pad them into one batched program "
        "per algorithm (dense backend), or supply `data` as a "
        "{num_agents: AgentData} mapping to run one group per size. "
        "Offending configs:\n" + "\n".join(lines))


def _mixed_process_error(configs, indices, why: str) -> ValueError:
    """The topology-process batching diagnostic, naming offending configs.

    A sweep group keyed only on the process *structure* can hold configs
    whose realized matrix streams differ (failure rate p, stream seed).
    Batching those needs the stream as a traced vmap operand — the dense
    backend's parameterised step.  Anywhere that is impossible this
    raises the same actionable shape of error the mixed-m grids get,
    instead of silently running every config on the representative's
    stream (or dying in an XLA shape error).
    """
    lines = []
    for i in indices:
        proc = configs[i].topology_process
        lines.append(
            f"  configs[{i}]: topology_process=(kind={proc.kind!r}, "
            f"p={proc.p}, seed={proc.resolve_seed(configs[i].seed)}), "
            f"backend={configs[i].backend!r}")
    return ValueError(
        f"sweep group mixes topology-process realizations but {why}; "
        "the matrix stream must be a traced vmap operand, which needs "
        "the dense consensus backend and a solver implementing "
        "_make_param_step. Use backend='dense', or split the grid so "
        "each group shares one (p, seed) stream. Offending configs:\n"
        + "\n".join(lines))


def sweep(configs: Sequence[SolverConfig], num_steps: int,
          record_every: int = 0, *, problem=None, x0=None, y0=None,
          data=None, num_agents: int = 5, n_per_agent: int = 600,
          metric_fn=None, x0_stack=None, y0_stack=None,
          measure: bool = False, compare_sequential: bool = False,
          return_states: bool = False, pad_agents: bool = False,
          pad_to: int | None = None,
          resume_dir: str | pathlib.Path | None = None) -> SweepResult:
    """Run a grid of experiments as one compiled program per vmap group.

    Args:
      configs: the grid (see ``expand_grid``); grouped automatically by
        ``SolverConfig.static_key()`` — same algo/topology/backend/
        hypergrad per group, seed/alpha/beta batched inside it.
      num_steps / record_every: shared by every experiment (they are
        trace-static).  ``record_every=0`` disables recording.
      problem / x0 / y0 / data: the problem instance; defaults to the
        paper's Section-6 synthetic setup (``default_setup``, seeded by
        the first config).  For network-size sweeps ``data`` may be a
        ``{num_agents: AgentData}`` mapping — each config draws the
        dataset matching its network size.
      metric_fn: traceable ``state -> scalar`` recorded in-scan;
        defaults to the eq.-(11) convergence metric
        (``repro.core.convergence_metric_fn``) when ``record_every > 0``.
        Under ``pad_agents=True`` the signature is
        ``(state, data, num_active) -> scalar`` (the ghost-masked form,
        default ``repro.core.masked_convergence_metric_fn``).
      x0_stack / y0_stack: optional per-experiment initial points —
        pytrees with a leading axis of ``len(configs)``, aligned with
        the config order (they join seed/alpha/beta as vmap axes).
        When omitted every experiment starts from the shared ``x0``/
        ``y0`` exactly as the paper does.
      measure: re-execute each warmed batched program and report that
        wall-clock in ``seconds`` (compile excluded) — the benchmarks'
        mode.  Default False: every group runs **once** and ``seconds``
        is the first-run wall-clock including compilation (callers that
        want results shouldn't pay for the grid twice).
      compare_sequential: also run the same grid one config at a time
        through the *same* compiled single-experiment function and
        record the wall-clock, so ``result.vmap_speedup`` measures
        batching alone (identical program, identical values).  Implies
        ``measure`` (both paths warmed before timing).
      return_states: keep the final solver states (stacked per group).
      pad_agents: ghost-pad every config's network to a common agent
        count so configs that differ only in network size / topology
        share one compiled program (dense backend only; see
        docs/SWEEPS.md for the semantics and the FLOPs-vs-dispatch
        trade-off).  Active-agent trajectories are bitwise unchanged.
      pad_to: the padded agent count; defaults to the grid's largest
        network.
      resume_dir: self-healing mode (docs/RESILIENCE.md).  Each group's
        traces land in ``resume_dir`` (atomic, CRC-checked) under a
        fingerprint of the sweep geometry + member configs the moment
        the group completes; re-invoking the same sweep after a
        mid-grid failure loads the finished groups bitwise from disk
        and recomputes only the missing / damaged ones (their
        ``SweepGroup.loaded`` flag says which).  The fingerprint covers
        configs, num_steps/record_every, padding, and the *content* of
        problem data and initial points — but not ``metric_fn`` or
        ``problem`` internals: keep those fixed across invocations of
        one resume_dir.  Incompatible with ``return_states`` (final
        states are not cached) and with ``measure`` /
        ``compare_sequential`` timing of loaded groups (their recorded
        first-run seconds are reused).

    Returns a ``SweepResult`` with traces aligned to the input order.
    """
    configs = list(configs)
    measure = measure or compare_sequential
    if not configs:
        raise ValueError("sweep needs at least one config")

    data_map = None
    if isinstance(data, Mapping):
        data_map = {int(k): v for k, v in data.items()}
        data = None
    built_default = problem is None or x0 is None or y0 is None or (
        data is None and data_map is None)
    if built_default:
        problem, x0, y0, built = default_setup(
            configs[0].seed, num_agents=num_agents, n_per_agent=n_per_agent)
        if data is None and data_map is None:
            data = built

    default_m = data.inner_x.shape[0] if data is not None else num_agents
    _data_cache: dict[int, AgentData] = {}

    def data_for(m: int, indices) -> AgentData:
        if data_map is not None:
            try:
                return data_map[m]
            except KeyError:
                raise _mixed_m_error(
                    configs, indices, m,
                    f"the data mapping only covers {sorted(data_map)}"
                ) from None
        if data.inner_x.shape[0] == m:
            return data
        if built_default:     # default Section-6 setup: build per size
            if m not in _data_cache:
                _data_cache[m] = default_setup(
                    configs[0].seed, num_agents=m,
                    n_per_agent=n_per_agent)[3]
            return _data_cache[m]
        raise _mixed_m_error(
            configs, indices, m,
            f"the supplied data has {data.inner_x.shape[0]}")

    def samples_of(d: AgentData) -> int:
        return d.inner_x.shape[1] + d.outer_x.shape[1]

    traces = [None] * len(configs)
    states: list[Any] = [None] * len(configs) if return_states else None
    groups: list[SweepGroup] = []
    seconds = 0.0
    seconds_seq: float | None = 0.0 if compare_sequential else None

    if resume_dir is not None and return_states:
        raise ValueError(
            "resume_dir caches group traces, not final states; "
            "return_states=True would hand back a half-empty result — "
            "drop one of the two")

    if pad_agents:
        bad = [i for i, c in enumerate(configs) if c.backend != "dense"]
        if bad:
            raise ValueError(
                "pad_agents=True needs the dense consensus backend (the "
                "padded mixing matrix is a traced vmap operand); configs "
                f"{bad} use {sorted({configs[i].backend for i in bad})}")
        ms = [c.resolve_num_agents(default_m) or default_m for c in configs]
        m_pad = pad_to if pad_to is not None else max(ms)
        if m_pad < max(ms):
            raise ValueError(
                f"pad_to={m_pad} is smaller than the grid's largest "
                f"network ({max(ms)} agents)")
        group_indices = _group_by_static_key(configs, pad_to=m_pad)
    else:
        m_pad, ms = None, None
        group_indices = _group_by_static_key(configs)

    resume_state = None
    if resume_dir is not None:
        from repro.resilience.snapshot import tree_fingerprint
        base_key = repr((
            int(num_steps), int(record_every), bool(pad_agents), m_pad,
            built_default, configs[0].seed, num_agents, n_per_agent,
            None if data is None else tree_fingerprint(data),
            None if data_map is None else sorted(
                (k, tree_fingerprint(v)) for k, v in data_map.items()),
            tree_fingerprint(x0), tree_fingerprint(y0),
            None if x0_stack is None else tree_fingerprint(x0_stack),
            None if y0_stack is None else tree_fingerprint(y0_stack),
        ))
        resume_state = _SweepResume(resume_dir, base_key, configs)

    for indices in group_indices:
        rep = configs[indices[0]]
        if resume_state is not None:
            cached = resume_state.load(resume_state.group_fp(indices))
            if cached is not None:
                g_traces, took = cached
                for row, i in enumerate(indices):
                    traces[i] = g_traces[row]
                seconds += took
                groups.append(SweepGroup(
                    indices=indices, config=rep, seconds=took,
                    pad_to=m_pad if pad_agents else None,
                    num_active=tuple(ms[i] for i in indices)
                    if pad_agents else None, loaded=True))
                continue
        proc = rep.topology_process
        # a stream process (link-failure / straggler / gossip) realizes a
        # per-config matrix stream; within a group only its VALUES (p,
        # stream seed) differ, so the stream batches as a vmap operand
        stream_group = not proc.is_static and not proc.state_dependent
        streams = None
        byz_ops = None

        if pad_agents:
            # pad + stack each *distinct* dataset once; experiments map
            # an index into the unique stack (seeds share their network's
            # data, so stacking per experiment would duplicate it).
            uniq_row: dict[int, int] = {}
            uniq_padded: list[AgentData] = []
            data_rows = []
            for i in indices:
                d = data_for(ms[i], [i])
                if id(d) not in uniq_row:
                    uniq_row[id(d)] = len(uniq_padded)
                    uniq_padded.append(pad_agent_data(d, m_pad))
                data_rows.append(uniq_row[id(d)])
            n = samples_of(uniq_padded[0])
            if any(samples_of(d) != n for d in uniq_padded):
                raise ValueError(
                    "padded group mixes per-agent sample counts "
                    f"{sorted({samples_of(d) for d in uniq_padded})}; only "
                    "the agent axis may differ under pad_agents")
            solver = make_solver(rep).build(problem, None,
                                            m=ms[indices[0]], n=n)
            if solver._param_step is None:
                raise ValueError(
                    f"solver {rep.algo!r} implements only the legacy "
                    "_make_step hook; pad_agents needs the parameterised "
                    "_make_param_step (the engine is a traced operand)")
            group_metric = metric_fn
            if group_metric is None and record_every:
                from repro.core import masked_convergence_metric_fn
                group_metric = masked_convergence_metric_fn(
                    problem, solver._hg_cfg)

            data_stack = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *uniq_padded)
            data_idx = jnp.asarray(data_rows, jnp.int32)
            mats = jnp.stack([
                jnp.asarray(pad_mixing(
                    configs[i].mixing_spec(ms[i]), m_pad))
                for i in indices])
            num_active = jnp.asarray([ms[i] for i in indices], jnp.int32)
            if rep.byzantine.attack_active:
                # attack structure (kind / combine / trim) is static per
                # group; its values batch exactly like seeds do
                byz_ops = {
                    "num_byzantine": jnp.asarray(
                        [configs[i].byzantine.num_byzantine
                         for i in indices], jnp.int32),
                    "scale": jnp.asarray(
                        [configs[i].byzantine.scale for i in indices],
                        jnp.float32),
                    "key": jnp.stack([
                        jax.random.PRNGKey(
                            configs[i].byzantine.resolve_seed(
                                configs[i].seed))
                        for i in indices]),
                }
            if stream_group:
                from repro.topology.process import realize_stream
                streams = jnp.stack([
                    jnp.asarray(realize_stream(
                        configs[i].topology_process,
                        configs[i].mixing_spec(ms[i]),
                        configs[i].topology_process.resolve_seed(
                            configs[i].seed)).padded(m_pad).matrices,
                        jnp.float32)
                    for i in indices])
        else:
            g_m = rep.resolve_num_agents(default_m) or default_m
            g_data = data_for(g_m, indices)
            m = g_data.inner_x.shape[0]
            n = samples_of(g_data)
            spec = rep.mixing_spec(m)
            if spec.num_agents != m:
                raise _mixed_m_error(
                    configs, indices, spec.num_agents,
                    f"its data has {m}")
            solver = make_solver(rep).build(problem, None, m=m, n=n)
            if solver._param_step is None and any(
                    (configs[i].alpha, configs[i].beta)
                    != (rep.alpha, rep.beta) for i in indices):
                raise ValueError(
                    f"solver {rep.algo!r} implements only the legacy "
                    "_make_step hook (config-bound step sizes); it cannot "
                    "batch configs with different alpha/beta — implement "
                    "_make_param_step or sweep step sizes sequentially")
            if stream_group:
                can_batch = (rep.backend == "dense"
                             and solver._param_step is not None)
                stream_ids = {
                    (configs[i].topology_process.p,
                     configs[i].topology_process.resolve_seed(
                         configs[i].seed)) for i in indices}
                if not can_batch:
                    if len(stream_ids) > 1:
                        why = (f"backend {rep.backend!r} cannot take it "
                               "as a traced operand"
                               if rep.backend != "dense" else
                               f"solver {rep.algo!r} implements only the "
                               "legacy _make_step hook")
                        raise _mixed_process_error(configs, indices, why)
                    # one realization: the engine built above already
                    # carries it (attach_topology in build), bake it in
                    stream_group = False
                else:
                    from repro.topology.process import realize_stream
                    streams = jnp.stack([
                        jnp.asarray(realize_stream(
                            configs[i].topology_process, spec,
                            configs[i].topology_process.resolve_seed(
                                configs[i].seed)).matrices, jnp.float32)
                        for i in indices])
            group_metric = metric_fn
            if group_metric is None and record_every:
                from repro.core import convergence_metric_fn
                group_metric = convergence_metric_fn(
                    problem, solver._hg_cfg, g_data)

        keys = jnp.stack([jax.random.PRNGKey(configs[i].seed)
                          for i in indices])
        alphas = jnp.asarray([configs[i].alpha for i in indices])
        betas = jnp.asarray([configs[i].beta for i in indices])

        take = lambda stack: jax.tree_util.tree_map(
            lambda leaf: leaf[np.asarray(indices)], stack)
        gx = take(x0_stack) if x0_stack is not None else x0
        gy = take(y0_stack) if y0_stack is not None else y0
        x_ax = 0 if x0_stack is not None else None
        y_ax = 0 if y0_stack is not None else None

        if pad_agents:
            one = _padded_experiment_fn(solver, n, num_steps, record_every,
                                        group_metric, data_stack,
                                        with_stream=streams is not None,
                                        with_byz=byz_ops is not None)
            axes = [0, 0, 0, x_ax, y_ax, 0, 0, 0]
            ops = [keys, alphas, betas, gx, gy, mats, num_active,
                   data_idx]
            if streams is not None:
                axes.append(0)
                ops.append(streams)
            if byz_ops is not None:
                axes.append(0)
                ops.append(byz_ops)
            batched = jax.jit(jax.vmap(one, in_axes=tuple(axes)))
            operands = tuple(ops)
        elif stream_group:
            one = _stream_experiment_fn(solver, g_data, n, num_steps,
                                        record_every, group_metric)
            batched = jax.jit(jax.vmap(
                one, in_axes=(0, 0, 0, x_ax, y_ax, 0)))
            operands = (keys, alphas, betas, gx, gy, streams)
        else:
            one = _experiment_fn(solver, g_data, num_steps, record_every,
                                 group_metric)
            batched = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, x_ax, y_ax)))
            operands = (keys, alphas, betas, gx, gy)

        t0 = time.perf_counter()
        out = batched(*operands)  # compile + first run
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        took = time.perf_counter() - t0
        if measure:     # re-run warmed so `seconds` excludes compilation
            t0 = time.perf_counter()
            out = batched(*operands)
            jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
            took = time.perf_counter() - t0
        seconds += took

        g_state, g_traces = out
        g_traces = np.asarray(g_traces)
        for row, i in enumerate(indices):
            traces[i] = g_traces[row]
            if return_states:
                states[i] = jax.tree_util.tree_map(lambda l: l[row], g_state)
        groups.append(SweepGroup(
            indices=indices, config=rep, seconds=took,
            pad_to=m_pad if pad_agents else None,
            num_active=tuple(ms[i] for i in indices) if pad_agents
            else None))
        if resume_state is not None:
            # persist the moment the group finishes: a kill during the
            # NEXT group loses nothing already computed
            resume_state.store(resume_state.group_fp(indices), indices,
                               g_traces, took)

        if compare_sequential:
            single = jax.jit(one)
            pick = lambda tree, r: jax.tree_util.tree_map(
                lambda l: l[r], tree)
            sx = lambda r: pick(gx, r) if x_ax == 0 else gx
            sy = lambda r: pick(gy, r) if y_ax == 0 else gy

            def row_operands(r):
                base = (keys[r], alphas[r], betas[r], sx(r), sy(r))
                if pad_agents:
                    base += (mats[r], num_active[r], data_idx[r])
                if streams is not None:
                    base += (streams[r],)
                if pad_agents and byz_ops is not None:
                    base += (jax.tree_util.tree_map(lambda l: l[r],
                                                    byz_ops),)
                return base

            warm = single(*row_operands(0))
            jax.block_until_ready(jax.tree_util.tree_leaves(warm)[0])
            t0 = time.perf_counter()
            for r in range(len(indices)):
                out_r = single(*row_operands(r))
                jax.block_until_ready(jax.tree_util.tree_leaves(out_r)[0])
            seconds_seq += time.perf_counter() - t0

    return SweepResult(configs=configs, traces=np.stack(traces),
                       groups=groups, seconds=seconds,
                       seconds_sequential=seconds_seq, measured=measure,
                       states=states, pad_to=m_pad)
