"""The batched sweep engine: vmap-over-experiments + in-scan recording.

Every Section-6 figure is a *grid* — algorithms x network sizes x
topologies x seeds x step sizes.  Executing the grid one config at a
time pays a Python loop, a fresh XLA dispatch, and an eager metric
round-trip per cell.  This module runs the grid as a handful of compiled
XLA programs instead:

1.  Configs are **grouped** by ``SolverConfig.static_key()`` — everything
    the compiled trace depends on (algo, topology, backend, hypergrad
    config, batch/q).  Within a group only the ``BATCH_FIELDS`` (seed,
    alpha, beta) differ, and those enter the computation as array
    values.

2.  Each group compiles **one** program: ``jax.vmap`` over the entire
    ``init -> run_traced`` pipeline (state init from the per-experiment
    PRNG key, ``num_steps`` solver iterations under ``lax.scan``, the
    convergence metric recorded in-scan every ``record_every`` steps via
    ``lax.cond``).  An 8-seed x 4-algorithm Figure-2 grid is 4 XLA
    dispatches, not 32 Python loops.

Usage::

    from repro.solvers import SolverConfig, expand_grid, sweep

    configs = expand_grid(SolverConfig(algo="interact"),
                          seed=range(8), alpha=(0.3, 0.1))
    result = sweep(configs, num_steps=40, record_every=5)
    result.traces          # (16, 9) on-device metric traces
    result.num_dispatches  # 1: one group, one compiled program

See docs/SWEEPS.md for the grouping semantics and the recording cost
model.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.solvers.api import _traced_scan, default_setup, make_solver
from repro.solvers.config import SolverConfig

__all__ = ["SweepGroup", "SweepResult", "expand_grid", "sweep"]


def expand_grid(base: SolverConfig = SolverConfig(),
                **axes: Sequence) -> list[SolverConfig]:
    """The cartesian grid of ``dataclasses.replace(base, ...)`` configs.

    ``expand_grid(base, seed=range(8), alpha=(0.3, 0.1))`` yields 16
    configs in row-major order (later axes vary fastest).  Any
    ``SolverConfig`` field is a valid axis; sweeping only the
    ``BATCH_FIELDS`` (seed / alpha / beta) keeps the whole grid in one
    vmap group, other axes split it into one group per distinct
    ``static_key()``.
    """
    names = list(axes)
    out = []
    for values in itertools.product(*(axes[k] for k in names)):
        out.append(dataclasses.replace(base, **dict(zip(names, values))))
    return out


@dataclasses.dataclass
class SweepGroup:
    """One vmap group: the configs that shared a compiled program."""

    indices: list[int]          # positions into the sweep's config list
    config: SolverConfig        # the group's representative (static fields)
    seconds: float              # batched wall-clock (post-warmup when
                                # measured, else first run incl. compile)


@dataclasses.dataclass
class SweepResult:
    """What ``sweep`` returns.

    ``traces[i]`` is config ``i``'s metric trace in the legacy
    ``run_recorded`` layout (metric before steps 0, record_every, ...,
    plus the final iterate); rows are aligned with the *input* config
    order regardless of grouping.  ``states`` holds the final solver
    states stacked per group (leading axis = group size) when
    ``return_states=True``, else None.
    """

    configs: list[SolverConfig]
    traces: np.ndarray                   # (num_configs, num_records)
    groups: list[SweepGroup]
    seconds: float                       # batched wall-clock (see measure)
    seconds_sequential: float | None     # same grid, one config at a time
    measured: bool = False               # True: seconds exclude compile
    states: list[Any] | None = None

    @property
    def num_dispatches(self) -> int:
        return len(self.groups)

    @property
    def vmap_speedup(self) -> float | None:
        """Sequential / batched wall-clock (None unless both measured)."""
        if self.seconds_sequential is None:
            return None
        return self.seconds_sequential / max(self.seconds, 1e-12)

    def trace_of(self, config: SolverConfig) -> np.ndarray:
        """The trace row of the first config matching ``config``.

        Matches by ``(static_key, batch_values)`` rather than dataclass
        equality — an explicit ``MixingSpec`` holds a numpy matrix, for
        which ``==`` is elementwise.
        """
        want = (config.static_key(), config.batch_values())
        for i, c in enumerate(self.configs):
            if c is config or (c.static_key(), c.batch_values()) == want:
                return self.traces[i]
        raise KeyError(config)

    def group_traces(self, group: SweepGroup) -> np.ndarray:
        return self.traces[np.asarray(group.indices)]


def _group_by_static_key(configs: Sequence[SolverConfig]):
    """Order-preserving grouping: static_key -> list of config indices."""
    groups: dict[tuple, list[int]] = {}
    for i, cfg in enumerate(configs):
        groups.setdefault(cfg.static_key(), []).append(i)
    return list(groups.values())


def _experiment_fn(solver, data, num_steps: int, record_every: int,
                   metric_fn):
    """The pure per-experiment pipeline: ``(key, alpha, beta, x0, y0)``
    -> ``(final_state, trace)``.

    Traceable end to end (init included), so it can be ``jax.vmap``-ped
    over stacked keys / step sizes / inits.  Solvers that predate the
    parameterised step hook fall back to the config-bound body — their
    groups are keyed on alpha/beta by the caller, so the ignored scalars
    are constant within a group.
    """
    problem, hg_cfg = solver._problem, solver._hg_cfg
    param = solver._param_step
    if param is None:
        raw = solver._raw_step          # config-bound alpha/beta

        def param(state, d, _a, _b):
            return raw(state, d)

    def one(key, alpha, beta, x0, y0):
        state = solver._init_state(key, problem, hg_cfg, x0, y0, data)
        return _traced_scan(param, state, data, num_steps, record_every,
                            metric_fn, alpha, beta)

    return one


def sweep(configs: Sequence[SolverConfig], num_steps: int,
          record_every: int = 0, *, problem=None, x0=None, y0=None,
          data=None, num_agents: int = 5, n_per_agent: int = 600,
          metric_fn=None, x0_stack=None, y0_stack=None,
          measure: bool = False, compare_sequential: bool = False,
          return_states: bool = False) -> SweepResult:
    """Run a grid of experiments as one compiled program per vmap group.

    Args:
      configs: the grid (see ``expand_grid``); grouped automatically by
        ``SolverConfig.static_key()`` — same algo/topology/backend/
        hypergrad per group, seed/alpha/beta batched inside it.
      num_steps / record_every: shared by every experiment (they are
        trace-static).  ``record_every=0`` disables recording.
      problem / x0 / y0 / data: the problem instance; defaults to the
        paper's Section-6 synthetic setup (``default_setup``, seeded by
        the first config).
      metric_fn: traceable ``state -> scalar`` recorded in-scan;
        defaults to the eq.-(11) convergence metric
        (``repro.core.convergence_metric_fn``) when ``record_every > 0``.
      x0_stack / y0_stack: optional per-experiment initial points —
        pytrees with a leading axis of ``len(configs)``, aligned with
        the config order (they join seed/alpha/beta as vmap axes).
        When omitted every experiment starts from the shared ``x0``/
        ``y0`` exactly as the paper does.
      measure: re-execute each warmed batched program and report that
        wall-clock in ``seconds`` (compile excluded) — the benchmarks'
        mode.  Default False: every group runs **once** and ``seconds``
        is the first-run wall-clock including compilation (callers that
        want results shouldn't pay for the grid twice).
      compare_sequential: also run the same grid one config at a time
        through the *same* compiled single-experiment function and
        record the wall-clock, so ``result.vmap_speedup`` measures
        batching alone (identical program, identical values).  Implies
        ``measure`` (both paths warmed before timing).
      return_states: keep the final solver states (stacked per group).

    Returns a ``SweepResult`` with traces aligned to the input order.
    """
    configs = list(configs)
    measure = measure or compare_sequential
    if not configs:
        raise ValueError("sweep needs at least one config")
    if problem is None or data is None or x0 is None or y0 is None:
        problem, x0, y0, data = default_setup(
            configs[0].seed, num_agents=num_agents, n_per_agent=n_per_agent)
    m = data.inner_x.shape[0]
    n = data.inner_x.shape[1] + data.outer_x.shape[1]

    traces = [None] * len(configs)
    states: list[Any] = [None] * len(configs) if return_states else None
    groups: list[SweepGroup] = []
    seconds = 0.0
    seconds_seq: float | None = 0.0 if compare_sequential else None

    for indices in _group_by_static_key(configs):
        rep = configs[indices[0]]
        solver = make_solver(rep).build(problem, None, m=m, n=n)
        if solver._param_step is None and any(
                (configs[i].alpha, configs[i].beta) != (rep.alpha, rep.beta)
                for i in indices):
            raise ValueError(
                f"solver {rep.algo!r} implements only the legacy "
                "_make_step hook (config-bound step sizes); it cannot "
                "batch configs with different alpha/beta — implement "
                "_make_param_step or sweep step sizes sequentially")
        group_metric = metric_fn
        if group_metric is None and record_every:
            from repro.core import convergence_metric_fn
            group_metric = convergence_metric_fn(problem, solver._hg_cfg,
                                                 data)

        keys = jnp.stack([jax.random.PRNGKey(configs[i].seed)
                          for i in indices])
        alphas = jnp.asarray([configs[i].alpha for i in indices])
        betas = jnp.asarray([configs[i].beta for i in indices])

        take = lambda stack: jax.tree_util.tree_map(
            lambda leaf: leaf[np.asarray(indices)], stack)
        gx = take(x0_stack) if x0_stack is not None else x0
        gy = take(y0_stack) if y0_stack is not None else y0
        x_ax = 0 if x0_stack is not None else None
        y_ax = 0 if y0_stack is not None else None

        one = _experiment_fn(solver, data, num_steps, record_every,
                             group_metric)
        batched = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, x_ax, y_ax)))

        t0 = time.perf_counter()
        out = batched(keys, alphas, betas, gx, gy)  # compile + first run
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        took = time.perf_counter() - t0
        if measure:     # re-run warmed so `seconds` excludes compilation
            t0 = time.perf_counter()
            out = batched(keys, alphas, betas, gx, gy)
            jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
            took = time.perf_counter() - t0
        seconds += took

        g_state, g_traces = out
        g_traces = np.asarray(g_traces)
        for row, i in enumerate(indices):
            traces[i] = g_traces[row]
            if return_states:
                states[i] = jax.tree_util.tree_map(lambda l: l[row], g_state)
        groups.append(SweepGroup(indices=indices, config=rep, seconds=took))

        if compare_sequential:
            single = jax.jit(one)
            pick = lambda tree, r: jax.tree_util.tree_map(
                lambda l: l[r], tree)
            sx = lambda r: pick(gx, r) if x_ax == 0 else gx
            sy = lambda r: pick(gy, r) if y_ax == 0 else gy
            warm = single(keys[0], alphas[0], betas[0], sx(0), sy(0))
            jax.block_until_ready(jax.tree_util.tree_leaves(warm)[0])
            t0 = time.perf_counter()
            for r in range(len(indices)):
                out_r = single(keys[r], alphas[r], betas[r], sx(r), sy(r))
                jax.block_until_ready(jax.tree_util.tree_leaves(out_r)[0])
            seconds_seq += time.perf_counter() - t0

    return SweepResult(configs=configs, traces=np.stack(traces),
                       groups=groups, seconds=seconds,
                       seconds_sequential=seconds_seq, measured=measure,
                       states=states)
