"""The `Solver` protocol, registry, and scan-compiled experiment runner.

One API surface drives every Section-6 algorithm:

    from repro.solvers import SolverConfig, make_solver

    solver = make_solver(SolverConfig(algo="interact", alpha=0.3, beta=0.3))
    state  = solver.init(None, problem, hg_cfg, x0, y0, data)
    state  = solver.step(state, data)            # one jitted iteration
    state  = solver.run(state, data, 100)        # lax.scan, compiled once

``make_solver`` looks the algorithm up in the ``@register_solver``
registry — adding a fifth algorithm is one decorated class, not a new
copy of the init/step/build triple (see docs/SOLVERS.md).

The step and run closures are jitted with ``donate_argnums=0``: the
incoming state buffers are donated to the outputs, so the simulator hot
loop updates in place instead of allocating a fresh state per call.
``run`` wraps the *same* step body in ``lax.scan`` (static ``num_steps``)
so a multi-step experiment dispatches one XLA computation instead of one
Python call per iteration, and ``run_traced`` additionally folds metric
recording into that scan (``lax.cond`` every ``record_every`` steps) so
a whole recorded experiment is a single program — the batched sweep
engine (``repro.solvers.sweep``, docs/SWEEPS.md) vmaps it over config
grids.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.consensus import make_engine
from repro.solvers.config import SolverConfig

__all__ = [
    "Solver",
    "SolverBase",
    "SolveResult",
    "available_solvers",
    "default_setup",
    "make_solver",
    "register_solver",
    "run_recorded",
    "solve",
]


def _traced_scan(param_step, state, data, num_steps: int, record_every: int,
                 metric_fn, alpha, beta):
    """One ``lax.scan`` that steps the solver *and* records the metric.

    The carry is the solver state; the stacked scan output is the
    per-step metric, computed every ``record_every`` steps through
    ``lax.cond`` (so off-boundary steps pay nothing) and ``NaN``-padded
    otherwise.  After the scan the padded column is compacted **on
    device** to the legacy ``run_recorded`` layout — metric before steps
    ``0, record_every, 2*record_every, ...`` plus the final iterate — so
    the whole experiment (stepping + recording) is a single XLA program
    with no host round-trips.

    ``param_step(state, data, alpha, beta)`` is the raw parameterised
    step body; ``alpha`` / ``beta`` may be traced scalars, which is what
    lets ``sweep`` vmap experiments over step sizes.

    Returns ``(final_state, trace)``; ``trace`` is an empty array when
    ``metric_fn`` is None.
    """
    chunk = record_every if record_every else num_steps

    if metric_fn is None:
        def body(s, _):
            return param_step(s, data, alpha, beta), None

        state, _ = jax.lax.scan(body, state, xs=None, length=num_steps)
        return state, jnp.zeros((0,), jnp.float32)

    aval = jax.eval_shape(metric_fn, state)
    dtype = aval.dtype

    def body(s, i):
        val = jax.lax.cond(
            (i % chunk) == 0,
            lambda st: jnp.asarray(metric_fn(st), dtype),
            lambda st: jnp.asarray(jnp.nan, dtype), s)
        return param_step(s, data, alpha, beta), val

    state, padded = jax.lax.scan(body, state, xs=jnp.arange(num_steps))
    final = jnp.asarray(metric_fn(state), dtype)
    trace = jnp.concatenate([padded[::chunk], final[None]])
    return state, trace

_REGISTRY: dict[str, type] = {}


def register_solver(name: str) -> Callable[[type], type]:
    """Class decorator: register a Solver implementation under ``name``."""

    def deco(cls: type) -> type:
        existing = _REGISTRY.get(name)
        if existing is not None and existing is not cls:
            raise ValueError(f"solver {name!r} already registered "
                             f"({existing.__name__})")
        _REGISTRY[name] = cls
        cls.name = name
        return cls

    return deco


def available_solvers() -> tuple[str, ...]:
    """Registered algorithm names, sorted."""
    return tuple(sorted(_REGISTRY))


def make_solver(config: SolverConfig) -> "Solver":
    """Instantiate the registered solver for ``config.algo``."""
    try:
        cls = _REGISTRY[config.algo]
    except KeyError:
        raise ValueError(
            f"unknown algorithm {config.algo!r}; "
            f"choose from {available_solvers()}") from None
    return cls(config)


@runtime_checkable
class Solver(Protocol):
    """What every registry algorithm exposes.

    ``init`` binds the problem instance (building the consensus engine and
    compiling the step/run closures) and returns the initial state;
    ``step`` advances one iteration; ``run`` advances ``num_steps``
    iterations inside one ``lax.scan``.  ``samples_per_step(n)`` is the
    per-agent IFO cost of one iteration (Definition 1) on an n-sample
    local dataset; ``communications_per_step`` the consensus rounds per
    iteration (Definition 2).
    """

    config: SolverConfig
    communications_per_step: int

    def init(self, key, problem, hg_cfg, x0, y0, data) -> Any: ...

    def step(self, state, data) -> Any: ...

    def run(self, state, data, num_steps: int) -> Any: ...

    def run_traced(self, state, data, num_steps: int, record_every: int = 0,
                   metric_fn=None) -> Any: ...

    def samples_per_step(self, n: int) -> float: ...

    def hypergrad_calls_per_step(self, n: int) -> float: ...


class SolverBase:
    """Shared plumbing: engine construction, jit + donation, scan runner.

    Subclasses implement ``_init_state`` and ``_make_step`` (returning the
    raw python step body over a bound ``ConsensusEngine``); everything
    else — registry construction, closure compilation, the scan runner,
    warmup — lives here once.
    """

    communications_per_step = 2  # Steps 1 and 3 each mix once

    def __init__(self, config: SolverConfig):
        self.config = config
        self._step_fn = None
        self._run_fn = None
        self._traced_fn = None
        self._chunk_fn = None
        self._param_step = None
        self._engine = None

    # -- subclass hooks ---------------------------------------------------
    def _init_state(self, key, problem, hg_cfg, x0, y0, data):
        raise NotImplementedError

    def _make_param_step(self, problem, hg_cfg, engine, n: int | None):
        """Return the raw ``step(state, data, alpha, beta) -> state``.

        The registry solvers implement this form: alpha/beta enter the
        body as (possibly traced) scalars instead of baked-in closure
        constants, so the sweep engine can ``vmap`` one compiled step
        over a batch of step sizes.  Solvers that predate the hook may
        override ``_make_step`` instead; they then lose only the
        step-size batch axis (``sweep`` keys their groups on alpha/beta).
        """
        raise NotImplementedError

    def _make_step(self, problem, hg_cfg, engine, n: int | None):
        """Return the raw (non-jitted) ``step(state, data) -> state``.

        Default: bind ``config.alpha`` / ``config.beta`` into the
        parameterised body from ``_make_param_step`` (reusing the one
        ``build`` already constructed for this engine when available).
        """
        param = (self._param_step if self._param_step is not None
                 else self._make_param_step(problem, hg_cfg, engine, n))
        alpha, beta = self.config.alpha, self.config.beta

        def step(state, data):
            return param(state, data, alpha, beta)

        return step

    # -- construction -----------------------------------------------------
    def build(self, problem, hg_cfg=None, *, m: int | None = None,
              n: int | None = None) -> "SolverBase":
        """Bind the problem + network and compile the step/run closures.

        ``init`` calls this automatically (deriving m, n from the data);
        call it directly only when constructing a step function without
        data in hand (the legacy ``make_*_step`` shims do).
        """
        hg_cfg = hg_cfg if hg_cfg is not None else self.config.hypergrad
        hg_cfg.resolve_backend()   # fail fast on unknown engine names
        spec = self.config.mixing_spec(m)
        if m is not None and spec.num_agents != m:
            # fail here, not as an XLA dot-shape error deep in the first
            # mix: config-declared network vs data-derived m disagree
            raise ValueError(
                f"config declares a {spec.num_agents}-agent network "
                f"(num_agents/mixing) but the data carries m={m} agents")
        engine = make_engine(
            self.config.backend, spec,
            compression=self.config.compression,
            communication_interval=self.config.communication_interval,
            byzantine=self.config.byzantine,
            **dict(self.config.backend_opts))
        if self.config.byzantine.attack_active:
            # the attack schedule inherits the solver seed unless the
            # ByzantineConfig pins its own
            engine.byz_values["key"] = jax.random.PRNGKey(
                self.config.byzantine.resolve_seed(self.config.seed))
        if not self.config.topology_process.is_static:
            from repro.topology import attach_topology
            attach_topology(engine, self.config.topology_process, spec,
                            seed=self.config.seed)
        self._engine = engine
        try:
            self._param_step = self._make_param_step(problem, hg_cfg,
                                                     engine, n)
        except NotImplementedError:
            self._param_step = None
        if self.config.guard.active:
            if self._param_step is None:
                raise ValueError(
                    f"GuardConfig is active but solver {self.name!r} "
                    f"implements no parameterised step to wrap")
            from repro.byzantine import guard_param_step
            self._param_step = guard_param_step(self._param_step,
                                                self.config.guard)
        raw = self._make_step(problem, hg_cfg, engine, n)
        self._raw_step = raw
        self._step_fn = jax.jit(raw, donate_argnums=0)

        def scan_run(state, data, num_steps):
            def body(s, _):
                return raw(s, data), None

            out, _ = jax.lax.scan(body, state, xs=None, length=num_steps)
            return out

        self._run_fn = jax.jit(scan_run, static_argnums=2, donate_argnums=0)

        def traced_run(state, data, num_steps, record_every, metric_fn):
            def param(s, d, _a, _b):
                return raw(s, d)

            return _traced_scan(param, state, data, num_steps, record_every,
                                metric_fn, self.config.alpha,
                                self.config.beta)

        self._traced_fn = jax.jit(traced_run, static_argnums=(2, 3, 4),
                                  donate_argnums=0)

        def chunk_run(state, data, chunk_len, record_mod, metric_fn,
                      start_step):
            """One checkpoint-interval chunk of the resumable runner.

            Identical step body to ``traced_run`` but (a) the scan index
            is offset by the traced ``start_step`` — the global step the
            incoming carry sits at — so the metric fires on the same
            global boundaries whatever chunk the run was cut into, and
            (b) the per-step metric column comes back *uncompacted*
            (NaN off-boundary): compaction needs the whole run, which
            the resilience runner assembles across chunks
            (docs/RESILIENCE.md).  ``start_step`` being a traced operand
            means every equal-length chunk shares one compile.
            """
            if metric_fn is None:
                def body(s, _):
                    return raw(s, data), None

                state, _ = jax.lax.scan(body, state, xs=None,
                                        length=chunk_len)
                return state, jnp.zeros((0,), jnp.float32)
            dtype = jax.eval_shape(metric_fn, state).dtype

            def body(s, i):
                val = jax.lax.cond(
                    (i % record_mod) == 0,
                    lambda st: jnp.asarray(metric_fn(st), dtype),
                    lambda st: jnp.asarray(jnp.nan, dtype), s)
                return raw(s, data), val

            xs = jnp.asarray(start_step, jnp.int32) + jnp.arange(chunk_len)
            return jax.lax.scan(body, state, xs=xs)

        self._chunk_fn = jax.jit(chunk_run, static_argnums=(2, 3, 4),
                                 donate_argnums=0)
        self._metric_jits: dict[int, Any] = {}
        self._problem, self._hg_cfg = problem, hg_cfg
        return self

    def metric_eval(self, metric_fn, state):
        """``metric_fn(state)`` under jit (cached per metric closure).

        The resilience runner's final-record evaluation: bitwise-equal
        to the in-program ``metric_fn(final_state)`` the one-scan
        ``run_traced`` computes.
        """
        fn = self._metric_jits.get(id(metric_fn))
        if fn is None:
            fn = self._metric_jits[id(metric_fn)] = jax.jit(metric_fn)
        return fn(state)

    def init(self, key, problem, hg_cfg, x0, y0, data):
        """Build the solver for this problem and return the initial state.

        ``key=None`` derives the sampling key from ``config.seed``;
        ``hg_cfg=None`` falls back to ``config.hypergrad``.
        """
        m = data.inner_x.shape[0]
        # n is the full per-agent dataset (inner + outer splits): the
        # paper's q = |S| = ceil(sqrt(n)) defaults are taken against it.
        n = data.inner_x.shape[1] + data.outer_x.shape[1]
        self.build(problem, hg_cfg, m=m, n=n)
        if key is None:
            key = jax.random.PRNGKey(self.config.seed)
        return self._init_state(key, self._problem, self._hg_cfg, x0, y0,
                                data)

    # -- stepping ---------------------------------------------------------
    def step(self, state, data):
        """One jitted iteration (state buffers donated)."""
        if self._step_fn is None:
            raise RuntimeError("call init()/build() before step()")
        return self._step_fn(state, data)

    def run(self, state, data, num_steps: int, *,
            checkpoint_every: int | None = None, ckpt_dir=None):
        """``num_steps`` iterations under one jitted ``lax.scan``.

        ``checkpoint_every`` chunks the scan at checkpoint boundaries
        and snapshots the complete solver carry into ``ckpt_dir`` after
        each chunk (atomic, CRC-checked — see docs/RESILIENCE.md), so a
        killed run resumes from its last boundary via
        ``repro.resilience.resume_run``.  Every equal-length chunk
        shares one compile; the final state is bitwise-equal to the
        unchunked scan.
        """
        if self._run_fn is None:
            raise RuntimeError("call init()/build() before run()")
        if checkpoint_every:
            from repro.resilience import run_resumable
            state, _, _ = run_resumable(
                self, state, data, num_steps,
                checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir)
            return state
        return self._run_fn(state, data, num_steps)

    def run_traced(self, state, data, num_steps: int, record_every: int = 0,
                   metric_fn=None, *, checkpoint_every: int | None = None,
                   ckpt_dir=None):
        """``num_steps`` iterations with the metric recorded *in-scan*.

        One jitted XLA program (state donated) steps the solver and
        evaluates ``metric_fn(state) -> scalar`` every ``record_every``
        steps (plus the final iterate) on device — no per-chunk host
        loop, no intermediate ``block_until_ready``, no recompiles for
        remainder chunk lengths.  ``metric_fn`` must be traceable (see
        ``repro.core.convergence_metric_fn``) and is a static jit
        argument: pass a stable closure, not a fresh lambda per call.

        Returns ``(state, trace)`` where ``trace`` is a device array
        laid out exactly like the legacy ``run_recorded`` list — metric
        before steps ``0, record_every, ...`` then after the last step —
        or an empty array when ``metric_fn`` is None.

        ``checkpoint_every`` routes through the resilience runner: the
        scan is cut at checkpoint boundaries (every equal-length chunk
        one compile), the complete carry plus the partial metric column
        is snapshotted into ``ckpt_dir`` after each chunk, and the
        returned trace is bitwise-equal to the unchunked program — the
        contract ``repro.resilience`` kill/resume parity is built on
        (docs/RESILIENCE.md).  Meant for fresh states (the global step
        offset is taken from ``state.t``); resuming an interrupted run
        goes through ``repro.resilience.resume_run``.
        """
        if self._traced_fn is None:
            raise RuntimeError("call init()/build() before run_traced()")
        if checkpoint_every:
            from repro.resilience import run_resumable
            state, trace, _ = run_resumable(
                self, state, data, num_steps, record_every, metric_fn,
                checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir)
            return state, trace
        return self._traced_fn(state, data, num_steps, record_every,
                               metric_fn)

    def warmup(self, state, data, num_steps: int | None = None) -> None:
        """Compile ``step`` (or ``run`` at ``num_steps``) without consuming
        ``state``: the donated argument is a copy, the result discarded.

        The copy is an explicit ``jnp.copy`` — ``jnp.array`` may return
        the input buffer unchanged on some JAX versions, and an aliased
        "copy" would let donation invalidate the caller's state.
        """
        copy = jax.tree_util.tree_map(jnp.copy, state)
        out = (self.step(copy, data) if num_steps is None
               else self.run(copy, data, num_steps))
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])

    def samples_per_step(self, n: int) -> float:
        raise NotImplementedError

    # Hypergradient evaluations per iteration (amortized): how many times
    # the algorithm invokes the eq.-(5)/(22) estimator per agent per step.
    # Multiplied by the engine's *measured* per-call HypergradStats this
    # yields the per-step hvp/grad counts that `solve` and the bench
    # harness report (Theorem-1/2 accounting, see docs/HYPERGRAD.md).
    def hypergrad_calls_per_step(self, n: int) -> float:
        return 1.0


def run_recorded(solver, state, data, num_steps: int, record_every: int = 0,
                 metric_fn=None, scan: bool = True):
    """Chunked timed runner shared by ``solve`` and the bench harness.

    Advances ``num_steps`` iterations in ``record_every``-sized chunks —
    through the scan-compiled ``solver.run`` (one compile per distinct
    chunk length), or the per-step python loop with ``scan=False``.
    Compilation happens on a throwaway state copy before the timer
    starts, and ``metric_fn(state) -> float`` (if given) is evaluated
    *between* timed chunks, so the returned seconds measure stepping
    only.  Returns ``(state, trace, seconds)``.
    """
    chunk = record_every if record_every else num_steps
    lengths = [chunk] * (num_steps // chunk)
    if num_steps % chunk:
        lengths.append(num_steps % chunk)
    if scan:
        for length in sorted(set(lengths)):
            solver.warmup(state, data, length)
    else:
        solver.warmup(state, data)

    trace, took = [], 0.0
    for length in lengths:
        if metric_fn is not None:
            trace.append(metric_fn(state))
        t0 = time.perf_counter()
        if scan:
            state = solver.run(state, data, length)
        else:
            for _ in range(length):
                state = solver.step(state, data)
        jax.block_until_ready(jax.tree_util.tree_leaves(state)[0])
        took += time.perf_counter() - t0
    if metric_fn is not None:
        trace.append(metric_fn(state))
    return state, trace, took


@dataclasses.dataclass
class SolveResult:
    """What ``solve`` returns: final state plus the experiment record."""

    state: Any
    trace: list[float]          # convergence metric every record_every steps
    us_per_step: float          # stepping time only (metrics excluded)
    samples_per_step: float     # per-agent IFO cost (Definition 1)
    communications_per_step: int
    # wire bytes one agent ships per consensus round under the engine's
    # compressor (engine.bytes_on_wire of the per-agent x payload) —
    # Definition-2 round counts priced in bytes.  Warmup / interval
    # scheduling is not folded in (see consensus.cumulative_wire_bytes).
    bytes_per_round: float = 0.0
    # measured per-agent hypergradient accounting (one step, amortized):
    # the engine's counted per-call HypergradStats at the initial iterate
    # times the algorithm's hypergrad calls per step — what Theorems 1-2
    # charge for, measured instead of inferred (docs/HYPERGRAD.md).
    hvp_per_step: float = 0.0
    grad_per_step: float = 0.0
    hess_per_step: float = 0.0
    # divergence-guard counters (SolverConfig.guard): how many scan
    # steps tripped a wire and were rolled back, and the step counter of
    # the last accepted state.  0 / -1 when no guard was configured —
    # time-to-detection is last_good_step vs num_steps.
    tripped_steps: int = 0
    last_good_step: int = -1
    # MEASURED communication, from the CommsLedger attached to the engine
    # before the step was traced: per-agent bytes the compiled program
    # actually shipped over the run (trace-time payload capture + the
    # host-replayed warmup/interval schedule — consensus/ledger.py), and
    # the median wall-clock of one warmed jitted consensus round.  None
    # when the backend cannot be timed outside shard_map (latency) —
    # bytes are recorded for every backend.
    measured_wire_bytes: float | None = None
    round_latency_us: float | None = None


def default_setup(seed: int = 0, num_agents: int = 5, n_per_agent: int = 600,
                  d_in: int = 16, hidden: int = 20, classes: int = 5):
    """The paper's Section-6 synthetic meta-learning instance.

    Returns ``(problem, x0, y0, data)`` — the default experiment that
    ``solve`` and ``sweep`` fall back to when no problem is supplied.
    """
    from repro.core import (MLPMetaProblem, init_head, init_mlp_backbone,
                            make_synthetic_agents)
    key = jax.random.PRNGKey(seed)
    data = make_synthetic_agents(key, num_agents=num_agents,
                                 n_per_agent=n_per_agent, d_in=d_in,
                                 num_classes=classes)
    problem = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(seed + 1), d_in, hidden=hidden)
    y0 = init_head(jax.random.PRNGKey(seed + 2), hidden, classes)
    return problem, x0, y0, data


def solve(config: SolverConfig, num_steps: int, record_every: int = 0,
          *, problem=None, hg_cfg=None, x0=None, y0=None, data=None,
          num_agents: int = 5, n_per_agent: int = 600,
          metric_fn=None, measure_hypergrad: bool | None = None
          ) -> SolveResult:
    """End-to-end experiment: build, init, scan-run, record.

    With only ``(config, num_steps, record_every)`` this reproduces the
    paper's Section-6 synthetic meta-learning setup (m agents, n samples
    per agent, the MLP problem, the eq.-11 convergence metric); pass
    ``problem``/``x0``/``y0``/``data`` to run on your own instance, and
    ``metric_fn(state) -> float`` to record a custom metric.

    Stepping runs through ``solver.run`` in ``record_every``-sized chunks
    (one compile per distinct chunk length); metric evaluation happens
    outside the timed window.

    Besides timing and the Definition-1/2 sample/communication costs,
    the result carries *measured* per-step hypergradient accounting
    (``hvp_per_step`` / ``grad_per_step`` / ``hess_per_step``): one
    counted engine call (``repro.hypergrad.measure_counts``) at the
    initial iterate times the algorithm's amortized estimator calls per
    step — see docs/HYPERGRAD.md.  The measurement is one eager
    estimator evaluation (a small fixed key set for stochastic-k
    configs), so ``measure_hypergrad`` defaults to ``record_every > 0``:
    callers that record nothing (sweep loops that only want the final
    state or their own timing) are not charged for accounting they would
    discard.  Pass True/False to force it either way (the count fields
    stay 0 when skipped).
    """
    if measure_hypergrad is None:
        measure_hypergrad = record_every > 0
    if problem is None or data is None or x0 is None or y0 is None:
        problem, x0, y0, data = default_setup(
            config.seed,
            num_agents=config.resolve_num_agents(num_agents),
            n_per_agent=n_per_agent)

    solver = make_solver(config)
    state = solver.init(None, problem, hg_cfg, x0, y0, data)
    # jit is lazy, so attaching after init/build still precedes the first
    # trace — every wire stream the compiled step ships gets recorded
    from repro.consensus import attach_ledger
    ledger = attach_ledger(solver._engine)

    if metric_fn is None and record_every:
        from repro.core import convergence_metric

        def metric_fn(st):
            rep = convergence_metric(solver._problem, solver._hg_cfg,
                                     st.x, st.y, 300, 0.5, data)
            return float(rep.total)

    state, trace, took = run_recorded(solver, state, data, num_steps,
                                      record_every, metric_fn)

    n = data.inner_x.shape[1] + data.outer_x.shape[1]
    counts = {}
    if measure_hypergrad:
        from repro.hypergrad import measure_problem_counts
        per_call = measure_problem_counts(problem, solver._hg_cfg, x0, y0,
                                          data)
        calls = solver.hypergrad_calls_per_step(n)
        counts = dict(hvp_per_step=per_call.hvp_count * calls,
                      grad_per_step=per_call.grad_count * calls,
                      hess_per_step=per_call.hess_count * calls)
    guard = getattr(state, "guard", None)
    if guard is not None:
        counts.update(tripped_steps=int(guard["tripped"]),
                      last_good_step=int(guard["last_good"]))
    ledger.commit_steps(num_steps)
    if solver._engine.name in ("dense", "pallas"):
        # single-host matrix backends mix outside shard_map, so one
        # warmed jitted combine times cleanly; mesh backends report
        # latency through the launch layer instead (docs/DISTRIBUTED.md)
        from repro.consensus import time_round_us
        engine = solver._engine
        ledger.observe_latency(time_round_us(
            jax.jit(lambda tr: engine.mix(tr)), state.x))
    # one agent's consensus payload: its slice of the outer iterate tree
    payload = jax.tree_util.tree_map(lambda l: l[0], state.x)
    return SolveResult(state=state, trace=trace,
                       us_per_step=1e6 * took / max(num_steps, 1),
                       samples_per_step=solver.samples_per_step(n),
                       communications_per_step=solver.communications_per_step,
                       bytes_per_round=float(
                           solver._engine.bytes_on_wire(payload)),
                       measured_wire_bytes=ledger.measured_wire_bytes,
                       round_latency_us=ledger.round_latency_us,
                       **counts)
