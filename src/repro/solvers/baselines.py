"""Registry entries for the Section-6 baselines: GT-DSGD and D-SGD.

GT-DSGD keeps INTERACT's tracking skeleton (two consensus rounds) on
plain minibatch gradients; D-SGD additionally drops tracking, so it
communicates once per iteration (Definition 2's cheapest row) but pays
for it in convergence (Fig. 2).
"""
from __future__ import annotations

from repro.byzantine import init_guard
from repro.core.baselines import (
    dsgd_step,
    gt_dsgd_step,
    init_dsgd_state,
    init_gt_dsgd_state,
)
from repro.solvers.api import SolverBase, register_solver

__all__ = ["DsgdSolver", "GtDsgdSolver"]


@register_solver("gt-dsgd")
class GtDsgdSolver(SolverBase):
    """Gradient-tracked decentralized SGD (stripped-down INTERACT)."""

    def _init_state(self, key, problem, hg_cfg, x0, y0, data):
        # full per-agent dataset, matching the n SolverBase.init resolves
        # q/batch against — init and step must use the same batch size
        n = data.inner_x.shape[1] + data.outer_x.shape[1]
        return init_gt_dsgd_state(problem, hg_cfg, x0, y0, data, key,
                                  self.config.resolve_batch(n),
                                  compression=self.config.compression,
                                  guard=init_guard(self.config.guard))

    def _make_param_step(self, problem, hg_cfg, engine, n):
        bs = self.config.resolve_batch(n)

        def step(state, data, alpha, beta):
            return gt_dsgd_step(problem, hg_cfg, engine, alpha, beta, bs,
                                state, data)

        return step

    def samples_per_step(self, n: int) -> float:
        return float(self.config.resolve_batch(n))


@register_solver("d-sgd")
class DsgdSolver(SolverBase):
    """Decentralized SGD without gradient tracking (one mix per step)."""

    communications_per_step = 1  # only x is mixed; no tracker exchange

    def _init_state(self, key, problem, hg_cfg, x0, y0, data):
        m = data.inner_x.shape[0]
        return init_dsgd_state(x0, y0, m, key,
                               compression=self.config.compression,
                               guard=init_guard(self.config.guard))

    def _make_param_step(self, problem, hg_cfg, engine, n):
        bs = self.config.resolve_batch(n)

        def step(state, data, alpha, beta):
            return dsgd_step(problem, hg_cfg, engine, alpha, beta, bs,
                             state, data)

        return step

    def samples_per_step(self, n: int) -> float:
        return float(self.config.resolve_batch(n))
