"""Registry entry for SVR-INTERACT (Algorithm 2).

SPIDER-style recursive estimators with a full refresh every q steps.
Amortized per-agent IFO cost: one n-sample refresh every q iterations
plus two batch-size evaluations per recursive step (Corollary 4's
O(sqrt(n)) regime at the paper's q = |S| = ceil(sqrt(n)) defaults).
"""
from __future__ import annotations

from repro.byzantine import init_guard
from repro.core.svr_interact import init_svr_state, svr_interact_step
from repro.solvers.api import SolverBase, register_solver

__all__ = ["SvrInteractSolver"]


@register_solver("svr-interact")
class SvrInteractSolver(SolverBase):
    """Variance-reduced INTERACT (eqs. 23-24 estimators)."""

    def _init_state(self, key, problem, hg_cfg, x0, y0, data):
        return init_svr_state(problem, hg_cfg, x0, y0, data, key,
                              compression=self.config.compression,
                              guard=init_guard(self.config.guard))

    def _make_param_step(self, problem, hg_cfg, engine, n):
        q = self.config.resolve_q(n)
        bs = self.config.resolve_batch(n)

        def step(state, data, alpha, beta):
            return svr_interact_step(problem, hg_cfg, engine, alpha, beta,
                                     q, bs, state, data)

        return step

    def samples_per_step(self, n: int) -> float:
        # amortized: one full refresh (n) every q steps + 2*batch otherwise
        q = self.config.resolve_q(n)
        bs = self.config.resolve_batch(n)
        return float(n / q + 2 * bs)

    def hypergrad_calls_per_step(self, n: int) -> float:
        # amortized exactly: a refresh step makes one full-batch estimator
        # call, every other step the two minibatch evaluations of the
        # recursive difference (eq. 23): (1 + 2(q-1)) / q = 2 - 1/q
        return 2.0 - 1.0 / self.config.resolve_q(n)
