"""Hypergradient estimators for nonconvex-strongly-convex bilevel problems.

Implements the approximate gradient of eq. (5),

    grad_bar f(x, y) = grad_x f(x, y)
        - H_xy(g)(x, y) [H_yy(g)(x, y)]^{-1} grad_y f(x, y),

without ever materialising a Hessian: both Hessian blocks act through
Hessian-vector products (HVPs) computed by automatic differentiation.

Two inverse approximations:

* ``cg``     — conjugate gradients on H_yy z = grad_y f.  Used by the
               deterministic INTERACT reference (the paper's exact-inverse
               eq. (5) up to solver tolerance).
* ``neumann``— the paper's stochastic K-term Neumann estimator, eq. (22):
               z = (k+1)/L_g * prod_{j<=k} (I - H_yy/L_g) grad_y f with
               k ~ U{0..K-1} (unbiased telescoping form), or the full
               deterministic K-term truncated sum.

Both operate on arbitrary pytrees for x and y.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Literal

import jax
import jax.numpy as jnp

__all__ = [
    "HypergradConfig",
    "hvp_yy",
    "hvp_xy",
    "cg_solve",
    "neumann_inverse_apply",
    "hypergradient",
]

Scalar = jax.Array
TreeDef = object


@dataclasses.dataclass(frozen=True)
class HypergradConfig:
    """How to apply the inner-Hessian inverse.

    Attributes:
      method: "cg" (deterministic solve) or "neumann" (paper eq. 22).
      cg_iters / cg_tol: CG budget for the deterministic path.
      neumann_k: K, the truncation order of eq. (22).
      lipschitz_g: L_g, the gradient-Lipschitz constant of g used to scale
        the Neumann series ((I - H/L_g) must be a contraction).
      stochastic_k: if True, draw k ~ U{0..K-1} and use the unbiased
        (K/L_g)-scaled single product of eq. (22); if False use the full
        truncated sum (deterministic bias (1 - mu/L)^K, Lemma 3).
    """

    method: Literal["cg", "neumann"] = "cg"
    cg_iters: int = 32
    cg_tol: float = 1e-8
    neumann_k: int = 8
    lipschitz_g: float = 1.0
    stochastic_k: bool = False


def _flat_dot(a, b) -> Scalar:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    return sum(jnp.vdot(la, lb) for la, lb in zip(leaves_a, leaves_b))


def _axpy(alpha, x, y):
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def _scale(alpha, x):
    return jax.tree_util.tree_map(lambda xi: alpha * xi, x)


def _sub(x, y):
    return jax.tree_util.tree_map(lambda xi, yi: xi - yi, x, y)


def hvp_yy(g: Callable, x, y, v, *args):
    """H_yy(g)(x, y) @ v via forward-over-reverse."""
    grad_y = lambda yy: jax.grad(g, argnums=1)(x, yy, *args)
    return jax.jvp(grad_y, (y,), (v,))[1]


def hvp_xy(g: Callable, x, y, v, *args):
    """H_xy(g)(x, y) @ v  =  grad_x <grad_y g(x, y), v>."""
    def inner(xx):
        gy = jax.grad(g, argnums=1)(xx, y, *args)
        return _flat_dot(gy, v)

    return jax.grad(inner)(x)


def cg_solve(matvec: Callable, b, iters: int, tol: float):
    """Conjugate gradients for SPD ``matvec`` on pytrees.

    Runs a fixed ``iters``-step lax loop (jit-friendly); ``tol`` freezes the
    iterate once the residual norm is small (no early exit, deterministic
    cost — appropriate for lowering on TPU).
    """
    x0 = jax.tree_util.tree_map(jnp.zeros_like, b)

    def body(_, carry):
        x, r, p, rs = carry
        ap = matvec(p)
        denom = _flat_dot(p, ap)
        alpha = jnp.where(denom > 0, rs / jnp.maximum(denom, 1e-30), 0.0)
        active = jnp.sqrt(rs) > tol
        alpha = jnp.where(active, alpha, 0.0)
        x = _axpy(alpha, p, x)
        r = _axpy(-alpha, ap, r)
        rs_new = _flat_dot(r, r)
        beta = jnp.where(active, rs_new / jnp.maximum(rs, 1e-30), 0.0)
        p = _axpy(beta, p, r)
        rs = jnp.where(active, rs_new, rs)
        return x, r, p, rs

    r0 = b
    rs0 = _flat_dot(b, b)
    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x0, r0, b, rs0))
    return x


def neumann_inverse_apply(
    g: Callable,
    x,
    y,
    b,
    *args,
    k_terms: int,
    lipschitz_g: float,
    stochastic_k: bool = False,
    key: jax.Array | None = None,
):
    """Approximate [H_yy g]^{-1} b with the Neumann series of eq. (22).

    Deterministic form:   (1/L) sum_{j=0}^{K-1} (I - H/L)^j b
    Stochastic form:      (K/L) (I - H/L)^k b,  k ~ U{0..K-1}
    """
    L = lipschitz_g

    def step(v):
        return _sub(v, _scale(1.0 / L, hvp_yy(g, x, y, v, *args)))

    if stochastic_k:
        if key is None:
            raise ValueError("stochastic_k requires a PRNG key")
        k = jax.random.randint(key, (), 0, k_terms)

        def body(i, v):
            return jax.tree_util.tree_map(
                lambda vi, si: jnp.where(i < k, si, vi), v, step(v)
            )

        v = jax.lax.fori_loop(0, k_terms, body, b)
        return _scale(float(k_terms) / L, v)

    def body(_, carry):
        v, acc = carry
        acc = jax.tree_util.tree_map(jnp.add, acc, v)
        return step(v), acc

    zero = jax.tree_util.tree_map(jnp.zeros_like, b)
    _, acc = jax.lax.fori_loop(0, k_terms, body, (b, zero))
    return _scale(1.0 / L, acc)


def hypergradient(
    f: Callable,
    g: Callable,
    x,
    y,
    cfg: HypergradConfig,
    f_args: tuple = (),
    g_args: tuple = (),
    key: jax.Array | None = None,
):
    """The approximate hypergradient grad_bar f(x, y) of eq. (5)/(22).

    ``f(x, y, *f_args)`` is the outer loss, ``g(x, y, *g_args)`` the inner
    (mu_g-strongly-convex in y).  Returns a pytree like x.
    """
    gx, gy = jax.grad(f, argnums=(0, 1))(x, y, *f_args)

    if cfg.method == "cg":
        matvec = lambda v: hvp_yy(g, x, y, v, *g_args)
        z = cg_solve(matvec, gy, cfg.cg_iters, cfg.cg_tol)
    elif cfg.method == "neumann":
        z = neumann_inverse_apply(
            g, x, y, gy, *g_args,
            k_terms=cfg.neumann_k,
            lipschitz_g=cfg.lipschitz_g,
            stochastic_k=cfg.stochastic_k,
            key=key,
        )
    else:
        raise ValueError(f"unknown hypergradient method {cfg.method!r}")

    correction = hvp_xy(g, x, y, z, *g_args)
    return _sub(gx, correction)
