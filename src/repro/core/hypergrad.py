"""Deprecated shim: hypergradient estimation moved to ``repro.hypergrad``.

This module keeps the historical entry points importable and
bit-compatible — same signatures, same numerics (``cg_solve`` keeps its
absolute-tolerance default here; the canonical function switched to a
relative test) — while emitting a ``DeprecationWarning`` on first use.
``HypergradConfig`` is re-exported unchanged (it is the same class).

Use instead::

    from repro.hypergrad import (HypergradConfig, hypergradient,
                                 cg_solve, hvp_yy, hvp_xy,
                                 neumann_inverse_apply)

which adds the backend registry ("cg-linearized", "cholesky", ...) and
measured evaluation counts (``hypergradient_with_stats``).  See
docs/HYPERGRAD.md.
"""
from __future__ import annotations

import warnings

from repro.hypergrad import HypergradConfig          # noqa: F401 (canonical)
from repro.hypergrad import cg as _cg
from repro.hypergrad import engine as _engine
from repro.hypergrad import neumann as _neumann

__all__ = [
    "HypergradConfig",
    "hvp_yy",
    "hvp_xy",
    "cg_solve",
    "neumann_inverse_apply",
    "hypergradient",
]

_warned: set[str] = set()


def _warn(name: str) -> None:
    if name in _warned:
        return
    _warned.add(name)
    warnings.warn(
        f"repro.core.hypergrad.{name} is deprecated; import it from "
        "repro.hypergrad (the HypergradEngine package)",
        DeprecationWarning, stacklevel=3)


def hvp_yy(g, x, y, v, *args):
    """Deprecated alias of ``repro.hypergrad.hvp_yy``."""
    _warn("hvp_yy")
    return _engine.hvp_yy(g, x, y, v, *args)


def hvp_xy(g, x, y, v, *args):
    """Deprecated alias of ``repro.hypergrad.hvp_xy``."""
    _warn("hvp_xy")
    return _engine.hvp_xy(g, x, y, v, *args)


def cg_solve(matvec, b, iters: int, tol: float):
    """Deprecated: ``repro.hypergrad.cg_solve`` (note: the canonical
    function defaults to a *relative* residual test; this shim pins
    ``rel_tol=False`` to preserve the historical absolute semantics
    bit-for-bit)."""
    _warn("cg_solve")
    return _cg.cg_solve(matvec, b, iters, tol, rel_tol=False)


def neumann_inverse_apply(g, x, y, b, *args, k_terms: int,
                          lipschitz_g: float, stochastic_k: bool = False,
                          key=None):
    """Deprecated alias of ``repro.hypergrad.neumann_inverse_apply``."""
    _warn("neumann_inverse_apply")
    return _neumann.neumann_inverse_apply(
        g, x, y, b, *args, k_terms=k_terms, lipschitz_g=lipschitz_g,
        stochastic_k=stochastic_k, key=key)


def hypergradient(f, g, x, y, cfg: HypergradConfig, f_args: tuple = (),
                  g_args: tuple = (), key=None):
    """Deprecated alias of ``repro.hypergrad.hypergradient``."""
    _warn("hypergradient")
    return _engine.hypergradient(f, g, x, y, cfg, f_args=f_args,
                                 g_args=g_args, key=key)
