"""Core: the paper's contribution — decentralized bilevel optimization."""
from repro.core.bilevel import (
    AgentData,
    BilevelProblem,
    MLPMetaProblem,
    init_head,
    init_mlp_backbone,
    make_synthetic_agents,
    pad_agent_data,
)
from repro.core.consensus import (
    MixingSpec,
    erdos_renyi_adjacency,
    laplacian_mixing,
    metropolis_mixing,
    mix_pytree,
    pad_mixing,
    ring_mixing,
    second_eigenvalue,
    torus_adjacency,
    torus_mixing,
    validate_mixing,
)
# Hypergradient estimation lives in repro.hypergrad (the engine package);
# these canonical re-exports keep `from repro.core import ...` working
# without routing through the repro.core.hypergrad deprecation shim.
# They carry the canonical defaults — in particular cg_solve's residual
# test is now relative (tol * ||b||); the repro.core.hypergrad shim keeps
# the historical absolute test bit-for-bit (rel_tol=False).
from repro.hypergrad import (
    HypergradConfig,
    cg_solve,
    hvp_xy,
    hvp_yy,
    hypergradient,
    neumann_inverse_apply,
)
from repro.core.interact import (
    InteractState,
    init_state,
    interact_step,
    make_interact_step,
    theorem1_step_sizes,
)
from repro.core.svr_interact import (
    SvrState,
    init_svr_state,
    make_svr_interact_step,
    per_agent_keys,
    svr_interact_step,
)
from repro.core.baselines import (
    DsgdState,
    GtDsgdState,
    dsgd_step,
    gt_dsgd_step,
    init_dsgd_state,
    init_gt_dsgd_state,
    make_dsgd_step,
    make_gt_dsgd_step,
)
from repro.core.metrics import (MetricReport, convergence_metric,
                                convergence_metric_fn,
                                masked_convergence_metric,
                                masked_convergence_metric_fn, solve_inner)

__all__ = [name for name in dir() if not name.startswith("_")]
