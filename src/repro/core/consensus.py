"""Network topologies and consensus (mixing) matrices.

The paper models the peer-to-peer network as a graph G = (N, L) with a
doubly-stochastic, symmetric mixing matrix M whose sparsity follows the
edges (Section 4.1, properties (a)-(c)).  The second-largest eigenvalue
magnitude lambda = max{|lambda_2|, |lambda_m|} governs the admissible
step sizes (Theorems 1 and 3).

Two families are provided:

* Erdos-Renyi graphs with the paper's Laplacian-based mixing matrix
  ``M = I - 2 L / (3 lambda_max(L))`` (Section 6) — used by the
  paper-faithful CPU experiments.
* Ring / torus mixings — used by the TPU mapping, where the agent axis is
  a physical ICI ring and the mixing is realised with two
  ``lax.ppermute`` neighbour exchanges (see ``repro/sharding``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MixingSpec",
    "erdos_renyi_adjacency",
    "laplacian_mixing",
    "metropolis_mixing",
    "pad_mixing",
    "ring_mixing",
    "ring_weights",
    "second_eigenvalue",
    "torus_adjacency",
    "torus_mixing",
    "validate_mixing",
]


@dataclasses.dataclass(frozen=True)
class MixingSpec:
    """A mixing matrix together with the quantities the theory needs.

    Attributes:
      matrix:  (m, m) doubly-stochastic symmetric mixing matrix.
      lam:     second-largest eigenvalue magnitude (the paper's lambda).
      neighbors: for sparse/ring topologies, the ppermute offsets used by
        the distributed implementation (empty for dense matrices).
      weights: per-offset weights aligned with ``neighbors`` (the self
        weight is ``1 - sum(weights)``).
    """

    matrix: np.ndarray
    lam: float
    neighbors: tuple[int, ...] = ()
    weights: tuple[float, ...] = ()

    @property
    def num_agents(self) -> int:
        return int(self.matrix.shape[0])

    @property
    def self_weight(self) -> float:
        return float(1.0 - sum(self.weights))


def erdos_renyi_adjacency(m: int, p_connect: float, seed: int) -> np.ndarray:
    """Sample a connected Erdos-Renyi graph adjacency matrix.

    Re-samples until connected (standard practice; the paper requires a
    connected graph for consensus to be feasible). A ring fallback edge set
    guarantees termination for very small ``p_connect``.
    """
    rng = np.random.default_rng(seed)
    for _ in range(512):
        upper = rng.random((m, m)) < p_connect
        adj = np.triu(upper, k=1)
        adj = (adj | adj.T).astype(np.float64)
        if _is_connected(adj):
            return adj
    # Fallback: ER sample + ring edges (connected by construction).
    adj = np.triu(rng.random((m, m)) < p_connect, k=1)
    adj = (adj | adj.T).astype(np.float64)
    for i in range(m):
        adj[i, (i + 1) % m] = 1.0
        adj[(i + 1) % m, i] = 1.0
    np.fill_diagonal(adj, 0.0)
    return adj


def _is_connected(adj: np.ndarray) -> bool:
    m = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.nonzero(adj[i])[0]:
            if j not in seen:
                seen.add(int(j))
                frontier.append(int(j))
    return len(seen) == m


def laplacian_mixing(adj: np.ndarray) -> MixingSpec:
    """The paper's Section-6 mixing matrix: W = I - 2L / (3 lambda_max(L))."""
    deg = np.diag(adj.sum(axis=1))
    lap = deg - adj
    lam_max = float(np.linalg.eigvalsh(lap)[-1])
    mat = np.eye(adj.shape[0]) - 2.0 * lap / (3.0 * lam_max)
    return MixingSpec(matrix=mat, lam=second_eigenvalue(mat))


def metropolis_mixing(adj: np.ndarray) -> MixingSpec:
    """Metropolis-Hastings weights: doubly stochastic for any graph."""
    m = adj.shape[0]
    deg = adj.sum(axis=1)
    mat = np.zeros((m, m))
    for i in range(m):
        for j in range(m):
            if i != j and adj[i, j] > 0:
                mat[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        mat[i, i] = 1.0 - mat[i].sum()
    return MixingSpec(matrix=mat, lam=second_eigenvalue(mat))


def ring_weights(self_weight: float = 1.0 / 3.0) -> tuple[float, float]:
    """Symmetric ring neighbour weights (w_left = w_right)."""
    w = (1.0 - self_weight) / 2.0
    return (w, w)


def ring_mixing(m: int, self_weight: float = 1.0 / 3.0) -> MixingSpec:
    """Doubly-stochastic symmetric ring: the TPU ICI-native topology.

    lambda for the ring is known analytically:
      eigenvalues are  w0 + 2 w1 cos(2 pi k / m),  k = 0..m-1.
    """
    if m < 1:
        raise ValueError("need at least one agent")
    w1 = (1.0 - self_weight) / 2.0
    mat = np.zeros((m, m))
    for i in range(m):
        mat[i, i] = self_weight
        mat[i, (i - 1) % m] += w1
        mat[i, (i + 1) % m] += w1
    if m == 1:
        mat[:] = 1.0
    lam = second_eigenvalue(mat)
    return MixingSpec(
        matrix=mat,
        lam=lam,
        neighbors=(-1, 1) if m > 1 else (),
        weights=(w1, w1) if m > 1 else (),
    )


def torus_adjacency(rows: int, cols: int) -> np.ndarray:
    """2-D torus adjacency: each agent links to its 4 grid neighbours
    (degenerate dimensions of size 1 or 2 collapse duplicate edges)."""
    m = rows * cols
    adj = np.zeros((m, m))
    for r in range(rows):
        for c in range(cols):
            i = r * cols + c
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                j = ((r + dr) % rows) * cols + (c + dc) % cols
                if j != i:
                    adj[i, j] = 1.0
    return adj


def torus_mixing(rows: int, cols: int) -> MixingSpec:
    """Doubly-stochastic symmetric torus mixing (Metropolis weights)."""
    return metropolis_mixing(torus_adjacency(rows, cols))


def pad_mixing(mixing, pad_to: int) -> np.ndarray:
    """Pad a mixing matrix to ``pad_to`` agents with ghost self-loops.

    Ghost agents (rows/cols >= the original m) get identity rows: they
    mix only with themselves and no active agent's row places weight on
    them, so the padded matrix

      * stays doubly stochastic and symmetric (Section-4.1 (a)/(b)),
      * leaves every active agent's combine bitwise unchanged — the
        extra contraction terms are exact ``0.0 * x_ghost`` zeros, and
      * makes ghost agents fixed points of the consensus combine
        (``x_ghost <- x_ghost``), which is what lets the padded sweep
        batch different network sizes into one program (docs/SWEEPS.md).

    ``mixing`` is a ``MixingSpec`` or raw (m, m) matrix; returns the
    (pad_to, pad_to) padded matrix (a copy; the input is untouched).
    """
    mat = mixing.matrix if isinstance(mixing, MixingSpec) else np.asarray(mixing)
    m = mat.shape[0]
    if pad_to < m:
        raise ValueError(f"cannot pad {m} agents down to {pad_to}")
    out = np.eye(pad_to, dtype=mat.dtype)
    out[:m, :m] = mat
    return out


def second_eigenvalue(mat: np.ndarray) -> float:
    """lambda = max{|lambda_2|, |lambda_m|} of a symmetric stochastic M."""
    eig = np.sort(np.linalg.eigvalsh(mat))
    if eig.shape[0] == 1:
        return 0.0
    return float(max(abs(eig[0]), abs(eig[-2])))


def validate_mixing(mat: np.ndarray, adj: np.ndarray | None = None,
                    atol: float = 1e-8) -> None:
    """Assert the Section-4.1 properties (a) doubly stochastic,
    (b) symmetric, (c) network-defined sparsity."""
    ones = np.ones(mat.shape[0])
    if not np.allclose(mat @ ones, ones, atol=atol):
        raise ValueError("rows do not sum to 1")
    if not np.allclose(mat.T @ ones, ones, atol=atol):
        raise ValueError("columns do not sum to 1")
    if not np.allclose(mat, mat.T, atol=atol):
        raise ValueError("matrix not symmetric")
    if adj is not None:
        off = ~np.eye(mat.shape[0], dtype=bool)
        if np.any((np.abs(mat) > atol) & off & (adj <= 0)):
            raise ValueError("nonzero weight on a non-edge")


def mix_pytree(matrix: jax.Array, tree):
    """Apply the consensus combine ``x_i <- sum_j M_ij x_j`` to every leaf.

    Leaves carry a leading agent dimension of size m.  This is the dense
    reference implementation (eq. 6 / eq. 10 left term); the distributed
    runtime uses ppermute instead (see repro/sharding/collectives.py).
    """
    def combine(leaf):
        return jnp.tensordot(matrix, leaf, axes=[[1], [0]]).astype(leaf.dtype)

    return jax.tree_util.tree_map(combine, tree)
