"""The INTERACT algorithm (Algorithm 1).

Inner-gradient-descent-outer-tracked-gradient.  Per iteration each agent:

  Step 1 (consensus + descent):   x_i <- sum_j M_ij x_j - alpha u_i   (6)
                                  y_i <- y_i - beta v_i               (7)
  Step 2 (full local gradients):  p_i = grad_bar f_i(x_i, y_i)        (8)
                                  v_i = grad_y g_i(x_i, y_i)          (9)
  Step 3 (gradient tracking):     u_i <- sum_j M_ij u_j + p_i - p_i^- (10)

State tensors carry a leading agent dimension m; gradients are vmapped
per agent.  Steps 1 and 3 are delegated to a pluggable
``ConsensusEngine`` (repro/consensus) through the shared
``consensus_descent_and_track`` step-core — the same skeleton drives
SVR-INTERACT, the Section-6 baselines, and the distributed LM train step.
Step sizes must satisfy the Theorem-1 bounds, exposed by
``theorem1_step_sizes``.

Quickstart (the unified Solver API, see docs/SOLVERS.md)::

    from repro.solvers import SolverConfig, make_solver
    solver = make_solver(SolverConfig(algo="interact", alpha=0.3,
                                      backend="dense"))
    state = solver.init(None, problem, hg_cfg, x0, y0, data)
    state = solver.run(state, data, 100)   # scan-compiled multi-step

``backend`` selects the combine implementation: ``"dense"`` (matmul
reference), ``"pallas"`` (the fused consensus+tracking kernel on the
simulator hot loop), or ``"ppermute"`` (device-mesh collectives, used by
repro/train).  ``make_interact_step`` remains as a deprecated shim over
the solver path.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.consensus import as_engine, consensus_descent_and_track, init_ef
from repro.core.bilevel import AgentData, BilevelProblem
from repro.core.consensus import MixingSpec
from repro.hypergrad import HypergradConfig, hypergradient

__all__ = [
    "InteractState",
    "init_state",
    "interact_step",
    "make_interact_step",
    "theorem1_step_sizes",
]


class InteractState(NamedTuple):
    x: object        # outer params, leaves (m, ...)
    y: object        # inner params, leaves (m, ...)
    u: object        # tracked global gradient estimate, like x
    v: object        # inner gradient, like y
    p_prev: object   # previous local hypergradient, like x
    t: jax.Array     # iteration counter
    ef: object = None  # error-feedback residuals {"x", "u"} (compressed wire)
    guard: object = None  # divergence-guard counters {"tripped", "last_good"}


def _per_agent_batch(data: AgentData):
    inner = (data.inner_x, data.inner_y)
    outer = (data.outer_x, data.outer_y)
    return inner, outer


def _agent_gradients(problem: BilevelProblem, hg_cfg: HypergradConfig,
                     x, y, inner_batch, outer_batch, key=None):
    """(p_i, v_i) for a single agent (no leading agent dim here)."""
    p = hypergradient(
        problem.outer, problem.inner, x, y, hg_cfg,
        f_args=(outer_batch,), g_args=(inner_batch,), key=key,
        inner_hess_yy=problem.inner_hess_yy,
    )
    v = jax.grad(problem.inner, argnums=1)(x, y, inner_batch)
    return p, v


def init_state(problem: BilevelProblem, hg_cfg: HypergradConfig,
               x0, y0, data: AgentData,
               compression=None, guard=None) -> InteractState:
    """Algorithm-1 initialisation: u_0 = grad_bar f(x_0, y_0), v_0 = grad_y g.

    ``x0``/``y0`` are single-agent pytrees; every agent starts from the same
    point (x^0, y^0) as in the paper, so we broadcast along the agent axis.

    ``compression`` (a ``repro.consensus.CompressionConfig``) adds the
    zero error-feedback residuals for the two consensus streams to the
    state when it uses EF; otherwise ``ef`` stays ``None`` and the state
    is bit-identical to the uncompressed layout.  ``guard`` is the
    divergence-guard counter carry (``repro.byzantine.init_guard``), the
    same trailing-``None`` convention.
    """
    m = data.inner_x.shape[0]
    bcast = lambda tree: jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (m,) + leaf.shape), tree)
    x = bcast(x0)
    y = bcast(y0)
    inner_b, outer_b = _per_agent_batch(data)
    grads = jax.vmap(
        partial(_agent_gradients, problem, hg_cfg)
    )(x, y, inner_b, outer_b)
    p, v = grads
    # p_prev is a copy of p: u and p_prev must not alias the same buffer
    # or the donating step closures cannot donate the state.
    p_prev = jax.tree_util.tree_map(jnp.array, p)
    return InteractState(x=x, y=y, u=p, v=v, p_prev=p_prev,
                         t=jnp.zeros((), jnp.int32),
                         ef=init_ef(compression, x=x, u=p), guard=guard)


def interact_step(
    problem: BilevelProblem,
    hg_cfg: HypergradConfig,
    mixing,
    alpha: float,
    beta: float,
    state: InteractState,
    data: AgentData,
) -> InteractState:
    """One INTERACT iteration over all agents.

    ``mixing`` is a ``ConsensusEngine`` (or a raw (m, m) matrix, coerced
    to the dense backend).  Steps 1 and 3 run through the shared
    step-core; Step 2 is the full local gradient pass (8)-(9).
    """
    engine = as_engine(mixing)

    def grads_fn(x_new, y_new):
        inner_b, outer_b = _per_agent_batch(data)
        p_new, v_new = jax.vmap(
            partial(_agent_gradients, problem, hg_cfg)
        )(x_new, y_new, inner_b, outer_b)
        return p_new, v_new, None

    x_new, y_new, u_new, v_new, p_new, ef_new, _ = (
        consensus_descent_and_track(
            engine, state.x, state.y, state.u, state.v, state.p_prev,
            alpha, beta, grads_fn, t=state.t, ef=state.ef))

    return InteractState(x=x_new, y=y_new, u=u_new, v=v_new,
                         p_prev=p_new, t=state.t + 1, ef=ef_new,
                         guard=state.guard)


def make_interact_step(problem: BilevelProblem, hg_cfg: HypergradConfig,
                       mixing: MixingSpec, alpha: float, beta: float,
                       backend: str = "dense", **backend_opts):
    """Deprecated shim: use ``repro.solvers.make_solver`` instead.

    Returns the registry solver's jitted step closure (state donated),
    preserving the legacy positional signature.
    """
    warnings.warn(
        "make_interact_step is deprecated; use repro.solvers."
        "make_solver(SolverConfig(algo='interact', ...))",
        DeprecationWarning, stacklevel=2)
    from repro.solvers import SolverConfig, make_solver
    cfg = SolverConfig(algo="interact", alpha=alpha, beta=beta,
                       mixing=mixing, backend=backend,
                       backend_opts=backend_opts)
    return make_solver(cfg).build(problem, hg_cfg).step


def theorem1_step_sizes(
    mu_g: float,
    L_g: float,
    lam: float,
    m: int,
    L_f: float | None = None,
    safety: float = 1.0,
) -> tuple[float, float]:
    """Conservative (alpha, beta) satisfying the Theorem-1 bounds.

    The theorem lists ~10 upper bounds built from the Lipschitz constants of
    Lemma 1/2; we compute the binding ones from (mu_g, L_g, lam, m) with
    L_f defaulting to L_g.  ``safety`` < 1 shrinks both (useful when the
    constants are estimated rather than exact).
    """
    L_f = L_f if L_f is not None else L_g
    L_y = (L_g / mu_g) ** 2          # Lemma 1 with C_gxy ~ L_g
    L_l = (L_f + L_f * L_g / mu_g) ** 2
    L_K = max(L_f, L_g)

    beta = safety * min(
        3.0 * (mu_g + L_g) / (mu_g * L_g),
        1.0 / (mu_g + L_g),
    )
    r = beta * mu_g * L_g / (3.0 * (mu_g + L_g))
    one_minus = max(1.0 - lam, 1e-3)
    alpha = safety * min(
        1.0 / (4.0 * L_l),
        1.0 / (2.0 * m),
        1.0 / (m * one_minus),
        one_minus ** 2 / (32.0 * L_K ** 2),
        m * one_minus / (4.0 * L_l),
        9.0 * r * r * m * one_minus / (32.0 * L_y ** 2 * (1.0 + 1.0 / r) * L_f ** 2 + 1e-30),
        (1.0 - r) * (1.0 + r) * r * one_minus ** 2
        / (32.0 * L_y ** 2 * (mu_g + L_g) * L_K ** 2 * beta + 1e-30),
        one_minus / (4.0 * L_K),
        1.0,
    )
    return float(alpha), float(beta)
