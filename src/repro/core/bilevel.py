"""Bilevel problem abstraction + the paper's meta-learning instance.

A ``BilevelProblem`` packages the per-agent outer loss f_i(x, y; batch) and
inner loss g_i(x, y; batch).  Problem (1) of the paper:

    min_x (1/m) sum_i f_i(x_i, y_i*(x_i)),
    y_i*(x_i) = argmin_y g_i(x_i, y_i),   g_i mu_g-strongly convex in y.

The reference instance is the Section-6 meta-learning task: a shared
two-hidden-layer backbone x (20 hidden units) and per-agent linear heads
y_i, with g_i = CE(train split) + (mu/2)||y||^2 so the inner problem is
strongly convex, and f_i = CE(validation split) — nonconvex in x.
MNIST/CIFAR are unavailable offline; a synthetic heterogeneous Gaussian
cluster generator stands in (see DESIGN.md §1).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "AgentData",
    "BilevelProblem",
    "MLPMetaProblem",
    "make_synthetic_agents",
    "init_mlp_backbone",
    "init_head",
    "pad_agent_data",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AgentData:
    """Per-agent dataset of n samples split into inner (train) / outer (val)."""

    inner_x: jax.Array  # (n_in, d)
    inner_y: jax.Array  # (n_in,) int labels
    outer_x: jax.Array  # (n_out, d)
    outer_y: jax.Array  # (n_out,)


@dataclasses.dataclass(frozen=True)
class BilevelProblem:
    """f(x, y, batch) outer loss, g(x, y, batch) inner loss.

    batch is an arbitrary pytree; for full-gradient algorithms pass the
    whole agent dataset, for stochastic ones pass a minibatch.

    ``inner_hess_yy`` is an optional closed form for the flat inner
    Hessian: ``inner_hess_yy(x, y, batch) -> (d_y, d_y)`` in
    ``ravel_pytree(y)`` ordering, ridge included.  The ``cholesky``
    hypergradient backend uses it instead of materialising H_yy through
    d_y automatic-differentiation HVPs (see docs/HYPERGRAD.md); every
    other backend ignores it, so it is purely an opt-in fast path.
    """

    outer: Callable  # f(x, y, (inputs, labels)) -> scalar
    inner: Callable  # g(x, y, (inputs, labels)) -> scalar
    mu_g: float      # strong-convexity modulus of g in y
    lipschitz_g: float  # gradient-Lipschitz bound L_g for the Neumann scale
    inner_hess_yy: Callable | None = None  # optional closed-form flat H_yy


def pad_agent_data(data: AgentData, pad_to: int) -> AgentData:
    """Ghost-pad the agent axis to ``pad_to`` by tiling real agents' data.

    Ghost agent i >= m sees a copy of agent ``i % m``'s dataset — real,
    finite samples, so the (discarded) ghost computations in a padded
    sweep group stay well-conditioned; zeros or NaN sentinels could leak
    through ``0 * NaN`` in the dense mixing matmul or blow up the ghost
    inner solves.  Active agents' rows are untouched (``i % m == i``).
    """
    m = data.inner_x.shape[0]
    if pad_to < m:
        raise ValueError(f"cannot pad {m} agents down to {pad_to}")
    if pad_to == m:
        return data
    idx = jnp.arange(pad_to) % m
    return jax.tree_util.tree_map(lambda leaf: leaf[idx], data)


# ---------------------------------------------------------------------------
# The paper's Section-6 instance: 2-hidden-layer MLP meta-learning.
# ---------------------------------------------------------------------------

def _mlp_features(params, inputs):
    h = inputs
    for w, b in params:
        h = jnp.tanh(h @ w + b)
    return h


def _cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def MLPMetaProblem(mu_g: float = 0.1, lipschitz_g: float = 4.0) -> BilevelProblem:
    """Backbone x = list[(W, b)], head y = (W_head, b_head).

    g(x, y) = CE(head(features(x, inner_x)), inner_y) + mu/2 ||y||^2
    f(x, y) = CE(head(features(x, outer_x)), outer_y)

    The inner problem is a linear head under softmax CE + ridge, so its
    Hessian wrt y has the closed form

        H[(i,c),(j,d)] = (1/n) sum_s phi_si phi_sj A_s[c,d] + mu I,
        A_s = diag(p_s) - p_s p_s^T,   phi_s = [features_s, 1],

    which ``inner_hess_yy`` materialises with two batched contractions —
    the ``cholesky`` hypergradient backend's small-head fast path.
    """

    def outer(x, y, batch):
        inputs, labels = batch
        feats = _mlp_features(x, inputs)
        w, b = y
        return _cross_entropy(feats @ w + b, labels)

    def inner(x, y, batch):
        inputs, labels = batch
        feats = _mlp_features(x, inputs)
        w, b = y
        ce = _cross_entropy(feats @ w + b, labels)
        reg = 0.5 * mu_g * (jnp.sum(w * w) + jnp.sum(b * b))
        return ce + reg

    def inner_hess_yy(x, y, batch):
        inputs, _labels = batch
        feats = _mlp_features(x, inputs)
        w, b = y
        p = jax.nn.softmax(feats @ w + b, axis=-1)        # (n, C)
        n, C = p.shape
        # phi rows [features, 1]: index i*C+c matches ravel_pytree((w, b))
        # = [w.ravel(), b] with the bias as the trailing phi column.
        phi = jnp.concatenate([feats, jnp.ones((n, 1), feats.dtype)],
                              axis=1)                      # (n, hd+1)
        hd1 = phi.shape[1]
        d = hd1 * C
        # A_s = diag(p_s) - p_s p_s^T split into its two contractions:
        # rank-one part as a gram of R[s,(i,c)] = phi_si p_sc, diagonal
        # part as C feature grams weighted by p[:, c].
        R = (phi[:, :, None] * p[:, None, :]).reshape(n, d)
        G = jnp.einsum('sc,si,sj->cij', p, phi, phi)       # (C, hd+1, hd+1)
        H = -(R.T @ R)
        H = H.reshape(hd1, C, hd1, C)
        # diagonal (c == d) blocks via a broadcast against eye — a scatter
        # here lowers poorly under vmap on CPU
        H = H + (G.transpose(1, 0, 2)[:, :, :, None]
                 * jnp.eye(C)[None, :, None, :])
        return H.reshape(d, d) / n + mu_g * jnp.eye(d)

    return BilevelProblem(outer=outer, inner=inner, mu_g=mu_g,
                          lipschitz_g=lipschitz_g,
                          inner_hess_yy=inner_hess_yy)


def init_mlp_backbone(key: jax.Array, d_in: int, hidden: int = 20,
                      depth: int = 2, scale: float = 0.5):
    params = []
    dims = [d_in] + [hidden] * depth
    for i in range(depth):
        key, k1 = jax.random.split(key)
        w = scale * jax.random.normal(k1, (dims[i], dims[i + 1])) / np.sqrt(dims[i])
        params.append((w, jnp.zeros((dims[i + 1],))))
    return params


def init_head(key: jax.Array, hidden: int, num_classes: int,
              scale: float = 0.1):
    w = scale * jax.random.normal(key, (hidden, num_classes)) / np.sqrt(hidden)
    return (w, jnp.zeros((num_classes,)))


def make_synthetic_agents(
    key: jax.Array,
    num_agents: int,
    n_per_agent: int = 1000,
    d_in: int = 32,
    num_classes: int = 10,
    heterogeneity: float = 0.5,
    outer_frac: float = 0.3,
) -> AgentData:
    """Synthetic heterogeneous classification tasks (MNIST stand-in).

    Class means are shared globally; each agent sees a skewed label
    distribution (Dirichlet with concentration 1/heterogeneity) plus an
    agent-specific mean shift, giving genuinely different f_i / g_i per
    agent as in multi-agent meta-learning.

    Returns stacked AgentData with a leading agent axis.
    """
    k_means, k_shift, k_lab, k_x = jax.random.split(key, 4)
    means = 2.0 * jax.random.normal(k_means, (num_classes, d_in))
    shifts = heterogeneity * jax.random.normal(k_shift, (num_agents, 1, d_in))

    conc = jnp.full((num_classes,), 1.0 / max(heterogeneity, 1e-3))
    probs = jax.random.dirichlet(k_lab, conc, shape=(num_agents,))
    labels = jax.vmap(
        lambda k, p: jax.random.categorical(k, jnp.log(p), shape=(n_per_agent,))
    )(jax.random.split(k_lab, num_agents), probs)

    noise = jax.random.normal(k_x, (num_agents, n_per_agent, d_in))
    xs = means[labels] + shifts + 0.75 * noise

    n_out = int(outer_frac * n_per_agent)
    return AgentData(
        inner_x=xs[:, n_out:], inner_y=labels[:, n_out:],
        outer_x=xs[:, :n_out], outer_y=labels[:, :n_out],
    )
