"""Baseline algorithms from Section 6: GT-DSGD and D-SGD.

* GT-DSGD — "stripped-down INTERACT": same consensus + gradient-tracking
  skeleton, but the local gradients are plain stochastic minibatch
  estimates (no variance reduction, no full refresh).
* D-SGD — GT-DSGD without gradient tracking: each agent descends its own
  stochastic hypergradient after the consensus combine.

Both use the stochastic Neumann hypergradient of eq. (22) for the outer
gradient (the bilevel analogue of a plain stochastic gradient).

Quickstart (the unified Solver API, see docs/SOLVERS.md)::

    from repro.solvers import SolverConfig, make_solver
    solver = make_solver(SolverConfig(algo="gt-dsgd", batch_size=12))
    state = solver.init(None, problem, hg_cfg, x0, y0, data)
    state = solver.run(state, data, 100)   # scan-compiled

``make_gt_dsgd_step`` / ``make_dsgd_step`` remain as deprecated shims.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.consensus import consensus_descent_and_track, init_ef
from repro.core.bilevel import AgentData, BilevelProblem
from repro.core.consensus import MixingSpec
from repro.hypergrad import HypergradConfig
from repro.core.svr_interact import _minibatch_grads, per_agent_keys

__all__ = [
    "GtDsgdState", "init_gt_dsgd_state", "gt_dsgd_step", "make_gt_dsgd_step",
    "DsgdState", "init_dsgd_state", "dsgd_step", "make_dsgd_step",
]


class GtDsgdState(NamedTuple):
    x: object
    y: object
    u: object
    v: object
    p_prev: object
    t: jax.Array
    key: jax.Array
    ef: object = None  # error-feedback residuals {"x", "u"} (compressed wire)
    guard: object = None  # divergence-guard counters {"tripped", "last_good"}


def _bcast(tree, m):
    return jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (m,) + leaf.shape), tree)


def init_gt_dsgd_state(problem: BilevelProblem, hg_cfg: HypergradConfig,
                       x0, y0, data: AgentData, key: jax.Array,
                       batch_size: int, compression=None,
                       guard=None) -> GtDsgdState:
    m = data.inner_x.shape[0]
    x, y = _bcast(x0, m), _bcast(y0, m)
    # m-independent key derivation (see per_agent_keys): ghost-padded
    # inits replay the active agents' sampling streams exactly.
    k_state, k_agents = jax.random.split(key)
    p, v = jax.vmap(
        partial(_minibatch_grads, problem, hg_cfg,
                batch_size=batch_size))(x, y, data,
                                        per_agent_keys(k_agents, m))
    # p_prev copied: u/p_prev must not alias one buffer (step donation)
    p_prev = jax.tree_util.tree_map(jnp.array, p)
    return GtDsgdState(x=x, y=y, u=p, v=v, p_prev=p_prev,
                       t=jnp.zeros((), jnp.int32), key=k_state,
                       ef=init_ef(compression, x=x, u=p), guard=guard)


def gt_dsgd_step(problem: BilevelProblem, hg_cfg: HypergradConfig,
                 engine, alpha: float, beta: float, batch_size: int,
                 state: GtDsgdState, data: AgentData) -> GtDsgdState:
    """One GT-DSGD iteration (raw body over a built engine)."""
    m = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    key, k_step = jax.random.split(state.key)
    agent_keys = per_agent_keys(k_step, m)

    def grads_fn(x_new, y_new):
        p_new, v_new = jax.vmap(
            partial(_minibatch_grads, problem, hg_cfg,
                    batch_size=batch_size))(x_new, y_new, data,
                                            agent_keys)
        return p_new, v_new, None

    x_new, y_new, u_new, v_new, p_new, ef_new, _ = (
        consensus_descent_and_track(
            engine, state.x, state.y, state.u, state.v, state.p_prev,
            alpha, beta, grads_fn, t=state.t, ef=state.ef))
    return GtDsgdState(x=x_new, y=y_new, u=u_new, v=v_new, p_prev=p_new,
                       t=state.t + 1, key=key, ef=ef_new,
                       guard=state.guard)


def make_gt_dsgd_step(problem: BilevelProblem, hg_cfg: HypergradConfig,
                      mixing: MixingSpec, alpha: float, beta: float,
                      batch_size: int, backend: str = "dense",
                      **backend_opts):
    """Deprecated shim: use ``repro.solvers.make_solver`` instead."""
    warnings.warn(
        "make_gt_dsgd_step is deprecated; use repro.solvers."
        "make_solver(SolverConfig(algo='gt-dsgd', ...))",
        DeprecationWarning, stacklevel=2)
    from repro.solvers import SolverConfig, make_solver
    cfg = SolverConfig(algo="gt-dsgd", alpha=alpha, beta=beta,
                       batch_size=batch_size, mixing=mixing,
                       backend=backend, backend_opts=backend_opts)
    return make_solver(cfg).build(problem, hg_cfg).step


class DsgdState(NamedTuple):
    x: object
    y: object
    t: jax.Array
    key: jax.Array
    ef: object = None  # error-feedback residual {"x"} (compressed wire)
    guard: object = None  # divergence-guard counters {"tripped", "last_good"}


def init_dsgd_state(x0, y0, m: int, key: jax.Array,
                    compression=None, guard=None) -> DsgdState:
    x = _bcast(x0, m)
    return DsgdState(x=x, y=_bcast(y0, m),
                     t=jnp.zeros((), jnp.int32), key=key,
                     ef=init_ef(compression, x=x), guard=guard)


def dsgd_step(problem: BilevelProblem, hg_cfg: HypergradConfig,
              engine, alpha: float, beta: float, batch_size: int,
              state: DsgdState, data: AgentData) -> DsgdState:
    """One D-SGD iteration (raw body over a built engine)."""
    m = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    key, k_step = jax.random.split(state.key)
    agent_keys = per_agent_keys(k_step, m)

    p, v = jax.vmap(
        partial(_minibatch_grads, problem, hg_cfg,
                batch_size=batch_size))(state.x, state.y, data, agent_keys)

    # No tracking: descend the raw stochastic hypergradient after the
    # consensus combine (D-SGD's single mix goes through the wire path —
    # compression / interval — when the engine has one configured).
    matrix = (engine.topology_matrix(state.t, state.x)
              if hasattr(engine, "topology_matrix") else None)
    if state.ef is not None or getattr(engine, "wire_active", False):
        ef_x = None if state.ef is None else state.ef.get("x")
        x_mixed, ef_x_new = engine.mix_ef(state.x, ef_x, state.t,
                                          matrix=matrix)
        ef_new = None if state.ef is None else {"x": ef_x_new}
    else:
        # mix_ef with no wire state is bitwise ``mix`` — routed through it
        # so an attached CommsLedger records D-SGD's single x stream too.
        x_mixed, _ = engine.mix_ef(state.x, None, state.t, matrix=matrix)
        ef_new = state.ef
    x_new = jax.tree_util.tree_map(
        lambda mx, g: mx - alpha * g, x_mixed, p)
    y_new = jax.tree_util.tree_map(
        lambda y, g: y - beta * g, state.y, v)
    return DsgdState(x=x_new, y=y_new, t=state.t + 1, key=key, ef=ef_new,
                     guard=state.guard)


def make_dsgd_step(problem: BilevelProblem, hg_cfg: HypergradConfig,
                   mixing: MixingSpec, alpha: float, beta: float,
                   batch_size: int, backend: str = "dense",
                   **backend_opts):
    """Deprecated shim: use ``repro.solvers.make_solver`` instead."""
    warnings.warn(
        "make_dsgd_step is deprecated; use repro.solvers."
        "make_solver(SolverConfig(algo='d-sgd', ...))",
        DeprecationWarning, stacklevel=2)
    from repro.solvers import SolverConfig, make_solver
    cfg = SolverConfig(algo="d-sgd", alpha=alpha, beta=beta,
                       batch_size=batch_size, mixing=mixing,
                       backend=backend, backend_opts=backend_opts)
    return make_solver(cfg).build(problem, hg_cfg).step
