"""The paper's convergence metric (eqs. 2 / 11) and its ingredients.

    M_t = ||grad l(x_bar)||^2            (stationarity of the average)
        + (1/m) sum_i ||x_i - x_bar||^2  (consensus error)
        + ||y* - y||^2                   (inner error, aggregated)

Evaluating grad l(x_bar) = grad_bar f(x_bar, y*(x_bar)) requires the inner
optimum; we compute y*(x) by running the strongly-convex inner problem to
tolerance with gradient descent (exact up to solver precision — this is an
*evaluation-only* cost, not part of any algorithm's sample complexity).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bilevel import AgentData, BilevelProblem
from repro.hypergrad import HypergradConfig, hypergradient

__all__ = ["MetricReport", "solve_inner", "convergence_metric",
           "convergence_metric_fn", "masked_convergence_metric",
           "masked_convergence_metric_fn"]


class MetricReport(NamedTuple):
    total: jax.Array
    stationarity: jax.Array
    consensus_error: jax.Array
    inner_error: jax.Array
    outer_loss: jax.Array


def _tree_sq_norm(tree) -> jax.Array:
    return sum(jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(tree))


def _tree_mean_over_agents(tree):
    return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), tree)


def solve_inner(problem: BilevelProblem, x, y0, batch,
                steps: int = 400, lr: float = 0.5):
    """y*(x) via GD on the strongly-convex inner problem (single agent)."""
    grad_g = jax.grad(problem.inner, argnums=1)

    def body(_, y):
        g = grad_g(x, y, batch)
        return jax.tree_util.tree_map(lambda yi, gi: yi - lr * gi, y, g)

    return jax.lax.fori_loop(0, steps, body, y0)


@partial(jax.jit, static_argnums=(0, 1, 4, 5))
def convergence_metric(problem: BilevelProblem, hg_cfg: HypergradConfig,
                       x_stack, y_stack, inner_steps: int, inner_lr: float,
                       data: AgentData) -> MetricReport:
    """Compute M_t for stacked per-agent iterates (leading axis m)."""
    m = jax.tree_util.tree_leaves(x_stack)[0].shape[0]
    x_bar = _tree_mean_over_agents(x_stack)

    # --- consensus error: (1/m) sum_i ||x_i - x_bar||^2
    cons = jax.tree_util.tree_map(
        lambda xi, xb: jnp.sum(jnp.square(xi - xb[None])), x_stack, x_bar)
    consensus_error = sum(jax.tree_util.tree_leaves(cons)) / m

    # --- inner error: sum_i ||y_i*(x_i) - y_i||^2  at the *current* x_i
    inner_batches = (data.inner_x, data.inner_y)

    def agent_inner_err(x_i, y_i, batch):
        y_star = solve_inner(problem, x_i, y_i, batch, inner_steps, inner_lr)
        return _tree_sq_norm(jax.tree_util.tree_map(
            lambda a, b: a - b, y_star, y_i))

    inner_error = jnp.sum(jax.vmap(agent_inner_err)(
        x_stack, y_stack, inner_batches))

    # --- stationarity: ||grad l(x_bar)||^2 with y* at x_bar per agent.
    def agent_hypergrad_at_bar(y_i, inner_b, outer_b):
        y_star = solve_inner(problem, x_bar, y_i, inner_b,
                             inner_steps, inner_lr)
        p = hypergradient(problem.outer, problem.inner, x_bar, y_star,
                          hg_cfg, f_args=(outer_b,), g_args=(inner_b,),
                          inner_hess_yy=problem.inner_hess_yy)
        f_val = problem.outer(x_bar, y_star, outer_b)
        return p, f_val

    outer_batches = (data.outer_x, data.outer_y)
    p_all, f_all = jax.vmap(agent_hypergrad_at_bar)(
        y_stack, inner_batches, outer_batches)
    grad_l = _tree_mean_over_agents(p_all)
    stationarity = _tree_sq_norm(grad_l)
    outer_loss = jnp.mean(f_all)

    total = stationarity + consensus_error + inner_error
    return MetricReport(total=total, stationarity=stationarity,
                        consensus_error=consensus_error,
                        inner_error=inner_error, outer_loss=outer_loss)


# -- ghost-masked metric (the padded sweep engine's counterpart) -----------
#
# The padded sweep (docs/SWEEPS.md) batches experiments whose agent count
# differs by ghost-padding every state/data tensor to a common m_pad;
# ghost agents must not contribute to M_t.  Beyond masking, the agent
# reductions here are *association-stable*: a sequential fold over the
# agent axis, so the sum over the active agents is built in exactly the
# same float association whatever m_pad is.  (jnp.mean/jnp.sum may pick
# a different reduction tree for different array sizes, which would
# break the bitwise padded-vs-unpadded trace contract even though the
# ghost terms are exact zeros.)


def _masked_agent_sum(tree, num_active):
    """Sequential masked sum over the leading agent axis of every leaf."""
    m_pad = jax.tree_util.tree_leaves(tree)[0].shape[0]

    def body(i, acc):
        take = jax.tree_util.tree_map(
            lambda l: jnp.where(i < num_active, l[i], jnp.zeros_like(l[i])),
            tree)
        return jax.tree_util.tree_map(jnp.add, acc, take)

    zero = jax.tree_util.tree_map(lambda l: jnp.zeros_like(l[0]), tree)
    return jax.lax.fori_loop(0, m_pad, body, zero)


def masked_convergence_metric(problem: BilevelProblem,
                              hg_cfg: HypergradConfig,
                              x_stack, y_stack, inner_steps: int,
                              inner_lr: float, data: AgentData,
                              num_active) -> MetricReport:
    """M_t over the first ``num_active`` agents of ghost-padded iterates.

    Semantics match ``convergence_metric`` with m = num_active: ghost
    rows (agent index >= num_active) are excluded from every average and
    sum.  ``num_active`` may be a traced scalar — the padded sweep
    engine vmaps it per experiment — while the padded agent count is
    static from the leaf shapes.  Per-agent work (inner solves,
    hypergradients) still runs on ghost rows (their padded data keeps it
    finite); only the cross-agent reductions mask, so the result is
    independent of whatever the ghosts drifted to.
    """
    x_bar_sum = _masked_agent_sum(x_stack, num_active)
    na = jnp.asarray(num_active,
                     jax.tree_util.tree_leaves(x_bar_sum)[0].dtype)
    x_bar = jax.tree_util.tree_map(lambda l: l / na, x_bar_sum)

    # --- consensus error: per-agent squared distances, masked sum / m
    def agent_cons(x_i):
        return _tree_sq_norm(jax.tree_util.tree_map(
            lambda a, b: a - b, x_i, x_bar))

    cons_vec = jax.vmap(agent_cons)(x_stack)
    consensus_error = _masked_agent_sum(cons_vec, num_active) / na

    # --- inner error: masked sum of per-agent ||y_i*(x_i) - y_i||^2
    inner_batches = (data.inner_x, data.inner_y)

    def agent_inner_err(x_i, y_i, batch):
        y_star = solve_inner(problem, x_i, y_i, batch, inner_steps, inner_lr)
        return _tree_sq_norm(jax.tree_util.tree_map(
            lambda a, b: a - b, y_star, y_i))

    inner_error = _masked_agent_sum(
        jax.vmap(agent_inner_err)(x_stack, y_stack, inner_batches),
        num_active)

    # --- stationarity: ||grad l(x_bar)||^2, the per-agent hypergradients
    # at x_bar averaged over active agents only.
    def agent_hypergrad_at_bar(y_i, inner_b, outer_b):
        y_star = solve_inner(problem, x_bar, y_i, inner_b,
                             inner_steps, inner_lr)
        p = hypergradient(problem.outer, problem.inner, x_bar, y_star,
                          hg_cfg, f_args=(outer_b,), g_args=(inner_b,),
                          inner_hess_yy=problem.inner_hess_yy)
        f_val = problem.outer(x_bar, y_star, outer_b)
        return p, f_val

    outer_batches = (data.outer_x, data.outer_y)
    p_all, f_all = jax.vmap(agent_hypergrad_at_bar)(
        y_stack, inner_batches, outer_batches)
    grad_l = jax.tree_util.tree_map(
        lambda l: l / na, _masked_agent_sum(p_all, num_active))
    stationarity = _tree_sq_norm(grad_l)
    outer_loss = _masked_agent_sum(f_all, num_active) / na

    total = stationarity + consensus_error + inner_error
    return MetricReport(total=total, stationarity=stationarity,
                        consensus_error=consensus_error,
                        inner_error=inner_error, outer_loss=outer_loss)


def masked_convergence_metric_fn(problem: BilevelProblem,
                                 hg_cfg: HypergradConfig,
                                 inner_steps: int = 300,
                                 inner_lr: float = 0.5):
    """Traceable ``(state, data, num_active) -> M_t`` for padded sweeps.

    Unlike ``convergence_metric_fn`` the data is an argument, not a
    closure constant: the padded sweep engine maps per-experiment padded
    datasets and active-agent counts as vmap operands.  Within one
    padded group, call it as ``lambda st: fn(st, data, num_active)``
    with the traced operands closed over (repro.solvers.sweep does).
    """

    def metric(state, data: AgentData, num_active):
        rep = masked_convergence_metric(problem, hg_cfg, state.x, state.y,
                                        inner_steps, inner_lr, data,
                                        num_active)
        return rep.total

    return metric


def convergence_metric_fn(problem: BilevelProblem, hg_cfg: HypergradConfig,
                          data: AgentData, inner_steps: int = 300,
                          inner_lr: float = 0.5):
    """A traceable ``state -> M_t`` closure for in-scan recording.

    ``convergence_metric`` itself is jitted and typically called eagerly
    (state in, Python float out) — that forces a host round-trip per
    record point.  The closure returned here stays abstract: it reads
    ``state.x`` / ``state.y`` and returns the scalar ``M_t`` as a traced
    value, so it can run inside ``lax.scan`` / ``lax.cond`` bodies
    (``Solver.run_traced``) and under ``jax.vmap`` (the sweep engine)
    while reusing the same hypergradient engine as the eager path —
    values are identical, only the dispatch boundary moves.

    The closure is a stable object: pass the *same* instance to repeated
    ``run_traced`` calls (it is a static jit argument there).
    """

    def metric(state):
        rep = convergence_metric(problem, hg_cfg, state.x, state.y,
                                 inner_steps, inner_lr, data)
        return rep.total

    return metric
