"""The paper's convergence metric (eqs. 2 / 11) and its ingredients.

    M_t = ||grad l(x_bar)||^2            (stationarity of the average)
        + (1/m) sum_i ||x_i - x_bar||^2  (consensus error)
        + ||y* - y||^2                   (inner error, aggregated)

Evaluating grad l(x_bar) = grad_bar f(x_bar, y*(x_bar)) requires the inner
optimum; we compute y*(x) by running the strongly-convex inner problem to
tolerance with gradient descent (exact up to solver precision — this is an
*evaluation-only* cost, not part of any algorithm's sample complexity).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bilevel import AgentData, BilevelProblem
from repro.hypergrad import HypergradConfig, hypergradient

__all__ = ["MetricReport", "solve_inner", "convergence_metric",
           "convergence_metric_fn"]


class MetricReport(NamedTuple):
    total: jax.Array
    stationarity: jax.Array
    consensus_error: jax.Array
    inner_error: jax.Array
    outer_loss: jax.Array


def _tree_sq_norm(tree) -> jax.Array:
    return sum(jnp.sum(jnp.square(l)) for l in jax.tree_util.tree_leaves(tree))


def _tree_mean_over_agents(tree):
    return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), tree)


def solve_inner(problem: BilevelProblem, x, y0, batch,
                steps: int = 400, lr: float = 0.5):
    """y*(x) via GD on the strongly-convex inner problem (single agent)."""
    grad_g = jax.grad(problem.inner, argnums=1)

    def body(_, y):
        g = grad_g(x, y, batch)
        return jax.tree_util.tree_map(lambda yi, gi: yi - lr * gi, y, g)

    return jax.lax.fori_loop(0, steps, body, y0)


@partial(jax.jit, static_argnums=(0, 1, 4, 5))
def convergence_metric(problem: BilevelProblem, hg_cfg: HypergradConfig,
                       x_stack, y_stack, inner_steps: int, inner_lr: float,
                       data: AgentData) -> MetricReport:
    """Compute M_t for stacked per-agent iterates (leading axis m)."""
    m = jax.tree_util.tree_leaves(x_stack)[0].shape[0]
    x_bar = _tree_mean_over_agents(x_stack)

    # --- consensus error: (1/m) sum_i ||x_i - x_bar||^2
    cons = jax.tree_util.tree_map(
        lambda xi, xb: jnp.sum(jnp.square(xi - xb[None])), x_stack, x_bar)
    consensus_error = sum(jax.tree_util.tree_leaves(cons)) / m

    # --- inner error: sum_i ||y_i*(x_i) - y_i||^2  at the *current* x_i
    inner_batches = (data.inner_x, data.inner_y)

    def agent_inner_err(x_i, y_i, batch):
        y_star = solve_inner(problem, x_i, y_i, batch, inner_steps, inner_lr)
        return _tree_sq_norm(jax.tree_util.tree_map(
            lambda a, b: a - b, y_star, y_i))

    inner_error = jnp.sum(jax.vmap(agent_inner_err)(
        x_stack, y_stack, inner_batches))

    # --- stationarity: ||grad l(x_bar)||^2 with y* at x_bar per agent.
    def agent_hypergrad_at_bar(y_i, inner_b, outer_b):
        y_star = solve_inner(problem, x_bar, y_i, inner_b,
                             inner_steps, inner_lr)
        p = hypergradient(problem.outer, problem.inner, x_bar, y_star,
                          hg_cfg, f_args=(outer_b,), g_args=(inner_b,),
                          inner_hess_yy=problem.inner_hess_yy)
        f_val = problem.outer(x_bar, y_star, outer_b)
        return p, f_val

    outer_batches = (data.outer_x, data.outer_y)
    p_all, f_all = jax.vmap(agent_hypergrad_at_bar)(
        y_stack, inner_batches, outer_batches)
    grad_l = _tree_mean_over_agents(p_all)
    stationarity = _tree_sq_norm(grad_l)
    outer_loss = jnp.mean(f_all)

    total = stationarity + consensus_error + inner_error
    return MetricReport(total=total, stationarity=stationarity,
                        consensus_error=consensus_error,
                        inner_error=inner_error, outer_loss=outer_loss)


def convergence_metric_fn(problem: BilevelProblem, hg_cfg: HypergradConfig,
                          data: AgentData, inner_steps: int = 300,
                          inner_lr: float = 0.5):
    """A traceable ``state -> M_t`` closure for in-scan recording.

    ``convergence_metric`` itself is jitted and typically called eagerly
    (state in, Python float out) — that forces a host round-trip per
    record point.  The closure returned here stays abstract: it reads
    ``state.x`` / ``state.y`` and returns the scalar ``M_t`` as a traced
    value, so it can run inside ``lax.scan`` / ``lax.cond`` bodies
    (``Solver.run_traced``) and under ``jax.vmap`` (the sweep engine)
    while reusing the same hypergradient engine as the eager path —
    values are identical, only the dispatch boundary moves.

    The closure is a stable object: pass the *same* instance to repeated
    ``run_traced`` calls (it is a static jit argument there).
    """

    def metric(state):
        rep = convergence_metric(problem, hg_cfg, state.x, state.y,
                                 inner_steps, inner_lr, data)
        return rep.total

    return metric
