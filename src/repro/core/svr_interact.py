"""SVR-INTERACT (Algorithm 2): variance-reduced INTERACT.

Identical consensus + tracking skeleton as Algorithm 1, but the local
gradients are SPIDER/SARAH-style recursive estimators refreshed with a
full-gradient pass every q iterations:

  mod(t, q) == 0:  p_t = grad_bar f(x_t, y_t)          (full, eqs. 8-9)
  otherwise:       p_t = p_{t-1} + (1/|S|) sum_xi [grad_bar f(x_t; xi)
                                 - grad_bar f(x_{t-1}; xi)]      (23)
                   d_t analogous for grad_y g                    (24)

with the K-term stochastic Neumann hypergradient of eq. (22) on minibatch
samples.  The paper sets |S| = q = ceil(sqrt(n)) which yields the
O(sqrt(n) eps^-1) sample complexity of Corollary 4.

Quickstart (the unified Solver API, see docs/SOLVERS.md)::

    from repro.solvers import SolverConfig, make_solver
    solver = make_solver(SolverConfig(algo="svr-interact", q=25))
    state = solver.init(None, problem, hg_cfg, x0, y0, data)
    state = solver.run(state, data, 100)   # scan-compiled

``make_svr_interact_step`` remains as a deprecated shim over that path.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.consensus import consensus_descent_and_track, init_ef
from repro.core.bilevel import AgentData, BilevelProblem
from repro.core.consensus import MixingSpec
from repro.hypergrad import HypergradConfig, hypergradient

__all__ = ["SvrState", "init_svr_state", "per_agent_keys",
           "svr_interact_step", "make_svr_interact_step"]


def per_agent_keys(key: jax.Array, m: int) -> jax.Array:
    """Agent i's sampling key as ``fold_in(key, i)`` — stacked (m, 2).

    Unlike ``jax.random.split(key, m)``, whose i-th output depends on m,
    ``fold_in`` keys depend only on the agent index: agent i draws the
    same stream whether the state carries m or a ghost-padded m' > m
    agents.  Every stochastic algorithm derives its per-agent keys here,
    which is what keeps active-agent trajectories bitwise invariant
    under the sweep engine's agent padding (docs/SWEEPS.md).
    """
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(m))


class SvrState(NamedTuple):
    x: object
    y: object
    u: object        # tracked gradient
    v: object        # inner-gradient estimator d_t
    p_prev: object   # previous outer estimator p_{t-1}
    x_prev: object   # previous iterates (needed by the recursive estimator)
    y_prev: object
    t: jax.Array
    key: jax.Array
    ef: object = None  # error-feedback residuals {"x", "u"} (compressed wire)
    guard: object = None  # divergence-guard counters {"tripped", "last_good"}


def _sample_batch(key, data_x, data_y, batch_size):
    idx = jax.random.randint(key, (batch_size,), 0, data_x.shape[0])
    return data_x[idx], data_y[idx]


def _full_grads(problem, hg_cfg, x, y, data: AgentData, key):
    inner_b = (data.inner_x, data.inner_y)
    outer_b = (data.outer_x, data.outer_y)
    p = hypergradient(problem.outer, problem.inner, x, y, hg_cfg,
                      f_args=(outer_b,), g_args=(inner_b,), key=key,
                      inner_hess_yy=problem.inner_hess_yy)
    v = jax.grad(problem.inner, argnums=1)(x, y, inner_b)
    return p, v


def _minibatch_grads(problem, hg_cfg, x, y, data: AgentData, key, batch_size):
    k_in, k_out, k_neu = jax.random.split(key, 3)
    inner_b = _sample_batch(k_in, data.inner_x, data.inner_y, batch_size)
    outer_b = _sample_batch(k_out, data.outer_x, data.outer_y, batch_size)
    p = hypergradient(problem.outer, problem.inner, x, y, hg_cfg,
                      f_args=(outer_b,), g_args=(inner_b,), key=k_neu,
                      inner_hess_yy=problem.inner_hess_yy)
    v = jax.grad(problem.inner, argnums=1)(x, y, inner_b)
    return p, v


def init_svr_state(problem: BilevelProblem, hg_cfg: HypergradConfig,
                   x0, y0, data: AgentData, key: jax.Array,
                   compression=None, guard=None) -> SvrState:
    m = data.inner_x.shape[0]
    bcast = lambda tree: jax.tree_util.tree_map(
        lambda leaf: jnp.broadcast_to(leaf, (m,) + leaf.shape), tree)
    x, y = bcast(x0), bcast(y0)
    # 2-way split + fold_in: the state key and every agent key are
    # independent of m, so ghost-padded inits replay the active agents'
    # streams exactly (see per_agent_keys).
    k_state, k_agents = jax.random.split(key)
    p, v = jax.vmap(partial(_full_grads, problem, hg_cfg))(
        x, y, data, per_agent_keys(k_agents, m))
    # copies: no two state leaves may alias one buffer (step donation)
    copy = lambda tree: jax.tree_util.tree_map(jnp.array, tree)
    return SvrState(x=x, y=y, u=p, v=v, p_prev=copy(p), x_prev=copy(x),
                    y_prev=copy(y), t=jnp.zeros((), jnp.int32), key=k_state,
                    ef=init_ef(compression, x=x, u=p), guard=guard)


def svr_interact_step(
    problem: BilevelProblem,
    hg_cfg: HypergradConfig,
    engine,
    alpha: float,
    beta: float,
    q: int,
    batch_size: int,
    state: SvrState,
    data: AgentData,
) -> SvrState:
    """One SVR-INTERACT iteration (raw body over a built engine).

    Consensus Steps 1/3 run through the shared step-core; only Step 2
    (the SPIDER estimator, full refresh every q steps) differs from
    Algorithm 1.
    """
    bs = batch_size

    def _vr_grads(x, y, x_prev, y_prev, v_prev, p_prev, data, key):
        """Per-agent recursive estimators (23)-(24) at minibatch bs."""
        k1, k2 = jax.random.split(key)
        p_now, v_now = _minibatch_grads(problem, hg_cfg, x, y, data, k1, bs)
        # Same samples evaluated at the previous iterate: reuse the key so
        # xi is common to both terms (correlated difference, eq. 23-24).
        p_old, v_old = _minibatch_grads(problem, hg_cfg, x_prev, y_prev,
                                        data, k1, bs)
        p = jax.tree_util.tree_map(lambda a, b, c: a + b - c,
                                   p_prev, p_now, p_old)
        v = jax.tree_util.tree_map(lambda a, b, c: a + b - c,
                                   v_prev, v_now, v_old)
        return p, v

    m = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    key, k_step = jax.random.split(state.key)
    agent_keys = per_agent_keys(k_step, m)

    def grads_fn(x_new, y_new):
        # Step 2: full refresh every q steps, recursive otherwise.
        full_p, full_v = jax.vmap(partial(_full_grads, problem, hg_cfg))(
            x_new, y_new, data, agent_keys)
        vr_p, vr_v = jax.vmap(_vr_grads)(
            x_new, y_new, state.x, state.y, state.v, state.p_prev,
            data, agent_keys)
        refresh = (state.t + 1) % q == 0
        pick = lambda a, b: jax.tree_util.tree_map(
            lambda ai, bi: jnp.where(refresh, ai, bi), a, b)
        return pick(full_p, vr_p), pick(full_v, vr_v), None

    x_new, y_new, u_new, v_new, p_new, ef_new, _ = (
        consensus_descent_and_track(
            engine, state.x, state.y, state.u, state.v, state.p_prev,
            alpha, beta, grads_fn, t=state.t, ef=state.ef))

    return SvrState(x=x_new, y=y_new, u=u_new, v=v_new, p_prev=p_new,
                    x_prev=state.x, y_prev=state.y,
                    t=state.t + 1, key=key, ef=ef_new, guard=state.guard)


def make_svr_interact_step(
    problem: BilevelProblem,
    hg_cfg: HypergradConfig,
    mixing: MixingSpec,
    alpha: float,
    beta: float,
    q: int,
    batch_size: int | None = None,
    backend: str = "dense",
    **backend_opts,
):
    """Deprecated shim: use ``repro.solvers.make_solver`` instead.

    Returns the registry solver's jitted step closure (state donated),
    preserving the legacy signature.  batch_size defaults to q (|S| = q).
    """
    warnings.warn(
        "make_svr_interact_step is deprecated; use repro.solvers."
        "make_solver(SolverConfig(algo='svr-interact', ...))",
        DeprecationWarning, stacklevel=2)
    from repro.solvers import SolverConfig, make_solver
    cfg = SolverConfig(algo="svr-interact", alpha=alpha, beta=beta, q=q,
                       batch_size=batch_size, mixing=mixing,
                       backend=backend, backend_opts=backend_opts)
    return make_solver(cfg).build(problem, hg_cfg).step
