"""Model builder: ArchConfig -> init / features / logits / decode.

Layer stacks are executed as ``lax.scan`` over *periods* (see base.py) with
parameters stacked on a leading period axis — one period may contain
several heterogeneous layers (jamba: 1 attention + 7 mamba).  This keeps
HLO size O(period) instead of O(num_layers) and is what makes 72-layer
dry-runs compile in reasonable time.

Bilevel split: ``features()`` returns final hidden states produced by the
*backbone* (the outer variable x of the paper); the LM head is a separate
parameter (the inner variable y_i, per-agent).  ``init_head`` /
``head_logits`` implement that readout.  For non-bilevel use,
``init_params`` can include a head and ``forward`` goes end to end.

VLM / audio frontends are stubs per the assignment: ``prefix_embed``
(precomputed patch/frame embeddings) is projected and prepended to the
token embeddings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.base import ArchConfig, LayerSpec
from repro.models import layers as L
from repro.models import mamba as Mb
from repro.models import moe as Moe
from repro.models import rwkv as Rk

__all__ = [
    "init_params", "init_head", "features", "head_logits", "forward",
    "lm_loss", "init_cache", "decode_step", "prefill", "param_count",
]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, spec: LayerSpec, key) -> dict:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 4)
    p: dict[str, Any] = {"pre_norm": L.init_rms_norm(cfg.d_model, dt),
                         "post_norm": L.init_rms_norm(cfg.d_model, dt)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(
            keys[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.resolved_head_dim, cfg.qk_norm, dt)
    elif spec.mixer == "mamba":
        p["mamba"] = Mb.init_mamba(keys[0], cfg.d_model, cfg.mamba_d_state,
                                   cfg.mamba_d_conv, cfg.mamba_expand, dt)
    elif spec.mixer == "rwkv":
        p["rwkv"] = Rk.init_rwkv_block(keys[0], cfg.d_model,
                                       cfg.rwkv_head_size, dt, cfg.d_ff)
    if spec.ffn == "dense" and spec.mixer != "rwkv":
        p["mlp"] = L.init_mlp(keys[1], cfg.d_model, cfg.d_ff, dt)
    elif spec.ffn == "moe":
        p["moe"] = Moe.init_moe(keys[1], cfg.d_model, cfg.d_ff,
                                cfg.num_experts, dt)
    return p


def init_params(cfg: ArchConfig, key, with_head: bool = False) -> dict:
    """Backbone parameters; period params stacked on leading axis."""
    cfg.validate()
    dt = _dtype(cfg)
    pattern = cfg.layer_pattern()
    n_periods = cfg.num_periods()
    k_embed, k_layers, k_head, k_front = jax.random.split(key, 4)

    params: dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model))
                  * (1.0 / jnp.sqrt(cfg.d_model))).astype(dt),
        "final_norm": L.init_rms_norm(cfg.d_model, dt),
    }

    def init_period(k):
        ks = jax.random.split(k, len(pattern))
        return [
            _init_layer(cfg, spec, ks[i]) for i, spec in enumerate(pattern)
        ]

    period_keys = jax.random.split(k_layers, n_periods)
    stacked = jax.vmap(init_period)(period_keys)
    params["layers"] = stacked

    if cfg.frontend != "none" and cfg.num_prefix_tokens:
        fd = cfg.frontend_dim or cfg.d_model
        params["frontend_proj"] = (
            jax.random.normal(k_front, (fd, cfg.d_model))
            * (1.0 / jnp.sqrt(fd))).astype(dt)
    if with_head:
        params["head"] = init_head(cfg, k_head)
    return params


def init_head(cfg: ArchConfig, key) -> jax.Array:
    """The inner-variable readout head y (d_model, vocab)."""
    return (jax.random.normal(key, (cfg.d_model, cfg.vocab_size))
            * (1.0 / jnp.sqrt(cfg.d_model))).astype(_dtype(cfg))


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------

def _apply_layer(cfg: ArchConfig, spec: LayerSpec, p: dict, x: jax.Array,
                 positions: jax.Array, impl: str,
                 cache: dict | None = None,
                 moe_impl: str = "capacity") -> tuple[jax.Array, dict | None, jax.Array]:
    """Pre-norm residual layer. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), dtype=jnp.float32)
    h = L.rms_norm(p["pre_norm"], x, cfg.norm_eps)
    new_cache = cache
    if spec.mixer == "attn":
        window = spec.sliding_window
        if cfg.long_context_mode == "window" and window is None:
            window = cfg.local_window
        out, new_attn = L.attention(
            p["attn"], h, positions,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            window=window, logit_softcap=cfg.attn_logit_softcap,
            qk_norm=cfg.qk_norm, norm_eps=cfg.norm_eps,
            cache=None if cache is None else cache["attn"], impl=impl)
        if cache is not None:
            new_cache = {**cache, "attn": new_attn}
    elif spec.mixer == "mamba":
        if cache is None:
            out = Mb.mamba_block(p["mamba"], h,
                                 seq_chunk=cfg.mamba_seq_chunk or None)
        elif h.shape[1] > 1:  # prefill into a fresh cache
            out, st = Mb.mamba_prefill(p["mamba"], h)
            new_cache = {**cache, "mamba": st}
        else:
            out, st = Mb.mamba_decode_step(p["mamba"], h, cache["mamba"])
            new_cache = {**cache, "mamba": st}
    elif spec.mixer == "rwkv":
        if cache is None:
            out, _, _ = Rk.rwkv_time_mix(p["rwkv"], h, cfg.rwkv_head_size,
                                         impl=impl)
        else:
            out, st = Rk.rwkv_time_mix_decode(p["rwkv"], h,
                                              cfg.rwkv_head_size,
                                              cache["rwkv"])
            new_cache = {**cache, "rwkv": st}
    else:
        raise ValueError(spec.mixer)
    x = x + out

    h = L.rms_norm(p["post_norm"], x, cfg.norm_eps)
    if spec.mixer == "rwkv" and spec.ffn == "dense":
        # RWKV uses its own token-shifted channel mix as the FFN.
        if cache is None:
            out, _ = Rk.rwkv_channel_mix(p["rwkv"], h)
        else:
            out, cm_last = Rk.rwkv_channel_mix(
                p["rwkv"], h, x_last=new_cache["rwkv"]["cm_last"].astype(h.dtype))
            new_cache = {**new_cache,
                         "rwkv": {**new_cache["rwkv"],
                                  "cm_last": cm_last.astype(jnp.float32)}}
        return x + out, new_cache, aux
    if spec.ffn == "dense":
        out = L.gated_mlp(p["mlp"], h)
    elif spec.ffn == "moe":
        if moe_impl == "exact":
            out, aux = Moe.moe_ffn_exact(p["moe"], h,
                                         num_experts=cfg.num_experts,
                                         top_k=cfg.experts_per_token)
        else:
            out, aux = Moe.moe_ffn(p["moe"], h, num_experts=cfg.num_experts,
                                   top_k=cfg.experts_per_token,
                                   capacity_factor=cfg.capacity_factor,
                                   token_chunk=cfg.moe_token_chunk or None,
                                   expert_parallel=cfg.expert_parallel)
    else:
        out = jnp.zeros_like(h)
    return x + out, new_cache, aux


def _embed_inputs(cfg: ArchConfig, params: dict, tokens: jax.Array,
                  prefix_embed: jax.Array | None) -> jax.Array:
    x = params["embed"][tokens] * jnp.sqrt(float(cfg.d_model)).astype(_dtype(cfg))
    if prefix_embed is not None:
        proj = params.get("frontend_proj")
        pre = prefix_embed.astype(x.dtype)
        if proj is not None:
            pre = pre @ proj
        x = jnp.concatenate([pre, x], axis=1)
    return x


def features(cfg: ArchConfig, params: dict, tokens: jax.Array,
             prefix_embed: jax.Array | None = None,
             impl: str = "reference", remat: bool = True,
             moe_impl: str = "capacity",
             act_spec=None, scan_layers: bool = True
             ) -> tuple[jax.Array, jax.Array]:
    """Backbone features: (batch, seq[, +prefix], d_model), plus MoE aux loss.

    ``act_spec``: optional PartitionSpec applied to the residual stream at
    every period boundary (sequence parallelism — perf iteration P4): the
    tensors *saved for backward* live sequence-sharded over the model
    axis; XLA gathers heads/kv only where attention needs them.

    ``scan_layers=False`` unrolls the period loop as Python — required
    inside partially-manual shard_map bodies on old-JAX stacks, whose
    partitioner cannot shard a while-loop over manual subgroups (see
    repro/sharding/compat.PARTIAL_AUTO_COLLECTIVES_SAFE).
    """
    x = _embed_inputs(cfg, params, tokens, prefix_embed)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    pattern = cfg.layer_pattern()

    def constrain(h):
        if act_spec is None:
            return h
        return jax.lax.with_sharding_constraint(h, act_spec)

    def period_body(carry, period_params):
        h, aux = carry
        for i, spec in enumerate(pattern):
            h, _, a = _apply_layer(cfg, spec, period_params[i], h,
                                   positions, impl, moe_impl=moe_impl)
            aux = aux + a
        return (constrain(h), aux), None

    body = jax.checkpoint(period_body) if remat else period_body
    carry0 = (constrain(x), jnp.zeros((), jnp.float32))
    if scan_layers:
        (x, aux), _ = jax.lax.scan(body, carry0, params["layers"])
    else:
        carry = carry0
        n_periods = jax.tree_util.tree_leaves(
            params["layers"])[0].shape[0]
        for i in range(n_periods):
            carry, _ = body(carry, jax.tree_util.tree_map(
                lambda l: l[i], params["layers"]))
        x, aux = carry
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, aux


def head_logits(cfg: ArchConfig, head: jax.Array, feats: jax.Array) -> jax.Array:
    logits = feats @ head
    return L.softcap(logits, cfg.final_logit_softcap)


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array,
            prefix_embed: jax.Array | None = None,
            impl: str = "reference", remat: bool = True,
            moe_impl: str = "capacity") -> tuple[jax.Array, jax.Array]:
    feats, aux = features(cfg, params, tokens, prefix_embed, impl, remat,
                          moe_impl)
    head = params["head"] if "head" in params else params["embed"].T
    return head_logits(cfg, head, feats), aux


def lm_loss(cfg: ArchConfig, logits: jax.Array, labels: jax.Array,
            aux: jax.Array | None = None) -> jax.Array:
    """Next-token CE; labels aligned with the *token* part of the sequence."""
    n_pre = logits.shape[1] - labels.shape[1]
    logits = logits[:, n_pre:, :]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp[:, :-1], labels[:, 1:, None], axis=-1)
    loss = jnp.mean(nll)
    if aux is not None:
        loss = loss + cfg.router_aux_weight * aux
    return loss


def param_count(params) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch: int,
                      max_len: int) -> dict:
    dt = _dtype(cfg)
    cache: dict[str, Any] = {}
    if spec.mixer == "attn":
        window = spec.sliding_window
        if cfg.long_context_mode == "window" and window is None:
            window = cfg.local_window
        # SWA layers only ever need `window` cache slots; full layers need
        # the whole sequence.  Bounded caches are what keep mixtral/gemma2
        # long_500k sub-quadratic in memory.
        size = max_len if window is None else min(max_len, window)
        cache["attn"] = {
            "k": jnp.zeros((batch, size, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype=dt),
            "v": jnp.zeros((batch, size, cfg.num_kv_heads,
                            cfg.resolved_head_dim), dtype=dt),
            "len": jnp.zeros((), jnp.int32),
        }
    elif spec.mixer == "mamba":
        cache["mamba"] = Mb.init_mamba_state(
            batch, cfg.d_model, cfg.mamba_d_state, cfg.mamba_d_conv,
            cfg.mamba_expand, dt)
    elif spec.mixer == "rwkv":
        cache["rwkv"] = Rk.init_rwkv_state(batch, cfg.d_model,
                                           cfg.rwkv_head_size)
    return cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> list:
    """Per-period-position caches stacked over periods (scan-compatible)."""
    pattern = cfg.layer_pattern()
    n = cfg.num_periods()

    def one_period(_):
        return [_init_layer_cache(cfg, spec, batch, max_len)
                for spec in pattern]

    return jax.vmap(one_period)(jnp.arange(n))


def prefill(cfg: ArchConfig, params: dict, head: jax.Array | None,
            tokens: jax.Array, cache,
            impl: str = "reference") -> tuple[jax.Array, Any]:
    """Fused prefill: full-sequence forward that POPULATES a fresh decode
    cache (KV ring buffers laid out for continuation, SSM states at the
    last token).  Returns (last-token logits (batch, vocab), cache)."""
    s = tokens.shape[1]
    logits, new_cache = decode_step(
        cfg, params, head, tokens, cache,
        jnp.arange(s, dtype=jnp.int32), impl=impl)
    return logits[:, -1, :], new_cache


def decode_step(cfg: ArchConfig, params: dict, head: jax.Array | None,
                token: jax.Array, cache, position: jax.Array,
                impl: str = "reference") -> tuple[jax.Array, Any]:
    """One-token decode.  token: (batch, 1) int32; position: scalar int32
    (or an (s,) position vector for the fused-prefill path).

    Returns (logits (batch, s, vocab), new_cache).
    """
    x = params["embed"][token] * jnp.sqrt(float(cfg.d_model)).astype(_dtype(cfg))
    positions = position[None] if position.ndim == 0 else position
    pattern = cfg.layer_pattern()

    def period_body(h, scanned):
        period_params, period_cache = scanned
        new_caches = []
        for i, spec in enumerate(pattern):
            h, nc, _ = _apply_layer(cfg, spec, period_params[i], h,
                                    positions, impl, cache=period_cache[i],
                                    moe_impl="exact")
            new_caches.append(nc)
        return h, new_caches

    x, new_cache = jax.lax.scan(period_body, x, (params["layers"], cache))
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    if head is None:
        head = params["head"] if "head" in params else params["embed"].T
    return head_logits(cfg, head, x), new_cache
