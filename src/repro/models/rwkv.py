"""RWKV-6 "Finch" block: attention-free time mixing with data-dependent decay.

Per head (size N), with receptance r_t, key k_t, value v_t, decay w_t
(all input-dependent in RWKV-6) and a learned bonus u:

    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T

The reference path below is a ``lax.scan`` over time; the Pallas kernel in
``repro/kernels/rwkv6`` implements the chunked formulation and is verified
against ``wkv6_ref``.  Decode carries the (heads, N, N) state — O(1) per
token, which is why rwkv6 runs long_500k.

Channel mixing is the RWKV variant of a gated MLP with token shift.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "init_rwkv_block", "rwkv_time_mix", "rwkv_channel_mix",
    "wkv6_ref", "init_rwkv_state", "rwkv_time_mix_decode",
]


def init_rwkv_block(key, d_model: int, head_size: int, dtype,
                    d_ff: int | None = None) -> dict:
    assert d_model % head_size == 0
    d_ff = d_ff or 4 * d_model
    keys = jax.random.split(key, 10)
    s = 1.0 / jnp.sqrt(d_model)
    num_heads = d_model // head_size
    return {
        # time mixing
        "w_r": (jax.random.normal(keys[0], (d_model, d_model)) * s).astype(dtype),
        "w_k": (jax.random.normal(keys[1], (d_model, d_model)) * s).astype(dtype),
        "w_v": (jax.random.normal(keys[2], (d_model, d_model)) * s).astype(dtype),
        "w_g": (jax.random.normal(keys[3], (d_model, d_model)) * s).astype(dtype),
        "w_decay": (jax.random.normal(keys[4], (d_model, d_model)) * 0.1 * s).astype(dtype),
        "decay_bias": jnp.full((d_model,), -5.0, dtype=dtype),
        "bonus_u": (0.5 * jax.random.normal(keys[5], (num_heads, head_size))).astype(dtype),
        "mix_coeff": (0.5 * jnp.ones((5, d_model))).astype(dtype),
        "w_out_t": (jax.random.normal(keys[6], (d_model, d_model)) * s).astype(dtype),
        "ln_x_scale": jnp.ones((d_model,), dtype=dtype),
        # channel mixing
        "cm_wk": (jax.random.normal(keys[7], (d_model, d_ff)) * s).astype(dtype),
        "cm_wv": (jax.random.normal(keys[8], (d_ff, d_model)) * 0.5 * s).astype(dtype),
        "cm_wr": (jax.random.normal(keys[9], (d_model, d_model)) * s).astype(dtype),
        "cm_mix": (0.5 * jnp.ones((2, d_model))).astype(dtype),
    }


def _token_shift(x: jax.Array, last: jax.Array | None = None) -> jax.Array:
    """Shift sequence right by one; ``last`` supplies the carry for decode."""
    if last is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([last[:, None, :], x[:, :-1]], axis=1) if x.shape[1] > 1 \
        else last[:, None, :]


def wkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
             u: jax.Array, state: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Sequential WKV6 oracle.

    r,k,v,w: (batch, seq, heads, N); u: (heads, N);
    state: (batch, heads, N, N) [k-major: state[b,h,i,j] = sum decay * k_i v_j].
    Returns (out: (batch, seq, heads, N), final_state).
    """
    b, s, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), dtype=jnp.float32)
    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    w32 = w.astype(jnp.float32)
    u32 = u.astype(jnp.float32)

    def step(carry, ts):
        st = carry
        rt, kt, vt, wt = ts  # (b, h, n)
        kv = kt[..., :, None] * vt[..., None, :]          # (b, h, n, n)
        att = st + u32[None, :, :, None] * kv             # bonus on current
        ot = jnp.einsum("bhn,bhnm->bhm", rt, att)
        st = wt[..., :, None] * st + kv
        return st, ot

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r32, k32, v32, w32))
    final, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), final


def rwkv_time_mix(params: dict, x: jax.Array, head_size: int,
                  state: jax.Array | None = None, x_last: jax.Array | None = None,
                  impl: str = "reference"
                  ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (out, final_wkv_state, last_token) for chaining decode."""
    b, s, d = x.shape
    h = d // head_size
    shifted = _token_shift(x, x_last)
    mix = params["mix_coeff"]
    xr = x * mix[0] + shifted * (1 - mix[0])
    xk = x * mix[1] + shifted * (1 - mix[1])
    xv = x * mix[2] + shifted * (1 - mix[2])
    xg = x * mix[3] + shifted * (1 - mix[3])
    xw = x * mix[4] + shifted * (1 - mix[4])

    r = (xr @ params["w_r"]).reshape(b, s, h, head_size)
    k = (xk @ params["w_k"]).reshape(b, s, h, head_size)
    v = (xv @ params["w_v"]).reshape(b, s, h, head_size)
    g = jax.nn.silu(xg @ params["w_g"])
    # data-dependent decay in (0, 1):  w = exp(-exp(decay))
    decay = params["decay_bias"] + xw @ params["w_decay"]
    w = jnp.exp(-jnp.exp(decay.astype(jnp.float32))).reshape(b, s, h, head_size)

    if impl == "pallas":
        from repro.kernels.rwkv6 import ops as wkv_ops
        out, final = wkv_ops.wkv6(r, k, v, w.astype(r.dtype),
                                  params["bonus_u"], state)
    else:
        out, final = wkv6_ref(r, k, v, w.astype(r.dtype), params["bonus_u"],
                              state)
    out = out.reshape(b, s, d)
    # group-norm over heads (ln_x in the reference implementation)
    out = out.reshape(b, s, h, head_size)
    mu = out.mean(-1, keepdims=True)
    var = out.var(-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(b, s, d)
    out = out * params["ln_x_scale"] * g
    return out @ params["w_out_t"], final, x[:, -1, :]


def rwkv_channel_mix(params: dict, x: jax.Array,
                     x_last: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    shifted = _token_shift(x, x_last)
    mix = params["cm_mix"]
    xk = x * mix[0] + shifted * (1 - mix[0])
    xr = x * mix[1] + shifted * (1 - mix[1])
    k = jnp.square(jax.nn.relu(xk @ params["cm_wk"]))
    kv = k @ params["cm_wv"]
    return jax.nn.sigmoid(xr @ params["cm_wr"]) * kv, x[:, -1, :]


def init_rwkv_state(batch: int, d_model: int, head_size: int) -> dict:
    h = d_model // head_size
    return {
        "wkv": jnp.zeros((batch, h, head_size, head_size), dtype=jnp.float32),
        "tm_last": jnp.zeros((batch, d_model), dtype=jnp.float32),
        "cm_last": jnp.zeros((batch, d_model), dtype=jnp.float32),
    }


def rwkv_time_mix_decode(params: dict, x: jax.Array, head_size: int,
                         state: dict) -> tuple[jax.Array, dict]:
    """Single-token decode; x: (batch, 1, d)."""
    out, wkv, last = rwkv_time_mix(
        params, x, head_size, state=state["wkv"],
        x_last=state["tm_last"].astype(x.dtype))
    return out, {**state, "wkv": wkv, "tm_last": last.astype(jnp.float32)}
