"""Model substrate."""
