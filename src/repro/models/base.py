"""Architecture configuration schema.

Every assigned architecture is expressed as an ``ArchConfig``; the model
builder (``repro/models/model.py``) consumes only this schema, so adding an
architecture is a config file, not code.

Layers are organised into repeating *periods* so heterogeneous stacks
(jamba's 1:7 attention:mamba interleave, gemma2's local/global alternation)
lower as a single ``lax.scan`` over stacked period parameters — essential to
keep HLO size and compile time bounded at 40-72 layers.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

__all__ = ["LayerSpec", "ArchConfig"]

Mixer = Literal["attn", "mamba", "rwkv"]
Ffn = Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period."""

    mixer: Mixer = "attn"
    ffn: Ffn = "dense"
    sliding_window: int | None = None  # None = full/global attention


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # --- identity -----------------------------------------------------
    name: str = "unnamed"
    family: Literal["dense", "moe", "hybrid", "ssm", "vlm", "audio"] = "dense"
    source: str = ""  # citation (arXiv id / model card), from the pool

    # --- trunk dimensions ----------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    d_ff: int = 1024
    vocab_size: int = 1024

    # --- attention ------------------------------------------------------
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int | None = None  # default: d_model // num_heads
    rope_theta: float = 10_000.0
    qk_norm: bool = False                 # qwen3
    attn_logit_softcap: float | None = None  # gemma2: 50.0
    final_logit_softcap: float | None = None  # gemma2: 30.0
    sliding_window: int | None = None     # mixtral: 4096
    local_global: bool = False            # gemma2: alternate SWA/global
    local_window: int = 4096

    # --- MoE --------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1        # apply MoE every k-th layer (jamba: 2)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_token_chunk: int = 0   # >0: dispatch in token chunks (perf, P3)
    expert_parallel: bool = False  # pin expert buffers to 'model' (perf, P5)

    # --- hybrid / SSM ------------------------------------------------------
    attn_every: int = 0       # jamba: 8 => 1 attention layer per 8
    mamba_seq_chunk: int = 0  # >0: chunked selective scan (perf, P7)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    rwkv_head_size: int = 64

    # --- modality frontend (stubs per spec) -------------------------------
    frontend: Literal["none", "vision", "audio"] = "none"
    num_prefix_tokens: int = 0   # vision: image patches; audio: frames
    frontend_dim: int = 0        # encoder output dim (0 = d_model, no proj)

    # --- numerics / misc ---------------------------------------------------
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # --- serving ------------------------------------------------------------
    long_context_mode: Literal["native", "window"] = "native"
    # "window": force all attention layers to the local window for the
    # sub-quadratic long_500k gate (documented deviation, DESIGN.md §4).

    # -------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.attn_every == 0

    def layer_pattern(self) -> tuple[LayerSpec, ...]:
        """The repeating period of layers; num_layers % len(period) == 0."""
        if self.family == "ssm":
            return (LayerSpec(mixer="rwkv", ffn="dense"),)

        if self.family == "hybrid":
            # jamba: period of attn_every layers, one attention layer per
            # period (at position 0); MoE on every ``moe_every``-th layer.
            period = []
            for i in range(self.attn_every):
                mixer = "attn" if i == 0 else "mamba"
                ffn = "moe" if (self.num_experts and i % self.moe_every == 1 % self.moe_every) else "dense"
                period.append(LayerSpec(mixer=mixer, ffn=ffn,
                                        sliding_window=self.sliding_window))
            return tuple(period)

        ffn: Ffn = "moe" if self.num_experts else "dense"
        if self.local_global:
            # gemma2: local (SWA) / global alternating.
            g_window = self.local_window if self.long_context_mode == "window" else None
            return (
                LayerSpec(mixer="attn", ffn=ffn, sliding_window=self.local_window),
                LayerSpec(mixer="attn", ffn=ffn, sliding_window=g_window),
            )
        return (LayerSpec(mixer="attn", ffn=ffn,
                          sliding_window=self.sliding_window),)

    def num_periods(self) -> int:
        pat = self.layer_pattern()
        if self.num_layers % len(pat) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"period length {len(pat)}")
        return self.num_layers // len(pat)

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.num_experts:
            assert 0 < self.experts_per_token <= self.num_experts, self.name
        self.num_periods()

    def reduced(self, **overrides) -> "ArchConfig":
        """A smoke-test-sized variant of the same family (<=2 periods,
        d_model <= 512, <= 4 experts) per the assignment spec."""
        pat_len = len(self.layer_pattern())
        small = dict(
            num_layers=max(pat_len, 2 if pat_len == 1 else pat_len),
            d_model=min(self.d_model, 128),
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2),
            head_dim=32,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.num_experts else 0,
            local_window=64,
            sliding_window=64 if self.sliding_window else None,
            mamba_d_state=8,
            rwkv_head_size=16,
            num_prefix_tokens=min(self.num_prefix_tokens, 8),
            dtype="float32",
        )
        if self.num_heads and self.num_kv_heads:
            ratio = self.num_heads // self.num_kv_heads
            small["num_heads"] = min(4, max(2, ratio))
            small["num_kv_heads"] = max(1, small["num_heads"] // min(ratio, small["num_heads"]))
        if self.family == "hybrid":
            small["attn_every"] = 2  # keep the attn/mamba mix, 1 period = 2 layers
            small["num_layers"] = 2
        small.update(overrides)
        return dataclasses.replace(self, **small)
