"""Mamba (S6) block — the SSM mixer used by jamba's 7-of-8 layers.

Selective state-space model:
    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t      (diagonal A < 0)
    y_t = C_t . h_t + D x_t

Training path uses ``jax.lax.associative_scan`` over time (parallel prefix
— the TPU-friendly formulation; a sequential scan would serialize 4k
steps).  Decode path is the O(1) single-step recurrence on a carried
state, which is what makes jamba eligible for long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_mamba", "mamba_block", "mamba_decode_step", "init_mamba_state"]


def init_mamba(key, d_model: int, d_state: int, d_conv: int,
               expand: int, dtype) -> dict:
    d_inner = expand * d_model
    keys = jax.random.split(key, 7)
    si = 1.0 / jnp.sqrt(d_model)
    sinner = 1.0 / jnp.sqrt(d_inner)
    # S4D-real initialisation for A.
    a_init = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32)[None, :],
                      (d_inner, 1))
    return {
        "w_in": (jax.random.normal(keys[0], (d_model, 2 * d_inner)) * si).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (d_conv, d_inner)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((d_inner,), dtype=dtype),
        "w_bcdt": (jax.random.normal(keys[2], (d_inner, 2 * d_state + 1)) * sinner).astype(dtype),
        "dt_bias": jnp.full((d_inner,), -4.0, dtype=dtype),  # softplus^-1(~0.018)
        "w_dt": (jax.random.normal(keys[3], (1, d_inner)) * 0.1).astype(dtype),
        "a_log": jnp.log(a_init).astype(dtype),
        "d_skip": jnp.ones((d_inner,), dtype=dtype),
        "w_out": (jax.random.normal(keys[4], (d_inner, d_model)) * sinner).astype(dtype),
    }


def _ssm_params(params, u):
    """Input-dependent (dt, B, C) from the post-conv activations u."""
    bcdt = u @ params["w_bcdt"]                       # (..., 2*ds + 1)
    d_state = (bcdt.shape[-1] - 1) // 2
    B, C, dt_raw = jnp.split(bcdt, [d_state, 2 * d_state], axis=-1)
    dt = jax.nn.softplus(dt_raw @ params["w_dt"] + params["dt_bias"])  # (..., d_inner)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))  # (d_inner, d_state)
    return dt, B, C, A


def _causal_conv(params, x):
    """Depthwise causal conv1d over (batch, seq, d_inner)."""
    d_conv = params["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * params["conv_w"][i]
              for i in range(d_conv))
    return out + params["conv_b"]


def _ssm_apply(params, u, dt, B, C, A, h0=None):
    """Selective scan over the full given span; returns (y, h_last).

    h_t = decay_t h_{t-1} + drive_t, with optional incoming state h0
    folded in closed form: h_t += (prod_{j<=t} decay_j) h0.
    """
    dt32, u32 = dt.astype(jnp.float32), u.astype(jnp.float32)
    B32, C32 = B.astype(jnp.float32), C.astype(jnp.float32)
    log_decay = dt32[..., None] * A                   # (b, s, d_inner, N)
    decay = jnp.exp(log_decay)
    drive = (dt32 * u32)[..., None] * B32[..., None, :]

    def combine(a, b_):
        d1, x1 = a
        d2, x2 = b_
        return d1 * d2, x1 * d2 + x2

    _, h = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    if h0 is not None:
        h = h + jnp.exp(jnp.cumsum(log_decay, axis=1)) * h0[:, None]
    y = jnp.einsum("bsdn,bsn->bsd", h, C32)
    y = y + params["d_skip"].astype(jnp.float32) * u32
    return y, h[:, -1]


def mamba_block(params: dict, x: jax.Array,
                seq_chunk: int | None = None) -> jax.Array:
    """x: (batch, seq, d_model) -> same; training/prefill path.

    ``seq_chunk`` (perf P7): run the selective scan in sequence chunks
    with a carried (d_inner, N) state — bounds the (b, s, d_inner, N)
    decay/drive temporaries to O(b * chunk * d_inner * N).  This is the
    XLA-side analogue of the fused mamba kernel's working-set control.
    """
    b, s, _ = x.shape
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                  # (b, s, d_inner)
    u = jax.nn.silu(_causal_conv(params, u))
    dt, B, C, A = _ssm_params(params, u)

    if seq_chunk is None or s % seq_chunk != 0 or s <= seq_chunk:
        y, _ = _ssm_apply(params, u, dt, B, C, A)
    else:
        nc = s // seq_chunk
        resh = lambda t: jnp.moveaxis(
            t.reshape(b, nc, seq_chunk, *t.shape[2:]), 1, 0)
        d_inner = u.shape[-1]
        h0 = jnp.zeros((b, d_inner, A.shape[-1]), jnp.float32)

        def body(h, xs):
            uc, dtc, Bc, Cc = xs
            yc, h_new = _ssm_apply(params, uc, dtc, Bc, Cc, A, h0=h)
            return h_new, yc

        _, ys = jax.lax.scan(jax.checkpoint(body), h0,
                             (resh(u), resh(dt), resh(B), resh(C)))
        y = jnp.moveaxis(ys, 0, 1).reshape(b, s, -1)

    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["w_out"]


def mamba_prefill(params: dict, x: jax.Array,
                  seq_chunk: int | None = None) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also emits the decode state (fresh
    cache): recurrent h after the last token + the conv tail."""
    b, s, _ = x.shape
    xz = x @ params["w_in"]
    u_pre, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(_causal_conv(params, u_pre))
    dt, B, C, A = _ssm_params(params, u)
    y, h_last = _ssm_apply(params, u, dt, B, C, A)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["w_out"]

    d_conv = params["conv_w"].shape[0]
    tail = d_conv - 1
    if s >= tail:
        conv_tail = u_pre[:, s - tail:, :]
    else:
        conv_tail = jnp.pad(u_pre, ((0, 0), (tail - s, 0), (0, 0)))
    return out, {"h": h_last, "conv": conv_tail.astype(x.dtype)}


def init_mamba_state(batch: int, d_model: int, d_state: int, d_conv: int,
                     expand: int, dtype) -> dict:
    d_inner = expand * d_model
    return {
        "h": jnp.zeros((batch, d_inner, d_state), dtype=jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, d_inner), dtype=dtype),
    }


def mamba_decode_step(params: dict, x: jax.Array, state: dict
                      ) -> tuple[jax.Array, dict]:
    """Single-token step.  x: (batch, 1, d_model)."""
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                  # (b, 1, d_inner)
    conv_buf = jnp.concatenate([state["conv"], u.astype(state["conv"].dtype)], axis=1)
    d_conv = params["conv_w"].shape[0]
    u_conv = jnp.einsum("bkd,kd->bd", conv_buf, params["conv_w"]) + params["conv_b"]
    u_act = jax.nn.silu(u_conv)[:, None, :]           # (b, 1, d_inner)

    dt, B, C, A = _ssm_params(params, u_act)
    dt32 = dt[:, 0].astype(jnp.float32)
    decay = jnp.exp(dt32[..., None] * A)              # (b, d_inner, d_state)
    drive = (dt32 * u_act[:, 0].astype(jnp.float32))[..., None] * \
        B[:, 0].astype(jnp.float32)[:, None, :]
    h = state["h"] * decay + drive
    y = jnp.einsum("bdn,bn->bd", h, C[:, 0].astype(jnp.float32))
    y = y + params["d_skip"].astype(jnp.float32) * u_act[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["w_out"])[:, None, :]
    return out, {"h": h, "conv": conv_buf[:, 1:, :]}
