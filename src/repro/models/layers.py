"""Shared transformer layers: RMSNorm, RoPE, GQA attention, gated MLP.

All functions are pure; parameters are plain dicts of arrays.  The
attention here is the *reference* (jnp) implementation — the Pallas flash
kernel in ``repro/kernels`` is numerically validated against
``attention_ref`` and selected with ``attn_impl='pallas'`` at model level.

Supported attention variants (everything the assigned archs need):
  * grouped-query (num_kv_heads < num_heads), MQA (kv=1)
  * causal masking, sliding-window (mixtral, gemma2-local)
  * attention-logit softcapping (gemma2)
  * per-head q/k RMSNorm (qwen3)
  * single-token decode against a KV cache
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm", "init_rms_norm",
    "rope_frequencies", "apply_rope",
    "init_attention", "attention_ref", "attention",
    "init_mlp", "gated_mlp",
    "softcap",
]

Params = dict


def init_rms_norm(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype=dtype)}


def rms_norm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + params["scale"].astype(jnp.float32))).astype(dtype)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., s, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, qk_norm: bool, dtype) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(d_model)
    scale_out = 1.0 / jnp.sqrt(num_heads * head_dim)
    p = {
        "wq": (jax.random.normal(k1, (d_model, num_heads, head_dim)) * scale_in).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, num_kv_heads, head_dim)) * scale_in).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, num_kv_heads, head_dim)) * scale_in).astype(dtype),
        "wo": (jax.random.normal(k4, (num_heads, head_dim, d_model)) * scale_out).astype(dtype),
    }
    if qk_norm:
        p["q_norm"] = init_rms_norm(head_dim, dtype)
        p["k_norm"] = init_rms_norm(head_dim, dtype)
    return p


def _attn_mask(q_pos: jax.Array, k_pos: jax.Array,
               window: int | None) -> jax.Array:
    """(q, k) boolean mask: causal, optionally sliding-window."""
    causal = q_pos[:, None] >= k_pos[None, :]
    if window is None:
        return causal
    return causal & (q_pos[:, None] - k_pos[None, :] < window)


def attention_ref(
    q: jax.Array,            # (batch, q_len, heads, head_dim)
    k: jax.Array,            # (batch, kv_len, kv_heads, head_dim)
    v: jax.Array,            # (batch, kv_len, kv_heads, head_dim)
    q_positions: jax.Array,  # (q_len,)
    kv_positions: jax.Array, # (kv_len,)
    window: int | None = None,
    logit_softcap: float | None = None,
    kv_valid: jax.Array | None = None,  # (kv_len,) bool
) -> jax.Array:
    """Exact softmax GQA attention (the oracle for the flash kernel)."""
    b, qlen, nh, hd = q.shape
    nkv = k.shape[2]
    group = nh // nkv
    qg = q.reshape(b, qlen, nkv, group, hd)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    logits = softcap(logits, logit_softcap)
    mask = _attn_mask(q_positions, kv_positions, window)
    if kv_valid is not None:
        mask = mask & kv_valid[None, :]
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, qlen, nh, hd).astype(q.dtype)


def attention_blockwise(
    q: jax.Array,            # (batch, q_len, heads, head_dim)
    k: jax.Array,
    v: jax.Array,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    window: int | None = None,
    logit_softcap: float | None = None,
    block_k: int = 1024,
) -> jax.Array:
    """Streaming-softmax attention: lax.scan over kv blocks.

    The XLA-side realisation of the flash algorithm: never materialises
    the (q_len, kv_len) score matrix — peak attention memory drops from
    O(s^2) to O(s * block_k).  Numerically identical to ``attention_ref``
    (same online-softmax recurrence as the Pallas kernel, which remains
    the TPU-optimal path; this one exists so *lowered* programs that
    cannot call Pallas (dry-run / CPU) get the same asymptotics).
    """
    b, sq, nh, hd = q.shape
    _, skv, nkv, _ = k.shape
    group = nh // nkv
    scale = 1.0 / float(hd) ** 0.5
    pad = (-skv) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, pad),),
                               constant_values=jnp.iinfo(jnp.int32).max)
    nblocks = k.shape[1] // block_k
    kb = k.reshape(b, nblocks, block_k, nkv, hd)
    vb = v.reshape(b, nblocks, block_k, nkv, hd)
    pb = kv_positions.reshape(nblocks, block_k)
    qg = q.reshape(b, sq, nkv, group, hd).astype(jnp.float32)

    def block(carry, xs):
        acc, mx, lse = carry
        kc, vc, pc = xs
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                       kc.astype(jnp.float32)) * scale
        s = softcap(s, logit_softcap)
        mask = q_positions[:, None] >= pc[None, :]
        if window is not None:
            mask &= q_positions[:, None] - pc[None, :] < window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(mx, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(mx - m_new)
        lse = lse * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p, vc.astype(jnp.float32))
        return (acc, m_new, lse), None

    acc0 = jnp.zeros((b, nkv, group, sq, hd), jnp.float32)
    m0 = jnp.full((b, nkv, group, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, nkv, group, sq), jnp.float32)
    (acc, _, lse), _ = jax.lax.scan(
        jax.checkpoint(block),
        (acc0, m0, l0),
        (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), pb))
    out = acc / jnp.maximum(lse, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, nh, hd)
    return out.astype(q.dtype)


def attention(params: Params, x: jax.Array, positions: jax.Array, *,
              num_heads: int, num_kv_heads: int, head_dim: int,
              rope_theta: float, window: int | None,
              logit_softcap: float | None, qk_norm: bool, norm_eps: float,
              cache: dict | None = None, impl: str = "reference") -> tuple[jax.Array, dict | None]:
    """Full attention layer: qkv projection, rope, SDPA, out projection.

    ``cache`` (decode): {"k": (b, max_len, kv, hd), "v": ..., "len": int32}
    — the new token is written at index ``len`` and attends to the prefix.
    """
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if qk_norm:
        q = rms_norm(params["q_norm"], q, norm_eps)
        k = rms_norm(params["k_norm"], k, norm_eps)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)

    if cache is not None and s > 1:
        # Prefill into a fresh cache: attend among the new tokens exactly,
        # then lay the (last `size`) roped keys into their ring slots
        # (token p -> slot p mod size), so subsequent decode steps see a
        # consistent ring buffer.
        size = cache["k"].shape[1]
        out = attention_ref(q, k, v, positions, positions, window,
                            logit_softcap)
        if s >= size:
            ck = jnp.roll(k[:, -size:].astype(cache["k"].dtype),
                          s % size, axis=1)
            cv = jnp.roll(v[:, -size:].astype(cache["v"].dtype),
                          s % size, axis=1)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        new_cache = {"k": ck, "v": cv, "len": cache["len"] + s}
    elif cache is not None:
        # Decode: ring-buffer cache.  SWA layers allocate only ``window``
        # slots; slot j currently holds absolute position
        #   pos_j = idx - ((idx - j) mod size)
        # (negative => slot not yet written).  Keys are stored post-RoPE so
        # absolute positions are only needed for masking.
        idx = cache["len"]
        size = cache["k"].shape[1]
        slot = idx % size
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        j = jnp.arange(size, dtype=jnp.int32)
        kv_pos = idx - jnp.mod(idx - j, size)
        out = attention_ref(q, ck, cv, positions, kv_pos, window,
                            logit_softcap, kv_valid=kv_pos >= 0)
        new_cache = {"k": ck, "v": cv, "len": idx + s}
    else:
        kv_pos = positions
        if impl == "pallas":
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                         logit_softcap=logit_softcap)
        elif impl == "blockwise":
            out = attention_blockwise(q, k, v, positions, kv_pos, window,
                                      logit_softcap)
        else:
            out = attention_ref(q, k, v, positions, kv_pos, window,
                                logit_softcap)
        new_cache = None

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU family)
# ---------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    si, so = 1.0 / jnp.sqrt(d_model), 1.0 / jnp.sqrt(d_ff)
    return {
        "w_gate": (jax.random.normal(k1, (d_model, d_ff)) * si).astype(dtype),
        "w_up": (jax.random.normal(k2, (d_model, d_ff)) * si).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d_model)) * so).astype(dtype),
    }


def gated_mlp(params: Params, x: jax.Array) -> jax.Array:
    gate = jax.nn.silu(x @ params["w_gate"])
    return (gate * (x @ params["w_up"])) @ params["w_down"]
