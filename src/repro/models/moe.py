"""Mixture-of-Experts FFN with capacity-based dispatch.

Top-k softmax router + Mesh-TF-style capacity dispatch: tokens are
assigned a position inside their expert's buffer via a cumulative sum;
overflowing tokens are dropped (standard practice, capacity_factor
controls the drop rate).  The dispatch/combine einsums are the
communication pattern the sharding layer turns into all-to-alls when
experts live on the ``model`` axis.

Compute cost is E * capacity * (3 d_model d_ff) = tokens * top_k * ffn
cost (up to the capacity factor) — i.e. the *active-expert* FLOPs, not a
dense all-experts evaluation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["init_moe", "moe_ffn", "moe_ffn_exact", "router_load_balance_loss"]


def init_moe(key, d_model: int, d_ff: int, num_experts: int, dtype) -> dict:
    k_r, k1, k2, k3 = jax.random.split(key, 4)
    si, so = 1.0 / jnp.sqrt(d_model), 1.0 / jnp.sqrt(d_ff)
    return {
        "router": (jax.random.normal(k_r, (d_model, num_experts)) * si).astype(dtype),
        "w_gate": (jax.random.normal(k1, (num_experts, d_model, d_ff)) * si).astype(dtype),
        "w_up": (jax.random.normal(k2, (num_experts, d_model, d_ff)) * si).astype(dtype),
        "w_down": (jax.random.normal(k3, (num_experts, d_ff, d_model)) * so).astype(dtype),
    }


def router_load_balance_loss(router_probs: jax.Array,
                             expert_mask: jax.Array) -> jax.Array:
    """Switch-style auxiliary loss: E * <fraction routed, mean prob>."""
    num_experts = router_probs.shape[-1]
    density = jnp.mean(expert_mask, axis=0)          # fraction of tokens/expert
    density_proxy = jnp.mean(router_probs, axis=0)   # mean router prob/expert
    return num_experts * jnp.sum(density * density_proxy)


def moe_ffn(params: dict, x: jax.Array, *, num_experts: int, top_k: int,
            capacity_factor: float = 1.25,
            token_chunk: int | None = None,
            expert_parallel: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (batch, seq, d_model) -> (output, aux_loss).

    ``token_chunk``: dispatch in chunks of this many tokens (lax.scan) —
    bounds the dispatch/combine one-hots to O(chunk * E * capacity_chunk)
    instead of O(n * E * capacity_n); routing stays token-local so the
    result is the same algorithm with per-chunk capacity (standard
    practice for long prefill).
    """
    b, s, d = x.shape
    n_total = b * s
    if token_chunk is not None and n_total > token_chunk \
            and n_total % token_chunk == 0:
        xt = x.reshape(n_total // token_chunk, 1, token_chunk, d)

        def body(acc, xc):
            out, aux = moe_ffn(params, xc, num_experts=num_experts,
                               top_k=top_k, capacity_factor=capacity_factor,
                               expert_parallel=expert_parallel)
            return acc + aux, out

        aux, outs = jax.lax.scan(body, jnp.zeros((), x.dtype), xt)
        return outs.reshape(b, s, d), aux / (n_total // token_chunk)
    tokens = x.reshape(b * s, d)
    n = tokens.shape[0]
    capacity = max(1, int(capacity_factor * n * top_k / num_experts))

    logits = (tokens @ params["router"]).astype(jnp.float32)  # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)       # (n, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # (n, k, E) one-hot of chosen experts, flattened to (n*k, E) for the
    # position-in-expert cumsum (k slots per token, priority by k-rank).
    onehot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.float32)
    flat = onehot.transpose(1, 0, 2).reshape(top_k * n, num_experts)
    pos = (jnp.cumsum(flat, axis=0) - 1.0) * flat             # (k*n, E)
    keep = pos < capacity
    flat = flat * keep
    pos_in_expert = jnp.sum(pos * keep, axis=-1)              # (k*n,)
    pos_oh = jax.nn.one_hot(pos_in_expert, capacity, dtype=jnp.float32)
    # dispatch tensor (n, k, E, C)
    dispatch = (flat[..., None] * pos_oh[:, None, :]).reshape(
        top_k, n, num_experts, capacity).transpose(1, 0, 2, 3)
    gates = gate_vals.T.reshape(top_k, n).T                   # (n, k)
    combine = dispatch * gates[..., None, None]

    # --- expert computation: (E, C, d) -> (E, C, d)
    # When experts are sharded over 'model' (expert parallelism), pin the
    # per-expert buffers to that layout so XLA dispatches tokens with an
    # all-to-all instead of all-gathering expert weights (perf P5).
    def _pin(t):
        if not expert_parallel:
            return t
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            t, P("model", *([None] * (t.ndim - 1))))

    xe = _pin(jnp.einsum("nkec,nd->ecd", dispatch.astype(x.dtype), tokens))
    h = _pin(jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])))
    h = h * jnp.einsum("ecd,edf->ecf", xe, params["w_up"])
    ye = _pin(jnp.einsum("ecf,efd->ecd", h, params["w_down"]))
    out = jnp.einsum("nkec,ecd->nd", combine.astype(x.dtype), ye)

    aux = router_load_balance_loss(probs, jnp.max(onehot, axis=1))
    return out.reshape(b, s, d), aux.astype(x.dtype)


def moe_ffn_exact(params: dict, x: jax.Array, *, num_experts: int,
                  top_k: int) -> tuple[jax.Array, jax.Array]:
    """Capacity-free routing: every selected expert computes its token.

    Exact (no drops), at the cost of evaluating *all* experts densely and
    masking — the right trade for decode, where the batch is small and the
    step is dominated by reading every expert's weights from HBM anyway.
    """
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    logits = (tokens @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    onehot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.float32)

    h = jax.nn.silu(jnp.einsum("nd,edf->nef", tokens, params["w_gate"]))
    h = h * jnp.einsum("nd,edf->nef", tokens, params["w_up"])
    y_all = jnp.einsum("nef,efd->ned", h, params["w_down"])
    weights = jnp.einsum("nke,nk->ne", onehot, gate_vals).astype(x.dtype)
    out = jnp.einsum("ne,ned->nd", weights, y_all)

    aux = router_load_balance_loss(probs, jnp.max(onehot, axis=1))
    return out.reshape(b, s, d), aux.astype(x.dtype)
