"""Ring consensus on the TPU mesh: the paper's mixing matrix as ppermute.

The doubly-stochastic ring mix  x_i <- w0 x_i + w1 x_{i-1} + w1 x_{i+1}
becomes two ``lax.collective_permute``s along the agent axes — O(2 |x|)
neighbour bytes per round instead of an all-reduce (DESIGN.md §3).  In the
multi-pod mesh the agent ring flattens ("pod", "data") pod-major, so
exactly two ring edges cross the pod boundary.

These helpers are used *inside* ``jax.shard_map`` bodies whose
``axis_names`` contain only the agent axes (the model axis stays auto and
is partitioned by XLA as usual).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = ["ring_mix_tree", "ring_mix_leaf", "agent_index",
           "quantize_int8", "dequantize_int8"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization (compressed consensus).

    The paper's conclusion names communication compression as the natural
    extension; this halves (bf16) or quarters (f32) the consensus wire
    bytes at the cost of a bounded quantization error that gradient
    tracking absorbs like any other consensus perturbation.
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _axis_name(agent_axes: Sequence[str]):
    return tuple(agent_axes) if len(agent_axes) > 1 else agent_axes[0]


def agent_index(agent_axes: Sequence[str]) -> jax.Array:
    return jax.lax.axis_index(_axis_name(agent_axes))


def ring_mix_leaf(x: jax.Array, agent_axes: Sequence[str],
                  self_weight: float, compress: str | None = None,
                  dp_sigma: float = 0.0,
                  dp_key: jax.Array | None = None) -> jax.Array:
    """One consensus combine of a per-agent leaf (inside shard_map).

    compress="int8": send int8-quantized neighbour payloads (+ scalar
      scale) — the paper's compression future-work direction.
    dp_sigma > 0: add Gaussian noise to the *outgoing* payload before it
      leaves the agent (local differential privacy on shared iterates —
      the paper's other future-work direction).  The local copy is mixed
      un-noised; neighbours only ever see the noisy value.
    """
    name = _axis_name(agent_axes)
    m = jax.lax.axis_size(name)
    if m == 1:
        return x
    w1 = (1.0 - self_weight) / 2.0
    fwd = [(i, (i + 1) % m) for i in range(m)]
    bwd = [(i, (i - 1) % m) for i in range(m)]

    payload = x
    if dp_sigma > 0.0:
        if dp_key is None:
            raise ValueError("dp_sigma requires dp_key")
        key = jax.random.fold_in(dp_key, jax.lax.axis_index(name))
        noise = dp_sigma * jax.random.normal(key, x.shape, jnp.float32)
        payload = (x.astype(jnp.float32) + noise).astype(x.dtype)

    if compress == "int8":
        q, scale = quantize_int8(payload)
        ql = jax.lax.ppermute(q, name, fwd)
        sl = jax.lax.ppermute(scale, name, fwd)
        qr = jax.lax.ppermute(q, name, bwd)
        sr = jax.lax.ppermute(scale, name, bwd)
        from_left = dequantize_int8(ql, sl)
        from_right = dequantize_int8(qr, sr)
    else:
        from_left = jax.lax.ppermute(payload, name, fwd)
        from_right = jax.lax.ppermute(payload, name, bwd)

    dtype = x.dtype
    mixed = (self_weight * x.astype(jnp.float32)
             + w1 * from_left.astype(jnp.float32)
             + w1 * from_right.astype(jnp.float32))
    return mixed.astype(dtype)


def ring_mix_tree(tree, agent_axes: Sequence[str], self_weight: float,
                  compress: str | None = None, dp_sigma: float = 0.0,
                  dp_key: jax.Array | None = None):
    return jax.tree_util.tree_map(
        lambda l: ring_mix_leaf(l, agent_axes, self_weight,
                                compress=compress, dp_sigma=dp_sigma,
                                dp_key=dp_key), tree)
