"""Sparse consensus on the device mesh: any mixing matrix as ppermutes.

The consensus combine ``x_i <- sum_j M_ij x_j`` is realised without ever
materialising the (m, m) matrix on device: any doubly-stochastic ``M`` is
decomposed into per-*offset* permute rounds (``permute_schedule``).  For
offset ``o`` every agent receives the payload of agent ``(i + o) mod m``
via one ``lax.ppermute`` (a full cyclic shift is always a valid
permutation) and scales it by its own row weight ``M[i, (i+o) mod m]`` —
so ring, torus, and Erdős–Rényi / Metropolis graphs all run under
``shard_map``.  The ring mix of DESIGN.md §3 is the two-offset special
case (``ring_mix_tree`` below is now a thin wrapper).

Wire cost is O(n_offsets · |x|) per combine, where n_offsets is the
number of *distinct ring offsets* carrying any edge — NOT the per-agent
degree.  Structured graphs stay cheap (ring 2, torus 4-5); a dense-ish
Erdős–Rényi sample populates most offsets and can approach (m-1) · |x|,
worse than a ~2·|x| bandwidth-optimal all-reduce.  For such graphs
prefer ``impl="psum"`` (one all-reduce of an m-row contribution) or a
structured topology; the engine does not silently switch.

These helpers are the implementation layer of the ``ppermute`` consensus
backend (``repro/consensus/ppermute.py``); algorithms never call them
directly — they go through the ``ConsensusEngine`` API.  They must run
*inside* ``shard_map`` bodies whose ``axis_names`` contain only the agent
axes (the model axis stays auto and is partitioned by XLA as usual).

Backend options carried per-schedule rather than per-call:

* int8 compression — quantize the outgoing payload once per round, send
  (q, scale) per offset; halves (bf16) / quarters (f32) wire bytes.
* local-DP noise — Gaussian noise added to the *outgoing* payload before
  it leaves the agent; the local copy mixes un-noised, neighbours only
  ever see the noisy value.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.compat import axis_size

__all__ = [
    "PermuteSchedule", "PermuteWeights", "permute_schedule",
    "permute_mix_leaf", "permute_mix_tree", "ring_mix_tree",
    "ring_mix_leaf", "agent_index", "quantize_int8", "dequantize_int8",
]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization (compressed consensus).

    The paper's conclusion names communication compression as the natural
    extension; this halves (bf16) or quarters (f32) the consensus wire
    bytes at the cost of a bounded quantization error that gradient
    tracking absorbs like any other consensus perturbation.
    """
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _axis_name(agent_axes: Sequence[str]):
    return tuple(agent_axes) if len(agent_axes) > 1 else agent_axes[0]


def agent_index(agent_axes: Sequence[str]) -> jax.Array:
    return jax.lax.axis_index(_axis_name(agent_axes))


@dataclasses.dataclass(frozen=True)
class PermuteSchedule:
    """A mixing matrix decomposed into cyclic-shift permute rounds.

    Attributes:
      num_agents:   m.
      offsets:      ring offsets o with any nonzero weight; one ppermute
                    (full cyclic shift by o) is issued per entry.
      weights:      (n_offsets, m) — ``weights[k, i] = M[i, (i+offsets[k]) % m]``,
                    the weight agent i applies to the payload it receives
                    in round k (zero where the graph has no edge).
      self_weights: (m,) — the diagonal ``M[i, i]``.
    """

    num_agents: int
    offsets: tuple[int, ...]
    weights: np.ndarray
    self_weights: np.ndarray
    matrix: np.ndarray

    @property
    def rounds_per_mix(self) -> int:
        """ppermutes per consensus combine (the wire-cost multiplier)."""
        return len(self.offsets)


class PermuteWeights(NamedTuple):
    """One round's weights on the *shared* offset schedule.

    The time-varying topology layer (docs/TOPOLOGY.md) batches matrix
    streams on the ppermute backend as the ROADMAP describes: the
    offsets stay those of the base schedule (one ppermute per offset,
    program shape unchanged) and only the per-round weights vary — a
    dropped edge is a zero weight on its offset.  Passed per call as the
    ``override`` of ``permute_mix_leaf`` / ``permute_mix_tree``.

    Attributes:
      weights:      (n_offsets, m) — replaces ``schedule.weights``.
      self_weights: (m,) — replaces ``schedule.self_weights``.
      matrix:       (m, m) — replaces ``schedule.matrix`` (psum impl).
    """

    weights: jax.Array
    self_weights: jax.Array
    matrix: jax.Array


def permute_schedule(mixing, tol: float = 1e-12) -> PermuteSchedule:
    """Decompose any (sparse or dense) mixing matrix into ppermute rounds.

    ``mixing`` is a ``repro.core.consensus.MixingSpec`` or a raw (m, m)
    matrix (duck-typed on ``.matrix`` to keep this module free of core
    imports).  Offsets whose weight vector is identically ~0 are dropped,
    so *offset-structured* topologies pay few rounds (ring 2, 2-D torus
    4-5); an unstructured Erdős–Rényi graph usually populates most of the
    m - 1 offsets — see the module docstring for the cost trade-off.
    """
    mat = np.asarray(getattr(mixing, "matrix", mixing), dtype=np.float64)
    m = mat.shape[0]
    idx = np.arange(m)
    offsets, weights = [], []
    for o in range(1, m):
        w = mat[idx, (idx + o) % m]
        if np.max(np.abs(w)) > tol:
            offsets.append(o)
            weights.append(w)
    return PermuteSchedule(
        num_agents=m,
        offsets=tuple(offsets),
        weights=(np.stack(weights) if weights else np.zeros((0, m))),
        self_weights=np.diag(mat).copy(),
        matrix=mat,
    )


def _outgoing_payload(x, i, dp_sigma, dp_key, leaf_index=0):
    """What this agent shares: the iterate, optionally DP-noised.

    ``dp_sigma > 0`` without a key is a loud error: a caller that wants
    an un-noised combine (e.g. the u-mix) must pass ``dp_sigma=0``
    explicitly — silently skipping the noise would be a privacy loss.

    The key folds in BOTH the agent index and the leaf index: same-shaped
    leaves must receive independent noise, otherwise a neighbour could
    difference two leaves and cancel the noise exactly.
    """
    if dp_sigma > 0.0:
        if dp_key is None:
            raise ValueError("dp_sigma requires dp_key")
        key = jax.random.fold_in(jax.random.fold_in(dp_key, leaf_index), i)
        noise = dp_sigma * jax.random.normal(key, x.shape, jnp.float32)
        return (x.astype(jnp.float32) + noise).astype(x.dtype)
    return x


def _ppermute_mix(x, name, m, schedule, i, compress, dp_sigma, dp_key,
                  leaf_index=0, payload=None, override=None):
    """Per-offset cyclic-shift rounds: the wire-frugal realisation.

    ``payload`` (when given) replaces ``x`` as the outgoing value — the
    consensus engine's error-feedback layer hands in the already-
    compressed (decoded-value) payload here, so the legacy ``compress``
    quantization is skipped for it.  The accumulator is seeded with the
    *clean* local ``x`` either way: the agent's own term never round-trips
    through the wire format.

    ``override`` (a ``PermuteWeights``) replaces the schedule's weights
    for this round — same offsets, per-step values — which is how
    time-varying topologies run here without changing the program shape.
    """
    sw = (override.self_weights if override is not None
          else jnp.asarray(schedule.self_weights, jnp.float32))
    self_w = sw[i]
    acc = self_w * x.astype(jnp.float32)
    if not schedule.offsets:
        return acc.astype(x.dtype)

    payload = _outgoing_payload(x if payload is None else payload,
                                i, dp_sigma, dp_key, leaf_index)
    if compress == "int8":
        q, scale = quantize_int8(payload)

    weights = (override.weights if override is not None
               else jnp.asarray(schedule.weights, jnp.float32))
    for k, o in enumerate(schedule.offsets):
        # Destination j receives the payload of agent (j + o) mod m.
        perm = [((j + o) % m, j) for j in range(m)]
        if compress == "int8":
            recv = dequantize_int8(jax.lax.ppermute(q, name, perm),
                                   jax.lax.ppermute(scale, name, perm))
        else:
            recv = jax.lax.ppermute(payload, name, perm)
        acc = acc + weights[k, i] * recv.astype(jnp.float32)
    return acc.astype(x.dtype)


def _psum_mix(x, name, m, schedule, i, compress, dp_sigma, dp_key,
              leaf_index=0, payload=None, override=None):
    """All-reduce realisation: agent j contributes M[:, j] (x) sent_j and
    everyone slices its own row of the psum.

    Used where the partitioner cannot lower ppermute under a partially
    manual shard_map (old-JAX stacks, see compat.PARTIAL_AUTO_COLLECTIVES
    _SAFE); costs one m-times-payload all-reduce instead of per-edge
    exchanges, but preserves the exact mixing semantics — including that
    the agent's *own* term mixes the clean local iterate while neighbours
    see the compressed / noised payload.  ``payload`` overrides the
    outgoing value (pre-compressed by the engine's error-feedback layer);
    the existing self-weight correction then yields exactly
    ``mix(payload) + M_ii (x - payload)``.
    """
    payload = _outgoing_payload(x if payload is None else payload,
                                i, dp_sigma, dp_key, leaf_index)
    if compress == "int8":
        q, scale = quantize_int8(payload)
        sent = dequantize_int8(q, scale)  # what neighbours decode
    else:
        sent = payload.astype(jnp.float32)

    mat = (override.matrix if override is not None
           else jnp.asarray(schedule.matrix, jnp.float32))
    col = mat[:, i].reshape((m,) + (1,) * x.ndim)
    mixed = jax.lax.psum(col * sent[None], name)[i]
    # The psum applied M_ii to the *shared* payload; the local copy mixes
    # un-noised / un-quantized.
    sw = (override.self_weights if override is not None
          else jnp.asarray(schedule.self_weights, jnp.float32))
    self_w = sw[i]
    mixed = mixed + self_w * (x.astype(jnp.float32) - sent)
    return mixed.astype(x.dtype)


def permute_mix_leaf(x: jax.Array, agent_axes: Sequence[str],
                     schedule: PermuteSchedule,
                     compress: str | None = None,
                     dp_sigma: float = 0.0,
                     dp_key: jax.Array | None = None,
                     impl: str = "ppermute",
                     agent_index: jax.Array | None = None,
                     leaf_index: int = 0,
                     payload: jax.Array | None = None,
                     override: PermuteWeights | None = None) -> jax.Array:
    """One consensus combine of a per-agent leaf (inside shard_map).

    compress="int8": send int8-quantized payloads (+ scalar scale).
    dp_sigma > 0 with dp_key set: Gaussian noise on the outgoing payload
    (local differential privacy on shared iterates); the local copy is
    mixed un-noised.
    impl: "ppermute" (per-edge exchanges) or "psum" (all-reduce fallback
    for partially-auto bodies on old JAX).
    agent_index: this agent's ring position; defaults to
    ``lax.axis_index``, but partially-auto old-JAX bodies must thread it
    in as data (partition-id does not lower there).
    payload: override for the outgoing value (the engine's error-feedback
    layer passes the pre-compressed payload here; DP noise still applies
    to it, the local copy still mixes clean).
    override: this round's ``PermuteWeights`` — per-step weights on the
    shared offset schedule (time-varying topologies, docs/TOPOLOGY.md).
    """
    name = _axis_name(agent_axes)
    m = axis_size(name)
    if m != schedule.num_agents:
        raise ValueError(
            f"schedule built for m={schedule.num_agents} but the agent "
            f"axes {tuple(agent_axes)} have size {m}")
    i = (jax.lax.axis_index(name) if agent_index is None
         else agent_index)
    mix = _psum_mix if impl == "psum" else _ppermute_mix
    return mix(x, name, m, schedule, i, compress, dp_sigma, dp_key,
               leaf_index, payload, override)


def permute_mix_tree(tree, agent_axes: Sequence[str],
                     schedule: PermuteSchedule,
                     compress: str | None = None, dp_sigma: float = 0.0,
                     dp_key: jax.Array | None = None,
                     impl: str = "ppermute",
                     agent_index: jax.Array | None = None,
                     payload_tree=None,
                     override: PermuteWeights | None = None):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    payloads = (jax.tree_util.tree_flatten(payload_tree)[0]
                if payload_tree is not None else [None] * len(leaves))
    mixed = [permute_mix_leaf(l, agent_axes, schedule,
                              compress=compress, dp_sigma=dp_sigma,
                              dp_key=dp_key, impl=impl,
                              agent_index=agent_index, leaf_index=k,
                              payload=pl, override=override)
             for k, (l, pl) in enumerate(zip(leaves, payloads))]
    return jax.tree_util.tree_unflatten(treedef, mixed)


def ring_mix_leaf(x: jax.Array, agent_axes: Sequence[str],
                  self_weight: float, compress: str | None = None,
                  dp_sigma: float = 0.0,
                  dp_key: jax.Array | None = None,
                  leaf_index: int = 0) -> jax.Array:
    """Ring special case: the schedule of ``ring_mixing(m, self_weight)``."""
    from repro.core.consensus import ring_mixing  # lazy: avoids core cycle
    name = _axis_name(agent_axes)
    m = axis_size(name)
    schedule = permute_schedule(ring_mixing(m, self_weight=self_weight))
    return permute_mix_leaf(x, agent_axes, schedule, compress=compress,
                            dp_sigma=dp_sigma, dp_key=dp_key,
                            leaf_index=leaf_index)


def ring_mix_tree(tree, agent_axes: Sequence[str], self_weight: float,
                  compress: str | None = None, dp_sigma: float = 0.0,
                  dp_key: jax.Array | None = None):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    mixed = [ring_mix_leaf(l, agent_axes, self_weight,
                           compress=compress, dp_sigma=dp_sigma,
                           dp_key=dp_key, leaf_index=k)
             for k, l in enumerate(leaves)]
    return jax.tree_util.tree_unflatten(treedef, mixed)
