"""Parameter / state sharding rules for the production meshes.

Rule for weight leaves: shard the largest dimension divisible by the
``model`` axis size (ties broken toward later dims — output features),
replicate 1-D leaves (norm scales, biases).  Per-agent stacked state
(leading dim = number of agents) puts the agent axis first.

This single divisibility-driven rule covers every assigned architecture:
  * embed (vocab, d)           -> vocab on model (vocab >> d)
  * attention wq (d, h, hd)    -> d or h on model depending on divisibility
  * MoE expert stacks (E, d, f)-> E on model when E % 16 == 0 (expert
    parallelism: dbrx/jamba 16e), else f (mixtral 8e -> tensor parallel
    inside experts)
  * mamba / rwkv inner dims    -> d_inner on model
KV caches shard batch on the data axes when divisible, else the sequence
dim (long_500k batch=1), else replicate.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = [
    "leaf_spec", "tree_specs", "tree_shardings", "stacked_tree_specs",
    "cache_specs", "batch_spec",
]


def _largest_divisible_dim(shape, size: int, skip: tuple[int, ...] = ()):
    """Index of the largest dim divisible by ``size`` (later dims win ties),
    or None."""
    best, best_dim = None, -1
    for i, d in enumerate(shape):
        if i in skip:
            continue
        if d % size == 0 and d >= size and d >= best_dim:
            best, best_dim = i, d
    return best


def leaf_spec(shape, model_size: int, agent_axes: tuple[str, ...] | None = None,
              agent_leading: bool = False,
              extra_axes: tuple[tuple[str, int], ...] = ()) -> P:
    """PartitionSpec for one weight leaf.

    ``extra_axes``: additional (axis_name, size) pairs to spread over
    further divisible dims — used by the agents-per-pod layout (perf P6)
    where each agent's parameters shard over model AND data.
    """
    entries: list[Any] = [None] * len(shape)
    start = 0
    if agent_leading:
        entries[0] = agent_axes if len(agent_axes) > 1 else agent_axes[0]
        start = 1
    if len(shape) - start >= 2:  # matrices and higher: shard on model
        skip: tuple[int, ...] = ()
        idx = _largest_divisible_dim(shape[start:], model_size)
        if idx is not None:
            entries[start + idx] = "model"
            skip = (idx,)
        for name, size in extra_axes:
            j = _largest_divisible_dim(shape[start:], size, skip=skip)
            if j is not None:
                entries[start + j] = name
                skip = skip + (j,)
    return P(*entries)


def tree_specs(tree, model_size: int) -> Any:
    """Specs for a plain (single-copy) parameter pytree."""
    return jax.tree_util.tree_map(
        lambda l: leaf_spec(l.shape, model_size), tree)


def stacked_tree_specs(tree, model_size: int,
                       agent_axes: tuple[str, ...],
                       extra_axes: tuple[tuple[str, int], ...] = ()) -> Any:
    """Specs for per-agent stacked state: leaves are (num_agents, ...)."""
    return jax.tree_util.tree_map(
        lambda l: leaf_spec(l.shape, model_size, agent_axes,
                            agent_leading=True, extra_axes=extra_axes), tree)


def tree_shardings(mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def batch_spec(agent_axes: tuple[str, ...], per_agent: bool = True) -> P:
    """Input batch: leading agent dim (per-agent layout) or plain batch."""
    ax = agent_axes if len(agent_axes) > 1 else agent_axes[0]
    return P(ax)


def cache_specs(tree, mesh, batch: int) -> Any:
    """Decode-cache sharding.

    Leaves look like (periods, batch, seq, kv_heads, hd) for attention or
    (periods, batch, inner, state) for SSM.  Strategy:
      * shard batch over the data axes when divisible,
      * else shard the largest remaining dim over 'data' (long-context
        single-request: the cache *sequence* gets sharded),
      * always try to put 'model' on a divisible trailing dim.
    """
    data_axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    data_size = int(np.prod([mesh.shape[a] for a in data_axes]))
    model_size = mesh.shape["model"]
    data_entry = data_axes if len(data_axes) > 1 else data_axes[0]

    def spec_for(l):
        shape = l.shape
        entries: list[Any] = [None] * len(shape)
        # periods dim (0) never sharded.
        used_data = False
        if len(shape) >= 2 and shape[1] == batch and batch % data_size == 0:
            entries[1] = data_entry
            used_data = True
        # model on the largest divisible trailing dim (skip periods+batch)
        idx = _largest_divisible_dim(shape[2:], model_size)
        if idx is not None:
            entries[2 + idx] = "model"
        if not used_data:
            # long_500k: batch too small — shard the big sequence dim on data
            cand = _largest_divisible_dim(
                shape[2:], data_size,
                skip=(() if idx is None else (idx,)))
            if cand is not None and shape[2 + cand] >= 4 * data_size:
                entries[2 + cand] = data_entry
        return P(*entries)

    return jax.tree_util.tree_map(spec_for, tree)
