"""Version-compat resolvers for JAX APIs that moved between releases.

Two surfaces the repo depends on have migrated across JAX versions:

* ``shard_map`` — new JAX exposes ``jax.shard_map(f, mesh=..., in_specs=...,
  out_specs=..., axis_names=..., check_vma=...)``; older releases (including
  the 0.4.x series) only have ``jax.experimental.shard_map.shard_map`` with
  positional args, ``check_rep`` instead of ``check_vma``, and an ``auto``
  set (the complement of ``axis_names``) for axes left to the partitioner.
* ``set_mesh`` — new JAX has ``jax.set_mesh`` as a context manager; older
  releases either provide ``jax.sharding.use_mesh`` or rely on the ``Mesh``
  object itself being a context manager.

Everything in ``repro`` (train steps, launchers, tests) routes through the
two wrappers below instead of touching ``jax.*`` directly, so the same code
runs on every JAX this repo has met.
"""
from __future__ import annotations

from typing import Iterable

import jax

__all__ = ["shard_map", "set_mesh", "axis_size",
           "HAS_NATIVE_SHARD_MAP", "PARTIAL_AUTO_COLLECTIVES_SAFE"]

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _OLD_SHARD_MAP
else:
    _OLD_SHARD_MAP = None

HAS_NATIVE_SHARD_MAP = _NEW_SHARD_MAP is not None

# On the old-JAX stack, XLA's SPMD partitioner cannot lower
# collective-permute / all-gather / partition-id inside a *partially*
# manual shard_map (manual agent axes + auto model axis): it aborts with
# "IsManualSubgroup" check failures.  Only all-reduce (psum/pmean)
# survives.  Consumers use this flag to select the psum-based consensus
# fallback when mixing under partial-auto bodies.
PARTIAL_AUTO_COLLECTIVES_SAFE = HAS_NATIVE_SHARD_MAP


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Iterable[str] | None = None,
              check_vma: bool | None = None):
    """``jax.shard_map`` with a uniform keyword surface on every JAX.

    ``axis_names`` is the set of mesh axes the body is *manual* over; the
    remaining axes stay automatic (partitioned by XLA).  ``check_vma``
    maps to ``check_rep`` on old JAX; when unspecified we disable the
    replication check — the repo's bodies mix manual collectives with
    auto-partitioned einsums, which the old checker rejects.
    """
    if _NEW_SHARD_MAP is not None:
        kwargs = {"check_vma": bool(check_vma)
                  if check_vma is not None else False}
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)
    manual = set(axis_names) if axis_names is not None else set(mesh.axis_names)
    auto = frozenset(set(mesh.axis_names) - manual)
    return _OLD_SHARD_MAP(f, mesh, in_specs, out_specs,
                          check_rep=bool(check_vma) if check_vma is not None
                          else False,
                          auto=auto)


def axis_size(name):
    """``jax.lax.axis_size`` with a fallback for JAX versions before it
    existed: ``psum(1, name)`` resolves to the static axis size."""
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(name)
    return jax.lax.psum(1, name)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    new = getattr(jax, "set_mesh", None)
    if new is not None:
        return new(mesh)
    use = getattr(jax.sharding, "use_mesh", None)
    if use is not None:
        return use(mesh)
    # Oldest supported path: Mesh is itself a context manager.
    return mesh
