"""Frozen, hashable configs: what attacks run and what guards watch.

Both configs ride on ``SolverConfig`` and participate in
``static_key()`` (frozen dataclasses hash structurally), so sweep
grouping stays correct when grids mix attack settings:

* Non-padded sweeps key on the *full* config plus the resolved attack
  seed — every distinct attack setting compiles (and batches) its own
  group, and a ``seed``-inheriting attack never silently shares one
  attack schedule across a seed grid.
* Padded sweeps (``pad_agents=True``) key on ``structural_key()`` only:
  ``num_byzantine``, ``scale`` and the attack key become vmap operands,
  so an attack grid batches as *one* dispatch per algorithm — the
  BENCH_byzantine gate.
"""
from __future__ import annotations

import dataclasses
import math

from repro.byzantine.attacks import attack_names
from repro.byzantine.combine import combine_rule_names, make_combine_rule

__all__ = ["ByzantineConfig", "GuardConfig"]


@dataclasses.dataclass(frozen=True)
class ByzantineConfig:
    """Attack injection + robust aggregation for one experiment.

    Attributes:
      kind: attack name from the registry, or ``"none"``.
      num_byzantine: how many slots attack (fixed seeded subset; may be
        swept as a vmap operand under ``pad_agents=True``).
      scale: attack magnitude (attack-specific semantics).
      seed: attack-schedule seed; ``None`` inherits ``SolverConfig.seed``
        (see :meth:`resolve_seed`).
      combine: aggregation rule name (``"weighted"`` is the paper's
        ``M @ X`` and the bitwise no-op default).
      trim: the f of ``trimmed-mean``; ``None`` resolves to
        ``max(num_byzantine, 1)``.  Set it explicitly when sweeping
        ``num_byzantine`` under padding, so the structural key stays
        uniform across the grid.
    """

    kind: str = "none"
    num_byzantine: int = 0
    scale: float = 1.0
    seed: int | None = None
    combine: str = "weighted"
    trim: int | None = None

    def __post_init__(self):
        if self.kind != "none" and self.kind not in attack_names():
            raise ValueError(f"unknown attack kind {self.kind!r}; "
                             f"registered: {attack_names()}")
        if self.combine not in combine_rule_names():
            raise ValueError(f"unknown combine rule {self.combine!r}; "
                             f"registered: {combine_rule_names()}")
        if self.num_byzantine < 0:
            raise ValueError("num_byzantine must be >= 0, got "
                             f"{self.num_byzantine}")
        if not math.isfinite(self.scale):
            raise ValueError(f"scale must be finite, got {self.scale}")
        if self.trim is not None and self.trim < 1:
            raise ValueError(f"trim must be >= 1, got {self.trim}")

    @property
    def attack_active(self) -> bool:
        return self.kind != "none"

    @property
    def active(self) -> bool:
        """Anything here forces the engine off the fast no-wire path."""
        return self.attack_active or self.combine != "weighted"

    def resolve_trim(self) -> int:
        return self.trim if self.trim is not None else max(
            int(self.num_byzantine), 1)

    def resolve_seed(self, fallback: int) -> int:
        return int(fallback if self.seed is None else self.seed)

    def structural_key(self):
        """What a padded group must share; values become operands."""
        trim = self.resolve_trim() if self.combine == "trimmed-mean" else 0
        return ("byzantine", self.kind, self.combine, trim)

    def validate_for(self, m: int) -> None:
        """Loud breakdown errors against a known network size."""
        if self.combine == "trimmed-mean" and 2 * self.resolve_trim() >= m:
            raise ValueError(
                f"trimmed-mean breakdown: f={self.resolve_trim()} needs "
                f"2f < m but m={m}; a majority-trimmed neighborhood has "
                f"no honest signal left")
        if self.attack_active and int(self.num_byzantine) >= m:
            raise ValueError(
                f"num_byzantine={self.num_byzantine} >= m={m}: at least "
                f"one honest agent is required")
        if self.combine != "weighted":
            make_combine_rule(self.combine)  # raises on unknown


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """In-scan divergence trip-wires (all off by default — bit-compat).

    Attributes:
      nan: roll back any step whose x/y iterates contain NaN/Inf.
      max_norm: roll back any step where ||x||_F (over all agents)
        exceeds this; 0 disables the norm trip-wire.

    A tripped step is replaced by the last good carry via ``jnp.where``
    (zero extra compiles) and counted; ``SolveResult.tripped_steps`` /
    ``last_good_step`` surface the counters so benches can report
    time-to-detection.
    """

    nan: bool = False
    max_norm: float = 0.0

    def __post_init__(self):
        if self.max_norm < 0 or not math.isfinite(self.max_norm):
            raise ValueError(f"max_norm must be finite and >= 0, got "
                             f"{self.max_norm}")

    @property
    def active(self) -> bool:
        return self.nan or self.max_norm > 0
