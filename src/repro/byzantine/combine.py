"""Robust combine rules: replacements for the ``M @ X`` contraction.

A :class:`CombineRule` aggregates, for each agent i, the payload rows of
its in-neighborhood — the support ``{j : M[i, j] != 0} ∪ {i}`` of its
mixing row.  Restricting to the support keeps two properties the rest
of the repo depends on:

* **Topology-respecting**: an agent only ever reads payloads its links
  actually deliver, so robust rules compose with link-failure streams
  and gossip matrices unchanged.
* **Ghost-pad invariance**: padded mixing matrices give ghost slots an
  identity row and zero cross-weights, so a ghost is in nobody's
  support (and its own support is just itself).  Whatever garbage a
  ghost row carries, active agents' aggregates are bitwise those of the
  unpadded run — the property ``sweep(..., pad_agents=True)`` is priced
  against.

Unlike ``weighted``, the robust rules are *nonlinear* in the payload:
they are not doubly-stochastic contractions (no exact average
preservation) and the engine's self-clean error-feedback correction
does not apply (see docs/BYZANTINE.md for the full matrix).  They need
all-to-all access to the payload rows, which only the dense backend
has; ``PermuteEngine`` refuses them loudly at construction.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "CombineRule",
    "combine_rule_names",
    "make_combine_rule",
    "register_combine_rule",
    "robust_combine",
]

_RULES: dict[str, type] = {}

_SUPPORT_TOL = 1e-12


def register_combine_rule(name: str):
    """Class decorator: register a :class:`CombineRule` under ``name``."""

    def wrap(cls):
        if name in _RULES:
            raise ValueError(f"combine rule {name!r} already registered "
                             f"({_RULES[name].__name__})")
        cls.name = name
        _RULES[name] = cls
        return cls

    return wrap


def combine_rule_names() -> tuple[str, ...]:
    return tuple(sorted(_RULES))


def make_combine_rule(name: str) -> "CombineRule":
    try:
        return _RULES[name]()
    except KeyError:
        raise ValueError(
            f"unknown combine rule {name!r}; registered: "
            f"{combine_rule_names()}") from None


class CombineRule:
    """Aggregate an (m, D) payload buffer row-neighborhood-wise.

    Attributes:
      needs_all_rows: True when the rule reads payload rows beyond the
        plain weighted contraction — i.e. it cannot run on a backend
        without the full (m, D) buffer (ppermute).
    """

    name = "?"
    needs_all_rows = True

    def aggregate(self, vals: jax.Array, support: jax.Array,
                  matrix: jax.Array, trim: int) -> jax.Array:
        """(m, D) float32 aggregate from (m, D) vals, (m, m) support."""
        raise NotImplementedError


@register_combine_rule("weighted")
class WeightedRule(CombineRule):
    """The paper's contraction ``M @ X`` — the bitwise no-op baseline.

    The only linear rule: preserves double stochasticity (exact average
    invariance) and the engine's self-clean property.  Zero Byzantine
    tolerance — one corrupted row moves every neighbor.
    """

    needs_all_rows = False

    def aggregate(self, vals, support, matrix, trim):
        del support, trim
        return matrix @ vals


@register_combine_rule("coordinate-median")
class CoordinateMedianRule(CombineRule):
    """Per-coordinate median over the in-neighborhood (incl. self).

    Breakdown point 1/2 of the neighborhood; ignores mixing weights
    (every support entry counts once).
    """

    def aggregate(self, vals, support, matrix, trim):
        del matrix, trim

        def one(sup_row):
            masked = jnp.where(sup_row[:, None], vals, jnp.nan)
            return jnp.nanmedian(masked, axis=0)

        return jax.vmap(one)(support)


@register_combine_rule("trimmed-mean")
class TrimmedMeanRule(CombineRule):
    """Drop the f smallest and f largest per coordinate, mean the rest.

    ``trim`` is f.  Tolerates f Byzantine in-neighbors per agent and
    needs ``2f < |support|``; a neighborhood too small to trim falls
    back to the plain support mean (never an empty aggregate).  The
    breakdown bound against the global m is enforced at engine
    construction (a loud config error, not a silent NaN).
    """

    def aggregate(self, vals, support, matrix, trim):
        del matrix
        m = vals.shape[0]
        idx = jnp.arange(m)[:, None]

        def one(sup_row):
            keyed = jnp.where(sup_row[:, None], vals, jnp.inf)
            order = jnp.argsort(keyed, axis=0)
            svals = jnp.take_along_axis(vals, order, axis=0)
            ssup = jnp.take_along_axis(
                jnp.broadcast_to(sup_row[:, None], vals.shape), order,
                axis=0)
            cnt = jnp.sum(sup_row)
            keep = ssup & (idx >= trim) & (idx < cnt - trim)
            keep = jnp.where(cnt > 2 * trim, keep, ssup)
            total = jnp.sum(jnp.where(keep, svals, 0.0), axis=0)
            return total / jnp.maximum(jnp.sum(keep, axis=0), 1)

        return jax.vmap(one)(support)


@register_combine_rule("krum-like")
class KrumLikeRule(CombineRule):
    """Nearest-neighbor screening: adopt the most central support row.

    Each agent scores every in-neighbor payload by its summed squared
    distance to the *other* support rows and adopts the row with the
    smallest score — a Krum-style selection restricted to the local
    neighborhood (true Krum also trims the k furthest from the score;
    with the small per-agent neighborhoods here the plain argmin is the
    stable variant).  Output is always one of the received rows, so a
    colluding majority in a neighborhood defeats it (breakdown at
    f >= |support|/2, like the other rules).
    """

    def aggregate(self, vals, support, matrix, trim):
        del matrix, trim
        diff = vals[:, None, :] - vals[None, :, :]
        d2 = jnp.sum(diff * diff, axis=-1)

        def one(sup_row):
            pair = sup_row[:, None] & sup_row[None, :]
            scores = jnp.sum(jnp.where(pair, d2, 0.0), axis=1)
            scores = jnp.where(sup_row, scores, jnp.inf)
            return vals[jnp.argmin(scores)]

        return jax.vmap(one)(support)


def robust_combine(matrix: jax.Array, tree, rule: str, trim: int = 1):
    """Aggregate a payload pytree under ``rule`` over the support of
    ``matrix`` (plus the diagonal), preserving leaf shapes/dtypes.

    Leaves are flattened to one (m, D) float32 buffer (krum scores need
    the full rows) and split back after aggregation.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    m = leaves[0].shape[0]
    flat = [leaf.astype(jnp.float32).reshape(m, -1) for leaf in leaves]
    sizes = [f.shape[1] for f in flat]
    vals = flat[0] if len(flat) == 1 else jnp.concatenate(flat, axis=1)
    mat = jnp.asarray(matrix, jnp.float32)
    support = (jnp.abs(mat) > _SUPPORT_TOL) | jnp.eye(m, dtype=bool)
    out = make_combine_rule(rule).aggregate(vals, support, mat, trim)
    pieces, off = [], 0
    for leaf, size in zip(leaves, sizes):
        piece = out[:, off:off + size]
        pieces.append(piece.reshape(leaf.shape).astype(leaf.dtype))
        off += size
    return jax.tree_util.tree_unflatten(treedef, pieces)
