"""Attack registry: seeded in-scan payload corruption.

An attack corrupts the payload a Byzantine agent *ships* on the wire;
its local state is untouched (a real adversary lies to its neighbors,
it does not have to damage itself).  Which slots are Byzantine is a
fixed seeded subset (:func:`byzantine_mask`) — the same agents attack
every round, which is both the standard threat model and what makes the
corrupted schedule reproducible.  Per-round randomness (the gaussian
and same-value draws) folds the step counter into the attack key, so a
re-run with the same ``ByzantineConfig.seed`` replays the identical
corrupted schedule.

Every derivation uses the per-slot ``fold_in`` idiom from
``repro.core.svr_interact.per_agent_keys``: slot i's draw depends only
on (key, i), never on m, so ghost-padded sweeps (``pad_agents=True``)
corrupt the active slots bitwise-identically to the unpadded run and a
``num_active`` operand can exclude ghost slots under ``vmap``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "Attack",
    "apply_attack",
    "attack_names",
    "byzantine_mask",
    "make_attack",
    "register_attack",
]

_ATTACKS: dict[str, type] = {}


def register_attack(name: str):
    """Class decorator: register an :class:`Attack` under ``name``."""

    def wrap(cls):
        if name in _ATTACKS:
            raise ValueError(f"attack {name!r} already registered "
                             f"({_ATTACKS[name].__name__})")
        cls.name = name
        _ATTACKS[name] = cls
        return cls

    return wrap


def attack_names() -> tuple[str, ...]:
    return tuple(sorted(_ATTACKS))


def make_attack(kind: str) -> "Attack":
    try:
        return _ATTACKS[kind]()
    except KeyError:
        raise ValueError(
            f"unknown attack {kind!r}; registered: {attack_names()}"
        ) from None


class Attack:
    """One way a Byzantine slot corrupts the payload it ships.

    Attributes:
      streams: which wire streams the attack touches.  INTERACT ships
        two streams per round — ``"x"`` (the outer iterate, eq. 6) and
        ``"u"`` (the tracked hypergradient, eq. 10).  The inner iterate
        y never crosses the wire, so the bilevel-specific
        ``inner-outer-split`` attack targets ``"u"``: the only wire
        stream carrying inner-problem information.
    """

    name = "?"
    streams: tuple[str, ...] = ("x", "u")

    def corrupt_row(self, row: jax.Array, slot_key: jax.Array,
                    leaf_key: jax.Array, scale) -> jax.Array:
        """Corrupted float32 payload for one agent's slice of one leaf.

        ``slot_key`` is private to the slot (independent noise);
        ``leaf_key`` is shared by every slot this round (collusion).
        """
        raise NotImplementedError


@register_attack("sign-flip")
class SignFlipAttack(Attack):
    """Ship ``-scale * value``: the classic direction-reversal attack."""

    def corrupt_row(self, row, slot_key, leaf_key, scale):
        del slot_key, leaf_key
        return -jnp.float32(1.0) * scale * row


@register_attack("gaussian")
class GaussianAttack(Attack):
    """Add ``scale``-sized gaussian noise, independent per slot."""

    def corrupt_row(self, row, slot_key, leaf_key, scale):
        del leaf_key
        return row + scale * jax.random.normal(slot_key, row.shape,
                                               jnp.float32)


@register_attack("same-value")
class SameValueAttack(Attack):
    """Collusion: every Byzantine slot ships the *same* random vector.

    Defeats per-agent outlier screens that assume attackers are
    mutually inconsistent — f colluding slots form a plausible cluster
    (the case trimmed-mean handles but naive distance filters do not).
    """

    def corrupt_row(self, row, slot_key, leaf_key, scale):
        del slot_key
        return scale * jax.random.normal(leaf_key, row.shape, jnp.float32)


@register_attack("inner-outer-split")
class InnerOuterSplitAttack(SignFlipAttack):
    """Sign-flip the tracking stream only (bilevel-specific).

    The outer iterate x is shipped honestly while the ``u`` stream —
    the gradient-tracking estimate built from the *inner*-problem
    hypergradient (eqs. 8–10) — is reversed.  Consensus on x looks
    healthy, but the descent direction every honest agent tracks is
    poisoned; a no-op against single-level baselines like D-SGD whose
    wire carries x alone.
    """

    streams = ("u",)


def byzantine_mask(key: jax.Array, m: int, num_byzantine,
                   num_active=None) -> jax.Array:
    """(m,) bool: which slots are Byzantine — fixed, seeded, pad-safe.

    Each slot draws a uniform score from ``fold_in(key, slot)``; the
    ``num_byzantine`` smallest-ranked *active* slots attack.  Because
    slot i's score never depends on m or ``num_active``, padding the
    network (ghost slots at the tail) leaves active slots' scores — and
    therefore their ranks among actives — unchanged: ghosts are scored
    ``inf`` and can never be selected.  ``num_byzantine`` and
    ``num_active`` may be traced (sweep batch operands).
    """
    slots = jnp.arange(m)
    scores = jax.vmap(
        lambda i: jax.random.uniform(jax.random.fold_in(key, i)))(slots)
    if num_active is not None:
        scores = jnp.where(slots < num_active, scores, jnp.inf)
    rank = jnp.sum(scores[None, :] < scores[:, None], axis=1)
    return (rank < num_byzantine) & jnp.isfinite(scores)


def apply_attack(attack: Attack, tree, mask: jax.Array, key_t: jax.Array,
                 scale, *, slots: jax.Array | None = None):
    """Corrupt the masked rows of every leaf; honest rows pass bitwise.

    Args:
      tree: payload pytree with a leading agent axis on every leaf.
      mask: bool, one entry per *local* row of ``tree``.
      key_t: per-(step, stream) attack key — already folded with t.
      scale: attack magnitude (may be traced).
      slots: global slot id of each local row (defaults to
        ``arange(rows)``).  A sharded backend holding rows
        ``[i*L, (i+1)*L)`` passes those ids so its draws match the
        dense reference bitwise.

    Honest (and all, when ``mask`` is all-False) rows go through
    ``jnp.where`` against their float32 selves, so a zero-attacker
    config is bitwise identical to no attack at all.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out = []
    for li, leaf in enumerate(leaves):
        leaf_key = jax.random.fold_in(key_t, li)
        rows = leaf.shape[0]
        ids = jnp.arange(rows) if slots is None else slots
        slot_keys = jax.vmap(
            lambda i: jax.random.fold_in(leaf_key, i))(ids)
        clean = leaf.astype(jnp.float32)
        bad = jax.vmap(
            lambda row, k: attack.corrupt_row(row, k, leaf_key, scale)
        )(clean, slot_keys)
        shaped = mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
        out.append(jnp.where(shaped, bad, clean).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
