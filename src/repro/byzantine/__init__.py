"""Byzantine-resilient consensus: attacks, robust combines, guards.

The paper motivates decentralized bilevel learning for peer-to-peer
settings where agents cannot be trusted; this package models the
*adversarial* end of that spectrum (the topology subsystem covers the
silent failures).  Three orthogonal layers, all carried by
``SolverConfig`` and threaded through the consensus engine so every
experiment — single solve, batched sweep, padded network grid — runs
attacks, robust aggregation and divergence guards inside the one
compiled ``lax.scan``:

* **Attack registry** (:mod:`repro.byzantine.attacks`): a fixed seeded
  subset of agent slots ships corrupted payloads every communication
  round (``sign-flip``, ``gaussian``, ``same-value`` collusion,
  ``inner-outer-split``).  Corruption happens *before* compression so
  error-feedback reference copies track what was actually transmitted.
* **Combine rules** (:mod:`repro.byzantine.combine`): ``weighted`` (the
  bitwise no-op baseline), ``coordinate-median``, ``trimmed-mean`` and
  ``krum-like`` replace the plain ``M @ X`` contraction, aggregating
  over each agent's in-neighborhood (the support of its mixing row).
* **Guards** (:mod:`repro.byzantine.guards`): NaN/Inf and iterate-norm
  trip-wires in the scan carry with ``jnp.where`` rollback-to-last-good
  — zero extra compiles, surfaced through ``SolveResult``.

See docs/BYZANTINE.md for the full matrix of which rules preserve the
self-clean / doubly-stochastic consensus semantics.
"""
from repro.byzantine.attacks import (
    Attack,
    apply_attack,
    attack_names,
    byzantine_mask,
    make_attack,
    register_attack,
)
from repro.byzantine.combine import (
    CombineRule,
    combine_rule_names,
    make_combine_rule,
    register_combine_rule,
    robust_combine,
)
from repro.byzantine.config import ByzantineConfig, GuardConfig
from repro.byzantine.guards import guard_param_step, init_guard

__all__ = [
    "Attack",
    "ByzantineConfig",
    "CombineRule",
    "GuardConfig",
    "apply_attack",
    "attack_names",
    "byzantine_mask",
    "combine_rule_names",
    "guard_param_step",
    "init_guard",
    "make_attack",
    "make_combine_rule",
    "register_attack",
    "register_combine_rule",
    "robust_combine",
]
