"""In-scan divergence guards: trip-wires + rollback in the scan carry.

The guard wraps the parameterised step body
``param_step(state, data, alpha, beta)`` — the one form every registry
solver exposes — so the same wrapper covers single solves, batched
sweeps and padded network grids.  Detection and rollback are pure
``jnp.where`` data flow on the existing carry: no ``lax.cond`` branches,
no extra compiles, and a guarded run with nothing tripped is the
unguarded trajectory plus two integer counters.

The counters ride the state's trailing ``guard`` field (``None``
default, same trick as the ``ef`` wire state, so unguarded states keep
their pre-guard pytree structure bitwise).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["guard_param_step", "init_guard"]

# state fields the rollback must NOT rewind: the step counter and the
# sampling key keep advancing (or a tripped run would replay the same
# minibatch forever), and the guard counters are updated separately.
_NEVER_ROLLED = ("t", "key", "guard")


def init_guard(cfg) -> dict | None:
    """The guard carry for a fresh state: counters at zero, or ``None``
    when the config is inactive (bit-compat with unguarded states)."""
    if cfg is None or not cfg.active:
        return None
    return {"tripped": jnp.zeros((), jnp.int32),
            "last_good": jnp.zeros((), jnp.int32)}


def _tripped(cfg, state):
    """Scalar bool: does the candidate state trip any wire?"""
    checks = []
    if cfg.nan:
        for leaf in jax.tree_util.tree_leaves((state.x, state.y)):
            checks.append(~jnp.all(jnp.isfinite(leaf)))
    if cfg.max_norm > 0.0:
        sq = sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                 for leaf in jax.tree_util.tree_leaves(state.x))
        checks.append(sq > jnp.float32(cfg.max_norm) ** 2)
    bad = checks[0]
    for check in checks[1:]:
        bad = bad | check
    return bad


def guard_param_step(param_step, cfg):
    """Wrap a ``step(state, data, alpha, beta)`` body with the guard.

    A tripped step rolls every iterate field back to the incoming carry
    (the last good state, by induction); ``t``/``key`` keep advancing
    and the ``guard`` counters record the trip.  ``last_good`` holds the
    step counter of the most recent accepted state.
    """

    def step(state, data, alpha, beta):
        new = param_step(state, data, alpha, beta)
        if getattr(new, "guard", None) is None:
            raise ValueError(
                "GuardConfig is active but the solver state carries no "
                "guard counters; initialize with guard=init_guard(cfg) "
                "(the registry solvers do this from SolverConfig.guard)")
        bad = _tripped(cfg, new)
        rolled = {
            field: jax.tree_util.tree_map(
                lambda old, cand: jnp.where(bad, old, cand),
                getattr(state, field), getattr(new, field))
            for field in new._fields if field not in _NEVER_ROLLED
        }
        step_idx = jnp.asarray(new.t, jnp.int32)
        guard = {"tripped": new.guard["tripped"] + bad.astype(jnp.int32),
                 "last_good": jnp.where(bad, new.guard["last_good"],
                                        step_idx)}
        return new._replace(guard=guard, **rolled)

    return step
