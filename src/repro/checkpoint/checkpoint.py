"""Pytree checkpointing (npz-based; no external deps).

Saves arbitrary pytrees (model params, TrainState, optimizer states) with
their treedef encoded as a JSON key-path manifest, so restore round-trips
exactly — including NamedTuples and nested dicts/lists — onto the same or
a different mesh (arrays come back as host numpy; re-shard with
``jax.device_put``).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any

import jax
import numpy as np

__all__ = ["save_pytree", "restore_pytree", "latest_step", "save_step",
           "restore_step"]


def _flatten_with_paths(tree):
    flat, _treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves


def save_pytree(path: str | pathlib.Path, tree: Any) -> None:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    paths, leaves = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    manifest = json.dumps(paths)
    np.savez(path, __manifest__=np.frombuffer(
        manifest.encode(), dtype=np.uint8), **arrays)


def restore_pytree(path: str | pathlib.Path, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(pathlib.Path(path), allow_pickle=False)
    manifest = json.loads(bytes(data["__manifest__"]).decode())
    paths_like, leaves_like = _flatten_with_paths(like)
    if paths_like != manifest:
        raise ValueError(
            "checkpoint structure mismatch:\n"
            f"  saved:    {manifest[:5]}...\n  expected: {paths_like[:5]}...")
    leaves = [data[f"leaf_{i}"] for i in range(len(manifest))]
    for got, want in zip(leaves, leaves_like):
        if tuple(got.shape) != tuple(np.shape(want)):
            raise ValueError(f"shape mismatch {got.shape} vs {np.shape(want)}")
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_step(ckpt_dir: str | pathlib.Path, step: int, tree: Any) -> None:
    save_pytree(pathlib.Path(ckpt_dir) / f"step_{step:08d}.npz", tree)


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.stem.split("_")[1]) for p in d.glob("step_*.npz"))
    return steps[-1] if steps else None


def restore_step(ckpt_dir: str | pathlib.Path, step: int, like: Any) -> Any:
    return restore_pytree(
        pathlib.Path(ckpt_dir) / f"step_{step:08d}.npz", like)
