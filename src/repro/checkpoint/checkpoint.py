"""Pytree checkpointing (npz-based; no external deps).

Saves arbitrary pytrees (model params, TrainState, optimizer states) with
their treedef encoded as a JSON key-path manifest, so restore round-trips
exactly — including NamedTuples and nested dicts/lists — onto the same or
a different mesh (arrays come back as host numpy; re-shard with
``jax.device_put``).

Crash safety (docs/RESILIENCE.md): every save writes to a temp file in
the target directory and lands via atomic ``os.replace`` — a process
killed mid-write leaves the previous checkpoint intact, never a
truncated ``.npz``.  The manifest (version 2) records a per-leaf CRC32
and dtype next to the key paths, so restore detects bit-rot and silent
dtype reinterpretation instead of feeding garbage downstream; version-1
checkpoints (bare path list) still restore, minus those checks.
``latest_step`` / ``restore_step`` skip unreadable or CRC-failing files
and fall back to the newest *valid* checkpoint, which is what makes a
directory that survived a crash (or a chaos fault plan) resumable
without manual cleanup.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import warnings
import zlib
from typing import Any

import jax
import numpy as np

__all__ = ["CorruptCheckpointError", "latest_step", "restore_latest",
           "restore_pytree", "restore_step", "save_pytree", "save_step",
           "valid_steps", "verify_checkpoint"]

MANIFEST_VERSION = 2


class CorruptCheckpointError(ValueError):
    """A checkpoint file failed integrity validation (truncated archive,
    unparseable manifest, CRC mismatch, or a leaf count/dtype that
    contradicts its own manifest)."""


def _flatten_with_paths(tree):
    flat, _treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves


def save_pytree(path: str | pathlib.Path, tree: Any) -> None:
    """Atomically write ``tree`` to ``path`` (temp file + ``os.replace``).

    The version-2 manifest records, per leaf: its key path, its dtype
    (restore refuses silent reinterpretation against the template), and
    the CRC32 of its bytes (restore refuses bit-rot).
    """
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    paths, leaves = _flatten_with_paths(tree)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    manifest = json.dumps({
        "version": MANIFEST_VERSION,
        "paths": paths,
        "dtypes": [str(arrays[f"leaf_{i}"].dtype)
                   for i in range(len(leaves))],
        "crcs": [zlib.crc32(np.ascontiguousarray(
            arrays[f"leaf_{i}"]).tobytes()) for i in range(len(leaves))],
    })
    # temp file in the TARGET directory: os.replace is atomic only
    # within one filesystem, and a kill mid-write must never leave a
    # half-written file under the final name.
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=path.name + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez(fh, __manifest__=np.frombuffer(
                manifest.encode(), dtype=np.uint8), **arrays)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def _load_manifest(data) -> dict:
    """Parse either manifest version into the v2 dict shape."""
    try:
        manifest = json.loads(bytes(data["__manifest__"]).decode())
    except Exception as exc:
        raise CorruptCheckpointError(
            f"unparseable checkpoint manifest: {exc}") from exc
    if isinstance(manifest, list):        # version 1: bare path list
        return {"version": 1, "paths": manifest, "dtypes": None,
                "crcs": None}
    return manifest


def _load_leaves(data, manifest: dict, path) -> list[np.ndarray]:
    """The leaf arrays, CRC- and dtype-validated against the manifest."""
    paths = manifest["paths"]
    try:
        leaves = [data[f"leaf_{i}"] for i in range(len(paths))]
    except Exception as exc:
        raise CorruptCheckpointError(
            f"{path}: leaf array missing or unreadable ({exc})") from exc
    if manifest.get("crcs") is not None:
        for i, (leaf, want) in enumerate(zip(leaves, manifest["crcs"])):
            got = zlib.crc32(np.ascontiguousarray(leaf).tobytes())
            if got != want:
                raise CorruptCheckpointError(
                    f"{path}: CRC mismatch on leaf {i} "
                    f"({manifest['paths'][i]}): stored {want}, "
                    f"recomputed {got}")
    if manifest.get("dtypes") is not None:
        for i, (leaf, want) in enumerate(zip(leaves, manifest["dtypes"])):
            if str(leaf.dtype) != want:
                raise CorruptCheckpointError(
                    f"{path}: leaf {i} ({manifest['paths'][i]}) decoded "
                    f"as {leaf.dtype} but the manifest records {want}")
    return leaves


def restore_pytree(path: str | pathlib.Path, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    path = pathlib.Path(path)
    try:
        data = np.load(path, allow_pickle=False)
    except OSError:
        raise
    except Exception as exc:   # truncated zip, bad magic, ...
        raise CorruptCheckpointError(
            f"{path}: unreadable archive ({exc})") from exc
    with data:
        manifest = _load_manifest(data)
        paths_like, leaves_like = _flatten_with_paths(like)
        if paths_like != manifest["paths"]:
            raise ValueError(
                "checkpoint structure mismatch:\n"
                f"  saved:    {manifest['paths'][:5]}...\n"
                f"  expected: {paths_like[:5]}...")
        leaves = _load_leaves(data, manifest, path)
    for i, (got, want) in enumerate(zip(leaves, leaves_like)):
        if tuple(got.shape) != tuple(np.shape(want)):
            raise ValueError(f"shape mismatch {got.shape} vs "
                             f"{np.shape(want)}")
        want_dtype = np.asarray(want).dtype
        if got.dtype != want_dtype:
            raise ValueError(
                f"dtype mismatch on leaf {i} ({manifest['paths'][i]}): "
                f"checkpoint holds {got.dtype}, template expects "
                f"{want_dtype} — refusing silent reinterpretation")
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def verify_checkpoint(path: str | pathlib.Path) -> bool:
    """Can this file be restored? (readable archive, parseable manifest,
    all leaves present with matching CRCs/dtypes — structure NOT checked,
    that needs a template)."""
    try:
        data = np.load(pathlib.Path(path), allow_pickle=False)
    except Exception:
        return False
    try:
        with data:
            manifest = _load_manifest(data)
            _load_leaves(data, manifest, path)
        return True
    except Exception:
        return False


def save_step(ckpt_dir: str | pathlib.Path, step: int, tree: Any) -> None:
    save_pytree(_step_path(ckpt_dir, step), tree)


def _step_path(ckpt_dir, step: int) -> pathlib.Path:
    return pathlib.Path(ckpt_dir) / f"step_{step:08d}.npz"


def _all_steps(ckpt_dir) -> list[int]:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return []
    steps = []
    for p in d.glob("step_*.npz"):
        try:
            steps.append(int(p.stem.split("_")[1]))
        except (IndexError, ValueError):
            continue   # stray file matching the glob, not a checkpoint
    return sorted(steps)


def valid_steps(ckpt_dir: str | pathlib.Path) -> list[int]:
    """Ascending steps whose checkpoint files pass integrity validation."""
    return [s for s in _all_steps(ckpt_dir)
            if verify_checkpoint(_step_path(ckpt_dir, s))]


def latest_step(ckpt_dir: str | pathlib.Path,
                validate: bool = True) -> int | None:
    """The newest restorable step (``None`` when the directory is empty).

    ``validate=True`` (default) skips unreadable / CRC-failing files and
    returns the newest checkpoint that actually verifies — a crash that
    corrupted the most recent file falls back to the one before it
    instead of poisoning the resume.  ``validate=False`` is the legacy
    name-ordering answer (no file reads).
    """
    steps = _all_steps(ckpt_dir)
    if not validate:
        return steps[-1] if steps else None
    for s in reversed(steps):
        if verify_checkpoint(_step_path(ckpt_dir, s)):
            return s
    return None


def restore_step(ckpt_dir: str | pathlib.Path, step: int, like: Any,
                 fallback: bool = False) -> Any:
    """Restore the checkpoint at ``step``.

    ``fallback=True``: when that file is corrupt or missing, warn and
    restore the newest *older* step that validates instead of raising —
    the behaviour a crash-resumed run wants (``restore_latest`` also
    reports which step was used).
    """
    if not fallback:
        return restore_pytree(_step_path(ckpt_dir, step), like)
    out = restore_latest(ckpt_dir, like, max_step=step)
    if out is None:
        raise FileNotFoundError(
            f"no restorable checkpoint at or before step {step} "
            f"in {ckpt_dir}")
    tree, used = out
    if used != step:
        warnings.warn(
            f"checkpoint step {step} in {ckpt_dir} is corrupt or "
            f"missing; fell back to step {used}", stacklevel=2)
    return tree


def restore_latest(ckpt_dir: str | pathlib.Path, like: Any,
                   max_step: int | None = None
                   ) -> tuple[Any, int] | None:
    """``(tree, step)`` of the newest checkpoint ≤ ``max_step`` that
    restores cleanly, skipping corrupt files; ``None`` if none does.

    Structure/shape mismatches (a *valid* checkpoint for a different
    template) still raise — falling back past those would silently
    resume from the wrong run.
    """
    steps = _all_steps(ckpt_dir)
    if max_step is not None:
        steps = [s for s in steps if s <= max_step]
    for s in reversed(steps):
        try:
            return restore_pytree(_step_path(ckpt_dir, s), like), s
        except (CorruptCheckpointError, OSError):
            continue
    return None
