"""Crash-safe pytree checkpointing (see docs/RESILIENCE.md)."""
from repro.checkpoint.checkpoint import (
    CorruptCheckpointError,
    latest_step,
    restore_latest,
    restore_pytree,
    restore_step,
    save_pytree,
    save_step,
    valid_steps,
    verify_checkpoint,
)

__all__ = [
    "CorruptCheckpointError",
    "latest_step",
    "restore_latest",
    "restore_pytree",
    "restore_step",
    "save_pytree",
    "save_step",
    "valid_steps",
    "verify_checkpoint",
]
