"""The paper's bilevel problem instantiated on the assigned architectures.

Per agent i (Section 3.2 meta-learning form, scaled up):

  outer  f_i(x, y_i) = CE(head y_i on backbone_x(outer split)) + router aux
  inner  g_i(x, y_i) = CE(head y_i on backbone_x(inner split)) + (mu/2)||y_i||^2

x = backbone parameters (consensus variable), y_i = per-agent LM head
(d_model, vocab) — strongly convex inner problem via the ridge.

Hypergradient (eq. 5 / 22) exploits the readout structure: H_yy(g) touches
x only through the backbone features, so the K-term Neumann series runs in
*head space* on cached features (K cheap HVPs, no backbone recompute); the
single cross-term H_xy z is one extra backward through the backbone.  This
is mathematically identical to eq. (22) — the factorisation is recorded as
a beyond-paper efficiency in EXPERIMENTS.md §Perf.

The LM-head cross entropy is computed in *sequence chunks* (lax.scan) so
the (tokens, vocab) logits tensor never materialises — peak activation
memory drops from O(b s V / shards) to O(b chunk V / shards).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.hypergrad.neumann import neumann_truncated_apply
from repro.models import model as M
from repro.models.base import ArchConfig

__all__ = ["BilevelHyper", "chunked_ce", "inner_loss", "outer_loss",
           "local_grads", "ridge"]

DEFAULT_CE_CHUNK = 512


@dataclasses.dataclass(frozen=True)
class BilevelHyper:
    """Hyper-parameters of the bilevel LM problem + estimator."""

    mu_g: float = 0.1            # inner strong convexity (ridge)
    neumann_k: int = 4           # K of eq. (22)
    lipschitz_g: float = 2.0     # L_g scale for the Neumann series
    ce_chunk: int = DEFAULT_CE_CHUNK
    remat: bool = True
    attn_impl: str = "reference"
    seq_shard: bool = False   # P4: sequence-shard the residual stream
    batch_shard: bool = False  # P6: batch-shard residuals over 'data'
    microbatch: int = 1        # P8: gradient-accumulation microbatches
    unroll_scans: bool = False  # old-JAX partial-auto shard_map compat:
    #   unroll layer scan / CE scan / Neumann loop (the SPMD partitioner
    #   there cannot shard while-loops over manual subgroups)


def ridge(y: jax.Array, mu: float) -> jax.Array:
    return 0.5 * mu * jnp.sum(jnp.square(y.astype(jnp.float32)))


def chunked_ce(cfg: ArchConfig, head: jax.Array, feats: jax.Array,
               labels: jax.Array, chunk: int,
               unroll: bool = False) -> jax.Array:
    """Next-token CE with the head applied chunk-by-chunk over tokens.

    feats: (b, s, d) backbone outputs; labels: (b, s) token ids (the
    sequence itself — shift happens here).  The prefix (vlm/audio) part of
    feats, if any, is dropped by aligning on the label length.
    """
    b, s_lab = labels.shape
    n_pre = feats.shape[1] - s_lab
    f = feats[:, n_pre:][:, :-1]                     # predict next token
    l = labels[:, 1:]
    ft = f.reshape(-1, f.shape[-1])
    lt = l.reshape(-1)
    n = ft.shape[0]
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        ft = jnp.pad(ft, ((0, pad), (0, 0)))
        lt = jnp.pad(lt, ((0, pad),))
    valid = (jnp.arange(ft.shape[0]) < n).astype(jnp.float32)
    ft = ft.reshape(-1, chunk, ft.shape[-1])
    lt = lt.reshape(-1, chunk)
    vt = valid.reshape(-1, chunk)

    def body(acc, xs):
        fc, lc, vc = xs
        logits = M.head_logits(cfg, head, fc[None]).astype(jnp.float32)[0]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
        return acc + jnp.sum((logz - gold) * vc), None

    if unroll:
        total = jnp.zeros((), jnp.float32)
        for c in range(ft.shape[0]):
            total, _ = body(total, (ft[c], lt[c], vt[c]))
    else:
        total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                                (ft, lt, vt))
    return total / jnp.float32(n)


def _backbone(cfg: ArchConfig, x, tokens, prefix, hyper: BilevelHyper):
    from jax.sharding import PartitionSpec as P
    act_spec = None
    if hyper.batch_shard:
        act_spec = P("data", None, None)
    elif hyper.seq_shard:
        act_spec = P(None, "model", None)
    return M.features(cfg, x, tokens, prefix_embed=prefix,
                      impl=hyper.attn_impl, remat=hyper.remat,
                      act_spec=act_spec,
                      scan_layers=not hyper.unroll_scans)


def inner_loss(cfg: ArchConfig, hyper: BilevelHyper, x, y, tokens,
               prefix=None) -> jax.Array:
    feats, _aux = _backbone(cfg, x, tokens, prefix, hyper)
    return (chunked_ce(cfg, y, feats, tokens, hyper.ce_chunk,
                       unroll=hyper.unroll_scans)
            + ridge(y, hyper.mu_g))


def outer_loss(cfg: ArchConfig, hyper: BilevelHyper, x, y, tokens,
               prefix=None) -> jax.Array:
    feats, aux = _backbone(cfg, x, tokens, prefix, hyper)
    ce = chunked_ce(cfg, y, feats, tokens, hyper.ce_chunk,
                    unroll=hyper.unroll_scans)
    return ce + cfg.router_aux_weight * aux


def _head_loss_on_feats(cfg: ArchConfig, hyper: BilevelHyper, y, feats,
                        labels) -> jax.Array:
    return (chunked_ce(cfg, y, feats, labels, hyper.ce_chunk,
                       unroll=hyper.unroll_scans)
            + ridge(y, hyper.mu_g))


def _neumann_head(cfg, hyper: BilevelHyper, y, feats, labels, b):
    """[H_yy g]^{-1} b via the K-term Neumann series in head space.

    The head-space HVP is linearized once (``jax.linearize`` on the head
    gradient at the cached features) and the K-term chain of eq. (22)
    replays the stored tangent through the shared
    ``repro.hypergrad.neumann_truncated_apply`` — the engine package's
    linearize-once discipline applied to the LM fast path, with the
    chain's final (discarded) HVP skipped.
    """
    grad_fn = jax.grad(
        lambda yy: _head_loss_on_feats(cfg, hyper, yy, feats, labels))
    _, hvp_lin = jax.linearize(grad_fn, y)
    z, _count = neumann_truncated_apply(
        hvp_lin, b, hyper.neumann_k, hyper.lipschitz_g,
        unroll=hyper.unroll_scans, skip_last=True)
    return z


def _accum_grads(loss_of_tokens, args, tokens, k, argnums):
    """Gradient accumulation over k microbatches (perf P8): peak
    activation memory of the pass drops by ~k; grads are exact means."""
    b = tokens.shape[0]
    tb = tokens.reshape(k, b // k, *tokens.shape[1:])

    def body(carry, toks):
        val, grads = carry
        v, g = jax.value_and_grad(loss_of_tokens, argnums=argnums)(
            *args, toks)
        grads = jax.tree_util.tree_map(
            lambda a, gi: a + gi / k, grads, g)
        return (val + v / k, grads), None

    zeros = jax.tree_util.tree_map(
        jnp.zeros_like, tuple(args[i] for i in argnums))
    (val, grads), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), zeros), tb)
    return val, grads


def local_grads(cfg: ArchConfig, hyper: BilevelHyper, x, y,
                inner_tokens, outer_tokens, prefix_inner=None,
                prefix_outer=None):
    """(p, v, outer_ce): the paper's eqs. (8)-(9) for the LM problem.

    p = grad_x f - H_xy(g) [H_yy(g)]^{-1} grad_y f     (hypergradient)
    v = grad_y g                                        (inner gradient)
    """
    k = hyper.microbatch
    use_mb = (k > 1 and prefix_outer is None and prefix_inner is None
              and outer_tokens.shape[0] % k == 0
              and inner_tokens.shape[0] % k == 0)

    # --- outer: grad wrt both x and y (one fwd+bwd through the backbone).
    def f_loss(xp, yh):
        return outer_loss(cfg, hyper, xp, yh, outer_tokens, prefix_outer)

    if use_mb:
        outer_val, (gx_f, gy_f) = _accum_grads(
            lambda xp, yh, toks: outer_loss(cfg, hyper, xp, yh, toks),
            (x, y), outer_tokens, k, (0, 1))
    else:
        outer_val, (gx_f, gy_f) = jax.value_and_grad(
            f_loss, argnums=(0, 1))(x, y)

    # --- inner features, computed once and reused by the K head-space HVPs.
    feats_in, _ = _backbone(cfg, x, inner_tokens, prefix_inner, hyper)
    feats_in = jax.lax.stop_gradient(feats_in)
    z = _neumann_head(cfg, hyper, y, feats_in, inner_tokens, gy_f)

    # --- cross term H_xy(g) z = grad_x d/de g(x, y + e z)  (one fwd+bwd).
    if use_mb:
        def cross_mb(xp, toks):
            def g_of_y(yh):
                return inner_loss(cfg, hyper, xp, yh, toks, None)
            return jax.jvp(g_of_y, (y,), (z,))[1]

        _, (gx_cross,) = _accum_grads(cross_mb, (x,), inner_tokens, k, (0,))
    else:
        def cross(xp):
            def g_of_y(yh):
                return inner_loss(cfg, hyper, xp, yh, inner_tokens,
                                  prefix_inner)
            return jax.jvp(g_of_y, (y,), (z,))[1]

        gx_cross = jax.grad(cross)(x)

    p = jax.tree_util.tree_map(lambda a, b: a - b, gx_f, gx_cross)
    v = jax.grad(
        lambda yh: _head_loss_on_feats(cfg, hyper, yh, feats_in,
                                       inner_tokens))(y)
    return p, v, outer_val
