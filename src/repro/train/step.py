"""Distributed INTERACT train step (shard_map + pjit hybrid).

Layout (DESIGN.md §5): the paper's m agents are the rows of the agent axes
(("data",) single-pod, ("pod", "data") multi-pod).  Every per-agent tensor
carries a leading agent dim of size m sharded one-agent-per-row, so the
per-device footprint equals plain data-parallel training while each agent
keeps a *distinct* x_i — exactly Problem (1).

The step body runs under ``shard_map`` over the agent axes only; the
``model`` axis stays auto, so XLA partitions every einsum in the backbone
exactly as in the serving path.  Consensus (eqs. 6/10) goes through the
``ConsensusEngine`` selected by ``InteractConfig`` — by default the
``ppermute`` backend, which decomposes the configured topology's mixing
matrix (ring, Erdős–Rényi, or torus — see ``InteractConfig.topology``)
into per-offset neighbour exchanges, so the paper-faithful ER-graph
Section-6 scenario runs on the distributed runtime, not just the ICI
ring.  int8 wire compression and local-DP noise are engine options.

One call == one INTERACT iteration (Algorithm 1), expressed through the
shared ``consensus_descent_and_track`` step-core (repro/consensus):
  Step 1: x <- mix(x) - alpha*u ; y <- y - beta*v
  Step 2: (p, v) local hypergradient / inner gradient at the new iterate
  Step 3: u <- mix(u) + p - p_prev
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.consensus import consensus_descent_and_track, make_engine
from repro.core.consensus import MixingSpec
from repro.launch.mesh import agent_axes, agent_count
from repro.models import model as M
from repro.models.base import ArchConfig
from repro.sharding.compat import PARTIAL_AUTO_COLLECTIVES_SAFE, shard_map
from repro.sharding.partition import (
    leaf_spec, stacked_tree_specs, tree_shardings)
from repro.train.bilevel_lm import BilevelHyper, local_grads

__all__ = ["TrainState", "InteractConfig", "init_train_state",
           "train_state_specs", "make_train_step", "make_eval_step"]


class TrainState(NamedTuple):
    x: Any            # backbone params, leaves (m, ...)
    y: jax.Array      # per-agent heads (m, d_model, vocab)
    u: Any            # tracked gradient, like x
    v: jax.Array      # inner gradient, like y
    p_prev: Any       # previous hypergradient, like x
    t: jax.Array      # step counter (replicated)


@dataclasses.dataclass(frozen=True)
class InteractConfig:
    alpha: float = 1e-2          # outer step size (Theorem 1 bound applies)
    beta: float = 0.5            # inner step size
    self_weight: float = 1.0 / 3.0  # ring mixing w0; lambda analytic
    hyper: BilevelHyper = BilevelHyper()
    # consensus engine selection (repro/consensus):
    consensus_backend: str = "ppermute"    # only mesh-native backend today
    topology: str = "ring"                 # ring | erdos-renyi | torus
    p_connect: float = 0.5                 # ER edge probability
    topology_seed: int = 0                 # ER graph sample seed
    # paper future-work extensions (conclusion, both opt-in):
    consensus_compress: str | None = None  # "int8" compressed consensus
    dp_sigma: float = 0.0                  # local-DP noise on shared x
    # SVR refresh period (used by make_svr_train_step when q not given)
    q: int | None = None

    def topology_config(self):
        """The declarative graph shared with ``repro.solvers``."""
        from repro.solvers.config import TopologyConfig
        return TopologyConfig(kind=self.topology, p_connect=self.p_connect,
                              seed=self.topology_seed,
                              self_weight=self.self_weight)

    def mixing_spec(self, m: int) -> MixingSpec:
        """The configured topology's mixing matrix for m agents."""
        return self.topology_config().mixing_spec(m)

    def solver_config(self, algo: str = "interact"):
        """The equivalent unified ``SolverConfig`` (docs/SOLVERS.md).

        The LM path's hypergradient is the head-space Neumann series on
        cached features — the linearize-once replay of eq. (22) — so the
        exported ``HypergradConfig`` records it as the
        ``neumann-linearized`` backend with BilevelHyper's K and L_g
        (round-tripped back by ``from_solver_config``).
        """
        from repro.hypergrad import HypergradConfig
        from repro.solvers.config import SolverConfig
        opts = {}
        if self.consensus_compress is not None:
            opts["compress"] = self.consensus_compress
        if self.dp_sigma:
            opts["dp_sigma"] = self.dp_sigma
        hg = HypergradConfig(method="neumann", backend="neumann-linearized",
                             neumann_k=self.hyper.neumann_k,
                             lipschitz_g=self.hyper.lipschitz_g)
        return SolverConfig(algo=algo, alpha=self.alpha, beta=self.beta,
                            q=self.q, topology=self.topology_config(),
                            backend=self.consensus_backend,
                            backend_opts=opts, hypergrad=hg)

    @classmethod
    def from_solver_config(cls, scfg, hyper: BilevelHyper | None = None):
        """Build the LM-runtime config from a unified ``SolverConfig``.

        ``hyper`` (the LM-specific ``BilevelHyper``) defaults to
        ``BilevelHyper()``, with the Neumann settings (K, L_g) imported
        from ``scfg.hypergrad`` when it selects a Neumann estimator —
        the only eq.-(22) knobs with an LM counterpart.  ``scfg.seed``
        plays no role on the LM path (deterministic token streams).
        """
        if scfg.mixing is not None:
            raise ValueError(
                "SolverConfig.mixing (an explicit MixingSpec) cannot drive "
                "the distributed runtime — the mesh realises the graph from "
                "the declarative topology; set SolverConfig.topology instead")
        opts = dict(scfg.backend_opts)
        if hyper is None:
            hyper = BilevelHyper()
            if scfg.hypergrad.resolve_backend().startswith("neumann"):
                hyper = dataclasses.replace(
                    hyper, neumann_k=scfg.hypergrad.neumann_k,
                    lipschitz_g=scfg.hypergrad.lipschitz_g)
        return cls(alpha=scfg.alpha, beta=scfg.beta,
                   self_weight=scfg.topology.self_weight,
                   hyper=hyper,
                   consensus_backend=scfg.backend,
                   topology=scfg.topology.kind,
                   p_connect=scfg.topology.p_connect,
                   topology_seed=scfg.topology.seed,
                   consensus_compress=opts.get("compress"),
                   dp_sigma=opts.get("dp_sigma", 0.0),
                   q=scfg.q)

    @classmethod
    def coerce(cls, cfg, hyper: BilevelHyper | None = None):
        """Accept either an InteractConfig or a unified SolverConfig."""
        if isinstance(cfg, cls):
            return cfg
        return cls.from_solver_config(cfg, hyper=hyper)

    def compat_hyper(self, a_axes, mesh) -> BilevelHyper:
        """The hyper config adjusted for the shard_map body: on old-JAX
        stacks a partially-auto body cannot contain while-loops over
        manual subgroups, so every scan in the backbone unrolls."""
        if (set(mesh.axis_names) - set(a_axes)
                and not PARTIAL_AUTO_COLLECTIVES_SAFE):
            return dataclasses.replace(self.hyper, unroll_scans=True)
        return self.hyper

    def consensus_engine(self, m: int, a_axes, mesh=None):
        """Build the distributed consensus engine for this config.

        When the mesh carries auto (non-agent) axes and the JAX stack
        cannot lower ppermute under a partially-manual body, the engine
        falls back to the psum realisation of the same mixing matrix
        (see sharding/compat.PARTIAL_AUTO_COLLECTIVES_SAFE).
        """
        if self.consensus_backend != "ppermute":
            raise ValueError(
                f"backend {self.consensus_backend!r} cannot run inside "
                "shard_map; the distributed runtime requires 'ppermute' "
                "(dense/pallas serve the single-host simulator)")
        impl = "ppermute"
        if (mesh is not None
                and set(mesh.axis_names) - set(a_axes)
                and not PARTIAL_AUTO_COLLECTIVES_SAFE):
            impl = "psum"
        return make_engine("ppermute", self.mixing_spec(m),
                           agent_axes=tuple(a_axes),
                           compress=self.consensus_compress,
                           dp_sigma=self.dp_sigma, impl=impl)


def _zeros_like_tree(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def init_train_state(cfg: ArchConfig, key: jax.Array, m: int) -> TrainState:
    """Host-side init (used under jax.eval_shape for the dry-run, or for
    real small-scale runs).  All agents start from the same (x0, y0) as in
    Algorithm 1; u/v/p start at zero (first step's tracking difference
    makes u_1 = p_1, preserving the u-average invariant)."""
    kx, ky = jax.random.split(key)
    x0 = M.init_params(cfg, kx, with_head=False)
    y0 = M.init_head(cfg, ky)
    bcast = lambda t: jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l[None], (m,) + l.shape), t)
    x = bcast(x0)
    y = bcast(y0)
    return TrainState(x=x, y=y, u=_zeros_like_tree(x),
                      v=jnp.zeros_like(y), p_prev=_zeros_like_tree(x),
                      t=jnp.zeros((), jnp.int32))


def train_state_specs(state_shapes: TrainState, mesh,
                      agent_mode: str = "rows") -> TrainState:
    """PartitionSpecs for every leaf of the state.

    agent_mode="rows": agents = ("pod","data") rows (paper-default layout).
    agent_mode="pods": agents = pods only (perf P6) — each agent's state
    additionally shards over the pod-internal "data" axis (FSDP-style),
    cutting per-chip INTERACT state by the data-axis size.  This is the
    feasible layout for 100B+ architectures.
    """
    msize = mesh.shape["model"]
    if agent_mode == "pods":
        a_axes = ("pod",)
        extra = (("data", mesh.shape["data"]),)
    else:
        a_axes = agent_axes(mesh)
        extra = ()
    def x_tree_specs(tree):
        # The embedding gather trips XLA's SPMD partitioner when its table
        # is sharded over both model and data (CHECK failure in
        # PartitionGather on the CPU backend) — keep embed model-only and
        # FSDP-shard the layer stacks, which hold ~all the bytes.
        specs = {}
        for key, sub in tree.items():
            ex = extra if key == "layers" else ()
            specs[key] = stacked_tree_specs(sub, msize, a_axes, ex)
        return specs

    x_specs = x_tree_specs(state_shapes.x)
    y_spec = leaf_spec(state_shapes.y.shape, msize, a_axes,
                       agent_leading=True, extra_axes=extra)
    return TrainState(
        x=x_specs,
        y=y_spec,
        u=x_tree_specs(state_shapes.u),
        v=y_spec,
        p_prev=x_tree_specs(state_shapes.p_prev),
        t=P(),
    )


def _agent_entry(a_axes):
    return a_axes if len(a_axes) > 1 else a_axes[0]


def make_train_step(cfg: ArchConfig, mesh, icfg: InteractConfig,
                    *, with_prefix: bool = False, agent_mode: str = "rows"):
    """Returns step(state, tokens[, prefix]) -> (state, metrics).

    ``icfg`` may be an ``InteractConfig`` or a unified
    ``repro.solvers.SolverConfig`` (coerced via ``from_solver_config``),
    so the same config object drives the simulator and the LM runtime.

    tokens: (m, per_agent_batch, seq) int32 — first half of the batch is
    the inner split, second half the outer split.

    agent_mode="pods" (perf P6): the shard_map is manual over the pod
    axis only; "data" stays auto, so each agent's backbone math is
    batch-parallel over its pod's data rows and its parameters live
    FSDP-sharded over them (see train_state_specs).
    """
    icfg = InteractConfig.coerce(icfg)
    if agent_mode == "pods":
        a_axes = ("pod",)
    else:
        a_axes = agent_axes(mesh)
    m = 1
    for ax in a_axes:
        m *= mesh.shape[ax]
    aentry = _agent_entry(a_axes)
    hyper = icfg.compat_hyper(a_axes, mesh)
    engine = icfg.consensus_engine(m, a_axes, mesh=mesh)

    def per_agent(state: TrainState, tokens, ids, prefix):
        # Leaves arrive with leading agent dim of local size 1; ``ids``
        # threads each agent's ring position in as data (axis_index does
        # not lower under partially-auto bodies on old JAX).
        sq = lambda t: jax.tree_util.tree_map(lambda l: l[0], t)
        un = lambda t: jax.tree_util.tree_map(lambda l: l[None], t)
        agent_idx = ids[0]

        dp_key = (jax.random.fold_in(jax.random.PRNGKey(0), state.t)
                  if icfg.dp_sigma > 0 else None)

        def grads_fn(x_new, y_new):
            # ---- Step 2: local gradients at the new iterate --------------
            toks = tokens[0]                       # (b, s) this agent
            # (pods mode: batch-parallelism is induced by the residual-
            # stream constraint inside features() — constraining the token
            # *indices* here trips XLA's gather partitioner, see
            # EXPERIMENTS.md P6.)
            half = toks.shape[0] // 2
            inner_t, outer_t = toks[:half], toks[half:]
            pre_in = pre_out = None
            if prefix is not None:
                pre = prefix[0]
                pre_in, pre_out = pre[:half], pre[half:]
            p_new, v_new, outer_ce = local_grads(
                cfg, hyper, sq(x_new), y_new[0], inner_t, outer_t,
                prefix_inner=pre_in, prefix_outer=pre_out)
            return un(p_new), v_new[None], outer_ce

        # Steps 1-3 via the shared step-core on the ppermute engine.
        # First iteration: p_prev is zero and u is zero, so Step 3 sets
        # u_1 = p_1 exactly (matches the Algorithm-1 init u_0 = p_0).
        x_new, y_new, u_new, v_new, p_new, _, outer_ce = (
            consensus_descent_and_track(
                engine, state.x, state.y, state.u, state.v, state.p_prev,
                icfg.alpha, icfg.beta, grads_fn, t=state.t, dp_key=dp_key,
                agent_index=agent_idx))

        # ---- metrics (replicated over agents) ----------------------------
        axis = aentry
        mean_ce = jax.lax.pmean(outer_ce, axis)
        gsq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                  for l in jax.tree_util.tree_leaves(u_new))
        grad_norm = jnp.sqrt(jax.lax.pmean(gsq, axis))

        new_state = TrainState(x=x_new, y=y_new, u=u_new, v=v_new,
                               p_prev=p_new, t=state.t + 1)
        return new_state, {"outer_ce": mean_ce, "grad_norm": grad_norm}

    def step(state: TrainState, tokens, prefix=None):
        # in/out specs: agent-leading dims manual, everything else auto.
        specs_state = jax.tree_util.tree_map(lambda _: P(aentry), state)
        specs_state = specs_state._replace(t=P())
        out_specs = (specs_state, {"outer_ce": P(), "grad_norm": P()})
        ids = jnp.arange(m, dtype=jnp.int32)
        if prefix is None:
            fn = shard_map(
                lambda s, tk, ii: per_agent(s, tk, ii, None), mesh=mesh,
                in_specs=(specs_state, P(aentry), P(aentry)),
                out_specs=out_specs, axis_names=set(a_axes),
                check_vma=False)
            return fn(state, tokens, ids)
        fn = shard_map(
            per_agent, mesh=mesh,
            in_specs=(specs_state, P(aentry), P(aentry), P(aentry)),
            out_specs=out_specs, axis_names=set(a_axes),
            check_vma=False)
        return fn(state, tokens, ids, prefix)

    return step


def make_eval_step(cfg: ArchConfig, mesh, icfg: InteractConfig):
    """Average outer CE over agents at the current iterate (no update)."""
    icfg = InteractConfig.coerce(icfg)
    a_axes = agent_axes(mesh)
    aentry = _agent_entry(a_axes)
    hyper = icfg.compat_hyper(a_axes, mesh)

    def per_agent(state: TrainState, tokens):
        from repro.train.bilevel_lm import outer_loss
        sq = lambda t: jax.tree_util.tree_map(lambda l: l[0], t)
        ce = outer_loss(cfg, hyper, sq(state.x), state.y[0], tokens[0])
        return jax.lax.pmean(ce, aentry)

    def step(state, tokens):
        specs_state = jax.tree_util.tree_map(lambda _: P(aentry), state)
        specs_state = specs_state._replace(t=P())
        return shard_map(per_agent, mesh=mesh,
                         in_specs=(specs_state, P(aentry)),
                         out_specs=P(),
                         axis_names=set(a_axes),
                         check_vma=False)(state, tokens)

    return step
