"""Distributed SVR-INTERACT (Algorithm 2) at LM scale.

Same consensus/tracking skeleton as ``repro/train/step.py`` but the local
gradients use the SPIDER-style recursive estimator (eqs. 23-24):

  mod(t, q) == 0:  p_t = local_grads(x_t, y_t)  on the full refresh batch
  otherwise:       p_t = p_{t-1} + grads(x_t, y_t; S) - grads(x_{t-1}, y_{t-1}; S)

with the *same* minibatch S evaluated at both iterates (the correlated
difference that makes the estimator variance-reduced).

Each ``local_grads`` call prices out as one eq.-(22) hypergradient —
K-1 head-space HVPs on the linearize-once tangent plus one backbone
cross term (see repro/hypergrad and docs/HYPERGRAD.md); the recursive
step pays it twice (new and previous iterate), matching the
``hypergrad_calls_per_step`` accounting of the simulator's SVR solver.

Cost note (documented design decision): the recursive estimator requires
the previous iterate (x_{t-1}, y_{t-1}) in state — two extra parameter
copies per agent on top of INTERACT's three.  At 100B+ scale that pushes
the per-chip state ~1.7x; the agents-per-pod layout (perf P6) absorbs it.
At LM scale the "full" refresh is approximated by a larger refresh batch
(the stream has no finite n); the paper's finite-sum refresh semantics
are preserved exactly in ``repro/core/svr_interact.py``.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.consensus import consensus_descent_and_track
from repro.launch.mesh import agent_axes
from repro.models.base import ArchConfig
from repro.sharding.compat import shard_map
from repro.train.bilevel_lm import local_grads
from repro.train.step import InteractConfig, TrainState, _agent_entry

__all__ = ["SvrTrainState", "init_svr_train_state", "make_svr_train_step"]


class SvrTrainState(NamedTuple):
    x: Any
    y: jax.Array
    u: Any
    v: jax.Array
    p_prev: Any
    x_prev: Any      # previous iterate (recursive estimator)
    y_prev: jax.Array
    t: jax.Array


def init_svr_train_state(cfg: ArchConfig, key: jax.Array,
                         m: int) -> SvrTrainState:
    from repro.train.step import init_train_state
    base: TrainState = init_train_state(cfg, key, m)
    return SvrTrainState(x=base.x, y=base.y, u=base.u, v=base.v,
                         p_prev=base.p_prev, x_prev=base.x,
                         y_prev=base.y, t=base.t)


def svr_train_state_specs(state_shapes: SvrTrainState, mesh,
                          agent_mode: str = "rows") -> SvrTrainState:
    from repro.train.step import train_state_specs
    base = train_state_specs(
        TrainState(x=state_shapes.x, y=state_shapes.y, u=state_shapes.u,
                   v=state_shapes.v, p_prev=state_shapes.p_prev,
                   t=state_shapes.t), mesh, agent_mode=agent_mode)
    return SvrTrainState(x=base.x, y=base.y, u=base.u, v=base.v,
                         p_prev=base.p_prev, x_prev=base.x,
                         y_prev=base.y, t=base.t)


def make_svr_train_step(cfg: ArchConfig, mesh, icfg: InteractConfig,
                        q: int | None = None, agent_mode: str = "rows"):
    """step(state, tokens) -> (state, metrics); refresh every q steps.

    ``icfg`` may be an ``InteractConfig`` or a unified
    ``repro.solvers.SolverConfig``; ``q=None`` reads the refresh period
    from the config (``InteractConfig.q`` / ``SolverConfig.q``).

    ``tokens``: (m, b, s) — the same batch plays the role of the refresh
    set on refresh steps and of S on recursive steps (deterministic
    streams make S fresh each call).
    """
    icfg = InteractConfig.coerce(icfg)
    if q is None:
        if icfg.q is None:
            raise ValueError("refresh period q not given and not set on "
                             "the config")
        q = icfg.q
    a_axes = ("pod",) if agent_mode == "pods" else agent_axes(mesh)
    aentry = _agent_entry(a_axes)
    hyper = icfg.compat_hyper(a_axes, mesh)
    m = 1
    for ax in a_axes:
        m *= mesh.shape[ax]
    engine = icfg.consensus_engine(m, a_axes, mesh=mesh)

    def per_agent(state: SvrTrainState, tokens, ids):
        sq = lambda t: jax.tree_util.tree_map(lambda l: l[0], t)
        un = lambda t: jax.tree_util.tree_map(lambda l: l[None], t)

        refresh = (state.t + 1) % q == 0

        def grads_fn(x_new, y_new):
            toks = tokens[0]
            half = toks.shape[0] // 2
            inner_t, outer_t = toks[:half], toks[half:]

            # gradients at the new iterate (always needed)
            p_now, v_now, ce = local_grads(cfg, hyper, sq(x_new), y_new[0],
                                           inner_t, outer_t)
            # same minibatch at the previous iterate (recursive difference)
            p_old, v_old, _ = local_grads(cfg, hyper, sq(state.x_prev),
                                          state.y_prev[0], inner_t, outer_t)

            pick = lambda full, vr: jax.tree_util.tree_map(
                lambda a, b: jnp.where(refresh, a, b), full, vr)
            p_vr = jax.tree_util.tree_map(
                lambda pp, a, b: pp[0] + a - b, state.p_prev, p_now, p_old)
            v_vr = state.v[0] + v_now - v_old
            return un(pick(p_now, p_vr)), pick(v_now, v_vr)[None], ce

        x_new, y_new, u_new, v_new, p_new, _, ce = (
            consensus_descent_and_track(
                engine, state.x, state.y, state.u, state.v, state.p_prev,
                icfg.alpha, icfg.beta, grads_fn, t=state.t,
                agent_index=ids[0]))

        mean_ce = jax.lax.pmean(ce, aentry)
        new_state = SvrTrainState(
            x=x_new, y=y_new, u=u_new, v=v_new, p_prev=p_new,
            x_prev=state.x, y_prev=state.y, t=state.t + 1)
        return new_state, {"outer_ce": mean_ce,
                           "refresh": refresh.astype(jnp.float32)}

    def step(state: SvrTrainState, tokens):
        specs_state = jax.tree_util.tree_map(lambda _: P(aentry), state)
        specs_state = specs_state._replace(t=P())
        out_specs = (specs_state, {"outer_ce": P(), "refresh": P()})
        ids = jnp.arange(m, dtype=jnp.int32)
        fn = shard_map(per_agent, mesh=mesh,
                       in_specs=(specs_state, P(aentry), P(aentry)),
                       out_specs=out_specs,
                       axis_names=set(a_axes), check_vma=False)
        return fn(state, tokens, ids)

    return step
