"""Deterministic synthetic data pipelines.

Two generators:

* ``TokenTaskStream`` — per-agent language-model token streams with
  agent-specific Markov structure (heterogeneous f_i/g_i as the paper's
  decentralized setting requires).  Used by the LM-scale INTERACT examples
  and the end-to-end driver.
* ``classification_agents`` — re-export of the core synthetic classifier
  data (the paper-faithful meta-learning experiments).

Everything is seeded and stateless: batch t of agent i is a pure function
of (seed, i, t), so runs are exactly reproducible and shardable without
host-side coordination — each agent row materialises only its own batch.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bilevel import make_synthetic_agents as classification_agents

__all__ = ["TokenTaskStream", "classification_agents"]


@dataclasses.dataclass(frozen=True)
class TokenTaskStream:
    """Heterogeneous per-agent token streams.

    Agent i draws tokens from a sticky first-order chain over a random
    agent-specific preferred-vocabulary subset — cheap to generate on
    device, deterministic, and genuinely non-iid across agents.
    """

    vocab_size: int
    num_agents: int
    seed: int = 0
    stickiness: float = 0.8
    subset_frac: float = 0.25

    def _agent_key(self, agent: int, step: int) -> jax.Array:
        return jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), agent), step)

    def agent_batch(self, agent: int, step: int, batch: int,
                    seq_len: int) -> jax.Array:
        """(batch, seq_len) int32 tokens for one agent at one step."""
        key = self._agent_key(agent, step)
        k_sub, k_first, k_next, k_stick = jax.random.split(key, 4)
        sub = max(2, int(self.subset_frac * self.vocab_size))
        # agent-preferred contiguous vocab band (cheap, deterministic)
        start = jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), agent),
            (), 0, max(1, self.vocab_size - sub))

        first = jax.random.randint(k_first, (batch, 1), 0, sub)
        jumps = jax.random.randint(k_next, (batch, seq_len), 0, sub)
        stick = jax.random.uniform(k_stick, (batch, seq_len)) < self.stickiness

        def chain(carry, ts):
            jump, st = ts
            nxt = jnp.where(st, carry, jump)
            return nxt, nxt

        _, toks = jax.lax.scan(
            chain, first[:, 0], (jumps.T, stick.T))
        return (toks.T + start).astype(jnp.int32) % self.vocab_size

    def global_batch(self, step: int, per_agent: int,
                     seq_len: int) -> jax.Array:
        """(num_agents, per_agent, seq_len) stacked over agents."""
        rows = [self.agent_batch(i, step, per_agent, seq_len)
                for i in range(self.num_agents)]
        return jnp.stack(rows, axis=0)
