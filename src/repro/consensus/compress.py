"""Compressed consensus with error feedback: the wire layer.

The paper's headline result is the O(eps^-1) *communication* complexity
of INTERACT (Definition 2 / Theorem 1) — and a payload-compression layer
is the production story for the bandwidth-limited peer networks it
models.  This module supplies that layer as a small registry of
``Compressor`` objects plus the ``CompressionConfig`` every consensus
backend carries:

    compressor = make_compressor(CompressionConfig(kind="sign1bit"))
    decoded, residual = compressor.compress(x + e)   # EF recursion
    bytes_  = compressor.bytes_on_wire(x.size)       # wire accounting

Error feedback (EF) is the standard compensation recursion (1-bit Adam /
DeepSqueeze style, modeled on Bagua's ``OnebitAdamAlgorithm`` warmup-
then-compress schedule): the agent communicates ``c = C(x + e)`` and
keeps the compression error ``e <- (x + e) - c`` for the next round, so
quantization error accumulates in local state instead of biasing the
consensus fixed point.  Under the ``none`` compressor ``c == x + e``
exactly, the residual is exactly zero forever, and the combine is the
uncompressed reference bit for bit.

Compressors (all value-faithful simulations: the *decoded* payload flows
through the math, the wire bytes are accounted analytically):

    none      identity, 4 bytes/entry.
    int8      per-payload symmetric int8 (existing uncompensated wire
              format), 1 byte/entry + one f32 scale.
    sign1bit  sign * mean(|v|) (Bagua 1-bit style), 1 bit/entry + one
              f32 scale — 32x fewer bits than f32 before EF overhead.
    topk      keep the k = ceil(frac * size) largest-magnitude entries,
              8 bytes/kept entry (f32 value + int32 index).

``CompressionConfig.compress_after`` is the Bagua-style warmup: the
first ``compress_after`` mixes ship full precision (the tracking state
is still moving fast), compression switches on afterwards via a
``jnp.where`` on the step index so the program stays one compile.
``error_feedback=False`` degrades to the uncompensated path (``c =
C(x)``, no residual state) — the baseline the benchmarks compare EF
against at equal bit budget.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

__all__ = [
    "COMPRESSORS",
    "CompressionConfig",
    "Compressor",
    "cumulative_wire_bytes",
    "init_ef",
    "make_compressor",
]


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Declarative wire-compression spec carried by ``SolverConfig``.

    Attributes:
      kind: "none" | "int8" | "sign1bit" | "topk" (see ``COMPRESSORS``).
      error_feedback: keep the EF residual ``e <- (x + e) - C(x + e)``
        in the solver scan carry; False sends ``C(x)`` uncompensated
        (the legacy int8 behaviour, kept as the bench baseline).
      compress_after: warmup mixes at full precision before compression
        switches on (Bagua's warmup-then-compress schedule); the warmup
        rounds are charged full f32 bytes by the accounting helpers.
      topk_frac: fraction of entries the "topk" compressor keeps.
      gamma: consensus damping on the compressed combine, ``mixed = x +
        gamma * (mix(payload) - x)`` — the CHOCO-Gossip stepsize.  1.0
        (default) is the undamped combine; hard-sparsifying wires
        (top-k) need ``gamma < 1`` for the compressed-gossip recursion
        to contract (undamped top-k provably diverges on tracking
        iterates).  Free on the wire: damping is applied by the
        receiver.

    Hashable (frozen dataclass), so it participates directly in
    ``SolverConfig.static_key()`` — two configs share a compiled sweep
    program only when their compression specs match.
    """

    kind: str = "none"
    error_feedback: bool = True
    compress_after: int = 0
    topk_frac: float = 0.05
    gamma: float = 1.0

    @property
    def active(self) -> bool:
        """Does any payload ever leave the agent compressed?"""
        return self.kind != "none"

    @property
    def uses_ef(self) -> bool:
        """Does the solver state need to carry a residual pytree?"""
        return self.active and self.error_feedback


class Compressor:
    """One wire format: decoded-value simulation + bytes accounting."""

    name = "base"

    def encode_decode(self, v: jax.Array) -> jax.Array:
        """What the receiver decodes from this payload (f32, v-shaped)."""
        raise NotImplementedError

    def compress(self, v: jax.Array) -> tuple[jax.Array, jax.Array]:
        """The EF pair: ``(wire_repr, new_residual)`` for payload ``v``.

        ``v`` is the compensated value ``x + e`` (or the bare ``x``
        without error feedback); the returned residual is exactly
        ``v - wire_repr`` — zero for the ``none`` compressor.
        """
        c = self.encode_decode(v)
        return c, v - c

    def bytes_on_wire(self, size: int) -> int:
        """Wire bytes of ONE payload of ``size`` f32 entries."""
        raise NotImplementedError


class NoneCompressor(Compressor):
    """Identity: full-precision f32 on the wire (the reference)."""

    name = "none"

    def encode_decode(self, v):
        return v

    def compress(self, v):
        # exact: the residual is a true zero, not a rounded one
        return v, jnp.zeros_like(v)

    def bytes_on_wire(self, size: int) -> int:
        return 4 * size


class Int8Compressor(Compressor):
    """Per-payload symmetric int8 (the existing uncompensated wire
    format of the ppermute backend, now EF-capable)."""

    name = "int8"

    def encode_decode(self, v):
        from repro.sharding.collectives import dequantize_int8, quantize_int8
        q, scale = quantize_int8(v)
        return dequantize_int8(q, scale)

    def bytes_on_wire(self, size: int) -> int:
        return size + 4                      # int8 entries + f32 scale


class Sign1BitCompressor(Compressor):
    """sign(v) * mean(|v|): the 1-bit format of 1-bit Adam / signSGD."""

    name = "sign1bit"

    def encode_decode(self, v):
        v32 = v.astype(jnp.float32)
        scale = jnp.mean(jnp.abs(v32))
        return jnp.sign(v32) * scale

    def bytes_on_wire(self, size: int) -> int:
        return math.ceil(size / 8) + 4       # bitmap + f32 scale


class TopKCompressor(Compressor):
    """Magnitude top-k sparsification: k = ceil(frac * size) entries."""

    name = "topk"

    def __init__(self, frac: float):
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got {frac}")
        self.frac = float(frac)

    def _k(self, size: int) -> int:
        return max(1, int(math.ceil(self.frac * size)))

    def encode_decode(self, v):
        v32 = v.astype(jnp.float32)
        flat = v32.reshape(-1)
        k = self._k(flat.shape[0])
        kth = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        # ties keep a few extra entries (the math is still a valid
        # contraction); the bytes accounting charges exactly k
        return jnp.where(jnp.abs(v32) >= kth, v32, 0.0)

    def bytes_on_wire(self, size: int) -> int:
        return 8 * self._k(size)             # f32 value + int32 index


COMPRESSORS = {
    "none": lambda cfg: NoneCompressor(),
    "int8": lambda cfg: Int8Compressor(),
    "sign1bit": lambda cfg: Sign1BitCompressor(),
    "topk": lambda cfg: TopKCompressor(cfg.topk_frac),
}


def make_compressor(config: CompressionConfig) -> Compressor:
    """Build the registered compressor for ``config.kind``."""
    try:
        factory = COMPRESSORS[config.kind]
    except KeyError:
        raise ValueError(
            f"unknown compressor {config.kind!r}; "
            f"choose from {sorted(COMPRESSORS)}") from None
    return factory(config)


def init_ef(compression: CompressionConfig | None, **streams):
    """Zero wire state for the named consensus streams, or ``None``.

    ``init_ef(cfg, x=x, u=u)`` -> ``{"x": {"e": zeros, "ref": zeros},
    "u": {...}}`` (f32 leaves, ready for the scan carry and buffer
    donation) when the config compresses with error feedback; ``None``
    otherwise, so un-compressed states carry no extra buffers and stay
    bit-compatible with pre-compression checkpoints.

    Per stream, ``e`` is the error-feedback residual and ``ref`` the
    gossip-tracked public copy: agents transmit the compressed
    *innovation* ``C(x - ref)`` and every peer (including the sender)
    advances ``ref <- ref + C(...)``, so as iterates converge the
    innovation shrinks and even 1-bit wires become asymptotically exact
    (CHOCO-style difference compression; see docs/CONSENSUS.md).
    """
    if compression is None or not compression.uses_ef:
        return None
    zeros = lambda tree: jax.tree_util.tree_map(
        lambda leaf: jnp.zeros(leaf.shape, jnp.float32), tree)
    return {name: {"e": zeros(tree), "ref": zeros(tree)}
            for name, tree in streams.items()}


def cumulative_wire_bytes(compression: CompressionConfig, size: int,
                          num_steps: int, comms_per_step: int = 2,
                          communication_interval: int = 1) -> list[int]:
    """Per-agent cumulative wire bytes after 0..num_steps solver steps.

    Accounts for the warmup schedule (the first ``compress_after`` mixes
    ship full f32) and the communication interval (steps with ``t %
    interval != 0`` ship nothing).  ``size`` is the per-payload entry
    count, ``comms_per_step`` the algorithm's Definition-2 rounds per
    iteration (2 for the tracking algorithms, 1 for D-SGD).  Returns a
    list of length ``num_steps + 1`` (entry t = bytes after t steps).
    """
    compressor = make_compressor(compression)
    full = NoneCompressor().bytes_on_wire(size)
    packed = compressor.bytes_on_wire(size)
    out, total = [0], 0
    for t in range(num_steps):
        if t % communication_interval == 0:
            per_round = full if t < compression.compress_after else packed
            total += comms_per_step * per_round
        out.append(total)
    return out
