"""all-gather consensus backend: the dense combine on the device mesh.

The open cell of the backend matrix (ROADMAP item 1): the dense matmul
reference, runnable *inside* ``shard_map`` across processes.  Each agent
``lax.all_gather``\\ s every peer's payload along the agent axes and dots
its own rows of the full (m, m) mixing matrix against the gathered
table:

    mixed[i] = M[i, :] @ gathered          (eq. 6 / eq. 10 left term)

Trade-off vs ppermute: the wire carries one payload per agent per round
(the broadcast model ``cumulative_wire_bytes`` prices — so measured
bytes match the priced model exactly, the property the
``check_distributed`` gate asserts), while ppermute ships one payload
per *link* per permute round (cheaper on sparse graphs with few
offsets, pricier on dense ones).  Because the engine holds the full
matrix, arbitrary **traced** matrix overrides work — time-varying
topology streams run on the mesh without a permute-weight schedule —
and the Byzantine robust rules (which need all-to-all payload access)
run here exactly as on the dense backend.

Must be called from inside a shard_map body whose manual axes include
``agent_axes``; leaves carry the local agent's slice (leading local
dim).  Local-DP noise is a ppermute wire option and is ignored here,
like on the single-host backends.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.byzantine import robust_combine
from repro.consensus.compress import CompressionConfig
from repro.consensus.engine import ConsensusEngine, MeshBackendMixin
from repro.core.consensus import MixingSpec

__all__ = ["AllGatherEngine"]


class AllGatherEngine(MeshBackendMixin, ConsensusEngine):

    name = "allgather"

    def __init__(self, mixing: MixingSpec | jax.Array,
                 agent_axes: Sequence[str] = ("data",),
                 compression: CompressionConfig | None = None,
                 communication_interval: int = 1, byzantine=None):
        mat = mixing.matrix if isinstance(mixing, MixingSpec) else mixing
        self.matrix = jnp.asarray(mat)
        self.agent_axes = tuple(agent_axes)
        self._slots_hint = None
        self._configure_wire(compression, communication_interval, byzantine)

    @property
    def _mesh_num_agents(self) -> int:
        return int(self.matrix.shape[0])

    def _gather(self, tree):
        """Gather every agent's rows along the agent axes: leaves
        (rows, ...) -> (m * rows, ...), ordered like ``_local_slots``
        (minor axis gathered first, so the final order is major-to-minor
        over ``agent_axes``)."""
        def leaf(l):
            out = l
            for ax in reversed(self.agent_axes):
                out = jax.lax.all_gather(out, ax, axis=0, tiled=True)
            return out
        return jax.tree_util.tree_map(leaf, tree)

    def mix(self, tree, *, matrix=None, dp_key=None, agent_index=None):
        del dp_key  # DP noise is a ppermute wire option; ignored here
        mat = self.matrix if matrix is None else matrix
        slots = self._local_slots(tree, agent_index)
        rows = jnp.asarray(mat, jnp.float32)[slots]
        gathered = self._gather(tree)

        def combine(g, l):
            mixed = jnp.tensordot(rows, g.astype(jnp.float32),
                                  axes=[[1], [0]])
            return mixed.astype(l.dtype)

        return jax.tree_util.tree_map(combine, gathered, tree)

    def _self_weights(self, matrix=None) -> jax.Array:
        """Self weights M[i, i] of the *local* rows.

        The base wire path broadcasts these against the local leaves, so
        under shard_map they must be the slot slice of the diagonal —
        ``mix_ef`` installs the slots before delegating to the base
        implementation."""
        mat = self.matrix if matrix is None else matrix
        diag = jnp.diagonal(jnp.asarray(mat, jnp.float32))
        return diag if self._slots_hint is None else diag[self._slots_hint]

    def _combine(self, tree, *, matrix=None, dp_key=None, agent_index=None):
        """Weighted mix, or a robust rule over the gathered rows.

        Unlike ppermute (which never holds more than the local slice),
        the gathered table gives every agent all-to-all access, so the
        Byzantine robust rules run here exactly as on the dense backend:
        each agent computes the full robust combine and keeps its rows.
        """
        rule = self.byzantine.combine
        if rule == "weighted":
            return self.mix(tree, matrix=matrix, dp_key=dp_key,
                            agent_index=agent_index)
        mat = self.matrix if matrix is None else matrix
        slots = self._local_slots(tree, agent_index)
        gathered = self._gather(tree)
        full = robust_combine(jnp.asarray(mat, jnp.float32), gathered, rule,
                              self.byzantine.resolve_trim())
        return jax.tree_util.tree_map(
            lambda fl, l: fl[slots].astype(l.dtype), full, tree)

    def _attack_payload(self, tree, t, stream):
        # local-slice corruption with global slot identities (bitwise vs
        # the dense reference, like ppermute)
        return self._attack_local(tree, t, stream, None)

    def mix_ef(self, tree, ef=None, t=None, *, matrix=None, dp_key=None,
               agent_index=None, stream="x"):
        """Base wire path with the self-clean weights sliced per slot.

        The compression/EF math is the base implementation verbatim —
        one concatenated per-agent buffer, byte-identical accounting to
        the dense backend; only the self-weight broadcast needs the
        local slot slice (see ``_self_weights``).
        """
        if not self.compression.active:
            return super().mix_ef(tree, ef, t, matrix=matrix,
                                  dp_key=dp_key, agent_index=agent_index,
                                  stream=stream)
        self._slots_hint = self._local_slots(tree, agent_index)
        try:
            return super().mix_ef(tree, ef, t, matrix=matrix,
                                  dp_key=dp_key, agent_index=agent_index,
                                  stream=stream)
        finally:
            self._slots_hint = None
