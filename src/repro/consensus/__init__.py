"""Unified consensus engine: one pluggable backend API behind all four
INTERACT update paths (see engine.py for the design).

Backend classes are exported lazily (PEP 562): importing this package —
which every ``repro.core`` algorithm does — must not pull in the pallas
TPU extras or the sharding collectives; those load only when the
corresponding backend is actually requested.
"""
from repro.consensus.compress import (
    COMPRESSORS,
    CompressionConfig,
    Compressor,
    cumulative_wire_bytes,
    init_ef,
    make_compressor,
)
from repro.consensus.engine import (
    BACKENDS,
    ConsensusEngine,
    MeshBackendMixin,
    as_engine,
    consensus_descent_and_track,
    make_engine,
    register_backend,
)
from repro.consensus.ledger import (
    CommsLedger,
    StreamRecord,
    attach_ledger,
    time_round_us,
)

__all__ = [
    "AllGatherEngine",
    "BACKENDS",
    "COMPRESSORS",
    "CommsLedger",
    "CompressionConfig",
    "Compressor",
    "ConsensusEngine",
    "DenseEngine",
    "MeshBackendMixin",
    "PallasEngine",
    "PermuteEngine",
    "StreamRecord",
    "as_engine",
    "attach_ledger",
    "consensus_descent_and_track",
    "cumulative_wire_bytes",
    "init_ef",
    "make_compressor",
    "make_engine",
    "register_backend",
    "time_round_us",
]

_LAZY_BACKENDS = {
    "AllGatherEngine": "repro.consensus.allgather",
    "DenseEngine": "repro.consensus.dense",
    "PallasEngine": "repro.consensus.pallas",
    "PermuteEngine": "repro.consensus.ppermute",
}


def __getattr__(name):
    module = _LAZY_BACKENDS.get(name)
    if module is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(module), name)
