"""Pallas consensus backend: the fused consensus+tracking kernel.

Wraps ``repro/kernels/consensus_step`` behind the ``ConsensusEngine`` API,
putting the kernel on the single-host m-agent simulator's hot loop: both
Step-1/3 matmuls run in one launch with the (m, m) mixing matrix
VMEM-resident and the flattened parameters streaming through once.
Arbitrary pytrees are handled by ``ravel_pytree`` and D is zero-padded to
the tile size inside the kernel, so any model / any dense ``M`` works.
``interpret=True`` (default) executes the same kernel body on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.consensus.compress import CompressionConfig
from repro.consensus.engine import ConsensusEngine
from repro.core.consensus import MixingSpec
from repro.kernels.consensus_step.kernel import DEFAULT_BLOCK_D
from repro.kernels.consensus_step.ops import consensus_mix, consensus_step

__all__ = ["PallasEngine"]


class PallasEngine(ConsensusEngine):

    name = "pallas"

    def __init__(self, mixing: MixingSpec | jax.Array,
                 block_d: int = DEFAULT_BLOCK_D, interpret: bool = True,
                 compression: CompressionConfig | None = None,
                 communication_interval: int = 1, byzantine=None):
        mat = mixing.matrix if isinstance(mixing, MixingSpec) else mixing
        self.matrix = jnp.asarray(mat, jnp.float32)
        self.block_d = int(block_d)
        self.interpret = bool(interpret)
        self._configure_wire(compression, communication_interval, byzantine)

    def mix(self, tree, *, matrix=None, dp_key=None, agent_index=None):
        del dp_key, agent_index  # single-host backend: no wire, no DP
        mat = self.matrix if matrix is None else jnp.asarray(matrix,
                                                             jnp.float32)
        return consensus_mix(mat, tree, block_d=self.block_d,
                             interpret=self.interpret)

    def step1_step3(self, x, u, p, p_prev, alpha, *, t=None, ef=None,
                    matrix=None, dp_key=None, agent_index=None):
        if ef is not None or self.wire_active:
            # wire path: compose two compressed mixes through the base
            # implementation (each still a kernel launch via self.mix);
            # the fused Step-1/3 kernel stays on the full-precision path.
            return super().step1_step3(x, u, p, p_prev, alpha, t=t, ef=ef,
                                       matrix=matrix, dp_key=dp_key,
                                       agent_index=agent_index)
        try:
            alpha_c = float(alpha)
        except (TypeError, jax.errors.ConcretizationTypeError):
            # traced step size (a sweep batch axis): the fused kernel
            # bakes alpha in at trace time, so compose the per-mix
            # kernel launches through the base implementation instead
            return super().step1_step3(x, u, p, p_prev, alpha, t=t,
                                       matrix=matrix, dp_key=dp_key,
                                       agent_index=agent_index)
        del dp_key, agent_index
        if matrix is None:
            matrix = self.topology_matrix(t, x)
        mat = self.matrix if matrix is None else jnp.asarray(matrix,
                                                             jnp.float32)
        return consensus_step(mat, x, u, p, p_prev,
                              alpha=alpha_c, block_d=self.block_d,
                              interpret=self.interpret)
