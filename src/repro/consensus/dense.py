"""Dense consensus backend: the (m, m) matmul reference.

Works for any topology; leaves carry a leading agent dim of size m.  This
is the single-host reference every other backend is validated against
(tests/test_consensus_backends.py).

The matrix operand may be a concrete ``MixingSpec``/array **or a traced
jax value** — the padded sweep engine (docs/SWEEPS.md) constructs a
``DenseEngine`` inside the vmapped experiment trace, with each
experiment's ghost-padded mixing matrix as a mapped operand rather than
a compile-time constant.  ``DenseEngine.padded`` builds the ghost-padded
form directly: identity self-loop rows keep the matrix doubly stochastic
and leave active agents' combines bitwise unchanged.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.consensus.compress import CompressionConfig
from repro.consensus.engine import ConsensusEngine
from repro.core.consensus import MixingSpec, mix_pytree, pad_mixing

__all__ = ["DenseEngine"]


class DenseEngine(ConsensusEngine):

    name = "dense"

    def __init__(self, mixing: MixingSpec | jax.Array,
                 compression: CompressionConfig | None = None,
                 communication_interval: int = 1, byzantine=None):
        mat = mixing.matrix if isinstance(mixing, MixingSpec) else mixing
        self.matrix = jnp.asarray(mat)
        self._configure_wire(compression, communication_interval, byzantine)

    @classmethod
    def padded(cls, mixing: MixingSpec | jax.Array, pad_to: int,
               **wire_opts) -> "DenseEngine":
        """A dense engine over the ghost-padded (pad_to, pad_to) matrix."""
        return cls(pad_mixing(mixing, pad_to), **wire_opts)

    def mix(self, tree, *, matrix=None, dp_key=None, agent_index=None):
        del dp_key, agent_index  # single-host backend: no wire, no DP
        return mix_pytree(self.matrix if matrix is None else matrix, tree)
