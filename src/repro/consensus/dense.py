"""Dense consensus backend: the (m, m) matmul reference.

Works for any topology; leaves carry a leading agent dim of size m.  This
is the single-host reference every other backend is validated against
(tests/test_consensus_backends.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.consensus.engine import ConsensusEngine
from repro.core.consensus import MixingSpec, mix_pytree

__all__ = ["DenseEngine"]


class DenseEngine(ConsensusEngine):

    name = "dense"

    def __init__(self, mixing: MixingSpec | jax.Array):
        mat = mixing.matrix if isinstance(mixing, MixingSpec) else mixing
        self.matrix = jnp.asarray(mat)

    def mix(self, tree, *, dp_key=None, agent_index=None):
        del dp_key, agent_index  # single-host backend: no wire, no DP
        return mix_pytree(self.matrix, tree)
