"""ppermute consensus backend: sparse topologies on the device mesh.

Decomposes any ``MixingSpec`` into per-offset cyclic-shift permute rounds
(``repro/sharding/collectives.permute_schedule``) so Erdős–Rényi /
Metropolis / torus graphs — not just the hard-coded ring — run under
``shard_map``.  Must be called from *inside* a shard_map body whose
manual axes are exactly ``agent_axes``; leaves are the local agent's
slice (leading local dim 1 in the train step, or unbatched in tests).

int8 wire compression and local-DP noise are backend options carried by
the engine, not ring-only kwargs: ``compress="int8"`` quantizes every
outgoing payload, ``dp_sigma > 0`` adds Gaussian noise to the payload
whenever a ``dp_key`` is supplied to ``mix`` (the x-mix passes one, the
u-mix does not — only shared iterates are privatized).

``impl="psum"`` selects the all-reduce realisation of the same matrix —
required for partially-auto bodies on old-JAX stacks whose partitioner
cannot lower ppermute there (see sharding/compat); it needs the agent
index threaded in via ``mix(..., agent_index=...)``.
"""
from __future__ import annotations

from typing import Sequence

from repro.consensus.engine import ConsensusEngine
from repro.core.consensus import MixingSpec
from repro.sharding.collectives import (
    PermuteSchedule, permute_mix_tree, permute_schedule)

__all__ = ["PermuteEngine"]


class PermuteEngine(ConsensusEngine):

    name = "ppermute"

    def __init__(self, mixing: MixingSpec | PermuteSchedule,
                 agent_axes: Sequence[str] = ("data",),
                 compress: str | None = None, dp_sigma: float = 0.0,
                 impl: str = "ppermute"):
        self.schedule = (mixing if isinstance(mixing, PermuteSchedule)
                         else permute_schedule(mixing))
        self.agent_axes = tuple(agent_axes)
        self.compress = compress
        self.dp_sigma = float(dp_sigma)
        if impl not in ("ppermute", "psum"):
            raise ValueError(f"unknown ppermute impl {impl!r}")
        self.impl = impl

    @property
    def rounds_per_mix(self) -> int:
        return self.schedule.rounds_per_mix

    def mix(self, tree, *, dp_key=None, agent_index=None):
        return permute_mix_tree(
            tree, self.agent_axes, self.schedule, compress=self.compress,
            dp_sigma=self.dp_sigma if dp_key is not None else 0.0,
            dp_key=dp_key, impl=self.impl, agent_index=agent_index)
