"""ppermute consensus backend: sparse topologies on the device mesh.

Decomposes any ``MixingSpec`` into per-offset cyclic-shift permute rounds
(``repro/sharding/collectives.permute_schedule``) so Erdős–Rényi /
Metropolis / torus graphs — not just the hard-coded ring — run under
``shard_map``.  Must be called from *inside* a shard_map body whose
manual axes are exactly ``agent_axes``; leaves are the local agent's
slice (leading local dim 1 in the train step, or unbatched in tests).

int8 wire compression and local-DP noise are backend options carried by
the engine, not ring-only kwargs: ``compress="int8"`` quantizes every
outgoing payload, ``dp_sigma > 0`` adds Gaussian noise to the payload
whenever a ``dp_key`` is supplied to ``mix`` (the x-mix passes one, the
u-mix does not — only shared iterates are privatized).

``impl="psum"`` selects the all-reduce realisation of the same matrix —
required for partially-auto bodies on old-JAX stacks whose partitioner
cannot lower ppermute there (see sharding/compat); it needs the agent
index threaded in via ``mix(..., agent_index=...)``.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.consensus.compress import CompressionConfig, Int8Compressor
from repro.consensus.engine import ConsensusEngine, MeshBackendMixin
from repro.core.consensus import MixingSpec
from repro.sharding.collectives import (
    PermuteSchedule, permute_mix_tree, permute_schedule)

__all__ = ["PermuteEngine"]


class PermuteEngine(MeshBackendMixin, ConsensusEngine):

    name = "ppermute"

    def __init__(self, mixing: MixingSpec | PermuteSchedule,
                 agent_axes: Sequence[str] = ("data",),
                 compress: str | None = None, dp_sigma: float = 0.0,
                 impl: str = "ppermute",
                 compression: CompressionConfig | None = None,
                 communication_interval: int = 1, byzantine=None):
        self.schedule = (mixing if isinstance(mixing, PermuteSchedule)
                         else permute_schedule(mixing))
        self.agent_axes = tuple(agent_axes)
        self.compress = compress
        self.dp_sigma = float(dp_sigma)
        if impl not in ("ppermute", "psum"):
            raise ValueError(f"unknown ppermute impl {impl!r}")
        self.impl = impl
        self._configure_wire(compression, communication_interval, byzantine)
        if self.compression.active and compress is not None:
            raise ValueError(
                "pass either the legacy compress= wire format or a "
                "CompressionConfig, not both")
        self.byzantine.validate_for(self.schedule.num_agents)
        if self.byzantine.combine != "weighted":
            raise NotImplementedError(
                f"combine rule {self.byzantine.combine!r} needs "
                f"all-to-all access to the payload rows, but the "
                f"ppermute backend only ever holds the local agent's "
                f"slice — robust rules require the dense backend")

    @property
    def rounds_per_mix(self) -> int:
        return self.schedule.rounds_per_mix

    @property
    def _mesh_num_agents(self) -> int:
        return self.schedule.num_agents

    def mix(self, tree, *, matrix=None, dp_key=None, agent_index=None):
        # ``matrix`` here is a ``PermuteWeights`` override — the round's
        # weights on the SAME offset schedule (time-varying topology).
        return permute_mix_tree(
            tree, self.agent_axes, self.schedule, compress=self.compress,
            dp_sigma=self.dp_sigma if dp_key is not None else 0.0,
            dp_key=dp_key, impl=self.impl, agent_index=agent_index,
            override=matrix)

    def _ledger_note(self, stream, tree):
        """Per-link wire template: one payload per LEAF per permute round.

        This is the unicast model ``bytes_on_wire`` prices for this
        backend — ``rounds_per_mix`` permute rounds each shipping every
        leaf separately — which exceeds the matrix backends' broadcast
        model by the offset fan-out on non-ring graphs.  A dropped link
        in a time-varying topology zeroes a *weight*, not a payload: the
        compiled program still ships the round (static shapes), and so
        does the measured accounting — docs/DISTRIBUTED.md spells out
        the contrast with the per-process priced model.
        """
        led = self.ledger
        if led is None:
            return
        from repro.consensus.ledger import StreamRecord
        compressor = self.compressor
        if not self.compression.active and self.compress == "int8":
            compressor = Int8Compressor()
        leaves = jax.tree_util.tree_leaves(tree)
        sizes = [int(l.size) // (int(l.shape[0]) if l.ndim else 1)
                 for l in leaves]
        rounds = self.rounds_per_mix
        led.note(stream, StreamRecord(
            op=f"{self.name}/{self.impl}", entries=sum(sizes),
            wire_bytes=rounds * sum(compressor.bytes_on_wire(s)
                                    for s in sizes),
            full_bytes=rounds * 4 * sum(sizes),
            collectives=rounds * len(leaves)))

    def mix_ef(self, tree, ef=None, t=None, *, matrix=None, dp_key=None,
               agent_index=None, stream="x"):
        """Per-neighbour wire path: compress each outgoing *leaf*.

        Unlike the matrix backends (one compressed buffer of all leaves
        concatenated per agent), every leaf is a separate wire payload
        here — so scale granularity differs and cross-backend agreement
        is a tolerance contract, not bitwise (the ``none`` compressor is
        exact on both).  The wire state carries the same ``{"e", "ref"}``
        innovation scheme as the matrix backends: the agent ships
        ``C(x - ref)`` and peers (who track ``ref`` by replaying received
        innovations) reconstruct ``ref + C(...)`` — the
        reconstruction is the payload tree handed to the collectives
        layer; the local self term mixes the clean value by construction
        (``_ppermute_mix`` seeds the accumulator with it, ``_psum_mix``
        applies the self-weight correction).
        """
        if matrix is None:
            matrix = self.topology_matrix(t, tree)
        self._ledger_note(stream, tree)
        sent = self._attack_local(tree, t, stream, agent_index)
        if self.compression.active:
            v = jax.tree_util.tree_map(
                lambda l: l.astype(jnp.float32), sent)
            if ef is not None:
                v = jax.tree_util.tree_map(
                    lambda a, r: a - r, v, ef["ref"])
            c = jax.tree_util.tree_map(self.compressor.encode_decode, v)
            if self.compression.compress_after > 0:
                warm = self._require_t(t) < self.compression.compress_after
                c = jax.tree_util.tree_map(
                    lambda vv, cc: jnp.where(warm, vv, cc), v, c)
            if ef is None:
                ef_new, recon = None, c
            else:
                recon = jax.tree_util.tree_map(
                    lambda r, cc: r + cc, ef["ref"], c)
                ef_new = {"e": jax.tree_util.tree_map(
                              lambda a, b: a - b, v, c),
                          "ref": recon}
            payload = jax.tree_util.tree_map(
                lambda cc, l: cc.astype(l.dtype), recon, tree)
            mixed = permute_mix_tree(
                tree, self.agent_axes, self.schedule, compress=None,
                dp_sigma=self.dp_sigma if dp_key is not None else 0.0,
                dp_key=dp_key, impl=self.impl, agent_index=agent_index,
                payload_tree=payload, override=matrix)
            mixed = self._damp(mixed, tree)
        else:
            mixed = self.mix(sent, matrix=matrix, dp_key=dp_key,
                             agent_index=agent_index)
            ef_new = ef
        return self._apply_interval(t, mixed, tree, ef_new, ef)

    def bytes_on_wire(self, tree) -> int:
        """Per-leaf payloads × ppermute rounds (what each link carries).

        The legacy ``compress="int8"`` wire format is accounted with the
        int8 compressor when no ``CompressionConfig`` is active.
        """
        compressor = self.compressor
        if not self.compression.active and self.compress == "int8":
            compressor = Int8Compressor()
        per_leaf = sum(compressor.bytes_on_wire(int(l.size))
                       for l in jax.tree_util.tree_leaves(tree))
        return self.rounds_per_mix * per_leaf
