"""The ConsensusEngine API: one pluggable backend behind every update path.

The paper's communication result hinges on a single primitive — the
consensus combine ``x_i <- sum_j M_ij x_j`` (eqs. 6/10).  Every INTERACT
variant (Algorithm 1, SVR-INTERACT, GT-DSGD, D-SGD, and the distributed LM
train step) expresses its Steps 1/3 through this API instead of carrying
its own copy of the combine:

    engine.mix(tree) -> tree
        The bare combine applied leaf-wise (leading agent dim m on the
        dense/pallas backends; the local agent's slice under shard_map on
        the ppermute backend).

    engine.step1_step3(x, u, p, p_prev, alpha) -> (x_new, u_new)
        The fused pair the algorithms actually need:
            x_new = mix(x) - alpha * u          (Step 1, eq. 6)
            u_new = mix(u) + (p - p_prev)       (Step 3, eq. 10)
        The base implementation composes two ``mix`` calls; the pallas
        backend overrides it with one fused kernel launch.

    engine.mix_ef(tree, ef, t) -> (tree, ef)
        The wire-aware combine: compress each agent's outgoing
        *innovation* against a gossip-tracked public copy with error
        feedback (``repro/consensus/compress``), honour the
        warmup-then-compress schedule and the communication interval,
        and return the updated wire state ``{"e": residual, "ref":
        public copy}`` alongside the mixed values.  With ``ef=None``
        and an inactive wire config it is exactly ``(mix(tree), None)``.

    engine.bytes_on_wire(tree) -> int
        Wire bytes ONE agent ships for ONE combine of a per-agent
        payload shaped like ``tree`` (no agent dim) under the engine's
        compressor — the accounting behind bytes-per-unit-stationarity.

Wire options (every backend): ``compression`` is a
``repro.consensus.compress.CompressionConfig``; ``communication_interval
= k`` mixes only on steps with ``t % k == 0`` (local descent in
between), realised as a ``jnp.where`` on the step index so the program
stays one compile.  When compression uses error feedback the solver
carries the residual pytree in its scan state (``ef`` fields on the
state NamedTuples), threaded through ``consensus_descent_and_track``.

Backends (see ``make_engine``):

    dense     (m, m) matmul reference — any topology, single host.
    pallas    fused consensus+tracking Pallas kernel — any topology,
              single host, the m-agent simulator's hot loop.
    ppermute  per-offset ``lax.ppermute`` schedule — any sparse symmetric
              topology, runs inside ``shard_map`` on the device mesh.
    allgather dense combine inside ``shard_map`` — ``lax.all_gather``
              the peer rows, dot the local rows of the full matrix; any
              topology (including traced matrix streams) on the mesh.

``register_backend`` is the extension point: a fifth backend is one
decorated factory.  Engines optionally carry a ``CommsLedger``
(``repro.consensus.ledger``) that records *measured* per-round wire
bytes at trace time — ``attach_ledger(engine, ...)`` before building
the step.

``consensus_descent_and_track`` is the shared step-core: the full Steps
1-3 skeleton (consensus + descent, local gradients via a callback,
gradient tracking) used by interact / svr_interact / baselines / the
distributed train steps, so the algorithm files only differ in how they
estimate the local gradients.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.byzantine import (ByzantineConfig, apply_attack, byzantine_mask,
                             make_attack, robust_combine)
from repro.consensus.compress import CompressionConfig, make_compressor

__all__ = [
    "ConsensusEngine", "MeshBackendMixin", "as_engine", "make_engine",
    "register_backend", "BACKENDS", "consensus_descent_and_track",
]


def _f32(leaf):
    return leaf.astype(jnp.float32)


# wire streams an INTERACT-family round ships, keyed for the per-stream
# attack derivation (the inner iterate y never crosses the wire).
_STREAM_IDS = {"x": 0, "u": 1}


class ConsensusEngine:
    """Base class: a consensus combine plus the fused Step-1/3 pair."""

    name = "base"

    # time-varying topology runtime (repro.topology.runtime), installed
    # by ``attach_topology``; None = the fixed-matrix path, bit for bit.
    topology = None

    # ghost-pad active-agent count (padded sweeps install a traced value
    # so the Byzantine mask never selects a ghost slot); None = all m.
    num_active = None

    # measured-communication ledger (repro.consensus.ledger), installed
    # by ``attach_ledger`` BEFORE the step is traced; None = no
    # accounting, zero trace cost.
    ledger = None

    def _configure_wire(self, compression: CompressionConfig | None = None,
                        communication_interval: int = 1,
                        byzantine: ByzantineConfig | None = None):
        """Install the wire options every backend carries (call from
        ``__init__``): the compressor, the mix cadence, and the
        Byzantine attack/combine configuration."""
        self.compression = compression or CompressionConfig()
        self.compressor = make_compressor(self.compression)
        self.communication_interval = int(communication_interval)
        if self.communication_interval < 1:
            raise ValueError("communication_interval must be >= 1, got "
                             f"{communication_interval}")
        if not 0.0 < self.compression.gamma <= 1.0:
            raise ValueError("compression.gamma must be in (0, 1], got "
                             f"{self.compression.gamma}")
        self.byzantine = byzantine or ByzantineConfig()
        mat = getattr(self, "matrix", None)
        if mat is not None:
            # loud breakdown / capacity errors against the known m
            # (shape is static even for traced padded matrices)
            self.byzantine.validate_for(int(mat.shape[0]))
        # attack operands: concrete here, overridden with traced sweep
        # operands by the padded batching path (num_byzantine / scale /
        # the schedule key are vmap batch axes there).
        if self.byzantine.attack_active:
            self.byz_values = {
                "num_byzantine": self.byzantine.num_byzantine,
                "scale": self.byzantine.scale,
                "key": jax.random.PRNGKey(self.byzantine.resolve_seed(0)),
            }
        else:
            self.byz_values = None

    def _damp(self, mixed, tree):
        """CHOCO consensus stepsize: ``x + gamma * (mixed - x)``."""
        g = self.compression.gamma
        if g == 1.0:
            return mixed
        return jax.tree_util.tree_map(
            lambda mx, xx: (g * _f32(mx) + (1.0 - g) * _f32(xx)
                            ).astype(mx.dtype), mixed, tree)

    @property
    def wire_active(self) -> bool:
        """Does this engine need the (t, ef) wire path at all?

        Byzantine options ride the wire path too: attacks corrupt the
        shipped payload and robust rules replace the combine, both of
        which live in ``mix_ef`` (this is also what routes the pallas
        fast path through the base composition).
        """
        return (self.compression.active
                or self.communication_interval != 1
                or self.byzantine.active)

    # -- Byzantine layer: payload corruption + robust aggregation ---------

    def _attack_payload(self, tree, t, stream: str):
        """Corrupt the Byzantine slots' outgoing payload for ``stream``.

        A python no-op (bitwise, zero trace cost) when no attack is
        configured or the attack does not touch this stream.  The mask
        is the fixed seeded subset of :func:`repro.byzantine.
        byzantine_mask`; the per-round key folds (stream, t) into the
        schedule key so re-runs replay the identical corruption.
        """
        byz = self.byzantine
        if not byz.attack_active:
            return tree
        attack = make_attack(byz.kind)
        if stream not in attack.streams:
            return tree
        vals = self.byz_values
        m = jax.tree_util.tree_leaves(tree)[0].shape[0]
        mask = byzantine_mask(vals["key"], m, vals["num_byzantine"],
                              num_active=self.num_active)
        key_t = jax.random.fold_in(
            jax.random.fold_in(vals["key"], _STREAM_IDS[stream]),
            self._require_t(t))
        return apply_attack(attack, tree, mask, key_t, vals["scale"])

    def _combine(self, tree, *, matrix=None, dp_key=None, agent_index=None):
        """The configured aggregation: ``mix`` for ``weighted``, else a
        robust rule over the mixing row's support (dense rows only)."""
        rule = self.byzantine.combine
        if rule == "weighted":
            return self.mix(tree, matrix=matrix, dp_key=dp_key,
                            agent_index=agent_index)
        mat = matrix if matrix is not None else getattr(self, "matrix",
                                                        None)
        if mat is None:
            raise NotImplementedError(
                f"combine rule {rule!r} needs all-to-all access to the "
                f"payload rows, but the {self.name!r} backend holds no "
                f"full mixing matrix — run robust rules on the dense "
                f"backend (pallas routes there automatically)")
        return robust_combine(mat, tree, rule,
                              self.byzantine.resolve_trim())

    def mix(self, tree, *, matrix=None, dp_key: jax.Array | None = None,
            agent_index: jax.Array | None = None):
        """Apply ``x_i <- sum_j M_ij x_j`` to every leaf of ``tree``.

        ``matrix`` overrides the engine's fixed mixing matrix for this
        call (the per-step matrix of a time-varying topology; on the
        ppermute backend a ``PermuteWeights`` override on the shared
        offset schedule).  ``dp_key`` (backends that support it) keys
        the local-DP noise on the outgoing payload; ``agent_index``
        threads the agent's ring position into distributed backends that
        cannot derive it from the mesh.  Single-host backends ignore
        both.
        """
        raise NotImplementedError

    def topology_matrix(self, t, tree=None):
        """The round's mixing-matrix override, or None on the fixed path.

        With a time-varying topology attached (``engine.topology``), the
        matrix stream is a function of the step index — gathering
        ``matrices[t % T]`` inside the scan keeps the whole run one
        compile.  The adaptive process additionally reads the current
        iterates (``tree``).
        """
        if self.topology is None:
            return None
        if t is None:
            raise ValueError(
                "a time-varying topology needs the step index: pass t= "
                "to mix_ef / step1_step3 (or resolve the matrix yourself "
                "via engine.topology_matrix(t) and pass matrix=)")
        return self.topology.matrix_at(t, tree)

    # -- measured wire accounting (repro.consensus.ledger) ----------------

    def _ledger_note(self, stream: str, tree) -> None:
        """Record ``stream``'s per-round wire template on the ledger.

        Called at trace time from every combine entry point; a python
        no-op (zero trace cost) without an attached ledger.  The matrix
        backends ship ONE concatenated per-agent buffer per stream per
        round — exactly what ``bytes_on_wire`` prices — so measured and
        priced bytes agree bit for bit here; ppermute overrides this
        with its per-leaf x permute-rounds template.
        """
        led = self.ledger
        if led is None:
            return
        from repro.consensus.ledger import StreamRecord
        leaves = jax.tree_util.tree_leaves(tree)
        m = int(leaves[0].shape[0]) if leaves[0].ndim else 1
        size = sum(int(l.size) for l in leaves) // max(1, m)
        led.note(stream, StreamRecord(
            op=self.name, entries=size,
            wire_bytes=int(self.compressor.bytes_on_wire(size)),
            full_bytes=4 * size, collectives=1))

    # -- the wire path: EF compression + warmup + interval ----------------

    def _self_weights(self, matrix=None) -> jax.Array:
        """Per-agent self weights M[i, i] (matrix-holding backends)."""
        mat = self.matrix if matrix is None else matrix
        return jnp.diagonal(mat).astype(jnp.float32)

    def _require_t(self, t):
        if t is None:
            raise ValueError(
                "the warmup schedule / communication interval need the "
                "step index: pass t= to mix_ef / step1_step3")
        return jnp.asarray(t)

    def _compress_payload(self, tree, ef, t):
        """Per-agent compression of the (m, ...) raveled buffer.

        Returns ``(payload_tree, ef_new)`` where ``payload_tree`` is the
        value the neighbours decode (leaf dtype, leaf-shaped).  Each
        agent's leaves are flattened and concatenated into one (m, D)
        buffer and compressed row-wise — one wire payload per agent per
        combine, and (because rows compress independently) bitwise
        invariant under ghost-agent padding.

        With wire state ``ef = {"e": residual, "ref": public copy}`` the
        agent transmits the compressed innovation ``c = C(x - ref)`` and
        everyone reconstructs ``payload = ref + c`` (CHOCO-style).  The
        feedback is intrinsic: ``ref`` advances only by what was
        actually transmitted, so the residual ``e = (x - ref) - c`` is
        automatically part of the NEXT innovation (``x' - ref' =
        (x' - x) + e``) — adding ``e`` explicitly would double-count it
        and provably diverges for hard-sparsifying wires.  ``ef_new``
        carries the updated residual (diagnostic) and the advanced
        public copy.  With ``ef=None`` the raw value is compressed
        uncompensated (``payload = C(x)``) — no memory, errors are
        never re-sent.
        """
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        m = leaves[0].shape[0]
        sizes = [int(l.size) // m for l in leaves]
        concat = lambda tr: jnp.concatenate(
            [_f32(l).reshape(m, -1)
             for l in jax.tree_util.tree_flatten(tr)[0]], axis=1)

        def split(buf, dtypes=None):
            parts = jnp.split(buf, _split_points(sizes), axis=1)
            return jax.tree_util.tree_unflatten(
                treedef,
                [p.reshape(l.shape) if dtypes is None
                 else p.reshape(l.shape).astype(l.dtype)
                 for p, l in zip(parts, leaves)])

        buf = concat(tree)
        if ef is not None:
            ref = concat(ef["ref"])
            v = buf - ref
        else:
            ref = jnp.zeros_like(buf)
            v = buf
        c = jax.vmap(self.compressor.encode_decode)(v)
        if self.compression.compress_after > 0:
            warm = self._require_t(t) < self.compression.compress_after
            c = jnp.where(warm, v, c)
        payload = ref + c
        ef_new = None
        if ef is not None:
            ef_new = {"e": split(v - c), "ref": split(payload)}
        return split(payload, dtypes=True), ef_new

    def _apply_interval(self, t, mixed, tree, ef_new, ef):
        """Skip the combine on steps with ``t % interval != 0``.

        The mixed values fall back to the un-mixed local ones (so Step 1
        degrades to plain local descent) and the wire state freezes —
        nothing was sent, so no compression error was incurred and no
        public copy advanced.
        """
        k = self.communication_interval
        if k == 1:
            return mixed, ef_new
        do = (self._require_t(t) % k) == 0
        pick = lambda a, b: jax.tree_util.tree_map(
            lambda aa, bb: jnp.where(do, aa, bb), a, b)
        mixed = pick(mixed, tree)
        if ef is not None:
            ef_new = pick(ef_new, ef)
        return mixed, ef_new

    def mix_ef(self, tree, ef=None, t=None, *, matrix=None,
               dp_key: jax.Array | None = None,
               agent_index: jax.Array | None = None, stream: str = "x"):
        """The wire-aware combine: ``(mixed, ef_new)``.

        ``ef`` is this stream's wire state ``{"e": EF residual, "ref":
        public copy}`` (``None`` when compression is off or
        uncompensated).  The reconstructed payload ``ref + C(x - ref +
        e)`` is what neighbours combine; the agent's own term mixes the
        clean local value (``mix(payload) + M_ii (x - payload)``) — the
        same self-clean semantics as the ppermute int8/DP wire.  With an
        inactive wire config this is exactly ``(mix(tree), ef)``.
        ``matrix`` (or an attached time-varying topology, resolved from
        ``t``) overrides the fixed matrix for this round.

        ``stream`` labels which wire stream this combine carries
        (``"x"``/``"u"``) so stream-selective attacks corrupt the right
        payload.  Corruption happens *before* compression: the CHOCO
        ``ref`` copies advance by what was actually transmitted, so a
        Byzantine ``ref`` stream never poisons honest agents'
        reconstruction of each other.  The self-clean correction applies
        only under the ``weighted`` rule — the robust rules are
        nonlinear and have no exact self term (docs/BYZANTINE.md).
        """
        if matrix is None:
            matrix = self.topology_matrix(t, tree)
        self._ledger_note(stream, tree)
        sent = self._attack_payload(tree, t, stream)
        if self.compression.active:
            payload, ef_new = self._compress_payload(sent, ef, t)
            mixed = self._combine(payload, matrix=matrix, dp_key=dp_key,
                                  agent_index=agent_index)
            if self.byzantine.combine == "weighted":
                d = self._self_weights(matrix)
                mixed = jax.tree_util.tree_map(
                    lambda mx, xx, cc: (
                        _f32(mx) + d.reshape((-1,) + (1,) * (mx.ndim - 1))
                        * (_f32(xx) - _f32(cc))).astype(mx.dtype),
                    mixed, tree, payload)
            mixed = self._damp(mixed, tree)
        else:
            mixed = self._combine(sent, matrix=matrix, dp_key=dp_key,
                                  agent_index=agent_index)
            ef_new = ef
        return self._apply_interval(t, mixed, tree, ef_new, ef)

    def bytes_on_wire(self, tree) -> int:
        """Wire bytes ONE agent ships for ONE combine of ``tree``.

        ``tree`` is a per-agent payload (no agent dim).  Matrix backends
        ship one compressed buffer of all leaves concatenated; warmup /
        interval scheduling is NOT folded in here (see
        ``repro.consensus.compress.cumulative_wire_bytes``).
        """
        size = sum(int(l.size) for l in jax.tree_util.tree_leaves(tree))
        return self.compressor.bytes_on_wire(size)

    def step1_step3(self, x, u, p, p_prev, alpha: float, *,
                    t=None, ef=None, matrix=None,
                    dp_key: jax.Array | None = None,
                    agent_index: jax.Array | None = None):
        """Fused eq. (6) + eq. (10).

        Returns ``(x_new, u_new)`` on the legacy full-precision path
        (``ef is None`` and no wire options configured), and ``(x_new,
        u_new, ef_new)`` on the wire path, where ``ef`` / ``ef_new`` is
        the per-stream wire-state dict ``{"x": {"e", "ref"}, "u":
        {...}}`` (or ``None`` for uncompensated compression / bare
        intervals).

        Math runs in float32 and is cast back to the leaf dtype, so bf16
        states mix without drift.  The tracking difference is grouped as
        ``mix(u) + (p - p_prev)`` so calling with ``p is p_prev`` yields
        ``mix(u)`` exactly (how the step-core obtains the mixed tracker
        before the new gradients exist).
        """
        if matrix is None:
            matrix = self.topology_matrix(t, x)
        wire = ef is not None or self.wire_active
        if wire:
            x_mixed, ef_x = self.mix_ef(
                x, None if ef is None else ef.get("x"), t,
                matrix=matrix, dp_key=dp_key, agent_index=agent_index,
                stream="x")
            u_mixed, ef_u = self.mix_ef(
                u, None if ef is None else ef.get("u"), t,
                matrix=matrix, agent_index=agent_index, stream="u")
        else:
            self._ledger_note("x", x)
            self._ledger_note("u", u)
            x_mixed = self.mix(x, matrix=matrix, dp_key=dp_key,
                               agent_index=agent_index)
            u_mixed = self.mix(u, matrix=matrix, agent_index=agent_index)
        x_new = jax.tree_util.tree_map(
            lambda mx, uu: (_f32(mx) - alpha * _f32(uu)).astype(mx.dtype),
            x_mixed, u)
        u_new = jax.tree_util.tree_map(
            lambda mu, pn, pp: (_f32(mu) + (_f32(pn) - _f32(pp))
                                ).astype(mu.dtype),
            u_mixed, p, p_prev)
        if not wire:
            return x_new, u_new
        ef_new = None if ef is None else {"x": ef_x, "u": ef_u}
        return x_new, u_new, ef_new


class MeshBackendMixin:
    """Shared helpers for backends that run *inside* ``shard_map``.

    Mesh backends (ppermute, allgather) see only the local agent's slice
    (leading local dim) and must recover global slot identities from the
    mesh axes — for Byzantine masks/keys that have to match the dense
    reference bitwise, and for slicing the local rows of a full mixing
    matrix.  Requires ``self.agent_axes`` and the usual wire attributes
    from ``_configure_wire``; ``_mesh_num_agents`` supplies the global
    agent count (schedule / matrix dependent).
    """

    @property
    def _mesh_num_agents(self) -> int:
        raise NotImplementedError

    def _axis_agent_index(self):
        """This shard's position along the (flattened) agent axes."""
        idx = jnp.int32(0)
        for ax in self.agent_axes:
            idx = idx * jax.lax.psum(1, ax) + jax.lax.axis_index(ax)
        return idx

    def _local_slots(self, tree, agent_index):
        """Global slot ids of this shard's rows (leading local dim)."""
        rows = jax.tree_util.tree_leaves(tree)[0].shape[0]
        if agent_index is None:
            idx = self._axis_agent_index()
        else:
            idx = jnp.asarray(agent_index, jnp.int32)
        return idx * rows + jnp.arange(rows, dtype=jnp.int32)

    def _attack_local(self, tree, t, stream, agent_index):
        """The local-slice form of the base ``_attack_payload``.

        The mask and per-slot keys are derived from *global* slot ids,
        so the corrupted payload matches the dense reference bitwise
        (under the exact ``none`` compressor).  Expects the standard
        leading local agent dim on every leaf.
        """
        byz = self.byzantine
        if not byz.attack_active:
            return tree
        attack = make_attack(byz.kind)
        if stream not in attack.streams:
            return tree
        vals = self.byz_values
        mask = byzantine_mask(vals["key"], self._mesh_num_agents,
                              vals["num_byzantine"],
                              num_active=self.num_active)
        slots = self._local_slots(tree, agent_index)
        key_t = jax.random.fold_in(
            jax.random.fold_in(vals["key"], _STREAM_IDS[stream]),
            self._require_t(t))
        return apply_attack(attack, tree, mask[slots], key_t,
                            vals["scale"], slots=slots)


def _split_points(sizes):
    """Split points for ``jnp.split`` from a list of leaf sizes."""
    out, acc = [], 0
    for s in sizes[:-1]:
        acc += s
        out.append(acc)
    return out


def consensus_descent_and_track(
    engine: ConsensusEngine,
    x, y, u, v, p_prev,
    alpha: float, beta: float,
    grads_fn: Callable,
    *,
    t=None,
    ef=None,
    dp_key: jax.Array | None = None,
    agent_index: jax.Array | None = None,
):
    """One INTERACT iteration skeleton shared by every tracking algorithm.

      Step 1: x_new = mix(x) - alpha u ;  y_new = y - beta v
      Step 2: (p_new, v_new, aux) = grads_fn(x_new, y_new)
      Step 3: u_new = mix(u) + p_new - p_prev

    Both mixes are issued through one ``engine.step1_step3`` call (with
    ``p = p_prev`` its tracking term vanishes and it returns exactly
    ``(x_new, mix(u))``), so the pallas backend fuses them into a single
    kernel launch; the tracking correction is applied element-wise once
    the new local gradients exist.

    ``grads_fn(x_new, y_new) -> (p_new, v_new, aux)``; ``aux`` is passed
    through untouched (metrics, or None).

    ``t`` (the step index) and ``ef`` (the per-stream wire-state dict
    ``{"x": {"e", "ref"}, ...}``, or ``None``) drive the engine's wire
    path — compression, warmup schedule, communication interval; both
    live in the solver's scan carry.  With an inactive wire config they
    pass straight through.

    Returns ``(x_new, y_new, u_new, v_new, p_new, ef_new, aux)``.
    """
    wire = ef is not None or getattr(engine, "wire_active", False)
    if wire:
        x_new, u_mixed, ef_new = engine.step1_step3(
            x, u, p_prev, p_prev, alpha, t=t, ef=ef, dp_key=dp_key,
            agent_index=agent_index)
    else:
        x_new, u_mixed = engine.step1_step3(x, u, p_prev, p_prev, alpha,
                                            t=t, dp_key=dp_key,
                                            agent_index=agent_index)
        ef_new = ef
    y_new = jax.tree_util.tree_map(
        lambda yy, vv: (_f32(yy) - beta * _f32(vv)).astype(yy.dtype), y, v)

    p_new, v_new, aux = grads_fn(x_new, y_new)

    u_new = jax.tree_util.tree_map(
        lambda mu, pn, pp: (_f32(mu) + (_f32(pn) - _f32(pp))
                            ).astype(mu.dtype),
        u_mixed, p_new, p_prev)
    return x_new, y_new, u_new, v_new, p_new, ef_new, aux


# Backend registry: name -> factory(mixing, **opts).  Factories import
# their engine module lazily (PEP-562 in the package __init__) so pulling
# in repro.core never loads the pallas extras or the sharding collectives.
BACKENDS: dict[str, Callable] = {}


def register_backend(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a consensus-backend factory under ``name``.

    The factory signature is ``factory(mixing, **opts) ->
    ConsensusEngine``; ``make_engine`` resolves names through this
    registry, so adding a backend is one decorated factory — no edits to
    the engine module required (the in-repo backends register here only
    to keep their imports lazy).
    """

    def deco(factory: Callable) -> Callable:
        existing = BACKENDS.get(name)
        if existing is not None and existing is not factory:
            raise ValueError(f"consensus backend {name!r} already "
                             f"registered ({existing!r})")
        BACKENDS[name] = factory
        return factory

    return deco


@register_backend("dense")
def _make_dense(mixing, **opts):
    from repro.consensus.dense import DenseEngine
    return DenseEngine(mixing, **opts)


@register_backend("pallas")
def _make_pallas(mixing, **opts):
    from repro.consensus.pallas import PallasEngine
    return PallasEngine(mixing, **opts)


@register_backend("ppermute")
def _make_ppermute(mixing, **opts):
    from repro.consensus.ppermute import PermuteEngine
    return PermuteEngine(mixing, **opts)


@register_backend("allgather")
def _make_allgather(mixing, **opts):
    from repro.consensus.allgather import AllGatherEngine
    return AllGatherEngine(mixing, **opts)


def make_engine(backend: str, mixing, **opts) -> ConsensusEngine:
    """Build a consensus backend by name.

    ``mixing`` is a ``MixingSpec`` or a raw (m, m) matrix.  Backend
    options: ``block_d``/``interpret`` (pallas), ``agent_axes``/
    ``compress``/``dp_sigma`` (ppermute), ``agent_axes`` (allgather);
    every backend additionally accepts ``compression``/
    ``communication_interval``/``byzantine`` wire options.
    """
    try:
        factory = BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown consensus backend {backend!r}; "
            f"choose from {sorted(BACKENDS)}") from None
    return factory(mixing, **opts)


def as_engine(mixing_or_engine) -> ConsensusEngine:
    """Coerce a raw mixing matrix / MixingSpec to a dense engine."""
    if isinstance(mixing_or_engine, ConsensusEngine):
        return mixing_or_engine
    return _make_dense(mixing_or_engine)
