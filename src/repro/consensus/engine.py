"""The ConsensusEngine API: one pluggable backend behind every update path.

The paper's communication result hinges on a single primitive — the
consensus combine ``x_i <- sum_j M_ij x_j`` (eqs. 6/10).  Every INTERACT
variant (Algorithm 1, SVR-INTERACT, GT-DSGD, D-SGD, and the distributed LM
train step) expresses its Steps 1/3 through this API instead of carrying
its own copy of the combine:

    engine.mix(tree) -> tree
        The bare combine applied leaf-wise (leading agent dim m on the
        dense/pallas backends; the local agent's slice under shard_map on
        the ppermute backend).

    engine.step1_step3(x, u, p, p_prev, alpha) -> (x_new, u_new)
        The fused pair the algorithms actually need:
            x_new = mix(x) - alpha * u          (Step 1, eq. 6)
            u_new = mix(u) + (p - p_prev)       (Step 3, eq. 10)
        The base implementation composes two ``mix`` calls; the pallas
        backend overrides it with one fused kernel launch.

Backends (see ``make_engine``):

    dense     (m, m) matmul reference — any topology, single host.
    pallas    fused consensus+tracking Pallas kernel — any topology,
              single host, the m-agent simulator's hot loop.
    ppermute  per-offset ``lax.ppermute`` schedule — any sparse symmetric
              topology, runs inside ``shard_map`` on the device mesh.

``consensus_descent_and_track`` is the shared step-core: the full Steps
1-3 skeleton (consensus + descent, local gradients via a callback,
gradient tracking) used by interact / svr_interact / baselines / the
distributed train steps, so the algorithm files only differ in how they
estimate the local gradients.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "ConsensusEngine", "as_engine", "make_engine", "BACKENDS",
    "consensus_descent_and_track",
]


def _f32(leaf):
    return leaf.astype(jnp.float32)


class ConsensusEngine:
    """Base class: a consensus combine plus the fused Step-1/3 pair."""

    name = "base"

    def mix(self, tree, *, dp_key: jax.Array | None = None,
            agent_index: jax.Array | None = None):
        """Apply ``x_i <- sum_j M_ij x_j`` to every leaf of ``tree``.

        ``dp_key`` (backends that support it) keys the local-DP noise on
        the outgoing payload; ``agent_index`` threads the agent's ring
        position into distributed backends that cannot derive it from the
        mesh.  Single-host backends ignore both.
        """
        raise NotImplementedError

    def step1_step3(self, x, u, p, p_prev, alpha: float, *,
                    dp_key: jax.Array | None = None,
                    agent_index: jax.Array | None = None):
        """Fused eq. (6) + eq. (10): returns (x_new, u_new).

        Math runs in float32 and is cast back to the leaf dtype, so bf16
        states mix without drift.  The tracking difference is grouped as
        ``mix(u) + (p - p_prev)`` so calling with ``p is p_prev`` yields
        ``mix(u)`` exactly (how the step-core obtains the mixed tracker
        before the new gradients exist).
        """
        x_mixed = self.mix(x, dp_key=dp_key, agent_index=agent_index)
        u_mixed = self.mix(u, agent_index=agent_index)
        x_new = jax.tree_util.tree_map(
            lambda mx, uu: (_f32(mx) - alpha * _f32(uu)).astype(mx.dtype),
            x_mixed, u)
        u_new = jax.tree_util.tree_map(
            lambda mu, pn, pp: (_f32(mu) + (_f32(pn) - _f32(pp))
                                ).astype(mu.dtype),
            u_mixed, p, p_prev)
        return x_new, u_new


def consensus_descent_and_track(
    engine: ConsensusEngine,
    x, y, u, v, p_prev,
    alpha: float, beta: float,
    grads_fn: Callable,
    *,
    dp_key: jax.Array | None = None,
    agent_index: jax.Array | None = None,
):
    """One INTERACT iteration skeleton shared by every tracking algorithm.

      Step 1: x_new = mix(x) - alpha u ;  y_new = y - beta v
      Step 2: (p_new, v_new, aux) = grads_fn(x_new, y_new)
      Step 3: u_new = mix(u) + p_new - p_prev

    Both mixes are issued through one ``engine.step1_step3`` call (with
    ``p = p_prev`` its tracking term vanishes and it returns exactly
    ``(x_new, mix(u))``), so the pallas backend fuses them into a single
    kernel launch; the tracking correction is applied element-wise once
    the new local gradients exist.

    ``grads_fn(x_new, y_new) -> (p_new, v_new, aux)``; ``aux`` is passed
    through untouched (metrics, or None).

    Returns ``(x_new, y_new, u_new, v_new, p_new, aux)``.
    """
    x_new, u_mixed = engine.step1_step3(x, u, p_prev, p_prev, alpha,
                                        dp_key=dp_key,
                                        agent_index=agent_index)
    y_new = jax.tree_util.tree_map(
        lambda yy, vv: (_f32(yy) - beta * _f32(vv)).astype(yy.dtype), y, v)

    p_new, v_new, aux = grads_fn(x_new, y_new)

    u_new = jax.tree_util.tree_map(
        lambda mu, pn, pp: (_f32(mu) + (_f32(pn) - _f32(pp))
                            ).astype(mu.dtype),
        u_mixed, p_new, p_prev)
    return x_new, y_new, u_new, v_new, p_new, aux


def _make_dense(mixing, **opts):
    from repro.consensus.dense import DenseEngine
    return DenseEngine(mixing, **opts)


def _make_pallas(mixing, **opts):
    from repro.consensus.pallas import PallasEngine
    return PallasEngine(mixing, **opts)


def _make_ppermute(mixing, **opts):
    from repro.consensus.ppermute import PermuteEngine
    return PermuteEngine(mixing, **opts)


BACKENDS = {
    "dense": _make_dense,
    "pallas": _make_pallas,
    "ppermute": _make_ppermute,
}


def make_engine(backend: str, mixing, **opts) -> ConsensusEngine:
    """Build a consensus backend by name.

    ``mixing`` is a ``MixingSpec`` or a raw (m, m) matrix.  Backend
    options: ``block_d``/``interpret`` (pallas), ``agent_axes``/
    ``compress``/``dp_sigma`` (ppermute).
    """
    try:
        factory = BACKENDS[backend]
    except KeyError:
        raise ValueError(
            f"unknown consensus backend {backend!r}; "
            f"choose from {sorted(BACKENDS)}") from None
    return factory(mixing, **opts)


def as_engine(mixing_or_engine) -> ConsensusEngine:
    """Coerce a raw mixing matrix / MixingSpec to a dense engine."""
    if isinstance(mixing_or_engine, ConsensusEngine):
        return mixing_or_engine
    return _make_dense(mixing_or_engine)
