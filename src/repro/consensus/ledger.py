"""CommsLedger: *measured* bytes-on-wire and per-round latency.

The repo prices communication analytically — ``engine.bytes_on_wire``
per combine, ``cumulative_wire_bytes`` for a schedule — but a priced
model can silently drift from what the program actually ships.  The
ledger closes that loop: an engine with ``engine.ledger`` set records,
**at trace time**, the payload dtypes/shapes of every wire stream that
actually crosses the mesh axis (one ``StreamRecord`` per stream: the
``x`` and ``u`` consensus streams of the tracking algorithms, just
``x`` for D-SGD), and the host commits the engine's deterministic
schedule afterwards:

    ledger = attach_ledger(engine, CommsLedger())
    ... trace/run the solver step ...          # records stream templates
    ledger.commit_steps(num_steps)             # applies warmup/interval
    ledger.measured_wire_bytes                 # per-agent bytes shipped

Trace-time capture is exact because the wire is static: the compression
schedule (warmup for ``t < compress_after``, silence when ``t %
interval != 0``) is a pure function of the step index, realised as
``jnp.where`` inside one compiled program — so the per-round payloads
never change shape and the host can replay the schedule without
instrumenting the device.  Re-traces overwrite the same stream keys
(idempotent), so warmup + run + recompile never double-count.

Two accounting models coexist, matching the backends (see
docs/DISTRIBUTED.md):

* matrix backends (dense / pallas / allgather) ship ONE concatenated
  per-agent buffer per stream per round — the broadcast model
  ``cumulative_wire_bytes`` prices, so measured == priced bit for bit
  under ``none``/``int8``/``sign1bit``.
* ppermute ships one payload per leaf per permute round (the per-link
  unicast model ``PermuteEngine.bytes_on_wire`` prices) — measured
  matches *that* model exactly, and exceeds the broadcast model by the
  ``rounds_per_mix`` fan-out factor on non-ring graphs.

``round_latency_us`` is observed separately (time a warmed jitted
combine dispatch; the launch layer and ``solve`` both do) and stored on
the ledger so one object carries the full measured-communication
read-out.
"""
from __future__ import annotations

import dataclasses
import time

__all__ = ["CommsLedger", "StreamRecord", "attach_ledger", "time_round_us"]


@dataclasses.dataclass
class StreamRecord:
    """Per-round wire template of ONE consensus stream (one agent).

    ``wire_bytes`` is what an active compressed round ships,
    ``full_bytes`` what a warmup (full-f32) round ships; ``entries`` the
    per-agent payload entry count and ``collectives`` how many
    collective ops realise one round (1 for matrix backends,
    ``rounds_per_mix x leaves`` for ppermute).
    """

    op: str
    entries: int
    wire_bytes: int
    full_bytes: int
    collectives: int = 1


class CommsLedger:
    """Measured per-agent communication accounting for one engine."""

    def __init__(self):
        self.streams: dict[str, StreamRecord] = {}
        # schedule knobs, copied from the engine by ``attach_ledger``
        self.compress_after = 0
        self.communication_interval = 1
        self.steps_committed = 0
        self.round_latency_us: float | None = None
        self._bytes = 0.0
        self._collectives = 0

    # -- trace-time capture ----------------------------------------------
    def note(self, stream: str, record: StreamRecord) -> None:
        """Record (or overwrite) one stream's per-round wire template."""
        self.streams[stream] = record

    # -- host-side commit -------------------------------------------------
    def commit_steps(self, num_steps: int) -> float:
        """Charge ``num_steps`` solver steps of the recorded streams.

        Applies the engine's deterministic wire schedule per step index
        (continuing from any previously committed steps): warmup rounds
        ship ``full_bytes``, silenced rounds (``t % interval != 0``)
        ship nothing, active rounds ship ``wire_bytes``.  Returns the
        bytes charged by THIS call.
        """
        start = self.steps_committed
        charged = 0.0
        for t in range(start, start + int(num_steps)):
            if t % self.communication_interval != 0:
                continue
            for rec in self.streams.values():
                charged += (rec.full_bytes if t < self.compress_after
                            else rec.wire_bytes)
                self._collectives += rec.collectives
        self.steps_committed += int(num_steps)
        self._bytes += charged
        return charged

    # -- read-out ---------------------------------------------------------
    @property
    def measured_wire_bytes(self) -> float:
        """Per-agent bytes shipped over all committed steps."""
        return self._bytes

    @property
    def collectives_issued(self) -> int:
        """Collective ops dispatched over all committed steps (per agent)."""
        return self._collectives

    def bytes_per_step(self) -> float:
        """Active-round bytes of one step (all streams, no schedule)."""
        return float(sum(r.wire_bytes for r in self.streams.values()))

    def observe_latency(self, us: float) -> None:
        self.round_latency_us = float(us)

    def summary(self) -> dict:
        """JSON-ready dump of everything measured."""
        return {
            "streams": {k: dataclasses.asdict(v)
                        for k, v in self.streams.items()},
            "compress_after": self.compress_after,
            "communication_interval": self.communication_interval,
            "steps_committed": self.steps_committed,
            "measured_wire_bytes": self.measured_wire_bytes,
            "collectives_issued": self.collectives_issued,
            "round_latency_us": self.round_latency_us,
        }


def attach_ledger(engine, ledger: CommsLedger | None = None) -> CommsLedger:
    """Install ``ledger`` on ``engine`` (before the step is traced!).

    Copies the engine's wire-schedule knobs onto the ledger so
    ``commit_steps`` replays the same warmup/interval the compiled
    program applies.  Returns the ledger.
    """
    if ledger is None:
        ledger = CommsLedger()
    ledger.compress_after = int(engine.compression.compress_after)
    ledger.communication_interval = int(engine.communication_interval)
    engine.ledger = ledger
    return ledger


def time_round_us(fn, *args, reps: int = 5) -> float:
    """Median wall-clock of one warmed dispatch of ``fn(*args)`` in us.

    ``fn`` should be a jitted combine (one consensus round); the first
    call compiles outside the timed window.
    """
    import jax

    out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    samples = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return 1e6 * samples[len(samples) // 2]
