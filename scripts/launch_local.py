#!/usr/bin/env python
"""Localhost multi-process launcher for the Section-6 mesh runner.

Spawns N worker processes of this same script, each a jax process with K
forced host devices, wires them to one coordinator, and runs
``repro.launch.distributed.run_section6`` in lockstep — a real
``jax.distributed`` run (gloo CPU collectives) on one machine:

    python scripts/launch_local.py --processes 2 --devices-per-process 4 \\
        --agents 8 --steps 30 --backend allgather --out result.json

Process 0 writes the JSON result (final eq.-11 stationarity, metric
trace, measured vs priced wire bytes, round latency, state digest); the
driver prints it.  ``--skip-init`` runs a plain single-process baseline
with NO distributed runtime — the bitwise reference the
``check_distributed`` gate compares a 1-process initialized run against.

The driver itself never imports jax: platform/device env vars must be
set before any jax import, so they are exported into the worker
environment (JAX_PLATFORMS=cpu, XLA_FLAGS=--xla_force_host_platform_
device_count=K, REPRO_COORDINATOR / REPRO_NUM_PROCESSES /
REPRO_PROCESS_ID — see docs/DISTRIBUTED.md).
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def parse_args(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--processes", type=int, default=2)
    ap.add_argument("--devices-per-process", type=int, default=4)
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--record-every", type=int, default=10)
    ap.add_argument("--backend", default="allgather",
                    choices=("allgather", "ppermute"))
    ap.add_argument("--compression", default="none",
                    choices=("none", "int8", "sign1bit"))
    ap.add_argument("--compress-after", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--n-per-agent", type=int, default=80)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--beta", type=float, default=0.1)
    ap.add_argument("--metric-inner-steps", type=int, default=120)
    ap.add_argument("--out", default=None,
                    help="JSON result path (default: temp file, printed)")
    ap.add_argument("--timeout", type=float, default=900.0,
                    help="per-worker wall-clock limit, seconds")
    ap.add_argument("--skip-init", action="store_true",
                    help="single-process baseline without "
                         "jax.distributed.initialize (requires "
                         "--processes 1)")
    # worker-only internals (the driver spawns itself with these)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--process-id", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--coordinator", default=None, help=argparse.SUPPRESS)
    return ap.parse_args(argv)


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def worker(args) -> None:
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.launch import distributed as D

    if not args.skip_init:
        D.initialize(D.DistributedConfig(
            coordinator=args.coordinator,
            num_processes=args.processes,
            process_id=args.process_id))
    compression = None
    if args.compression != "none":
        from repro.consensus import CompressionConfig
        compression = CompressionConfig(kind=args.compression,
                                        compress_after=args.compress_after)
    import jax
    result = D.run_section6(
        num_agents=args.agents, num_steps=args.steps,
        record_every=args.record_every, backend=args.backend,
        compression=compression, seed=args.seed,
        n_per_agent=args.n_per_agent, alpha=args.alpha, beta=args.beta,
        metric_inner_steps=args.metric_inner_steps)
    result["skip_init"] = bool(args.skip_init)
    if jax.process_index() == 0:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    if not args.skip_init:
        D.shutdown()


def main() -> int:
    args = parse_args()
    if args.worker:
        worker(args)
        return 0

    if args.skip_init and args.processes != 1:
        raise SystemExit("--skip-init is the single-process baseline: "
                         "pass --processes 1 with it")
    out = args.out or os.path.join(tempfile.mkdtemp(prefix="launch_local_"),
                                   "result.json")
    coordinator = f"127.0.0.1:{_free_port()}"

    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                        f"{args.devices_per_process}")
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    env[D_ENV_COORD] = coordinator
    env[D_ENV_NPROC] = str(args.processes)

    passthrough = [
        "--processes", str(args.processes),
        "--devices-per-process", str(args.devices_per_process),
        "--agents", str(args.agents),
        "--steps", str(args.steps),
        "--record-every", str(args.record_every),
        "--backend", args.backend,
        "--compression", args.compression,
        "--compress-after", str(args.compress_after),
        "--seed", str(args.seed),
        "--n-per-agent", str(args.n_per_agent),
        "--alpha", str(args.alpha),
        "--beta", str(args.beta),
        "--metric-inner-steps", str(args.metric_inner_steps),
        "--out", out,
    ]
    if args.skip_init:
        passthrough.append("--skip-init")

    procs = []
    for pid in range(args.processes):
        wenv = dict(env)
        wenv[D_ENV_PID] = str(pid)
        procs.append(subprocess.Popen(
            [sys.executable, os.path.abspath(__file__), "--worker",
             "--process-id", str(pid), "--coordinator", coordinator,
             *passthrough],
            env=wenv))

    failed = []
    try:
        for pid, proc in enumerate(procs):
            rc = proc.wait(timeout=args.timeout)
            if rc != 0:
                failed.append((pid, rc))
    finally:
        for proc in procs:
            if proc.poll() is None:
                proc.kill()
    if failed:
        for pid, rc in failed:
            print(f"worker {pid} exited {rc}", file=sys.stderr)
        return 1

    with open(out) as f:
        result = json.load(f)
    print(json.dumps(result, indent=1))
    if args.out is None:
        print(f"\n(result written to {out})", file=sys.stderr)
    return 0


# env-var names mirrored from repro.launch.distributed WITHOUT importing
# it here: the driver process must stay jax-free
D_ENV_COORD = "REPRO_COORDINATOR"
D_ENV_NPROC = "REPRO_NUM_PROCESSES"
D_ENV_PID = "REPRO_PROCESS_ID"


if __name__ == "__main__":
    sys.exit(main())
