"""Paper Fig. 4: impact of edge-connectivity probability p_c.

Claim validated: the metric M is relatively insensitive to p_c in
{0.3, 0.5, 0.7}, increasing slightly as the network gets sparser.
"""
from __future__ import annotations

from benchmarks.common import Row, make_setup, run_algo

ITERS = 40


def run(smoke: bool = False) -> list:
    iters = 10 if smoke else ITERS
    rows = []
    finals = {}
    for pc in (0.3, 0.5, 0.7):
        s = make_setup(m=5, p_connect=pc)
        for algo in ("interact", "svr-interact"):
            trace, us, _ = run_algo(s, algo, iters)
            finals[(algo, pc)] = trace[-1]
            rows.append(Row(f"fig4_connectivity_pc{pc}_{algo}", us,
                            f"final_metric={trace[-1]:.5f};lambda={s.spec.lam:.3f}"))
    # insensitivity: spread across pc within 1 order of magnitude
    for algo in ("interact", "svr-interact"):
        vals = [finals[(algo, pc)] for pc in (0.3, 0.5, 0.7)]
        ratio = max(vals) / max(min(vals), 1e-12)
        rows.append(Row(f"fig4_claim_{algo}_insensitive", 0.0,
                        f"max_over_min={ratio:.2f};holds={ratio < 10.0}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
