"""Paper Fig. 4: impact of the network on convergence — and the padded
sweep over the network itself.

Two grids share this suite:

* **Edge-connectivity** (the figure's claim): the metric M is relatively
  insensitive to p_c in {0.3, 0.5, 0.7}, increasing slightly as the
  network gets sparser.  Each p_c realises a different mixing matrix, so
  the plain sweep engine groups the grid into one compiled program per
  (algo, p_c) — seeds batch inside each group.

* **Network size x topology** (the padded-batching claim): an
  m x topology x algorithm grid used to compile one XLA program per
  (m, topology) cell because the agent count changes every state shape.
  Under ``sweep(..., pad_agents=True)`` every cell's mixing matrix is
  ghost-padded to the grid's largest network and the whole grid runs as
  **one dispatch per algorithm**, active-agent traces bitwise equal to
  the per-size runs (dense backend).  The cold (compile-inclusive)
  wall-clock ratio is the ``pad_speedup`` headline in
  ``BENCH_sweep.json`` — what padding actually buys is deleting the
  per-size compiles/dispatches, so the honest baseline is the one-
  program-per-cell walk, compiles included.  ``benchmarks/check_gates``
  gates ``pad_speedup >= 1``, the bitwise ``pad_trace_match``, and the
  dispatch collapse in CI.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (Row, make_setup, metric_fn_of,
                               record_sweep_section)
from repro.core import masked_convergence_metric_fn
from repro.solvers import SolverConfig, TopologyConfig, expand_grid, sweep

ITERS = 40
SEEDS = (0, 1, 2)

PAD_SIZES = (4, 8)                       # network sizes m in the pad grid
PAD_TOPOLOGIES = ("ring", "erdos-renyi")
PAD_ALGOS = ("interact", "svr-interact")


def _connectivity_grid(smoke: bool, rows: list, records: list) -> None:
    iters = 10 if smoke else ITERS
    seeds = SEEDS[:2] if smoke else SEEDS
    finals = {}
    for pc in (0.3, 0.5, 0.7):
        s = make_setup(m=5, p_connect=pc)
        mfn = metric_fn_of(s)
        configs = expand_grid(
            SolverConfig(mixing=s.spec, hypergrad=s.hg),
            algo=("interact", "svr-interact"), seed=seeds)
        res = sweep(configs, iters, rec := 5, problem=s.prob, x0=s.x0,
                    y0=s.y0, data=s.data, metric_fn=mfn, measure=True)
        for group in res.groups:
            algo = group.config.algo
            traces = res.group_traces(group)
            mean, std = traces.mean(axis=0), traces.std(axis=0)
            finals[(algo, pc)] = float(mean[-1])
            us = 1e6 * group.seconds / (len(seeds) * iters)
            rows.append(Row(
                f"fig4_connectivity_pc{pc}_{algo}", us,
                f"final_metric={mean[-1]:.5f};final_std={std[-1]:.5f};"
                f"seeds={len(seeds)};lambda={s.spec.lam:.3f}"))
            records.append({"name": f"fig4_pc{pc}_{algo}", "algo": algo,
                            "p_connect": pc, "lam": float(s.spec.lam),
                            "spectral_gap": 1.0 - float(s.spec.lam),
                            "seeds": len(seeds), "iters": iters,
                            "record_every": rec,
                            "trace_mean": mean.tolist(),
                            "trace_std": std.tolist()})
    # insensitivity: spread across pc within 1 order of magnitude
    for algo in ("interact", "svr-interact"):
        vals = [finals[(algo, pc)] for pc in (0.3, 0.5, 0.7)]
        ratio = max(vals) / max(min(vals), 1e-12)
        rows.append(Row(f"fig4_claim_{algo}_insensitive", 0.0,
                        f"max_over_min={ratio:.2f};holds={ratio < 10.0}"))
        records.append({"name": f"fig4_claim_{algo}",
                        "max_over_min": ratio, "holds": ratio < 10.0})


def _padded_network_grid(smoke: bool, rows: list,
                         records: list) -> dict:
    """The m x topology x algorithm grid, padded vs per-cell — returns
    the headline fields the CI gate asserts."""
    iters = 10 if smoke else ITERS
    seeds = SEEDS[:2] if smoke else SEEDS
    rec = 5
    sizes, topos, algos = PAD_SIZES, PAD_TOPOLOGIES, PAD_ALGOS

    s0 = make_setup(m=sizes[0])          # m-independent problem/x0/y0/hg
    datas = {m: (s0.data if m == s0.m else make_setup(m=m).data)
             for m in sizes}
    mask_fn = masked_convergence_metric_fn(s0.prob, s0.hg)

    configs = expand_grid(
        SolverConfig(hypergrad=s0.hg),
        algo=algos, num_agents=sizes,
        topology=tuple(TopologyConfig(kind=t) for t in topos),
        seed=seeds)

    # -- unpadded baseline: one cold sweep per (algo, m, topology) cell,
    # exactly the per-group dispatch pattern padding collapses.  Cold
    # (compile included) on both sides: the compiles ARE the cost.
    unpadded_traces = {}
    cell_seconds: dict[tuple, float] = {}
    for algo in algos:
        for m in sizes:
            for topo in topos:
                idx, cell = zip(*[
                    (i, c) for i, c in enumerate(configs)
                    if (c.algo, c.num_agents, c.topology.kind)
                    == (algo, m, topo)])
                mfn = (lambda d, na: lambda st: mask_fn(st, d, na))(
                    datas[m], jnp.int32(m))
                res = sweep(cell, iters, rec, problem=s0.prob, x0=s0.x0,
                            y0=s0.y0, data=datas[m], metric_fn=mfn)
                cell_seconds[(algo, m, topo)] = res.seconds
                for r, i in enumerate(idx):
                    unpadded_traces[i] = res.traces[r]
                mean = res.traces.mean(axis=0)
                records.append({
                    "name": f"fig4_pad_cell_{algo}_m{m}_{topo}",
                    "algo": algo, "m": m, "topology": topo,
                    "seeds": len(seeds), "iters": iters,
                    "record_every": rec,
                    "seconds_unpadded_cold": res.seconds,
                    "final_metric": float(mean[-1]),
                    "trace_mean": mean.tolist()})

    # -- padded: the same grid, one cold dispatch per algorithm
    res_pad = sweep(configs, iters, rec, problem=s0.prob, x0=s0.x0,
                    y0=s0.y0, data=datas, metric_fn=mask_fn,
                    pad_agents=True)

    match = all(
        (unpadded_traces[i] == res_pad.traces[i]).all()
        for i in range(len(configs)))
    dispatches_unpadded = len(cell_seconds)
    dispatches_padded = res_pad.num_dispatches

    speedups = {}
    for group in res_pad.groups:
        algo = group.config.algo
        seq = sum(sec for (a, _, _), sec in cell_seconds.items()
                  if a == algo)
        speedups[algo] = seq / max(group.seconds, 1e-12)
        us = 1e6 * group.seconds / (len(group.indices) * iters)
        rows.append(Row(
            f"fig4_pad_grid_{algo}", us,
            f"pad_to={group.pad_to};cells={len(group.indices)};"
            f"seconds_padded_cold={group.seconds:.3f};"
            f"seconds_unpadded_cold={seq:.3f};"
            f"pad_speedup={speedups[algo]:.2f}"))
        records.append({
            "name": f"fig4_pad_grid_{algo}", "algo": algo,
            "pad_to": group.pad_to,
            "sizes": list(sizes), "topologies": list(topos),
            "seeds": len(seeds), "iters": iters,
            "seconds_padded_cold": group.seconds,
            "seconds_unpadded_cold": seq,
            "pad_speedup": speedups[algo]})

    headline = {
        "pad_speedup": min(speedups.values()),
        "pad_trace_match": bool(match),
        "pad_dispatches_unpadded": dispatches_unpadded,
        "pad_dispatches_padded": dispatches_padded,
    }
    rows.append(Row(
        "fig4_pad_engine", 0.0,
        f"min_pad_speedup={headline['pad_speedup']:.2f};"
        f"pad_trace_match={match};"
        f"dispatches={dispatches_unpadded}->{dispatches_padded}"))
    return headline


def run(smoke: bool = False) -> list:
    rows: list = []
    records: list = []
    _connectivity_grid(smoke, rows, records)
    headline = _padded_network_grid(smoke, rows, records)
    record_sweep_section("connectivity", records, **headline)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
