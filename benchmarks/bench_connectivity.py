"""Paper Fig. 4: impact of edge-connectivity probability p_c.

Claim validated: the metric M is relatively insensitive to p_c in
{0.3, 0.5, 0.7}, increasing slightly as the network gets sparser.

Each p_c realises a different mixing matrix, so the sweep engine groups
the grid into one compiled program per (algo, p_c) — seeds batch inside
each group (6 dispatches for 6 x len(seeds) cells).
"""
from __future__ import annotations

from benchmarks.common import (Row, make_setup, metric_fn_of,
                               record_sweep_section)
from repro.solvers import SolverConfig, expand_grid, sweep

ITERS = 40
SEEDS = (0, 1, 2)


def run(smoke: bool = False) -> list:
    iters = 10 if smoke else ITERS
    seeds = SEEDS[:2] if smoke else SEEDS
    rows, records = [], []
    finals = {}
    for pc in (0.3, 0.5, 0.7):
        s = make_setup(m=5, p_connect=pc)
        mfn = metric_fn_of(s)
        configs = expand_grid(
            SolverConfig(mixing=s.spec, hypergrad=s.hg),
            algo=("interact", "svr-interact"), seed=seeds)
        res = sweep(configs, iters, rec := 5, problem=s.prob, x0=s.x0,
                    y0=s.y0, data=s.data, metric_fn=mfn, measure=True)
        for group in res.groups:
            algo = group.config.algo
            traces = res.group_traces(group)
            mean, std = traces.mean(axis=0), traces.std(axis=0)
            finals[(algo, pc)] = float(mean[-1])
            us = 1e6 * group.seconds / (len(seeds) * iters)
            rows.append(Row(
                f"fig4_connectivity_pc{pc}_{algo}", us,
                f"final_metric={mean[-1]:.5f};final_std={std[-1]:.5f};"
                f"seeds={len(seeds)};lambda={s.spec.lam:.3f}"))
            records.append({"name": f"fig4_pc{pc}_{algo}", "algo": algo,
                            "p_connect": pc, "lam": float(s.spec.lam),
                            "seeds": len(seeds), "iters": iters,
                            "record_every": rec,
                            "trace_mean": mean.tolist(),
                            "trace_std": std.tolist()})
    # insensitivity: spread across pc within 1 order of magnitude
    for algo in ("interact", "svr-interact"):
        vals = [finals[(algo, pc)] for pc in (0.3, 0.5, 0.7)]
        ratio = max(vals) / max(min(vals), 1e-12)
        rows.append(Row(f"fig4_claim_{algo}_insensitive", 0.0,
                        f"max_over_min={ratio:.2f};holds={ratio < 10.0}"))
        records.append({"name": f"fig4_claim_{algo}",
                        "max_over_min": ratio, "holds": ratio < 10.0})
    record_sweep_section("connectivity", records)
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
