"""The consolidated CI bench gate: validate every ``BENCH_*.json`` dump.

The bench-smoke CI job used to assert a couple of ``BENCH_sweep.json``
headline fields from an inline heredoc in the workflow file — invisible
to local runs and silent about every other dump.  This module is that
gate as code: it checks the headline fields of *all* known benchmark
dumps (sweep speedups >= 1, bitwise parity flags, padded-batching
speedup and dispatch collapse, hypergradient accounting present,
measured-vs-priced wire bytes) and is runnable locally exactly as CI
runs it:

    PYTHONPATH=src BENCH_JSON_DIR=bench-artifacts \
        python -m benchmarks.check_gates

Every validator runs to completion and reports ALL tripped gates for its
dump — not just the first — and a per-gate summary table closes the
report, so one CI run shows the full damage instead of a
fix-one-see-the-next loop.

Dumps are searched in ``$BENCH_JSON_DIR`` (or the cwd).  A *known* dump
that is missing fails the gate — the benches write them uncondition-
ally, so absence means the harness rotted; pass ``--allow-missing``
when deliberately checking a partial run (absent dumps and absent
headline fields become skips; out-of-bound values present still fail).
Unknown ``BENCH_*.json`` files only have to parse.  Exit status is the
CI contract: 0 iff every gate holds.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

_MISSING = object()


class GateReport:
    """Per-dump collector: every failure, missing field, and ok-note.

    Validators call ``need``/``true``/``ge``/``check``/``fail``/``note``
    and always run to the end of their checklist; nothing raises, so one
    report carries ALL tripped gates of its dump.
    """

    def __init__(self, path: str):
        self.path = path
        self.notes: list[str] = []
        self.failures: list[str] = []
        self.missing: list[str] = []

    # -- primitives -------------------------------------------------------
    def need(self, dump: dict, field: str):
        """Fetch a headline field; records it as missing (and returns the
        ``_MISSING`` sentinel) when absent."""
        if field not in dump:
            self.missing.append(f"headline field {field!r} missing")
            return _MISSING
        return dump[field]

    def fail(self, msg: str) -> None:
        self.failures.append(msg)

    def note(self, msg: str) -> None:
        self.notes.append(msg)

    def check(self, cond: bool, fail_msg: str,
              note_msg: str | None = None) -> bool:
        if not cond:
            self.fail(fail_msg)
        elif note_msg:
            self.note(note_msg)
        return bool(cond)

    # -- field-level gates ------------------------------------------------
    def ge(self, dump: dict, field: str, bound: float):
        val = self.need(dump, field)
        if val is _MISSING:
            return None
        if not isinstance(val, (int, float)) or not val >= bound:
            self.fail(f"{field}={val} < {bound}")
        else:
            self.note(f"{field}={val:.2f}")
        return val

    def true(self, dump: dict, field: str, fail_msg: str | None = None):
        val = self.need(dump, field)
        if val is _MISSING:
            return None
        if val is not True:
            self.fail(fail_msg or f"{field} is not True")
        else:
            self.note(f"{field}=True")
        return val


def check_sweep(dump: dict, g: GateReport) -> None:
    """BENCH_sweep.json: batching + padding regression gates.

    * ``vmap_speedup`` >= 1 — the batched sweep must not lose to the
      sequential baseline it replaced.
    * ``scan_speedup`` >= 0.8 — the scan runner vs the python loop.
      The min is taken across algorithms, and the cheapest baseline
      (d-sgd, ~1 ms/step) sits at genuine scan/loop parity on a 1-core
      CPU host, so the measured ratio wobbles across 1.0 run to run;
      the floor catches a collapse (per-chunk recompiles, a scan body
      that stopped fusing) without failing the build on scheduler
      noise.
    * ``trace_bitwise_match`` — in-scan recording reproduces the legacy
      chunked trace bit for bit.
    * ``pad_speedup`` >= 1 — the padded m x topology grid (one program
      per algorithm, compile included) must not lose to the one-program-
      per-(m, topology) walk it collapses.
    * ``pad_trace_match`` — padded active-agent traces are bitwise equal
      to the unpadded per-size runs (dense backend).
    * ``pad_dispatches_padded < pad_dispatches_unpadded`` — padding must
      actually collapse dispatch groups, not just relabel them.
    """
    g.ge(dump, "vmap_speedup", 1.0)
    g.ge(dump, "scan_speedup", 0.8)
    g.true(dump, "trace_bitwise_match")
    g.ge(dump, "pad_speedup", 1.0)
    g.true(dump, "pad_trace_match")
    unpad = g.need(dump, "pad_dispatches_unpadded")
    pad = g.need(dump, "pad_dispatches_padded")
    if unpad is not _MISSING and pad is not _MISSING:
        g.check(pad < unpad,
                f"padding did not collapse dispatches ({pad} padded vs "
                f"{unpad} unpadded)",
                f"dispatches {unpad}->{pad}")


def check_hypergrad(dump: dict, g: GateReport) -> None:
    """BENCH_hypergrad.json: measured accounting present on every row.

    Theorem-1/2 complexity claims hang off the *measured* per-call
    hvp/grad/hess counts; a row without them means the counting
    LinearOperator got bypassed.
    """
    rows = g.need(dump, "rows")
    if rows is _MISSING:
        return
    if not rows:
        g.fail("no benchmark rows")
        return
    clean = True
    for row in rows:
        for field in ("hvp", "grad", "hess"):
            val = row.get(field)
            if not isinstance(val, (int, float)) or val < 0:
                g.fail(f"row {row.get('name', '?')!r} lacks a measured "
                       f"{field!r} count (got {val!r})")
                clean = False
    if clean:
        g.note(f"{len(rows)} rows carry hvp/grad/hess counts")


def check_compression(dump: dict, g: GateReport) -> None:
    """BENCH_compression.json: wire-traffic-per-stationarity gates.

    * ``bytes_reduction_sign1bit >= 8`` — sign1bit+EF must reach the
      reference eq.-11 gap with at least 8x fewer wire bytes than the
      uncompressed run (per-round the wire is ~32x smaller; the slack
      absorbs the extra iterates the coarser wire needs).
    * ``sign1bit_matched_stationarity`` — the reduction is measured at
      matched quality (the compressed run actually reached the
      reference gap within the bench's ``match_tol``), never at a worse
      stationarity point.
    * ``ef_beats_noef`` — at byte-identical wire usage (same
      compressor, same step count), the innovation/EF wire state ends
      strictly below the stateless quantizer.
    """
    red = g.need(dump, "bytes_reduction_sign1bit")
    if red is not _MISSING:
        g.check(isinstance(red, (int, float)) and red >= 8.0,
                f"bytes_reduction_sign1bit={red} < 8",
                f"bytes_reduction_sign1bit={red:.1f}x"
                if isinstance(red, (int, float)) else None)
    g.true(dump, "sign1bit_matched_stationarity",
           "sign1bit run did not reach the reference stationarity "
           "(reduction measured at unmatched quality)")
    ef = g.need(dump, "ef_beats_noef")
    if ef is not _MISSING:
        g.check(ef is True,
                f"EF did not beat stateless int8 at equal bit budget "
                f"(EF {dump.get('int8_ef_final_gap')} vs no-EF "
                f"{dump.get('int8_noef_final_gap')})",
                "ef_beats_noef=True")


def check_topology(dump: dict, g: GateReport) -> None:
    """BENCH_topology.json: time-varying topology gates.

    * ``static_bitwise_match`` — the explicit ``static`` process AND the
      p = 0 link-failure stream reproduce the fixed-matrix trace bit for
      bit, per algorithm: the subsystem is a no-op until a link drops.
    * ``p03_convergence_factor <= p03_gate_factor`` — at a 30% per-edge
      drop rate every algorithm still converges within the stated factor
      of the failure-free run (the self-loop repair degrades the
      spectral gap gracefully, it never stalls).
    * every ``link_failure`` row carries a measured
      ``mean_spectral_gap`` in [0, 1] and nonnegative, p-monotone wire
      bytes (more drops can only ship fewer bytes).
    * the ``gossip`` section carries the matched-bandwidth read-out
      (byte marks + both metrics at them).
    """
    g.true(dump, "static_bitwise_match")
    factor = g.need(dump, "p03_convergence_factor")
    gate = g.need(dump, "p03_gate_factor")
    if factor is not _MISSING and gate is not _MISSING:
        g.check(factor <= gate,
                f"p03_convergence_factor={factor:.3f} > {gate}",
                f"p03_factor={factor:.2f}<={gate}")
    lf = g.need(dump, "link_failure")
    if lf is not _MISSING:
        if not lf:
            g.fail("no link_failure rows")
        bytes_by_algo: dict[str, list[tuple[float, float]]] = {}
        clean = bool(lf)
        for row in lf:
            gap = row.get("mean_spectral_gap")
            if not isinstance(gap, (int, float)) or not 0.0 <= gap <= 1.0:
                g.fail(f"row {row.get('name', '?')!r} lacks a valid "
                       f"mean_spectral_gap (got {gap!r})")
                clean = False
            wb = row.get("wire_bytes_total")
            if not isinstance(wb, (int, float)) or wb < 0:
                g.fail(f"row {row.get('name', '?')!r} lacks nonnegative "
                       f"wire_bytes_total (got {wb!r})")
                clean = False
                continue
            bytes_by_algo.setdefault(row["algo"], []).append(
                (row["p"], float(wb)))
        for algo, pairs in bytes_by_algo.items():
            pairs.sort()
            totals = [b for _, b in pairs]
            if any(b > a for a, b in zip(totals, totals[1:])):
                g.fail(f"wire bytes increase with drop rate for "
                       f"{algo!r}: {pairs}")
                clean = False
        if clean:
            g.note(f"{len(lf)} link_failure rows carry gap+bytes columns")
    gos = g.need(dump, "gossip")
    if gos is not _MISSING:
        clean = True
        for row in gos:
            for field in ("matched_bytes",
                          "gossip_metric_at_matched_bytes",
                          "static_metric_at_matched_bytes"):
                if not row.get(field):
                    g.fail(f"gossip row {row.get('name', '?')!r} lacks "
                           f"the matched-bandwidth field {field!r}")
                    clean = False
        if clean:
            g.note(f"{len(gos)} gossip rows carry matched-bandwidth "
                   f"read-out")


def check_byzantine(dump: dict, g: GateReport) -> None:
    """BENCH_byzantine.json: Byzantine-resilience gates.

    * ``weighted_zero_bitwise`` — the Byzantine subsystem configured
      with zero attackers under the ``weighted`` rule reproduces the
      no-byzantine baseline trace bit for bit, per algorithm: the
      resilience layer is a strict no-op until an attacker exists.
    * ``trimmed_f1_factor <= trimmed_gate_factor`` — trimmed-mean with
      one sign-flip attacker ends within the stated factor (3x) of the
      clean eq.-11 stationarity gap, for every algorithm.
    * ``weighted_attacked_factor >= weighted_diverge_factor`` — the
      same attack under the plain weighted combine exceeds 10x the
      clean gap (the robust rule is doing real work, the attack is not
      a perturbation the baseline absorbs anyway).
    * ``single_dispatch_grids`` — every attacker-count x seed grid
      compiled ONE program per (algorithm, rule) under
      ``sweep(..., pad_agents=True)``: attack values batch as vmap
      operands, never as trace constants.
    """
    g.true(dump, "weighted_zero_bitwise")
    factor = g.need(dump, "trimmed_f1_factor")
    gate = g.need(dump, "trimmed_gate_factor")
    if factor is not _MISSING and gate is not _MISSING:
        g.check(factor <= gate,
                f"trimmed_f1_factor={factor:.3f} > {gate}",
                f"trimmed_f1_factor={factor:.2f}<={gate}")
    wf = g.need(dump, "weighted_attacked_factor")
    div = g.need(dump, "weighted_diverge_factor")
    if wf is not _MISSING and div is not _MISSING:
        g.check(wf >= div,
                f"weighted_attacked_factor={wf:.3f} < {div} — the attack "
                f"did not break the unprotected baseline",
                f"weighted_attacked_factor={wf:.1f}>={div}")
    g.true(dump, "single_dispatch_grids",
           "an attack grid split into multiple dispatches under "
           "pad_agents=True")
    grids = g.need(dump, "grids")
    if grids is not _MISSING:
        if not grids:
            g.fail("no attack-grid rows")
        clean = bool(grids)
        for row in grids:
            if not row.get("finals_by_nb"):
                g.fail(f"grid {row.get('name', '?')!r} lacks finals_by_nb")
                clean = False
        if clean:
            g.note(f"{len(grids)} attack grids carry finals_by_nb")
    guard = g.need(dump, "guard")
    if guard is not _MISSING:
        clean = True
        for row in guard:
            for field in ("tripped_steps", "last_good_step"):
                if not isinstance(row.get(field), int):
                    g.fail(f"guard row {row.get('algo', '?')!r} lacks an "
                           f"integer {field!r} (got {row.get(field)!r})")
                    clean = False
        if clean:
            g.note(f"{len(guard)} guard rows carry detection counters")


def check_resilience(dump: dict, g: GateReport) -> None:
    """BENCH_resilience.json: fault-tolerance gates (docs/RESILIENCE.md).

    * ``resume_bitwise`` — every kill/resume case (all four registry
      algorithms on the dense backend, plus sign1bit+EF) reproduced the
      uninterrupted metric trace bit for bit, and every per-case row
      says so individually.
    * ``checkpoint_overhead_pct <= overhead_gate_pct`` — the chunked
      resumable runner at ``checkpoint_every=50`` (snapshot writes
      included) costs at most 10% over the single-scan ``run_traced``.
    * ``chaos_completed`` + ``chaos_matched_stationarity`` — the seeded
      chaos campaign (>= 3 kills plus corrupt/stale checkpoint
      injections) finished the Section-6 instance with zero manual
      intervention and its final eq.-11 metric matches the fault-free
      run.
    """
    g.true(dump, "resume_bitwise")
    cases = g.need(dump, "resume_cases")
    if cases is not _MISSING:
        if len(cases) < 5:
            g.fail(f"only {len(cases)} resume cases (need the four "
                   f"registry algorithms plus a compressed+EF config)")
        clean = True
        for case in cases:
            if case.get("bitwise") is not True:
                g.fail(f"resume case {case.get('name', '?')!r} is not "
                       f"bitwise")
                clean = False
        if clean and len(cases) >= 5:
            g.note(f"resume_bitwise over {len(cases)} cases")
    overhead = g.need(dump, "checkpoint_overhead_pct")
    gate = g.need(dump, "overhead_gate_pct")
    if overhead is not _MISSING and gate is not _MISSING:
        g.check(overhead <= gate,
                f"checkpoint_overhead_pct={overhead:.2f} > {gate}",
                f"checkpoint_overhead={overhead:.1f}%<={gate:.0f}%")
    g.true(dump, "chaos_completed", "chaos campaign did not complete")
    if g.need(dump, "chaos_matched_stationarity") not in (_MISSING, True):
        chaos = dump.get("chaos", {})
        g.fail(f"chaos final metric {chaos.get('final_metric')} does not "
               f"match the fault-free final {chaos.get('clean_final')}")
    chaos = g.need(dump, "chaos")
    if chaos is not _MISSING:
        g.check(chaos.get("kills", 0) >= 3,
                f"chaos campaign survived only {chaos.get('kills')} kills "
                f"(need >= 3 kill/resume cycles)",
                f"chaos completed: {chaos.get('kills')} kills, "
                f"{chaos.get('restarts')} restarts")


def check_complexity(dump: dict, g: GateReport) -> None:
    """BENCH_complexity.json: measured-communication columns present.

    Every Table-1 row must carry the ``measured_wire_bytes`` /
    ``round_latency_us`` columns (CommsLedger + timed consensus round —
    consensus/ledger.py).  ``null`` is legal — a backend that records or
    times nothing — but an absent key means the bench stopped
    measuring.
    """
    rows = g.need(dump, "rows")
    if rows is _MISSING:
        return
    if not rows:
        g.fail("no benchmark rows")
        return
    clean = True
    for row in rows:
        for field in ("measured_wire_bytes", "round_latency_us"):
            if field not in row:
                g.fail(f"row {row.get('name', '?')!r} lacks the "
                       f"{field!r} column")
                clean = False
                continue
            val = row[field]
            if val is not None and (not isinstance(val, (int, float))
                                    or val < 0):
                g.fail(f"row {row.get('name', '?')!r} has invalid "
                       f"{field}={val!r}")
                clean = False
    if clean:
        g.note(f"{len(rows)} rows carry measured wire bytes + latency")


def check_distributed(dump: dict, g: GateReport) -> None:
    """BENCH_distributed.json: real multi-process launch gates.

    * measured-vs-priced ratio within ``ratio_band`` (10%) of 1 for the
      ``none`` / ``int8`` / ``sign1bit`` compressors on the allgather
      backend (broadcast model), and for ppermute against its per-link
      unicast model — the CommsLedger agrees with the analytic pricing.
    * ``single_process_bitwise`` — the 1-process mesh run with the
      distributed runtime matches the no-runtime baseline digest.
    * ``two_process.stationarity_matched`` — the 2-process launch
      reaches the 1-process eq.-11 stationarity within ``match_tol``.
    * measured ``round_latency_us`` is present and positive.
    """
    band = dump.get("ratio_band", 0.10)
    lo, hi = 1.0 - band, 1.0 + band
    rows = g.need(dump, "measured_vs_priced")
    if rows is not _MISSING:
        kinds = {row.get("kind") for row in rows}
        for want in ("none", "int8", "sign1bit"):
            if want not in kinds:
                g.fail(f"no measured-vs-priced row for compressor "
                       f"{want!r}")
        clean = True
        for row in rows:
            ratio = row.get("ratio")
            if not isinstance(ratio, (int, float)) or not lo <= ratio <= hi:
                g.fail(f"{row.get('kind', '?')}: measured/priced "
                       f"ratio={ratio!r} outside [{lo:.2f}, {hi:.2f}]")
                clean = False
        if clean and kinds >= {"none", "int8", "sign1bit"}:
            g.note(f"{len(rows)} compressors measured within "
                   f"{100 * band:.0f}% of priced")
    pp = g.need(dump, "ppermute")
    if pp is not _MISSING:
        ratio = pp.get("ratio")
        g.check(isinstance(ratio, (int, float)) and lo <= ratio <= hi,
                f"ppermute measured/per-link ratio={ratio!r} outside "
                f"[{lo:.2f}, {hi:.2f}]",
                f"ppermute per-link ratio={ratio:.3f}"
                if isinstance(ratio, (int, float)) else None)
    g.true(dump, "single_process_bitwise",
           "1-process initialized run is not bitwise vs the no-runtime "
           "baseline")
    two = g.need(dump, "two_process")
    if two is not _MISSING:
        g.check(two.get("stationarity_matched") is True,
                f"2-process final metric {two.get('final_metric')} did "
                f"not match the baseline {two.get('baseline_final_metric')} "
                f"(rel diff {two.get('rel_diff')})",
                f"2-process stationarity matched "
                f"(rel diff {two.get('rel_diff', 0):.1e})")
        lat = two.get("round_latency_us")
        g.check(isinstance(lat, (int, float)) and lat > 0,
                f"2-process round_latency_us={lat!r} is not positive",
                f"round_latency_us={lat:.0f}"
                if isinstance(lat, (int, float)) else None)


# Known dumps: file name -> validator.  Every generator in benchmarks/
# that dumps a BENCH_*.json should register its gate here so the CI
# bench-smoke job (and anyone running the module locally) checks it.
GATES = {
    "BENCH_sweep.json": check_sweep,
    "BENCH_hypergrad.json": check_hypergrad,
    "BENCH_compression.json": check_compression,
    "BENCH_topology.json": check_topology,
    "BENCH_byzantine.json": check_byzantine,
    "BENCH_resilience.json": check_resilience,
    "BENCH_complexity.json": check_complexity,
    "BENCH_distributed.json": check_distributed,
}


def _print_summary(reports: list[tuple[str, str, int]]) -> None:
    """The per-gate summary table: dump, status, tripped-gate count."""
    width = max(len(name) for name, _, _ in reports)
    print("\nper-gate summary:")
    print(f"  {'gate'.ljust(width)}  status  failures")
    for name, status, count in reports:
        print(f"  {name.ljust(width)}  {status:<6}  {count}")


def run_gates(json_dir: str, allow_missing: bool = False) -> int:
    """Validate every dump in ``json_dir``; returns the failure count.

    Each validator collects ALL its tripped gates; the report lists
    every failure and closes with a per-gate summary table.
    """
    failures = 0
    seen = set()
    summary: list[tuple[str, str, int]] = []
    for name in sorted(GATES):
        path = os.path.join(json_dir, name)
        if not os.path.exists(path):
            if allow_missing:
                print(f"skip: MISSING {path}")
                summary.append((name, "skip", 0))
                continue
            print(f"FAIL: MISSING {path} (pass --allow-missing for "
                  f"partial runs)")
            failures += 1
            summary.append((name, "FAIL", 1))
            continue
        seen.add(os.path.abspath(path))
        g = GateReport(name)
        try:
            with open(path) as fh:
                dump = json.load(fh)
            GATES[name](dump, g)
        except (OSError, json.JSONDecodeError) as exc:
            g.fail(f"unreadable dump ({exc})")
        except Exception as exc:  # validator crash = a failed gate, but
            g.fail(f"validator crashed: {exc!r}")  # keep checking others
        # Missing headline fields: a partial run legitimately lacks the
        # fields of the suites that didn't run (BENCH_sweep.json is
        # rewritten after every contributing suite).
        missing_fail = 0 if allow_missing else len(g.missing)
        for msg in g.missing:
            tag = "skip" if allow_missing else "FAIL"
            print(f"{tag}: {name}: {msg}"
                  + (" (partial run)" if allow_missing else ""))
        for msg in g.failures:
            print(f"FAIL: {name}: {msg}")
        count = len(g.failures) + missing_fail
        failures += count
        if count:
            summary.append((name, "FAIL", count))
        else:
            status = "skip" if (g.missing and allow_missing
                                and not g.notes) else "ok"
            summary.append((name, status, 0))
            if g.notes:
                print(f"ok: {name}: " + "; ".join(g.notes))

    for path in sorted(glob.glob(os.path.join(json_dir, "BENCH_*.json"))):
        if os.path.abspath(path) in seen:
            continue
        base = os.path.basename(path)
        if base in GATES:
            continue  # already reported missing above
        try:
            with open(path) as fh:
                json.load(fh)
            print(f"ok: {base}: no registered gate, parses")
            summary.append((base, "ok", 0))
        except (OSError, json.JSONDecodeError) as exc:
            print(f"FAIL: {base}: unreadable dump ({exc})")
            failures += 1
            summary.append((base, "FAIL", 1))
    if summary:
        _print_summary(summary)
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=None,
                    help="dump directory (default: $BENCH_JSON_DIR or cwd)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="skip (instead of fail) absent known dumps and "
                         "absent headline fields — for partial local runs; "
                         "out-of-bound values present still fail")
    args = ap.parse_args(argv)
    json_dir = args.dir or os.environ.get("BENCH_JSON_DIR", os.getcwd())
    failures = run_gates(json_dir, allow_missing=args.allow_missing)
    if failures:
        print(f"{failures} gate(s) failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
