"""The consolidated CI bench gate: validate every ``BENCH_*.json`` dump.

The bench-smoke CI job used to assert a couple of ``BENCH_sweep.json``
headline fields from an inline heredoc in the workflow file — invisible
to local runs and silent about every other dump.  This module is that
gate as code: it checks the headline fields of *all* known benchmark
dumps (sweep speedups >= 1, bitwise parity flags, padded-batching
speedup and dispatch collapse, hypergradient accounting present) and is
runnable locally exactly as CI runs it:

    PYTHONPATH=src BENCH_JSON_DIR=bench-artifacts \
        python -m benchmarks.check_gates

Dumps are searched in ``$BENCH_JSON_DIR`` (or the cwd).  A *known* dump
that is missing fails the gate — the benches write them uncondition-
ally, so absence means the harness rotted; pass ``--allow-missing``
when deliberately checking a partial run.  Unknown ``BENCH_*.json``
files only have to parse.  Exit status is the CI contract: 0 iff every
gate holds.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys


class GateFailure(Exception):
    """One failed gate (message names the dump, field and bound)."""


class MissingGateField(GateFailure):
    """A headline field is absent — a partial run under --allow-missing
    skips these; a full CI run fails on them."""


def _need(dump: dict, field: str, path: str):
    if field not in dump:
        raise MissingGateField(f"{path}: headline field {field!r} missing")
    return dump[field]


def check_sweep(dump: dict, path: str) -> list[str]:
    """BENCH_sweep.json: batching + padding regression gates.

    * ``vmap_speedup`` >= 1 — the batched sweep must not lose to the
      sequential baseline it replaced.
    * ``scan_speedup`` >= 0.8 — the scan runner vs the python loop.
      The min is taken across algorithms, and the cheapest baseline
      (d-sgd, ~1 ms/step) sits at genuine scan/loop parity on a 1-core
      CPU host, so the measured ratio wobbles across 1.0 run to run;
      the floor catches a collapse (per-chunk recompiles, a scan body
      that stopped fusing) without failing the build on scheduler
      noise.
    * ``trace_bitwise_match`` — in-scan recording reproduces the legacy
      chunked trace bit for bit.
    * ``pad_speedup`` >= 1 — the padded m x topology grid (one program
      per algorithm, compile included) must not lose to the one-program-
      per-(m, topology) walk it collapses.
    * ``pad_trace_match`` — padded active-agent traces are bitwise equal
      to the unpadded per-size runs (dense backend).
    * ``pad_dispatches_padded < pad_dispatches_unpadded`` — padding must
      actually collapse dispatch groups, not just relabel them.
    """
    out = []

    def ge(field, bound):
        val = _need(dump, field, path)
        if not val >= bound:
            raise GateFailure(f"{path}: {field}={val:.3f} < {bound}")
        out.append(f"{field}={val:.2f}")

    def ge1(field):
        ge(field, 1.0)

    def true(field):
        if _need(dump, field, path) is not True:
            raise GateFailure(f"{path}: {field} is not True")
        out.append(f"{field}=True")

    ge1("vmap_speedup")
    ge("scan_speedup", 0.8)
    true("trace_bitwise_match")
    ge1("pad_speedup")
    true("pad_trace_match")
    unpad = _need(dump, "pad_dispatches_unpadded", path)
    pad = _need(dump, "pad_dispatches_padded", path)
    if not pad < unpad:
        raise GateFailure(
            f"{path}: padding did not collapse dispatches "
            f"({pad} padded vs {unpad} unpadded)")
    out.append(f"dispatches {unpad}->{pad}")
    return out


def check_hypergrad(dump: dict, path: str) -> list[str]:
    """BENCH_hypergrad.json: measured accounting present on every row.

    Theorem-1/2 complexity claims hang off the *measured* per-call
    hvp/grad/hess counts; a row without them means the counting
    LinearOperator got bypassed.
    """
    rows = _need(dump, "rows", path)
    if not rows:
        raise GateFailure(f"{path}: no benchmark rows")
    for row in rows:
        for field in ("hvp", "grad", "hess"):
            val = row.get(field)
            if not isinstance(val, (int, float)) or val < 0:
                raise GateFailure(
                    f"{path}: row {row.get('name', '?')!r} lacks a "
                    f"measured {field!r} count (got {val!r})")
    return [f"{len(rows)} rows carry hvp/grad/hess counts"]


def check_compression(dump: dict, path: str) -> list[str]:
    """BENCH_compression.json: wire-traffic-per-stationarity gates.

    * ``bytes_reduction_sign1bit >= 8`` — sign1bit+EF must reach the
      reference eq.-11 gap with at least 8x fewer wire bytes than the
      uncompressed run (per-round the wire is ~32x smaller; the slack
      absorbs the extra iterates the coarser wire needs).
    * ``sign1bit_matched_stationarity`` — the reduction is measured at
      matched quality (the compressed run actually reached the
      reference gap within the bench's ``match_tol``), never at a worse
      stationarity point.
    * ``ef_beats_noef`` — at byte-identical wire usage (same
      compressor, same step count), the innovation/EF wire state ends
      strictly below the stateless quantizer.
    """
    out = []
    red = _need(dump, "bytes_reduction_sign1bit", path)
    if not red >= 8.0:
        raise GateFailure(
            f"{path}: bytes_reduction_sign1bit={red:.2f} < 8")
    out.append(f"bytes_reduction_sign1bit={red:.1f}x")
    if _need(dump, "sign1bit_matched_stationarity", path) is not True:
        raise GateFailure(
            f"{path}: sign1bit run did not reach the reference "
            f"stationarity (reduction measured at unmatched quality)")
    out.append("sign1bit_matched_stationarity=True")
    if _need(dump, "ef_beats_noef", path) is not True:
        ef = dump.get("int8_ef_final_gap")
        noef = dump.get("int8_noef_final_gap")
        raise GateFailure(
            f"{path}: EF did not beat stateless int8 at equal bit "
            f"budget (EF {ef} vs no-EF {noef})")
    out.append("ef_beats_noef=True")
    return out


def check_topology(dump: dict, path: str) -> list[str]:
    """BENCH_topology.json: time-varying topology gates.

    * ``static_bitwise_match`` — the explicit ``static`` process AND the
      p = 0 link-failure stream reproduce the fixed-matrix trace bit for
      bit, per algorithm: the subsystem is a no-op until a link drops.
    * ``p03_convergence_factor <= p03_gate_factor`` — at a 30% per-edge
      drop rate every algorithm still converges within the stated factor
      of the failure-free run (the self-loop repair degrades the
      spectral gap gracefully, it never stalls).
    * every ``link_failure`` row carries a measured
      ``mean_spectral_gap`` in [0, 1] and nonnegative, p-monotone wire
      bytes (more drops can only ship fewer bytes).
    * the ``gossip`` section carries the matched-bandwidth read-out
      (byte marks + both metrics at them).
    """
    out = []
    if _need(dump, "static_bitwise_match", path) is not True:
        raise GateFailure(f"{path}: static_bitwise_match is not True")
    out.append("static_bitwise_match=True")
    factor = _need(dump, "p03_convergence_factor", path)
    gate = _need(dump, "p03_gate_factor", path)
    if not factor <= gate:
        raise GateFailure(
            f"{path}: p03_convergence_factor={factor:.3f} > {gate}")
    out.append(f"p03_factor={factor:.2f}<={gate}")
    lf = _need(dump, "link_failure", path)
    if not lf:
        raise GateFailure(f"{path}: no link_failure rows")
    bytes_by_algo: dict[str, list[tuple[float, float]]] = {}
    for row in lf:
        gap = row.get("mean_spectral_gap")
        if not isinstance(gap, (int, float)) or not 0.0 <= gap <= 1.0:
            raise GateFailure(
                f"{path}: row {row.get('name', '?')!r} lacks a valid "
                f"mean_spectral_gap (got {gap!r})")
        wb = row.get("wire_bytes_total")
        if not isinstance(wb, (int, float)) or wb < 0:
            raise GateFailure(
                f"{path}: row {row.get('name', '?')!r} lacks nonnegative "
                f"wire_bytes_total (got {wb!r})")
        bytes_by_algo.setdefault(row["algo"], []).append(
            (row["p"], float(wb)))
    for algo, pairs in bytes_by_algo.items():
        pairs.sort()
        totals = [b for _, b in pairs]
        if any(b > a for a, b in zip(totals, totals[1:])):
            raise GateFailure(
                f"{path}: wire bytes increase with drop rate for "
                f"{algo!r}: {pairs}")
    out.append(f"{len(lf)} link_failure rows carry gap+bytes columns")
    gos = _need(dump, "gossip", path)
    for row in gos:
        for field in ("matched_bytes", "gossip_metric_at_matched_bytes",
                      "static_metric_at_matched_bytes"):
            if not row.get(field):
                raise GateFailure(
                    f"{path}: gossip row {row.get('name', '?')!r} lacks "
                    f"the matched-bandwidth field {field!r}")
    out.append(f"{len(gos)} gossip rows carry matched-bandwidth read-out")
    return out


def check_byzantine(dump: dict, path: str) -> list[str]:
    """BENCH_byzantine.json: Byzantine-resilience gates.

    * ``weighted_zero_bitwise`` — the Byzantine subsystem configured
      with zero attackers under the ``weighted`` rule reproduces the
      no-byzantine baseline trace bit for bit, per algorithm: the
      resilience layer is a strict no-op until an attacker exists.
    * ``trimmed_f1_factor <= trimmed_gate_factor`` — trimmed-mean with
      one sign-flip attacker ends within the stated factor (3x) of the
      clean eq.-11 stationarity gap, for every algorithm.
    * ``weighted_attacked_factor >= weighted_diverge_factor`` — the
      same attack under the plain weighted combine exceeds 10x the
      clean gap (the robust rule is doing real work, the attack is not
      a perturbation the baseline absorbs anyway).
    * ``single_dispatch_grids`` — every attacker-count x seed grid
      compiled ONE program per (algorithm, rule) under
      ``sweep(..., pad_agents=True)``: attack values batch as vmap
      operands, never as trace constants.
    """
    out = []
    if _need(dump, "weighted_zero_bitwise", path) is not True:
        raise GateFailure(f"{path}: weighted_zero_bitwise is not True")
    out.append("weighted_zero_bitwise=True")
    factor = _need(dump, "trimmed_f1_factor", path)
    gate = _need(dump, "trimmed_gate_factor", path)
    if not factor <= gate:
        raise GateFailure(
            f"{path}: trimmed_f1_factor={factor:.3f} > {gate}")
    out.append(f"trimmed_f1_factor={factor:.2f}<={gate}")
    wf = _need(dump, "weighted_attacked_factor", path)
    div = _need(dump, "weighted_diverge_factor", path)
    if not wf >= div:
        raise GateFailure(
            f"{path}: weighted_attacked_factor={wf:.3f} < {div} — the "
            f"attack did not break the unprotected baseline")
    out.append(f"weighted_attacked_factor={wf:.1f}>={div}")
    if _need(dump, "single_dispatch_grids", path) is not True:
        raise GateFailure(
            f"{path}: an attack grid split into multiple dispatches "
            f"under pad_agents=True")
    out.append("single_dispatch_grids=True")
    grids = _need(dump, "grids", path)
    if not grids:
        raise GateFailure(f"{path}: no attack-grid rows")
    for row in grids:
        finals = row.get("finals_by_nb")
        if not finals:
            raise GateFailure(
                f"{path}: grid {row.get('name', '?')!r} lacks "
                f"finals_by_nb")
    out.append(f"{len(grids)} attack grids carry finals_by_nb")
    guard = _need(dump, "guard", path)
    for row in guard:
        for field in ("tripped_steps", "last_good_step"):
            if not isinstance(row.get(field), int):
                raise GateFailure(
                    f"{path}: guard row {row.get('algo', '?')!r} lacks "
                    f"an integer {field!r} (got {row.get(field)!r})")
    out.append(f"{len(guard)} guard rows carry detection counters")
    return out


def check_resilience(dump: dict, path: str) -> list[str]:
    """BENCH_resilience.json: fault-tolerance gates (docs/RESILIENCE.md).

    * ``resume_bitwise`` — every kill/resume case (all four registry
      algorithms on the dense backend, plus sign1bit+EF) reproduced the
      uninterrupted metric trace bit for bit, and every per-case row
      says so individually.
    * ``checkpoint_overhead_pct <= overhead_gate_pct`` — the chunked
      resumable runner at ``checkpoint_every=50`` (snapshot writes
      included) costs at most 10% over the single-scan ``run_traced``.
    * ``chaos_completed`` + ``chaos_matched_stationarity`` — the seeded
      chaos campaign (>= 3 kills plus corrupt/stale checkpoint
      injections) finished the Section-6 instance with zero manual
      intervention and its final eq.-11 metric matches the fault-free
      run.
    """
    out = []
    if _need(dump, "resume_bitwise", path) is not True:
        raise GateFailure(f"{path}: resume_bitwise is not True")
    cases = _need(dump, "resume_cases", path)
    if len(cases) < 5:
        raise GateFailure(
            f"{path}: only {len(cases)} resume cases (need the four "
            f"registry algorithms plus a compressed+EF config)")
    for case in cases:
        if case.get("bitwise") is not True:
            raise GateFailure(
                f"{path}: resume case {case.get('name', '?')!r} is not "
                f"bitwise")
    out.append(f"resume_bitwise=True over {len(cases)} cases")
    overhead = _need(dump, "checkpoint_overhead_pct", path)
    gate = _need(dump, "overhead_gate_pct", path)
    if not overhead <= gate:
        raise GateFailure(
            f"{path}: checkpoint_overhead_pct={overhead:.2f} > {gate}")
    out.append(f"checkpoint_overhead={overhead:.1f}%<={gate:.0f}%")
    if _need(dump, "chaos_completed", path) is not True:
        raise GateFailure(f"{path}: chaos campaign did not complete")
    if _need(dump, "chaos_matched_stationarity", path) is not True:
        chaos = dump.get("chaos", {})
        raise GateFailure(
            f"{path}: chaos final metric {chaos.get('final_metric')} "
            f"does not match the fault-free final "
            f"{chaos.get('clean_final')}")
    chaos = _need(dump, "chaos", path)
    if not chaos.get("kills", 0) >= 3:
        raise GateFailure(
            f"{path}: chaos campaign survived only "
            f"{chaos.get('kills')} kills (need >= 3 kill/resume cycles)")
    out.append(
        f"chaos completed: {chaos.get('kills')} kills, "
        f"{chaos.get('restarts')} restarts, matched stationarity")
    return out


# Known dumps: file name -> validator.  Every generator in benchmarks/
# that dumps a BENCH_*.json should register its gate here so the CI
# bench-smoke job (and anyone running the module locally) checks it.
GATES = {
    "BENCH_sweep.json": check_sweep,
    "BENCH_hypergrad.json": check_hypergrad,
    "BENCH_compression.json": check_compression,
    "BENCH_topology.json": check_topology,
    "BENCH_byzantine.json": check_byzantine,
    "BENCH_resilience.json": check_resilience,
}


def run_gates(json_dir: str, allow_missing: bool = False) -> int:
    """Validate every dump in ``json_dir``; returns the failure count."""
    failures = 0
    seen = set()
    for name in sorted(GATES):
        path = os.path.join(json_dir, name)
        if not os.path.exists(path):
            msg = f"MISSING {path}"
            if allow_missing:
                print(f"skip: {msg}")
                continue
            print(f"FAIL: {msg} (pass --allow-missing for partial runs)")
            failures += 1
            continue
        seen.add(os.path.abspath(path))
        try:
            with open(path) as fh:
                dump = json.load(fh)
            notes = GATES[name](dump, name)
            print(f"ok: {name}: " + "; ".join(notes))
        except MissingGateField as exc:
            # BENCH_sweep.json is rewritten after every contributing
            # suite, so a partial run legitimately lacks the headline
            # fields of the suites that didn't run.
            if allow_missing:
                print(f"skip: {exc} (partial run)")
            else:
                print(f"FAIL: {exc}")
                failures += 1
        except GateFailure as exc:
            print(f"FAIL: {exc}")
            failures += 1
        except (OSError, json.JSONDecodeError, TypeError) as exc:
            print(f"FAIL: {name}: unreadable dump ({exc})")
            failures += 1

    for path in sorted(glob.glob(os.path.join(json_dir, "BENCH_*.json"))):
        if os.path.abspath(path) in seen:
            continue
        base = os.path.basename(path)
        if base in GATES:
            continue  # already reported missing/failed above
        try:
            with open(path) as fh:
                json.load(fh)
            print(f"ok: {base}: no registered gate, parses")
        except (OSError, json.JSONDecodeError) as exc:
            print(f"FAIL: {base}: unreadable dump ({exc})")
            failures += 1
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dir", default=None,
                    help="dump directory (default: $BENCH_JSON_DIR or cwd)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="skip (instead of fail) absent known dumps and "
                         "absent headline fields — for partial local runs; "
                         "out-of-bound values present still fail")
    args = ap.parse_args(argv)
    json_dir = args.dir or os.environ.get("BENCH_JSON_DIR", os.getcwd())
    failures = run_gates(json_dir, allow_missing=args.allow_missing)
    if failures:
        print(f"{failures} gate(s) failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
