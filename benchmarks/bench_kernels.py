"""Kernel micro-benchmarks (interpret-mode timings are NOT TPU numbers —
the derived column carries the structural quantities the §Roofline uses:
FLOPs, VMEM working set, arithmetic intensity)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row


def _time(f, *args, iters=3):
    f(*args)  # compile/warm
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return 1e6 * (time.time() - t0) / iters


def run(smoke: bool = False) -> list:
    rows = []

    # flash attention: FLOPs = 4 * b*h*s^2*hd (qk + pv), causal halves it
    from repro.kernels.flash_attention import ops as fa
    b, s, nh, nkv, hd = 1, 512, 4, 2, 64
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, nh, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    us = _time(lambda *a: fa.flash_attention(*a, causal=True), q, k, v)
    flops = 4 * b * nh * s * s * hd / 2
    vmem = (128 * hd * 4 * 2 + 2 * 128 * hd * 4 + 128 * 128 * 4)
    rows.append(Row("kernel_flash_attention_s512", us,
                    f"flops={flops:.3e};vmem_bytes={vmem};"
                    f"ai={flops / (3 * b * s * nh * hd * 4):.1f}"))

    # wkv6: FLOPs ~ 2*b*h*(s*C*n + s*n*n) chunked
    from repro.kernels.rwkv6 import ops as wkv
    b, s, h, n, c = 1, 256, 2, 64, 64
    r = jax.random.normal(jax.random.PRNGKey(3), (b, s, h, n))
    kk = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, n))
    vv = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, n))
    w = jax.nn.sigmoid(jax.random.normal(jax.random.PRNGKey(6),
                                         (b, s, h, n))) * 0.5 + 0.45
    u = 0.1 * jax.random.normal(jax.random.PRNGKey(7), (h, n))
    us = _time(lambda *a: wkv.wkv6(*a, chunk=c)[0], r, kk, vv, w, u)
    flops = 2 * b * h * (s * c * n + 2 * s * n * n)
    rows.append(Row("kernel_wkv6_s256", us,
                    f"flops={flops:.3e};state_bytes={h * n * n * 4}"))

    # consensus step: 2 matmuls (m x m) @ (m x D)
    from repro.kernels.consensus_step import ops as cs
    from repro.core import ring_mixing
    m, d = 16, 4096
    mix = jnp.asarray(ring_mixing(m).matrix, jnp.float32)
    X = jax.random.normal(jax.random.PRNGKey(8), (m, d))
    us = _time(lambda mx, x: cs.consensus_step(mx, x, x, x, x, alpha=0.1),
               mix, X)
    rows.append(Row("kernel_consensus_m16_d4096", us,
                    f"flops={2 * 2 * m * m * d:.3e};"
                    f"bytes={5 * m * d * 4}"))

    rows += run_consensus_backends(smoke=smoke)
    return rows


def run_consensus_backends(smoke: bool = False) -> list:
    """ConsensusEngine backend sweep: dense vs pallas step1_step3 over
    (m, D).  Derived fields carry the structural quantities the roofline
    ingests (flops, HBM bytes, and the ppermute backend's wire bytes for
    the same ring round: 2 edges x D x 4 bytes) so backend wins are
    tracked in the bench trajectory.
    """
    from repro.consensus import make_engine
    from repro.core import ring_mixing

    rows = []
    for m in (8,) if smoke else (8, 64, 256):
        spec = ring_mixing(m)
        for d in (4096,) if smoke else (4096, 65536):
            ks = jax.random.split(jax.random.PRNGKey(9), 4)
            x = {"w": jax.random.normal(ks[0], (m, d))}
            u = {"w": jax.random.normal(ks[1], (m, d))}
            p = {"w": jax.random.normal(ks[2], (m, d))}
            pp = {"w": jax.random.normal(ks[3], (m, d))}
            flops = 2 * 2 * m * m * d          # two (m,m)@(m,D) matmuls
            hbm = 6 * m * d * 4                # 4 in + 2 out streams
            wire = 2 * d * 4                   # ring ppermute equivalent
            for backend in ("dense", "pallas"):
                eng = make_engine(backend, spec)
                fn = jax.jit(lambda a, b, c, e:
                             eng.step1_step3(a, b, c, e, 0.1))
                us = _time(fn, x, u, p, pp, iters=1)
                rows.append(Row(
                    f"consensus_{backend}_m{m}_D{d}", us,
                    f"flops={flops:.3e};bytes={hbm};wire_bytes={wire};"
                    f"backend={backend};m={m};D={d};"
                    f"ai={flops / hbm:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
