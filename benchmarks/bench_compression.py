"""Compressed-consensus wire traffic per unit of stationarity.

Sweeps compressor x communication-interval on the Section-6 instance and
prices Definition-2 communication in *bytes* instead of rounds: for each
wire config, how many bytes does one agent ship before the eq.-11 metric
reaches the gap the uncompressed reference run ends at?

Headline contracts (asserted here AND by ``benchmarks.check_gates`` on
the ``BENCH_compression.json`` dump):

* ``bytes_reduction_sign1bit >= 8`` — sign1bit+EF reaches the reference
  stationarity with at least 8x fewer wire bytes (per-round the ratio is
  ~32x; the gate leaves headroom for extra iterates the coarser wire
  needs).
* ``sign1bit_matched_stationarity`` — the compressed run actually got
  to the reference gap (within ``MATCH_TOL``), i.e. the reduction is
  measured at matched quality, not at a worse point.
* ``ef_beats_noef`` — at an equal bit budget (same compressor, same
  step count, so byte-for-byte identical wire usage) int8 WITH the
  innovation/EF wire state ends strictly below stateless int8: the
  feedback recursion, not the quantizer, is what preserves convergence.
  The contrast runs at a fixed longer horizon (``EF_CONTRAST_STEPS``)
  because the stateless wire's bias floor only separates from the
  compensated run near stationarity.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import Row, Setup, make_setup, metric_of
from repro.consensus import CompressionConfig, cumulative_wire_bytes
from repro.solvers import SolverConfig, make_solver

MATCH_TOL = 0.10          # matched-stationarity tolerance on the gap
REF_STEPS = 40            # uncompressed reference horizon
CAP_STEPS = 120           # compressed runs may take extra iterates
SMOKE_REF, SMOKE_CAP = 8, 24
EF_CONTRAST_STEPS = 240   # horizon where the stateless bias floor shows

# the compressor x interval grid (innovation/EF wire state on)
GRID = (
    ("int8", 1),
    ("sign1bit", 1),
    ("sign1bit", 2),          # interval > 1 stacks on top of compression
    ("topk", 1),
)


def _build(s: Setup, comp: CompressionConfig | None, interval: int = 1,
           seed: int = 7):
    cfg = SolverConfig(algo="interact", alpha=0.3, beta=0.3,
                       mixing=s.spec, hypergrad=s.hg, seed=seed,
                       compression=comp or CompressionConfig(),
                       communication_interval=interval)
    solver = make_solver(cfg)
    state = solver.init(None, s.prob, s.hg, s.x0, s.y0, s.data)
    return solver, state


def _trace(s: Setup, solver, state, steps: int,
           stop_at: float | None = None) -> list[float]:
    """Per-step eq.-11 metric; early-exits once ``stop_at`` is reached."""
    out = []
    for _ in range(steps):
        state = solver.step(state, s.data)
        out.append(metric_of(s, state))
        if stop_at is not None and out[-1] <= stop_at:
            break
    return out


def _payload_size(state) -> int:
    """f32 entries one agent ships per stream (the per-agent x slice; u
    mirrors it, priced by comms_per_step)."""
    return sum(int(l[0].size)
               for l in jax.tree_util.tree_leaves(state.x))


def _bytes_at(comp: CompressionConfig, size: int, step: int, cps: int,
              interval: int) -> float:
    return cumulative_wire_bytes(comp, size, step, comms_per_step=cps,
                                 communication_interval=interval)[step]


def run(smoke: bool = False) -> list[Row]:
    ref_steps = SMOKE_REF if smoke else REF_STEPS
    cap_steps = SMOKE_CAP if smoke else CAP_STEPS
    s = make_setup(m=5)
    rows: list[Row] = []

    solver, state = _build(s, None)
    cps = solver.communications_per_step
    size = _payload_size(state)
    ref_trace = _trace(s, solver, state, ref_steps)
    target = ref_trace[-1] * (1.0 + MATCH_TOL)
    bytes_ref = _bytes_at(CompressionConfig(), size, len(ref_trace), cps, 1)
    rows.append(Row("compress_ref", 0.0,
                    f"gap={ref_trace[-1]:.4f};steps={len(ref_trace)};"
                    f"wire_bytes={bytes_ref:.0f}"))

    dump: dict = {"bench": "compression", "jax": jax.__version__,
                  "payload_f32_entries": size,
                  "comms_per_step": cps,
                  "ref_final_gap": ref_trace[-1],
                  "ref_steps": len(ref_trace),
                  "bytes_ref": bytes_ref,
                  "match_tol": MATCH_TOL,
                  "rows": []}

    for kind, interval in GRID:
        comp = CompressionConfig(kind)
        solver, state = _build(s, comp, interval)
        trace = _trace(s, solver, state, cap_steps, stop_at=target)
        matched = trace[-1] <= target
        step = len(trace)
        wire = _bytes_at(comp, size, step, cps, interval)
        reduction = bytes_ref / wire if matched else 0.0
        dump["rows"].append({
            "kind": kind, "interval": interval,
            "final_gap": trace[-1], "steps": step, "wire_bytes": wire,
            "matched": matched, "bytes_reduction": reduction})
        rows.append(Row(f"compress_{kind}_k{interval}", 0.0,
                        f"gap={trace[-1]:.4f};steps={step};"
                        f"wire_bytes={wire:.0f};matched={matched};"
                        f"reduction={reduction:.1f}x"))

    sign_row = next(r for r in dump["rows"]
                    if r["kind"] == "sign1bit" and r["interval"] == 1)
    dump["bytes_reduction_sign1bit"] = sign_row["bytes_reduction"]
    dump["sign1bit_matched_stationarity"] = sign_row["matched"]

    # EF contrast at equal bit budget: same compressor, same interval,
    # same step count => byte-identical wire usage; only the final gap
    # is evaluated (the run itself is the cheap part)
    contrast = {}
    for ef in (True, False):
        comp = CompressionConfig("int8", error_feedback=ef)
        solver, state = _build(s, comp)
        for _ in range(EF_CONTRAST_STEPS):
            state = solver.step(state, s.data)
        contrast[ef] = metric_of(s, state)
        rows.append(Row(f"compress_int8_{'ef' if ef else 'noef'}_long",
                        0.0, f"gap={contrast[ef]:.6f};"
                             f"steps={EF_CONTRAST_STEPS}"))
    ef_gap, noef_gap = contrast[True], contrast[False]
    dump["ef_contrast_steps"] = EF_CONTRAST_STEPS
    dump["int8_ef_final_gap"] = ef_gap
    dump["int8_noef_final_gap"] = noef_gap
    dump["ef_beats_noef"] = bool(ef_gap < noef_gap)

    path = os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_compression.json")
    try:
        with open(path, "w") as fh:
            json.dump(dump, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything

    assert dump["sign1bit_matched_stationarity"], (
        f"sign1bit+EF never reached the reference gap "
        f"(got {sign_row['final_gap']:.4f}, target {target:.4f})")
    assert dump["bytes_reduction_sign1bit"] >= 8.0, (
        f"sign1bit+EF wire reduction "
        f"{dump['bytes_reduction_sign1bit']:.1f}x < 8x")
    assert dump["ef_beats_noef"], (
        f"EF did not beat no-feedback int8 at equal bit budget "
        f"(EF {ef_gap:.5f} vs no-EF {noef_gap:.5f})")

    rows.append(Row("compress_headline", 0.0,
                    f"reduction_sign1bit="
                    f"{dump['bytes_reduction_sign1bit']:.1f}x;"
                    f"ef_beats_noef={dump['ef_beats_noef']};"
                    f"int8_ef={ef_gap:.5f};int8_noef={noef_gap:.5f}"))
    return rows


if __name__ == "__main__":
    for r in run(smoke="--smoke" in __import__("sys").argv):
        print(r.csv())
