"""Distributed launch suite: measured bytes-on-wire vs the priced model.

Every row comes from a REAL launch of ``scripts/launch_local.py`` — the
same multi-process driver a user runs — so this suite exercises the
whole stack: ``jax.distributed.initialize``, the global agent mesh, the
shard_map-wrapped INTERACT step, the CommsLedger, and the eq.-11
stationarity read-out.  Four claims, dumped to ``BENCH_distributed.json``
and asserted by the ``check_distributed`` gate
(``benchmarks.check_gates``):

* measured == priced: the ledger's measured per-agent wire bytes match
  the analytic broadcast model (``cumulative_wire_bytes``) within 10%
  for the ``none`` / ``int8`` / ``sign1bit`` compressors on the
  allgather backend (they match exactly; the slack absorbs future
  payload framing), and match the ppermute backend's per-link unicast
  model (docs/DISTRIBUTED.md).
* single_process_bitwise: a 1-process mesh run WITH
  ``jax.distributed.initialize`` reproduces the no-runtime baseline's
  final iterates bit for bit (same digest) — the distributed bring-up
  itself perturbs nothing.
* stationarity_matched: the 2-process x 4-device run converges to the
  same eq.-11 stationarity as the 1-process baseline (rel tol
  ``MATCH_TOL``).
* round latency is measured and positive (one warmed jitted mix
  dispatch, median of reps).

Launches are subprocesses with their own env (JAX_PLATFORMS,
XLA_FLAGS), so this suite does not care how many devices the parent
process forced.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile

from benchmarks.common import Row

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LAUNCHER = os.path.join(REPO, "scripts", "launch_local.py")

AGENTS = 8
MATCH_TOL = 5e-3
RATIO_BAND = 0.10


def _launch(*, processes: int, devices: int, steps: int, backend: str,
            compression: str = "none", compress_after: int = 0,
            skip_init: bool = False, record_every: int = 0,
            n_per_agent: int = 40, metric_inner_steps: int = 100,
            timeout: float = 900.0) -> dict:
    out = os.path.join(tempfile.mkdtemp(prefix="bench_distributed_"),
                       "result.json")
    cmd = [sys.executable, LAUNCHER,
           "--processes", str(processes),
           "--devices-per-process", str(devices),
           "--agents", str(AGENTS),
           "--steps", str(steps),
           "--record-every", str(record_every or steps),
           "--backend", backend,
           "--compression", compression,
           "--compress-after", str(compress_after),
           "--n-per-agent", str(n_per_agent),
           "--metric-inner-steps", str(metric_inner_steps),
           "--out", out]
    if skip_init:
        cmd.append("--skip-init")
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"launch_local failed ({' '.join(cmd)}):\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    with open(out) as fh:
        return json.load(fh)


def _json_path() -> str:
    return os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_distributed.json")


def run(smoke: bool = False) -> list:
    steps = 10 if smoke else 24
    rows = []
    dump = {"bench": "distributed", "agents": AGENTS, "steps": steps,
            "match_tol": MATCH_TOL, "ratio_band": RATIO_BAND,
            "measured_vs_priced": []}

    # -- measured vs priced, per compressor kind (allgather = broadcast
    # model; compress_after exercises the warmup schedule the host
    # replays) ---------------------------------------------------------
    baseline = None
    for kind in ("none", "int8", "sign1bit"):
        res = _launch(processes=1, devices=AGENTS, steps=steps,
                      backend="allgather", compression=kind,
                      compress_after=0 if kind == "none" else 2,
                      skip_init=True)
        if kind == "none":
            baseline = res
        ratio = res["measured_wire_bytes"] / res["priced_wire_bytes"]
        dump["measured_vs_priced"].append({
            "kind": kind, "backend": "allgather",
            "measured_wire_bytes": res["measured_wire_bytes"],
            "priced_wire_bytes": res["priced_wire_bytes"],
            "ratio": ratio,
            "final_metric": res["final_metric"]})
        rows.append(Row(f"distributed_bytes_{kind}", 0.0,
                        f"measured={res['measured_wire_bytes']:.0f};"
                        f"priced={res['priced_wire_bytes']:.0f};"
                        f"ratio={ratio:.4f}"))

    # -- ppermute: measured vs the per-link unicast model ---------------
    resp = _launch(processes=1, devices=AGENTS, steps=steps,
                   backend="ppermute", skip_init=True)
    pratio = resp["measured_wire_bytes"] / resp["per_link_priced_bytes"]
    dump["ppermute"] = {
        "measured_wire_bytes": resp["measured_wire_bytes"],
        "per_link_priced_bytes": resp["per_link_priced_bytes"],
        "ratio": pratio}
    rows.append(Row("distributed_bytes_ppermute", 0.0,
                    f"measured={resp['measured_wire_bytes']:.0f};"
                    f"per_link_priced={resp['per_link_priced_bytes']:.0f};"
                    f"ratio={pratio:.4f}"))

    # -- 1-process mesh WITH the distributed runtime: bitwise vs the
    # no-runtime baseline ----------------------------------------------
    res1 = _launch(processes=1, devices=AGENTS, steps=steps,
                   backend="allgather")
    bitwise = res1["digest"] == baseline["digest"]
    dump["single_process_bitwise"] = bitwise
    dump["single_process_digests"] = {
        "initialized": res1["digest"], "baseline": baseline["digest"]}
    rows.append(Row("distributed_1proc_bitwise", 0.0,
                    f"bitwise={bitwise}"))

    # -- the tentpole claim: 2 processes x 4 devices reach the matched
    # eq.-11 stationarity ----------------------------------------------
    res2 = _launch(processes=2, devices=AGENTS // 2, steps=steps,
                   backend="allgather")
    ref = baseline["final_metric"]
    rel = abs(res2["final_metric"] - ref) / max(abs(ref), 1e-12)
    matched = rel <= MATCH_TOL
    dump["two_process"] = {
        "num_processes": res2["num_processes"],
        "final_metric": res2["final_metric"],
        "baseline_final_metric": ref,
        "rel_diff": rel,
        "stationarity_matched": matched,
        "digest_bitwise": res2["digest"] == baseline["digest"],
        "measured_wire_bytes": res2["measured_wire_bytes"],
        "round_latency_us": res2["round_latency_us"]}
    dump["round_latency_us"] = res2["round_latency_us"]
    rows.append(Row("distributed_2proc", res2["round_latency_us"],
                    f"final={res2['final_metric']:.4f};ref={ref:.4f};"
                    f"rel_diff={rel:.2e};matched={matched};"
                    f"procs={res2['num_processes']}"))

    try:
        with open(_json_path(), "w") as fh:
            json.dump(dump, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything
    return rows


if __name__ == "__main__":
    for r in run(smoke="--smoke" in sys.argv):
        print(r.csv())
