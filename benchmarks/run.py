"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig2  convergence of INTERACT/SVR-INTERACT vs GT-DSGD/D-SGD (5/10 agents)
  fig4  edge-connectivity sensitivity
  fig5  learning-rate sensitivity
  table1 sample & communication complexity to eps-stationarity
  compression  compressor x interval wire-bytes-per-stationarity sweep
         (+ BENCH_compression.json dump, see benchmarks.check_gates)
  hypergrad  HypergradEngine backend sweep (+ BENCH_hypergrad.json dump)
  kernels  Pallas kernel micro-structure
  topology  time-varying topology: stationarity + wire bytes vs link
         failure, gossip vs static at matched bandwidth
         (+ BENCH_topology.json dump, see benchmarks.check_gates)
  byzantine  Byzantine resilience: stationarity vs attacker count per
         combine rule, guard time-to-detection
         (+ BENCH_byzantine.json dump, see benchmarks.check_gates)
  resilience  fault tolerance: kill/resume bitwise parity, checkpoint
         overhead, chaos-campaign recovery
         (+ BENCH_resilience.json dump, see benchmarks.check_gates)
  distributed  real multi-process launches (scripts/launch_local.py):
         measured vs priced bytes-on-wire per compressor, 1-process
         bitwise parity with/without the distributed runtime, 2-process
         matched stationarity, round latency
         (+ BENCH_distributed.json dump, see benchmarks.check_gates)
  roofline dry-run derived roofline terms (if dry-run artifacts exist)

The figure suites (fig2/fig4/fig5) run their seed x config grids through
the batched sweep engine (``repro.solvers.sweep``, docs/SWEEPS.md) —
one compiled vmapped program per algo/topology group — and share one
``BENCH_sweep.json`` dump (``$BENCH_JSON_DIR`` or cwd) whose headline
``vmap_speedup`` / ``scan_speedup`` / ``trace_bitwise_match`` fields the
bench-smoke CI job asserts on, so batching regressions fail the build.

``--smoke`` runs every suite at CI-sized iteration counts (used by the
bench-smoke CI job to keep the harness from rotting against API changes):

    PYTHONPATH=src python -m benchmarks.run --smoke

The harness runs each suite in its own subprocess so results stay
bitwise-identical to standalone runs (``--suite NAME`` runs one suite
in-process; that is what the children execute).
"""
from __future__ import annotations

import argparse
import sys
import traceback


SUITE_NAMES = ("fig2", "fig4", "fig5", "table1", "compression",
               "hypergrad", "kernels", "topology", "byzantine",
               "resilience", "distributed", "roofline")


def _suite_fn(name: str):
    from benchmarks import (bench_byzantine, bench_complexity,
                            bench_compression, bench_connectivity,
                            bench_convergence, bench_distributed,
                            bench_hypergrad, bench_kernels, bench_lr,
                            bench_resilience, bench_topology,
                            roofline_report)
    return {
        "fig2": bench_convergence.run,
        "fig4": bench_connectivity.run,
        "fig5": bench_lr.run,
        "table1": bench_complexity.run,
        "compression": bench_compression.run,
        "hypergrad": bench_hypergrad.run,
        "kernels": bench_kernels.run,
        "topology": bench_topology.run,
        "byzantine": bench_byzantine.run,
        "resilience": bench_resilience.run,
        "distributed": bench_distributed.run,
        "roofline": roofline_report.run,
    }[name]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-iteration run of every suite (CI)")
    ap.add_argument("--suite", choices=SUITE_NAMES, default=None,
                    help="run a single suite in-process (the full "
                         "harness spawns one such child per suite)")
    args = ap.parse_args()

    if args.suite is not None:
        fn = _suite_fn(args.suite)
        try:
            for row in fn(smoke=args.smoke):
                print(row.csv(), flush=True)
        except Exception:
            print(f"{args.suite},0.0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
            raise SystemExit(1)
        return

    # Each suite runs in its own subprocess.  jaxlib 0.4.37's CPU
    # backend misbehaves once a few hundred compiled executables have
    # accumulated in one process (low-bit result corruption, and
    # eventually SIGSEGV — the same pathology tests/conftest.py works
    # around), and jax.clear_caches() between suites does not reset the
    # responsible process-global state.  Process isolation does: it
    # keeps every suite's results bitwise-identical to a standalone
    # run, which the bitwise gates (trace_bitwise_match,
    # static_bitwise_match) depend on.
    import subprocess

    print("name,us_per_call,derived", flush=True)
    failures = 0
    for name in SUITE_NAMES:
        cmd = [sys.executable, "-m", "benchmarks.run", "--suite", name]
        if args.smoke:
            cmd.append("--smoke")
        if subprocess.run(cmd).returncode != 0:
            failures += 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
