"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  fig2  convergence of INTERACT/SVR-INTERACT vs GT-DSGD/D-SGD (5/10 agents)
  fig4  edge-connectivity sensitivity
  fig5  learning-rate sensitivity
  table1 sample & communication complexity to eps-stationarity
  compression  compressor x interval wire-bytes-per-stationarity sweep
         (+ BENCH_compression.json dump, see benchmarks.check_gates)
  hypergrad  HypergradEngine backend sweep (+ BENCH_hypergrad.json dump)
  kernels  Pallas kernel micro-structure
  roofline dry-run derived roofline terms (if dry-run artifacts exist)

The figure suites (fig2/fig4/fig5) run their seed x config grids through
the batched sweep engine (``repro.solvers.sweep``, docs/SWEEPS.md) —
one compiled vmapped program per algo/topology group — and share one
``BENCH_sweep.json`` dump (``$BENCH_JSON_DIR`` or cwd) whose headline
``vmap_speedup`` / ``scan_speedup`` / ``trace_bitwise_match`` fields the
bench-smoke CI job asserts on, so batching regressions fail the build.

``--smoke`` runs every suite at CI-sized iteration counts (used by the
bench-smoke CI job to keep the harness from rotting against API changes):

    PYTHONPATH=src python -m benchmarks.run --smoke
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-iteration run of every suite (CI)")
    args = ap.parse_args()

    from benchmarks import (bench_complexity, bench_compression,
                            bench_connectivity, bench_convergence,
                            bench_hypergrad, bench_kernels, bench_lr,
                            roofline_report)
    suites = [
        ("fig2", bench_convergence.run),
        ("fig4", bench_connectivity.run),
        ("fig5", bench_lr.run),
        ("table1", bench_complexity.run),
        ("compression", bench_compression.run),
        ("hypergrad", bench_hypergrad.run),
        ("kernels", bench_kernels.run),
        ("roofline", roofline_report.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        try:
            for row in fn(smoke=args.smoke):
                print(row.csv(), flush=True)
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
