"""Byzantine-resilience bench: stationarity under attack, per combine rule.

One grid per (algorithm, combine rule), all through the batched sweep
engine with ``pad_agents=True``: the attack *values* (num_byzantine,
scale, schedule seed) are vmap operands exactly like seeds are, so the
attacker-count x seed grid is ONE compiled program per (algorithm, rule)
— the acceptance criterion behind the ``single_dispatch_grids`` gate.

Three claims, asserted by ``benchmarks.check_gates``:

* **Weighted + zero attackers is bitwise**: configuring the Byzantine
  subsystem with ``kind="sign-flip", num_byzantine=0`` under the
  ``weighted`` rule reproduces the no-byzantine baseline trace bit for
  bit, per algorithm — honest rows pass through ``jnp.where`` against
  their own values and the plain ``M @ X`` contraction is untouched.

* **Trimmed-mean contains f=1**: with one sign-flip attacker on the
  complete Section-6 graph, ``trimmed-mean(f=1)`` reaches a final
  eq.-11 stationarity gap within ``TRIMMED_GATE_FACTOR`` (3x) of the
  clean run — the attacked coordinate is the extreme value in (almost)
  every dimension, so the symmetric trim removes it.

* **Weighted diverges**: the same attack under the plain ``weighted``
  rule ends beyond ``WEIGHTED_DIVERGE_FACTOR`` (10x) of the clean gap
  (non-finite finals clamp to 1e9) — a single corrupted payload
  destroys the stationarity trajectory the paper's communication
  complexity is priced against.

The guard section reports time-to-detection: one attacked run per
algorithm with the in-scan divergence guard active, surfacing the
``tripped_steps`` / ``last_good_step`` counters from ``SolveResult``.

Dumped to ``BENCH_byzantine.json``; see docs/BYZANTINE.md.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import Row, make_setup, metric_fn_of
from repro.byzantine import ByzantineConfig, GuardConfig
from repro.solvers import SolverConfig, expand_grid, solve, sweep

ITERS = 24
REC = 6
SEEDS = (0, 1, 2)
ALGOS = ("interact", "gt-dsgd")
NB_GRID = (0, 1, 2)
RULES = ("weighted", "coordinate-median", "trimmed-mean", "krum-like")
ATTACK = "sign-flip"
SCALE = 25.0

# trimmed-mean with f=1 must end within this factor of the clean final
# gap; plain weighted under the same attack must exceed the diverge
# factor (a sign-flipped payload at scale 25 compounds geometrically).
TRIMMED_GATE_FACTOR = 3.0
WEIGHTED_DIVERGE_FACTOR = 10.0

# guard trip-wire for the time-to-detection section: well above any
# clean trajectory's iterate norm, crossed within a few attacked steps
GUARD_MAX_NORM = 1e3


def _json_path() -> str:
    return os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_byzantine.json")


def _clamp(x: float) -> float:
    return float(x) if np.isfinite(x) else 1e9


def _byz_axis(rule: str, nb_grid) -> tuple:
    trim = 1 if rule == "trimmed-mean" else None
    return tuple(ByzantineConfig(kind=ATTACK, num_byzantine=nb,
                                 scale=SCALE, combine=rule, trim=trim)
                 for nb in nb_grid)


def run(smoke: bool = False) -> list:
    import json

    iters = 8 if smoke else ITERS
    rec = 4 if smoke else REC
    seeds = SEEDS[:2] if smoke else SEEDS
    nb_grid = NB_GRID

    # complete graph: every robust rule sees all m rows, so trimming one
    # attacker leaves m - 2 honest coordinates per combine
    s = make_setup(m=5, p_connect=1.0)
    rows: list = []
    dump: dict = {"bench": "byzantine", "jax": jax.__version__,
                  "algos": list(ALGOS), "rules": list(RULES),
                  "nb_grid": list(nb_grid), "attack": ATTACK,
                  "scale": SCALE, "iters": iters, "seeds": len(seeds),
                  "trimmed_gate_factor": TRIMMED_GATE_FACTOR,
                  "weighted_diverge_factor": WEIGHTED_DIVERGE_FACTOR,
                  "grids": [], "guard": []}

    base_cfg = SolverConfig(mixing=s.spec, hypergrad=s.hg,
                            alpha=0.3, beta=0.3)
    bitwise = True
    single_dispatch = True
    trimmed_factor = 0.0
    weighted_factor = float("inf")

    for algo in ALGOS:
        # clean baseline through the SAME padded pipeline the attack
        # grids use, so the bitwise claim compares identical programs
        # modulo the byzantine layer
        base_cfgs = expand_grid(
            SolverConfig(algo=algo, mixing=s.spec, hypergrad=s.hg,
                         alpha=0.3, beta=0.3), seed=tuple(seeds))
        base = sweep(base_cfgs, iters, rec, problem=s.prob, x0=s.x0,
                     y0=s.y0, data=s.data, pad_agents=True)
        clean_final = float(base.traces.mean(axis=0)[-1])

        for rule in RULES:
            cfgs = expand_grid(
                SolverConfig(algo=algo, mixing=s.spec, hypergrad=s.hg,
                             alpha=0.3, beta=0.3),
                byzantine=_byz_axis(rule, nb_grid), seed=tuple(seeds))
            res = sweep(cfgs, iters, rec, problem=s.prob, x0=s.x0,
                        y0=s.y0, data=s.data, pad_agents=True)
            single_dispatch = single_dispatch and res.num_dispatches == 1

            finals = {}
            trace_means = {}
            for nb in nb_grid:
                traces = np.stack([
                    res.trace_of(c) for c in cfgs
                    if c.byzantine.num_byzantine == nb])
                mean = traces.mean(axis=0)
                finals[nb] = _clamp(mean[-1])
                trace_means[nb] = [_clamp(v) for v in mean]
                if nb == 0 and rule == "weighted":
                    bitwise = bitwise and bool(
                        (traces == base.traces).all())
                us = 1e6 * res.groups[0].seconds / (len(cfgs) * iters)
                rows.append(Row(
                    f"byzantine_{rule}_nb{nb}_{algo}", us,
                    f"final_metric={finals[nb]:.5f};rule={rule};"
                    f"num_byzantine={nb};seeds={len(seeds)}"))
            # degradation relative to the same rule's attack-free run:
            # robust rules pay a clean-run consensus penalty vs exact
            # averaging, and the resilience claim is about how little
            # *additional* gap one attacker buys
            factor_1 = finals[1] / max(finals[0], 1e-12)
            if rule == "trimmed-mean":
                trimmed_factor = max(trimmed_factor, factor_1)
            if rule == "weighted":
                weighted_factor = min(weighted_factor, factor_1)
            dump["grids"].append({
                "name": f"byzantine_{rule}_{algo}", "algo": algo,
                "rule": rule, "seeds": len(seeds), "iters": iters,
                "record_every": rec, "clean_final": clean_final,
                "finals_by_nb": {str(nb): finals[nb] for nb in nb_grid},
                "trace_mean_by_nb": {str(nb): trace_means[nb]
                                     for nb in nb_grid},
                "f1_factor": _clamp(factor_1),
                "dispatches": res.num_dispatches})

        # time-to-detection: the divergence guard on the weighted rule
        # under one attacker — rollback keeps the state finite while the
        # tripped counter records every contained step
        guarded = solve(
            SolverConfig(
                algo=algo, mixing=s.spec, hypergrad=s.hg,
                alpha=0.3, beta=0.3,
                byzantine=ByzantineConfig(kind=ATTACK, num_byzantine=1,
                                          scale=SCALE),
                guard=GuardConfig(nan=True, max_norm=GUARD_MAX_NORM)),
            iters, rec, problem=s.prob, x0=s.x0, y0=s.y0, data=s.data,
            metric_fn=metric_fn_of(s))
        rows.append(Row(
            f"byzantine_guard_{algo}", 0.0,
            f"tripped_steps={guarded.tripped_steps};"
            f"last_good_step={guarded.last_good_step};"
            f"num_steps={iters}"))
        dump["guard"].append({
            "algo": algo, "num_steps": iters,
            "tripped_steps": guarded.tripped_steps,
            "last_good_step": guarded.last_good_step,
            "final_metric": _clamp(np.asarray(guarded.trace)[-1])})

    dump["weighted_zero_bitwise"] = bool(bitwise)
    dump["trimmed_f1_factor"] = _clamp(trimmed_factor)
    dump["weighted_attacked_factor"] = _clamp(weighted_factor)
    dump["single_dispatch_grids"] = bool(single_dispatch)
    try:
        with open(_json_path(), "w") as fh:
            json.dump(dump, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything
    rows.append(Row(
        "byzantine_headline", 0.0,
        f"weighted_zero_bitwise={bitwise};"
        f"trimmed_f1_factor={dump['trimmed_f1_factor']:.3f};"
        f"weighted_attacked_factor={dump['weighted_attacked_factor']:.3f};"
        f"single_dispatch_grids={single_dispatch}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
