"""Time-varying topology bench: stationarity + wire bytes under churn.

Three grids, all through the batched sweep engine (the failure-rate x
seed grid is ONE compiled program per algorithm — the realized matrix
streams are vmap operands, docs/TOPOLOGY.md):

* **Link failure**: eq.-11 stationarity and cumulative wire bytes vs the
  per-edge drop rate p in {0, 0.1, 0.3, 0.5}.  Each row carries the
  measured mean spectral gap of its realized matrices (1 - lambda per
  step, averaged) and the per-link wire bytes from the edge mask — a
  dropped link ships zero bytes, composing with the compression layer's
  warmup / interval schedules.

* **Static bitwise**: an explicit ``static`` topology process AND the
  p = 0 link-failure row must reproduce the fixed-matrix path's trace
  bit for bit, per algorithm — the subsystem is a no-op until a link
  actually drops.

* **Gossip vs static at matched bandwidth**: random gossip mixes one
  matching per round (cheap rounds, small spectral gap), the static
  graph mixes every edge (expensive rounds, full gap).  The honest
  comparison is stationarity at equal cumulative wire bytes, read off
  both byte-vs-metric curves at the gossip run's byte marks.

Dumped to ``BENCH_topology.json``; ``benchmarks.check_gates`` asserts
the static bitwise match, the p = 0.3 convergence factor, and the
presence/sanity of the per-row spectral-gap + wire-bytes columns, in CI
and locally.
"""
from __future__ import annotations

import os

import jax
import numpy as np

from benchmarks.common import Row, make_setup, metric_fn_of
from repro.solvers import SolverConfig, expand_grid, make_solver, sweep
from repro.topology import (TopologyProcessConfig, realize_stream,
                            stream_wire_bytes)

ITERS = 40
REC = 5
SEEDS = (0, 1, 2)
P_GRID = (0.0, 0.1, 0.3, 0.5)
ALGOS = ("interact", "gt-dsgd")

# p = 0.3 must reach within this factor of the p = 0 final metric: link
# failure degrades the realized spectral gap, not the algorithm, and the
# self-loop repair keeps every round a valid consensus step.
P03_GATE_FACTOR = 3.0


def _json_path() -> str:
    return os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_topology.json")


def _payload_size(x0) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(x0))


def _wire_marks(cfg: SolverConfig, spec, size: int, iters: int, rec: int,
                seeds) -> tuple[float, list[float], float]:
    """(mean total bytes, mean cumulative bytes at the record marks,
    mean spectral gap) of ``cfg``'s realized streams over ``seeds``."""
    comms = make_solver(cfg).communications_per_step
    totals, marks, gaps = [], [], []
    for seed in seeds:
        stream = realize_stream(
            cfg.topology_process, spec,
            cfg.topology_process.resolve_seed(seed), num_steps=iters)
        cum = stream_wire_bytes(
            stream, cfg.compression, size, iters, comms_per_step=comms,
            communication_interval=cfg.communication_interval)
        totals.append(cum[-1])
        marks.append([cum[t] for t in range(0, iters + 1, rec)])
        gaps.append(stream.mean_spectral_gap)
    return (float(np.mean(totals)),
            np.mean(np.asarray(marks, dtype=np.float64), axis=0).tolist(),
            float(np.mean(gaps)))


def _run_process_grid(s, algo: str, processes, seeds, iters: int,
                      rec: int):
    """One sweep dispatch: ``processes`` x ``seeds`` for one algorithm.

    Returns ``(result, configs_by_process)`` — the per-process config
    rows, in seed order.
    """
    configs = expand_grid(
        SolverConfig(algo=algo, mixing=s.spec, hypergrad=s.hg),
        topology_process=tuple(processes), seed=tuple(seeds))
    res = sweep(configs, iters, rec, problem=s.prob, x0=s.x0, y0=s.y0,
                data=s.data, metric_fn=metric_fn_of(s))
    rows_of = {
        proc: [c for c in configs if c.topology_process == proc]
        for proc in processes}
    return res, rows_of


def run(smoke: bool = False) -> list:
    import json

    iters = 8 if smoke else ITERS
    rec = 4 if smoke else REC
    seeds = SEEDS[:2] if smoke else SEEDS

    s = make_setup(m=5)
    size = _payload_size(s.x0)
    rows: list = []
    dump: dict = {"bench": "topology", "jax": jax.__version__,
                  "p_grid": list(P_GRID), "algos": list(ALGOS),
                  "iters": iters, "seeds": len(seeds),
                  "link_failure": [], "gossip": [],
                  "p03_gate_factor": P03_GATE_FACTOR}

    static_proc = TopologyProcessConfig(kind="static")
    fail_procs = [TopologyProcessConfig(kind="link-failure", p=p,
                                        period=iters) for p in P_GRID]
    gossip_proc = TopologyProcessConfig(kind="random-gossip", period=iters)

    bitwise = True
    p03_factor = 0.0

    for algo in ALGOS:
        # fixed-matrix baseline: the default (static) process, untouched
        base_cfgs = expand_grid(
            SolverConfig(algo=algo, mixing=s.spec, hypergrad=s.hg),
            seed=tuple(seeds))
        base = sweep(base_cfgs, iters, rec, problem=s.prob, x0=s.x0,
                     y0=s.y0, data=s.data, metric_fn=metric_fn_of(s))

        # explicit static process: must be bitwise the same program
        stat = sweep(expand_grid(
            SolverConfig(algo=algo, mixing=s.spec, hypergrad=s.hg,
                         topology_process=static_proc),
            seed=tuple(seeds)), iters, rec, problem=s.prob, x0=s.x0,
            y0=s.y0, data=s.data, metric_fn=metric_fn_of(s))
        algo_bitwise = bool((stat.traces == base.traces).all())

        # the failure grid: every p and seed in ONE dispatch
        res, rows_of = _run_process_grid(s, algo, fail_procs, seeds,
                                         iters, rec)
        finals = {}
        for proc in fail_procs:
            traces = np.stack([res.trace_of(c) for c in rows_of[proc]])
            mean, std = traces.mean(axis=0), traces.std(axis=0)
            finals[proc.p] = float(mean[-1])
            total, marks, gap = _wire_marks(
                rows_of[proc][0], s.spec, size, iters, rec, seeds)
            if proc.p == 0.0:
                algo_bitwise = algo_bitwise and bool(
                    (traces == base.traces).all())
            us = 1e6 * res.groups[0].seconds / (len(res.configs) * iters)
            rows.append(Row(
                f"topology_linkfail_p{proc.p}_{algo}", us,
                f"final_metric={mean[-1]:.5f};spectral_gap={gap:.4f};"
                f"wire_bytes={total:.0f};seeds={len(seeds)}"))
            dump["link_failure"].append({
                "name": f"topology_p{proc.p}_{algo}", "algo": algo,
                "p": proc.p, "seeds": len(seeds), "iters": iters,
                "record_every": rec,
                "final_metric": float(mean[-1]),
                "trace_mean": mean.tolist(), "trace_std": std.tolist(),
                "mean_spectral_gap": gap,
                "wire_bytes_total": total,
                "wire_bytes_at_records": marks,
                "dispatches": res.num_dispatches})
        factor = finals[0.3] / max(finals[0.0], 1e-12)
        p03_factor = max(p03_factor, factor)
        bitwise = bitwise and algo_bitwise
        rows.append(Row(
            f"topology_claims_{algo}", 0.0,
            f"static_bitwise={algo_bitwise};p03_factor={factor:.3f};"
            f"dispatches={res.num_dispatches}"))

        # gossip vs static at matched wire budget
        gos, gos_rows = _run_process_grid(s, algo, [gossip_proc], seeds,
                                          iters, rec)
        gtr = np.stack([gos.trace_of(c)
                        for c in gos_rows[gossip_proc]]).mean(axis=0)
        g_total, g_marks, g_gap = _wire_marks(
            gos_rows[gossip_proc][0], s.spec, size, iters, rec, seeds)
        s_cfg = SolverConfig(algo=algo, mixing=s.spec, hypergrad=s.hg,
                             topology_process=static_proc)
        s_total, s_marks, s_gap = _wire_marks(s_cfg, s.spec, size, iters,
                                              rec, seeds)
        btr = base.traces.mean(axis=0)
        # equal-bandwidth read-out: both curves at the gossip byte marks
        # (gossip rounds are the cheap ones, so its marks are in range
        # for both; the static curve is interpolated down to them)
        static_at = np.interp(g_marks, s_marks, btr).tolist()
        for pname, gap_, total_, final_ in (
                ("random-gossip", g_gap, g_total, float(gtr[-1])),
                ("static", s_gap, s_total, float(btr[-1]))):
            rows.append(Row(
                f"topology_gossip_{pname}_{algo}", 0.0,
                f"final_metric={final_:.5f};spectral_gap={gap_:.4f};"
                f"wire_bytes={total_:.0f}"))
        dump["gossip"].append({
            "name": f"topology_gossip_{algo}", "algo": algo,
            "seeds": len(seeds), "iters": iters, "record_every": rec,
            "gossip_final_metric": float(gtr[-1]),
            "static_final_metric": float(btr[-1]),
            "gossip_mean_spectral_gap": g_gap,
            "static_mean_spectral_gap": s_gap,
            "gossip_wire_bytes_total": g_total,
            "static_wire_bytes_total": s_total,
            "matched_bytes": g_marks,
            "gossip_metric_at_matched_bytes": gtr.tolist(),
            "static_metric_at_matched_bytes": static_at})

    dump["static_bitwise_match"] = bool(bitwise)
    dump["p03_convergence_factor"] = p03_factor
    dump["p03_within_gate"] = bool(p03_factor <= P03_GATE_FACTOR)
    try:
        with open(_json_path(), "w") as fh:
            json.dump(dump, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything
    rows.append(Row(
        "topology_headline", 0.0,
        f"static_bitwise_match={bitwise};"
        f"p03_convergence_factor={p03_factor:.3f};"
        f"gate_factor={P03_GATE_FACTOR}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
