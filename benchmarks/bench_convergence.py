"""Paper Fig. 2 / Fig. 3: convergence of the four algorithms on the
meta-learning task, 5-agent and 10-agent networks.

Claim validated: INTERACT and SVR-INTERACT reach a lower convergence
metric M than GT-DSGD / D-SGD at equal iteration count.
"""
from __future__ import annotations

from benchmarks.common import ALGORITHMS, Row, make_setup, run_algo

ITERS = 40


def run() -> list:
    rows = []
    for m in (5, 10):
        s = make_setup(m=m)
        finals = {}
        for algo in ALGORITHMS:
            trace, us, _ = run_algo(s, algo, ITERS)
            finals[algo] = trace[-1]
            rows.append(Row(f"fig2_convergence_m{m}_{algo}", us,
                            f"final_metric={trace[-1]:.5f}"))
        ok = (finals["interact"] < finals["gt-dsgd"]
              and finals["interact"] < finals["d-sgd"]
              and finals["svr-interact"] < finals["gt-dsgd"])
        rows.append(Row(f"fig2_claim_m{m}_interact_beats_baselines", 0.0,
                        f"holds={ok}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
