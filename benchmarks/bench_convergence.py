"""Paper Fig. 2 / Fig. 3: convergence of the four algorithms on the
meta-learning task, 5-agent and 10-agent networks — run as a *batched
sweep*: seeds x algorithms dispatch one compiled XLA program per
algorithm (``repro.solvers.sweep``), with the convergence metric
recorded in-scan instead of through the legacy chunked host loop.

Claims validated:
* INTERACT and SVR-INTERACT reach a lower convergence metric M (mean
  over seeds) than GT-DSGD / D-SGD at equal iteration count.
* The batched sweep engine beats the legacy sequential per-seed loop —
  the pre-engine grid walk that rebuilt the solver per cell (per-cell
  jit retrace), init'd eagerly and chunked through ``run_recorded``
  with eager metric round-trips: ``vmap_speedup`` >= 1 is asserted by
  CI.  A fully-warmed variant (``vmap_speedup_warm``, compile excluded
  on both sides) is reported next to it so compile noise can't mask a
  batching regression.
* The scan-compiled ``solver.run`` steps faster than the per-step python
  loop from the same built solver and initial state (``us_loop`` /
  ``scan_speedup`` columns — one build, one init, both timings).
* ``run_traced``'s on-device trace is bitwise identical to the legacy
  ``run_recorded`` trace for every algorithm (``trace_bitwise_match``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (ALGORITHMS, Row, build, make_setup,
                               metric_fn_of, metric_of,
                               record_sweep_section)
from repro.solvers import SolverConfig, expand_grid, run_recorded, sweep

ITERS = 40
SEEDS = 8
TIMING_ITERS = 40   # scan-vs-loop stepping comparison (metric-free)
TIMING_REPS = 3


def _legacy_sequential_seconds(s, algo, seeds, iters, record_every,
                               warm: bool) -> float:
    """The pre-sweep grid walk: one config at a time, eager init, chunked
    ``run_recorded`` with the eager convergence metric.

    ``warm=False`` is the *faithful* pre-engine path — exactly what
    ``run_algo`` did for every grid cell before the sweep engine: build
    a fresh solver per seed (new jit closures, so XLA retraces per
    cell) and pay the compiles the engine was built to eliminate.
    ``warm=True`` is the generous variant: one solver, every program
    (step, scan, metric) compiled outside the clock, so the timed loop
    pays only the irreducible host work — per-seed eager init compute,
    chunked per-record dispatches, host syncs, eager metric round-trips,
    and ``run_recorded``'s per-call warmup executions.  The warm ratio
    can hover near 1.0 on CPU for trivial-init algorithms (d-sgd); it
    exists so batching regressions can't hide behind compile noise."""
    metric = lambda st_: metric_of(s, st_)
    if warm:
        solver, state = build(s, algo, seed=seeds[0])
        run_recorded(solver, jax.tree_util.tree_map(jnp.copy, state),
                     s.data, iters, record_every, metric_fn=metric)
    total = 0.0
    for seed in seeds:
        t0 = time.perf_counter()
        if warm:
            st = solver._init_state(jax.random.PRNGKey(seed), s.prob,
                                    s.hg, s.x0, s.y0, s.data)
        else:
            solver, st = build(s, algo, seed=seed)  # pre-PR per-cell build
        run_recorded(solver, st, s.data, iters, record_every,
                     metric_fn=metric)
        total += time.perf_counter() - t0
    return total


def _scan_vs_loop(s, algo) -> tuple[float, float]:
    """(us_scan, us_loop) per step from ONE built solver and ONE initial
    state — only the stepping differs between the timed runs, so the
    ratio compares dispatch, not construction/init/metric noise.
    Best-of-``TIMING_REPS`` wall-clock, no metric evaluations."""
    solver, state = build(s, algo)

    def timed(scan: bool) -> float:
        best = float("inf")
        for _ in range(TIMING_REPS):
            st = jax.tree_util.tree_map(jnp.copy, state)
            _, _, took = run_recorded(solver, st, s.data, TIMING_ITERS, 0,
                                      metric_fn=None, scan=scan)
            best = min(best, took)
        return 1e6 * best / TIMING_ITERS

    return timed(True), timed(False)


def _traced_matches_recorded(s, algo, iters, record_every) -> bool:
    """One seed per algorithm: in-scan trace vs legacy chunked trace."""
    solver, state = build(s, algo)
    copy = jax.tree_util.tree_map(jnp.copy, state)
    _, legacy, _ = run_recorded(solver, copy, s.data, iters, record_every,
                                metric_fn=lambda st: metric_of(s, st))
    _, traced = solver.run_traced(state, s.data, iters, record_every,
                                  metric_fn_of(s))
    return bool(np.array_equal(np.asarray(legacy, np.asarray(traced).dtype),
                               np.asarray(traced)))


def run(smoke: bool = False) -> list:
    iters = 10 if smoke else ITERS
    rec = 5
    sizes = (5,) if smoke else (5, 10)
    seeds = tuple(range(SEEDS))
    rows, records = [], []
    speedups, scan_speedups, bitwise_all = [], [], True
    for m in sizes:
        s = make_setup(m=m)
        configs = expand_grid(
            SolverConfig(mixing=s.spec, hypergrad=s.hg),
            algo=ALGORITHMS, seed=seeds)
        res = sweep(configs, iters, rec, problem=s.prob, x0=s.x0, y0=s.y0,
                    data=s.data, metric_fn=metric_fn_of(s), measure=True)

        finals = {}
        for group in res.groups:
            algo = group.config.algo
            traces = res.group_traces(group)          # (seeds, records)
            mean, std = traces.mean(axis=0), traces.std(axis=0)
            finals[algo] = float(mean[-1])
            us_batched = 1e6 * group.seconds / (len(seeds) * iters)

            seq = _legacy_sequential_seconds(s, algo, seeds, iters, rec,
                                             warm=False)
            seq_warm = _legacy_sequential_seconds(s, algo, seeds, iters,
                                                  rec, warm=True)
            vmap_speedup = seq / max(group.seconds, 1e-12)
            vmap_speedup_warm = seq_warm / max(group.seconds, 1e-12)
            speedups.append(vmap_speedup)

            us_scan, us_loop = _scan_vs_loop(s, algo)
            scan_speedup = us_loop / max(us_scan, 1e-9)
            scan_speedups.append(scan_speedup)

            bitwise = _traced_matches_recorded(s, algo, iters, rec)
            bitwise_all &= bitwise

            rows.append(Row(
                f"fig2_convergence_m{m}_{algo}", us_batched,
                f"final_metric={mean[-1]:.5f};final_std={std[-1]:.5f};"
                f"seeds={len(seeds)};vmap_speedup={vmap_speedup:.2f};"
                f"vmap_speedup_warm={vmap_speedup_warm:.2f};"
                f"us_loop={us_loop:.1f};scan_speedup={scan_speedup:.2f};"
                f"trace_bitwise={bitwise}"))
            records.append({
                "name": f"fig2_m{m}_{algo}", "m": m, "algo": algo,
                "seeds": len(seeds), "iters": iters,
                "record_every": rec,
                "us_per_step_batched": us_batched,
                "seconds_batched": group.seconds,
                "seconds_sequential": seq,
                "seconds_sequential_warm": seq_warm,
                "vmap_speedup": vmap_speedup,
                "vmap_speedup_warm": vmap_speedup_warm,
                "us_scan": us_scan, "us_loop": us_loop,
                "scan_speedup": scan_speedup,
                "trace_bitwise_match": bitwise,
                "trace_mean": mean.tolist(), "trace_std": std.tolist()})

        ok = (finals["interact"] < finals["gt-dsgd"]
              and finals["interact"] < finals["d-sgd"]
              and finals["svr-interact"] < finals["gt-dsgd"])
        rows.append(Row(f"fig2_claim_m{m}_interact_beats_baselines", 0.0,
                        f"holds={ok}"))

    record_sweep_section(
        "convergence", records, smoke=smoke,
        vmap_speedup=min(speedups),
        scan_speedup=min(scan_speedups),
        trace_bitwise_match=bitwise_all)
    rows.append(Row("fig2_sweep_engine", 0.0,
                    f"min_vmap_speedup={min(speedups):.2f};"
                    f"min_scan_speedup={min(scan_speedups):.2f};"
                    f"trace_bitwise_match={bitwise_all}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
