"""Paper Fig. 2 / Fig. 3: convergence of the four algorithms on the
meta-learning task, 5-agent and 10-agent networks.

Claims validated:
* INTERACT and SVR-INTERACT reach a lower convergence metric M than
  GT-DSGD / D-SGD at equal iteration count.
* The scan-compiled ``solver.run`` steps faster than the per-step python
  loop at equal iteration count (``us_loop`` / ``scan_speedup`` columns).
"""
from __future__ import annotations

from benchmarks.common import ALGORITHMS, Row, make_setup, run_algo

ITERS = 40


def run(smoke: bool = False) -> list:
    iters = 10 if smoke else ITERS
    sizes = (5,) if smoke else (5, 10)
    rows = []
    for m in sizes:
        s = make_setup(m=m)
        finals = {}
        for algo in ALGORITHMS:
            trace, us_scan, _ = run_algo(s, algo, iters)
            _, us_loop, _ = run_algo(s, algo, iters, scan=False)
            finals[algo] = trace[-1]
            rows.append(Row(
                f"fig2_convergence_m{m}_{algo}", us_scan,
                f"final_metric={trace[-1]:.5f};us_loop={us_loop:.1f};"
                f"scan_speedup={us_loop / max(us_scan, 1e-9):.2f}"))
        ok = (finals["interact"] < finals["gt-dsgd"]
              and finals["interact"] < finals["d-sgd"]
              and finals["svr-interact"] < finals["gt-dsgd"])
        rows.append(Row(f"fig2_claim_m{m}_interact_beats_baselines", 0.0,
                        f"holds={ok}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
