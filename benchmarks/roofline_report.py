"""§Roofline report generator: reads experiments/dryrun/*.json and prints
the three-term roofline table per (arch x shape) on the single-pod mesh.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report [--tag pod]
"""
from __future__ import annotations

import argparse
import pathlib

from benchmarks.common import Row
from repro.configs import get_config
from repro.roofline.analysis import (
    load_dryrun, report_table, roofline_terms)

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _trip_correction(arch: str, shape: str) -> float:
    """XLA cost analysis counts scan bodies once (verified in
    tests/test_roofline.py); multiply the layer-loop share back in."""
    cfg = get_config(arch)
    return float(cfg.num_periods())


def run(tag: str = "pod", smoke: bool = False) -> list:
    rows = []
    reports = []
    for res in load_dryrun(RESULTS, tag=tag):
        if res.get("skipped"):
            continue
        cfg = get_config(res["arch"])
        rep = roofline_terms(res, cfg,
                             scan_trip_correction=_trip_correction(
                                 res["arch"], res["shape"]))
        reports.append(rep)
        rows.append(Row(
            f"roofline_{res['arch']}_{res['shape']}_{tag}", 0.0,
            f"compute_s={rep.compute_s:.3e};memory_s={rep.memory_s:.3e};"
            f"collective_s={rep.collective_s:.3e};dominant={rep.dominant};"
            f"useful_ratio={rep.useful_ratio:.3f}"))
    if reports:
        print(report_table(reports))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="pod")
    args = ap.parse_args()
    for r in run(args.tag):
        print(r.csv())
