"""Hypergradient engine sweep: backend x head-dim x agents x solver budget.

Times one jitted, vmapped hypergradient call (all m agents, post-warmup)
per cell of the grid on the Section-6 meta-learning instance, and
records measured evaluation counts (``HypergradStats``) next to the
wall-clock:

  * ``cg`` reference rows at each cg_iters budget (the frozen fixed-trip
    loop executes every matvec — its hvp count IS the budget);
  * ``cg-linearized`` rows per budget cap (early exit means the cap is a
    ceiling, not a cost — the hvp count shows where it actually stopped);
  * one ``cholesky`` row per (head, agents) with speedups against every
    reference budget (``speedup_vs_cg{it}``): the direct solve is exact,
    so the tight-budget references are its accuracy-matched comparisons
    (CG's exactness guarantee needs up to d_y iterations);
  * a ``neumann`` / ``neumann-linearized`` pair per (head, agents, K).

Besides the CSV rows, the sweep is dumped as ``BENCH_hypergrad.json``
(into ``$BENCH_JSON_DIR`` or the cwd) so CI can archive the perf
trajectory across PRs (the bench-smoke job uploads ``BENCH_*.json`` as a
workflow artifact).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.core import (MLPMetaProblem, init_head, init_mlp_backbone,
                        make_synthetic_agents)
from repro.hypergrad import (HypergradConfig, hypergradient,
                             measure_problem_counts)

N_PER_AGENT = 600
HIDDEN = 20
D_IN = 16


def _time(fn, *args, iters: int) -> float:
    """Median per-call wall time (robust to CI noise), post-warmup."""
    out = fn(*args)
    jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
    samples = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(jax.tree_util.tree_leaves(out)[0])
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return 1e6 * samples[len(samples) // 2]


def _setup(classes: int, m: int):
    key = jax.random.PRNGKey(0)
    data = make_synthetic_agents(key, num_agents=m, n_per_agent=N_PER_AGENT,
                                 d_in=D_IN, num_classes=classes)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(1), D_IN, hidden=HIDDEN)
    y0 = init_head(jax.random.PRNGKey(2), HIDDEN, classes)
    bcast = lambda t: jax.tree_util.tree_map(
        lambda l: jnp.broadcast_to(l, (m,) + l.shape), t)
    return prob, bcast(x0), bcast(y0), data


def _call(prob, cfg: HypergradConfig):
    """One jitted hypergradient evaluation vmapped over the agent axis."""

    def per_agent(x, y, ib_x, ib_y, ob_x, ob_y):
        return hypergradient(prob.outer, prob.inner, x, y, cfg,
                             f_args=((ob_x, ob_y),),
                             g_args=((ib_x, ib_y),),
                             inner_hess_yy=prob.inner_hess_yy)

    return jax.jit(jax.vmap(per_agent))


def _counts(prob, cfg: HypergradConfig, x, y, data) -> dict:
    one = lambda t: jax.tree_util.tree_map(lambda l: l[0], t)
    st = measure_problem_counts(prob, cfg, one(x), one(y), data)
    return {"hvp": st.hvp_count, "grad": st.grad_count,
            "hess": st.hess_count}


def _json_path() -> str:
    return os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_hypergrad.json")


def run(smoke: bool = False) -> list:
    classes_sweep = (5,) if smoke else (5, 10)
    agents_sweep = (1,) if smoke else (1, 5)
    iters_sweep = (32, 256) if smoke else (32, 256, 512)
    k_sweep = (8,) if smoke else (8, 64)
    timing_iters = 5 if smoke else 20

    rows: list[Row] = []
    records: list[dict] = []

    def emit(name, us, **fields):
        derived = ";".join(f"{k}={v}" for k, v in fields.items())
        rows.append(Row(name, us, derived))
        records.append({"name": name, "us_per_call": us, **fields})

    for classes in classes_sweep:
        d_y = HIDDEN * classes + classes
        for m in agents_sweep:
            prob, x, y, data = _setup(classes, m)
            args = (x, y, data.inner_x, data.inner_y,
                    data.outer_x, data.outer_y)

            refs = {}
            for it in iters_sweep:
                cfg = HypergradConfig(method="cg", cg_iters=it)
                us = _time(_call(prob, cfg), *args, iters=timing_iters)
                refs[it] = us
                emit(f"hypergrad_cg_d{d_y}_m{m}_it{it}", us,
                     backend="cg", d_y=d_y, m=m, cg_iters=it,
                     speedup_vs_cg=1.0,
                     **_counts(prob, cfg, x, y, data))

            for it in iters_sweep:
                cfg = HypergradConfig(backend="cg-linearized", cg_iters=it)
                us = _time(_call(prob, cfg), *args, iters=timing_iters)
                emit(f"hypergrad_cg-linearized_d{d_y}_m{m}_it{it}", us,
                     backend="cg-linearized", d_y=d_y, m=m, cg_iters=it,
                     speedup_vs_cg=round(refs[it] / us, 2),
                     **_counts(prob, cfg, x, y, data))

            cfg = HypergradConfig(backend="cholesky")
            us = _time(_call(prob, cfg), *args, iters=timing_iters)
            speedups = {f"speedup_vs_cg{it}": round(refs[it] / us, 2)
                        for it in iters_sweep}
            emit(f"hypergrad_cholesky_d{d_y}_m{m}", us,
                 backend="cholesky", d_y=d_y, m=m, **speedups,
                 **_counts(prob, cfg, x, y, data))

            for k in k_sweep:
                cfg = HypergradConfig(method="neumann", neumann_k=k,
                                      lipschitz_g=4.0)
                us_ref = _time(_call(prob, cfg), *args, iters=timing_iters)
                emit(f"hypergrad_neumann_d{d_y}_m{m}_K{k}", us_ref,
                     backend="neumann", d_y=d_y, m=m, neumann_k=k,
                     speedup_vs_neumann=1.0,
                     **_counts(prob, cfg, x, y, data))
                cfg = HypergradConfig(backend="neumann-linearized",
                                      neumann_k=k, lipschitz_g=4.0)
                us = _time(_call(prob, cfg), *args, iters=timing_iters)
                emit(f"hypergrad_neumann-linearized_d{d_y}_m{m}_K{k}", us,
                     backend="neumann-linearized", d_y=d_y, m=m,
                     neumann_k=k,
                     speedup_vs_neumann=round(us_ref / us, 2),
                     **_counts(prob, cfg, x, y, data))

    payload = {"bench": "hypergrad", "smoke": smoke,
               "jax": jax.__version__,
               "n_per_agent": N_PER_AGENT, "rows": records}
    try:
        with open(_json_path(), "w") as fh:
            json.dump(payload, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything
    return rows


if __name__ == "__main__":
    for r in run(smoke=os.environ.get("SMOKE", "") == "1"):
        print(r.csv())
