"""Shared harness for the paper-reproduction benchmarks.

Each bench_* module exposes ``run(smoke=False) -> list[Row]``;
benchmarks/run.py aggregates them into the required
``name,us_per_call,derived`` CSV (``--smoke`` shrinks every suite to a
CI-sized run).

Algorithm construction goes through the unified Solver API
(``repro.solvers``): ``build`` is a registry lookup — no per-algorithm
branches — and ``run_algo`` drives the scan-compiled ``solver.run``
(or the per-step python loop with ``scan=False``), timing the stepping
separately from the convergence-metric evaluations.  The figure suites
(fig2/fig4/fig5) run their grids through the batched sweep engine
(``repro.solvers.sweep``, see docs/SWEEPS.md) and share one
``BENCH_sweep.json`` dump via ``record_sweep_section``.
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp

from repro.core import (
    HypergradConfig, MLPMetaProblem, convergence_metric,
    convergence_metric_fn, erdos_renyi_adjacency, init_head,
    init_mlp_backbone, laplacian_mixing, make_synthetic_agents,
)
from repro.solvers import SolverConfig, make_solver, run_recorded


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


@dataclasses.dataclass
class Setup:
    data: object
    prob: object
    x0: object
    y0: object
    spec: object
    hg: object
    m: int
    n: int


def make_setup(m: int = 5, n: int = 600, p_connect: float = 0.5,
               seed: int = 0, d_in: int = 16, classes: int = 5) -> Setup:
    key = jax.random.PRNGKey(seed)
    data = make_synthetic_agents(key, num_agents=m, n_per_agent=n,
                                 d_in=d_in, num_classes=classes)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(seed + 1), d_in, hidden=20)
    y0 = init_head(jax.random.PRNGKey(seed + 2), 20, classes)
    spec = laplacian_mixing(erdos_renyi_adjacency(m, p_connect, seed=seed + 3))
    hg = HypergradConfig(method="cg", cg_iters=24)
    return Setup(data, prob, x0, y0, spec, hg, m, n)


def metric_of(s: Setup, state) -> float:
    rep = convergence_metric(s.prob, s.hg, state.x, state.y, 300, 0.5,
                             s.data)
    return float(rep.total)


def metric_fn_of(s: Setup):
    """The traceable in-scan counterpart of ``metric_of`` (same values)."""
    return convergence_metric_fn(s.prob, s.hg, s.data)


ALGORITHMS = ("interact", "svr-interact", "gt-dsgd", "d-sgd")


def build(s: Setup, algo: str, alpha: float = 0.3, beta: float = 0.3,
          batch: int | None = None, q: int | None = None, seed: int = 7):
    """(solver, state) via the registry — one code path for every algo.

    batch/q default to the paper's ceil(sqrt(n)) inside the solver;
    ``solver.samples_per_step(s.n)`` reports the per-agent IFO cost
    (Definition 1) that the old ladder hand-computed per branch.
    """
    cfg = SolverConfig(algo=algo, alpha=alpha, beta=beta, batch_size=batch,
                       q=q, mixing=s.spec, hypergrad=s.hg, seed=seed)
    solver = make_solver(cfg)
    state = solver.init(None, s.prob, s.hg, s.x0, s.y0, s.data)
    return solver, state


def run_algo(s: Setup, algo: str, iters: int, record_every: int = 5,
             scan: bool = True, solver_state=None,
             **kw) -> tuple[list[float], float, float]:
    """Returns (metric trace, us_per_step, samples_per_step).

    Delegates to the shared ``run_recorded`` runner: stepping runs in
    ``record_every``-sized chunks through the scan-compiled
    ``solver.run`` (``scan=False`` falls back to the per-step python
    loop for comparison), compilation happens before the timer starts,
    and the convergence metric is evaluated between timed chunks, so
    ``us_per_step`` measures stepping only.

    Pass ``solver_state=(solver, state)`` to reuse one built solver and
    one initial state across several timed runs (the state is copied
    here, never consumed) — e.g. the scan-vs-loop comparison must time
    the *same* compiled solver stepping from the *same* point, or
    ``scan_speedup`` would compare construction/init noise instead of
    stepping.
    """
    if solver_state is None:
        solver_state = build(s, algo, **kw)
    solver, state = solver_state
    state = jax.tree_util.tree_map(jnp.copy, state)
    _, trace, took = run_recorded(solver, state, s.data, iters,
                                  record_every,
                                  metric_fn=lambda st: metric_of(s, st),
                                  scan=scan)
    return trace, 1e6 * took / iters, solver.samples_per_step(s.n)


# -- BENCH_sweep.json: one dump shared by the fig2/fig4/fig5 suites ------
#
# The three figure suites each contribute a section; the file is
# rewritten after every contribution so the dump is complete whatever
# subset of suites ran (and in whatever order).  Headline fields come
# from fig2 (vmap_speedup / scan_speedup / trace_bitwise_match) and
# fig4's padded network grid (pad_speedup / pad_trace_match /
# pad_dispatches_*) — `python -m benchmarks.check_gates` asserts them,
# locally and in the CI bench-smoke job.

_SWEEP_DUMP: dict = {"bench": "sweep", "jax": jax.__version__,
                     "sections": {}}


def sweep_json_path() -> str:
    return os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_sweep.json")


def record_sweep_section(section: str, records: list[dict],
                         **headline) -> None:
    """Merge one suite's records (+ optional headline fields) and dump.

    The merge goes through the on-disk file, not just module state, so
    the dump stays complete when the contributing suites run in
    separate processes (the ``benchmarks.run`` harness spawns one child
    per suite) — this process's contributions win any conflict.
    """
    path = sweep_json_path()
    try:
        with open(path) as fh:
            on_disk = json.load(fh)
        if on_disk.get("bench") == "sweep":
            sections = dict(on_disk.get("sections", {}))
            sections.update(_SWEEP_DUMP["sections"])
            on_disk.update(_SWEEP_DUMP)
            on_disk["sections"] = sections
            _SWEEP_DUMP.clear()
            _SWEEP_DUMP.update(on_disk)
    except (OSError, ValueError):
        pass  # no prior dump (or unreadable): start from module state
    _SWEEP_DUMP["sections"][section] = records
    _SWEEP_DUMP.update(headline)
    try:
        with open(path, "w") as fh:
            json.dump(_SWEEP_DUMP, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything
