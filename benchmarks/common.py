"""Shared harness for the paper-reproduction benchmarks.

Each bench_* module exposes ``run() -> list[Row]``; benchmarks/run.py
aggregates them into the required ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.core import (
    HypergradConfig, MLPMetaProblem, convergence_metric,
    erdos_renyi_adjacency, init_dsgd_state, init_gt_dsgd_state, init_head,
    init_mlp_backbone, init_state, init_svr_state, laplacian_mixing,
    make_dsgd_step, make_gt_dsgd_step, make_interact_step,
    make_svr_interact_step, make_synthetic_agents,
)


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


@dataclasses.dataclass
class Setup:
    data: object
    prob: object
    x0: object
    y0: object
    spec: object
    hg: object
    m: int
    n: int


def make_setup(m: int = 5, n: int = 600, p_connect: float = 0.5,
               seed: int = 0, d_in: int = 16, classes: int = 5) -> Setup:
    key = jax.random.PRNGKey(seed)
    data = make_synthetic_agents(key, num_agents=m, n_per_agent=n,
                                 d_in=d_in, num_classes=classes)
    prob = MLPMetaProblem(mu_g=0.5, lipschitz_g=4.0)
    x0 = init_mlp_backbone(jax.random.PRNGKey(seed + 1), d_in, hidden=20)
    y0 = init_head(jax.random.PRNGKey(seed + 2), 20, classes)
    spec = laplacian_mixing(erdos_renyi_adjacency(m, p_connect, seed=seed + 3))
    hg = HypergradConfig(method="cg", cg_iters=24)
    return Setup(data, prob, x0, y0, spec, hg, m, n)


def metric_of(s: Setup, state) -> float:
    rep = convergence_metric(s.prob, s.hg, state.x, state.y, 300, 0.5,
                             s.data)
    return float(rep.total)


ALGORITHMS = ("interact", "svr-interact", "gt-dsgd", "d-sgd")


def build(s: Setup, algo: str, alpha: float = 0.3, beta: float = 0.3,
          batch: int | None = None, q: int | None = None, seed: int = 7):
    """(state, step_fn, samples_per_step) for one algorithm.

    samples_per_step = IFO calls per agent per iteration (Definition 1):
    full gradients cost n, minibatch estimators cost the batch size, the
    SVR recursive estimator evaluates 2 points per sample.
    """
    q = q or int(np.ceil(np.sqrt(s.n)))
    batch = batch or q
    if algo == "interact":
        st = init_state(s.prob, s.hg, s.x0, s.y0, s.data)
        fn = make_interact_step(s.prob, s.hg, s.spec, alpha, beta)
        return st, fn, float(s.n)
    if algo == "svr-interact":
        st = init_svr_state(s.prob, s.hg, s.x0, s.y0, s.data,
                            jax.random.PRNGKey(seed))
        fn = make_svr_interact_step(s.prob, s.hg, s.spec, alpha, beta, q=q,
                                    batch_size=batch)
        # amortized: one full refresh (n) every q steps + 2*batch otherwise
        return st, fn, float(s.n / q + 2 * batch)
    if algo == "gt-dsgd":
        st = init_gt_dsgd_state(s.prob, s.hg, s.x0, s.y0, s.data,
                                jax.random.PRNGKey(seed), batch)
        fn = make_gt_dsgd_step(s.prob, s.hg, s.spec, alpha, beta, batch)
        return st, fn, float(batch)
    if algo == "d-sgd":
        st = init_dsgd_state(s.x0, s.y0, s.m, jax.random.PRNGKey(seed))
        fn = make_dsgd_step(s.prob, s.hg, s.spec, alpha, beta, batch)
        return st, fn, float(batch)
    raise ValueError(algo)


def run_algo(s: Setup, algo: str, iters: int, record_every: int = 5,
             **kw) -> tuple[list[float], float, float]:
    """Returns (metric trace, us_per_step, samples_per_step)."""
    state, fn, spc = build(s, algo, **kw)
    trace = []
    # warmup compile
    state = fn(state, s.data)
    t0 = time.time()
    for t in range(iters):
        if t % record_every == 0:
            trace.append(metric_of(s, state))
        state = fn(state, s.data)
    jax.block_until_ready(jax.tree_util.tree_leaves(state.x)[0])
    took = time.time() - t0
    trace.append(metric_of(s, state))
    return trace, 1e6 * took / iters, spc
