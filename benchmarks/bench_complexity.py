"""Paper Table 1: sample and communication complexity to reach an
eps-stationary point.

Measures, for each algorithm, the number of communication rounds and the
per-agent evaluation counts needed to drive the metric M below eps;
validates Corollaries 2/4: SVR-INTERACT needs ~sqrt(n)/n the samples of
INTERACT at the same communication complexity.  Rounds are counted as
iterations x ``solver.communications_per_step`` (Definition 2: D-SGD
mixes once per iteration, the tracking algorithms twice).

Per-step evaluation counts are *measured*, not inferred: one counted
hypergradient call (``repro.hypergrad.measure_counts``) yields the
HVP/gradient evaluations the engine actually executed — including
data-dependent trip counts such as the early-exit CG — and
``solver.hypergrad_calls_per_step`` amortizes it over the algorithm's
estimator calls.  The per-sample oracle count charges each evaluation
for the batch it actually touches: HVP/Hessian evaluations and the
eq.-(9) inner-gradient pass run on the *inner* batch only, gradient
evaluations on the inner+outer pair (an upper bound for the grad side:
the grad_{x,y} f pass sees only the outer split, the linearization
primal only the inner).
"""
from __future__ import annotations

import jax

from benchmarks.common import ALGORITHMS, Row, build, make_setup, metric_of
from repro.hypergrad import measure_problem_counts

EPS = 0.05
MAX_ITERS = 120


def _per_call_evals(s) -> tuple[int, int, int]:
    """Measured (hvp, grad, hess) counts of one hypergradient call."""
    st = measure_problem_counts(s.prob, s.hg, s.x0, s.y0, s.data)
    return st.hvp_count, st.grad_count, st.hess_count


def _guard_cols(state) -> str:
    """Trailing divergence-guard columns (``SolveResult.tripped_steps``
    / ``last_good_step`` equivalents, read off the final carry): how
    often the Byzantine guard rolled the iterates back, and the last
    step it certified.  ``chaos_run`` reports the same counters when a
    trip is recovered as a resumable fault (docs/RESILIENCE.md)."""
    guard = getattr(state, "guard", None)
    if guard is None:
        return "tripped_steps=0;last_good_step=-1"
    return (f"tripped_steps={int(guard['tripped'])};"
            f"last_good_step={int(guard['last_good'])}")


def _bytes_per_round(solver, state) -> float:
    """Wire bytes one agent ships per Definition-2 round: the engine's
    ``bytes_on_wire`` of the per-agent x payload (the same accounting
    ``SolveResult.bytes_per_round`` reports)."""
    payload = jax.tree_util.tree_map(lambda l: l[0], state.x)
    return float(solver._engine.bytes_on_wire(payload))


def run(smoke: bool = False) -> list:
    max_iters = 10 if smoke else MAX_ITERS
    rows = []
    s = make_setup(m=5)
    for algo in ALGORITHMS:
        solver, state = build(s, algo)
        # appended last so existing column parsing stays positional-safe
        byz_col = f"byzantine_kind={solver.config.byzantine.kind}"
        wire = _bytes_per_round(solver, state)
        iters = None
        for t in range(max_iters):
            if metric_of(s, state) <= EPS:
                iters = t
                break
            state = solver.step(state, s.data)
        if iters is None:
            cap = max_iters * solver.communications_per_step
            rows.append(Row(f"table1_{algo}", 0.0,
                            f"eps={EPS};comm_rounds=>{cap};"
                            f"bytes_per_round={wire:.0f};samples=NA;"
                            f"{byz_col};{_guard_cols(state)}"))
            continue
        hvp, grad, hess = _per_call_evals(s)
        calls = solver.hypergrad_calls_per_step(s.n)
        hvp_evals = iters * calls * hvp
        grad_evals = iters * calls * (grad + 1)   # +1: the eq.-(9) v pass
        # per-sample oracle cost: HVP/Hessian/v evaluations touch the
        # inner batch of their call, gradient evaluations the inner+outer
        # pair; a call is full-batch or a bs-sized minibatch per split.
        inner_n, outer_n = s.data.inner_x.shape[1], s.data.outer_x.shape[1]

        def call_samples(isz, osz):
            return (hvp + hess + 1) * isz + grad * (isz + osz)

        if algo == "interact":
            per_step = call_samples(inner_n, outer_n)
        elif algo == "svr-interact":
            q = solver.config.resolve_q(s.n)
            bs = solver.config.resolve_batch(s.n)
            per_step = (call_samples(inner_n, outer_n) / q
                        + 2 * (q - 1) / q * call_samples(bs, bs))
        else:
            bs = solver.config.resolve_batch(s.n)
            per_step = call_samples(bs, bs)
        samples = iters * per_step
        rounds = iters * solver.communications_per_step
        rows.append(Row(f"table1_{algo}", 0.0,
                        f"eps={EPS};comm_rounds={rounds};"
                        f"bytes_per_round={wire:.0f};"
                        f"wire_bytes={rounds * wire:.0f};"
                        f"hvp_evals={hvp_evals:.0f};"
                        f"grad_evals={grad_evals:.0f};"
                        f"samples_per_agent={samples:.0f};"
                        f"{byz_col};{_guard_cols(state)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
