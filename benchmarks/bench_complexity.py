"""Paper Table 1: sample and communication complexity to reach an
eps-stationary point.

Measures, for each algorithm, the number of communication rounds and the
per-agent evaluation counts needed to drive the metric M below eps;
validates Corollaries 2/4: SVR-INTERACT needs ~sqrt(n)/n the samples of
INTERACT at the same communication complexity.  Rounds are counted as
iterations x ``solver.communications_per_step`` (Definition 2: D-SGD
mixes once per iteration, the tracking algorithms twice).

Per-step evaluation counts are *measured*, not inferred: one counted
hypergradient call (``repro.hypergrad.measure_counts``) yields the
HVP/gradient evaluations the engine actually executed — including
data-dependent trip counts such as the early-exit CG — and
``solver.hypergrad_calls_per_step`` amortizes it over the algorithm's
estimator calls.  The per-sample oracle count charges each evaluation
for the batch it actually touches: HVP/Hessian evaluations and the
eq.-(9) inner-gradient pass run on the *inner* batch only, gradient
evaluations on the inner+outer pair (an upper bound for the grad side:
the grad_{x,y} f pass sees only the outer split, the linearization
primal only the inner).

Besides the priced ``bytes_per_round`` column, every row carries the
*measured* communication: ``measured_wire_bytes`` from a ``CommsLedger``
attached before the step trace (the bytes the compiled program actually
shipped over the counted iterations — consensus/ledger.py) and
``round_latency_us`` (median wall-clock of one warmed jitted consensus
round).  Backends that cannot be measured outside shard_map would report
``NA``; the dense backend used here always measures.  The same rows are
dumped to ``BENCH_complexity.json`` for the ``check_complexity`` gate.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import ALGORITHMS, Row, build, make_setup, metric_of
from repro.consensus import attach_ledger, time_round_us
from repro.hypergrad import measure_problem_counts

EPS = 0.05
MAX_ITERS = 120


def _per_call_evals(s) -> tuple[int, int, int]:
    """Measured (hvp, grad, hess) counts of one hypergradient call."""
    st = measure_problem_counts(s.prob, s.hg, s.x0, s.y0, s.data)
    return st.hvp_count, st.grad_count, st.hess_count


def _guard_cols(state) -> str:
    """Trailing divergence-guard columns (``SolveResult.tripped_steps``
    / ``last_good_step`` equivalents, read off the final carry): how
    often the Byzantine guard rolled the iterates back, and the last
    step it certified.  ``chaos_run`` reports the same counters when a
    trip is recovered as a resumable fault (docs/RESILIENCE.md)."""
    guard = getattr(state, "guard", None)
    if guard is None:
        return "tripped_steps=0;last_good_step=-1"
    return (f"tripped_steps={int(guard['tripped'])};"
            f"last_good_step={int(guard['last_good'])}")


def _bytes_per_round(solver, state) -> float:
    """Wire bytes one agent ships per Definition-2 round: the engine's
    ``bytes_on_wire`` of the per-agent x payload (the same accounting
    ``SolveResult.bytes_per_round`` reports)."""
    payload = jax.tree_util.tree_map(lambda l: l[0], state.x)
    return float(solver._engine.bytes_on_wire(payload))


def _measured_cols(solver, ledger, steps: int, state) -> tuple[str, dict]:
    """Commit the ledger and time one consensus round: the measured
    columns (``NA`` when the backend records/times nothing — e.g. a mesh
    backend whose mix cannot run outside shard_map)."""
    ledger.commit_steps(steps)
    measured = ledger.measured_wire_bytes if ledger.streams else None
    latency = None
    if solver._engine.name in ("dense", "pallas"):
        engine = solver._engine
        latency = time_round_us(jax.jit(lambda tr: engine.mix(tr)), state.x,
                                reps=3)
    col = (f"measured_wire_bytes="
           f"{'NA' if measured is None else format(measured, '.0f')};"
           f"round_latency_us="
           f"{'NA' if latency is None else format(latency, '.1f')}")
    return col, {"measured_wire_bytes": measured,
                 "round_latency_us": latency}


def _json_path() -> str:
    return os.path.join(os.environ.get("BENCH_JSON_DIR", os.getcwd()),
                        "BENCH_complexity.json")


def run(smoke: bool = False) -> list:
    max_iters = 10 if smoke else MAX_ITERS
    rows = []
    dump = {"bench": "complexity", "eps": EPS, "rows": []}
    s = make_setup(m=5)
    for algo in ALGORITHMS:
        solver, state = build(s, algo)
        # jit is lazy: attaching after build/init still precedes the
        # first step trace, so the ledger sees every wire stream
        ledger = attach_ledger(solver._engine)
        # appended last so existing column parsing stays positional-safe
        byz_col = f"byzantine_kind={solver.config.byzantine.kind}"
        wire = _bytes_per_round(solver, state)
        iters = None
        for t in range(max_iters):
            if metric_of(s, state) <= EPS:
                iters = t
                break
            state = solver.step(state, s.data)
        if iters is None:
            cap = max_iters * solver.communications_per_step
            mcol, mfields = _measured_cols(solver, ledger, max_iters, state)
            rows.append(Row(f"table1_{algo}", 0.0,
                            f"eps={EPS};comm_rounds=>{cap};"
                            f"bytes_per_round={wire:.0f};samples=NA;"
                            f"{mcol};{byz_col};{_guard_cols(state)}"))
            dump["rows"].append({"name": f"table1_{algo}", "algo": algo,
                                 "converged": False, "iters": max_iters,
                                 "bytes_per_round": wire, **mfields})
            continue
        hvp, grad, hess = _per_call_evals(s)
        calls = solver.hypergrad_calls_per_step(s.n)
        hvp_evals = iters * calls * hvp
        grad_evals = iters * calls * (grad + 1)   # +1: the eq.-(9) v pass
        # per-sample oracle cost: HVP/Hessian/v evaluations touch the
        # inner batch of their call, gradient evaluations the inner+outer
        # pair; a call is full-batch or a bs-sized minibatch per split.
        inner_n, outer_n = s.data.inner_x.shape[1], s.data.outer_x.shape[1]

        def call_samples(isz, osz):
            return (hvp + hess + 1) * isz + grad * (isz + osz)

        if algo == "interact":
            per_step = call_samples(inner_n, outer_n)
        elif algo == "svr-interact":
            q = solver.config.resolve_q(s.n)
            bs = solver.config.resolve_batch(s.n)
            per_step = (call_samples(inner_n, outer_n) / q
                        + 2 * (q - 1) / q * call_samples(bs, bs))
        else:
            bs = solver.config.resolve_batch(s.n)
            per_step = call_samples(bs, bs)
        samples = iters * per_step
        rounds = iters * solver.communications_per_step
        mcol, mfields = _measured_cols(solver, ledger, iters, state)
        rows.append(Row(f"table1_{algo}", 0.0,
                        f"eps={EPS};comm_rounds={rounds};"
                        f"bytes_per_round={wire:.0f};"
                        f"wire_bytes={rounds * wire:.0f};"
                        f"hvp_evals={hvp_evals:.0f};"
                        f"grad_evals={grad_evals:.0f};"
                        f"samples_per_agent={samples:.0f};"
                        f"{mcol};{byz_col};{_guard_cols(state)}"))
        dump["rows"].append({"name": f"table1_{algo}", "algo": algo,
                             "converged": True, "iters": iters,
                             "comm_rounds": rounds,
                             "bytes_per_round": wire,
                             "priced_wire_bytes": rounds * wire,
                             **mfields})
    try:
        with open(_json_path(), "w") as fh:
            json.dump(dump, fh, indent=1)
    except OSError:
        pass  # read-only workdir: CSV rows still carry everything
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
