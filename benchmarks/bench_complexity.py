"""Paper Table 1: sample and communication complexity to reach an
eps-stationary point.

Measures, for each algorithm, the number of communication rounds and the
per-agent IFO calls needed to drive the metric M below eps; validates
Corollaries 2/4: SVR-INTERACT needs ~sqrt(n)/n the samples of INTERACT at
the same communication complexity.
"""
from __future__ import annotations

from benchmarks.common import ALGORITHMS, Row, build, make_setup, metric_of

EPS = 0.05
MAX_ITERS = 120


def run() -> list:
    rows = []
    s = make_setup(m=5)
    for algo in ALGORITHMS:
        state, fn, samples_per_step = build(s, algo)
        rounds = None
        for t in range(MAX_ITERS):
            if metric_of(s, state) <= EPS:
                rounds = t
                break
            state = fn(state, s.data)
        if rounds is None:
            rows.append(Row(f"table1_{algo}", 0.0,
                            f"eps={EPS};rounds=>{MAX_ITERS};samples=NA"))
            continue
        samples = rounds * samples_per_step
        rows.append(Row(f"table1_{algo}", 0.0,
                        f"eps={EPS};comm_rounds={rounds};"
                        f"samples_per_agent={samples:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
