"""Paper Table 1: sample and communication complexity to reach an
eps-stationary point.

Measures, for each algorithm, the number of communication rounds and the
per-agent IFO calls needed to drive the metric M below eps; validates
Corollaries 2/4: SVR-INTERACT needs ~sqrt(n)/n the samples of INTERACT at
the same communication complexity.  Rounds are counted as iterations x
``solver.communications_per_step`` (Definition 2: D-SGD mixes once per
iteration, the tracking algorithms twice).
"""
from __future__ import annotations

from benchmarks.common import ALGORITHMS, Row, build, make_setup, metric_of

EPS = 0.05
MAX_ITERS = 120


def run(smoke: bool = False) -> list:
    max_iters = 10 if smoke else MAX_ITERS
    rows = []
    s = make_setup(m=5)
    for algo in ALGORITHMS:
        solver, state = build(s, algo)
        iters = None
        for t in range(max_iters):
            if metric_of(s, state) <= EPS:
                iters = t
                break
            state = solver.step(state, s.data)
        if iters is None:
            cap = max_iters * solver.communications_per_step
            rows.append(Row(f"table1_{algo}", 0.0,
                            f"eps={EPS};comm_rounds=>{cap};samples=NA"))
            continue
        samples = iters * solver.samples_per_step(s.n)
        rounds = iters * solver.communications_per_step
        rows.append(Row(f"table1_{algo}", 0.0,
                        f"eps={EPS};comm_rounds={rounds};"
                        f"samples_per_agent={samples:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r.csv())
